"""Repo-wide pytest config: auto-mark the long model-build/training
parametrizations ``slow`` so ``pytest -m "not slow"`` gives a fast
iteration tier.  Tier-1 CI runs the full suite (no deselection)."""
import pytest

# node-id substrings of the heavyweight tests (full model builds + jitted
# train/decode steps; several seconds each on CPU)
SLOW_NODE_PATTERNS = (
    "test_models_smoke.py::",
    "test_training.py::test_loss_decreases",
    "test_substrates.py::test_engine_batched_equals_solo",
    "test_substrates.py::test_training_resumes_identically",
)


def pytest_collection_modifyitems(config, items):
    slow = pytest.mark.slow
    for item in items:
        if any(p in item.nodeid for p in SLOW_NODE_PATTERNS):
            item.add_marker(slow)
