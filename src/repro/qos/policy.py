"""Pluggable queue disciplines for :class:`repro.serving.simulator.EngineSim`.

The engine's iteration loop asks its discipline which waiting request to
admit next (``select``) and charges the admitted request's service cost
back (``on_admit``); the default FIFO discipline reproduces the seed
engine's arrival-order behavior exactly.

``priority`` orders by workflow-aware urgency: deadline slack minus the
aggregate-pipeline estimate of the workflow request's remaining work
(:meth:`repro.qos.slo.RequestQoS.slack`), so a request one LLM call from
finishing its workflow jumps a fresh fan-out burst.  Best-effort
requests (no deadline) always queue behind deadline classes, ordered by
class weight then arrival.

``wfq`` is deficit-round-robin over tenants (workflow names): each
backlogged tenant's deficit counter grows by ``quantum x weight`` per
round and a tenant may admit requests while its deficit covers their
token cost, which gives every pooled tenant its routing-weight share of
the replica's served tokens and makes the discipline starvation-free
under overload (any positive-weight tenant's deficit grows without
bound until its head request is served).
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional


def request_cost(req) -> float:
    """Service cost of one engine request in tokens (prefill work the
    engine actually runs plus decode work)."""
    return float(req.prompt_tokens - req.cached_prefix + req.output_tokens)


def _tenant(req) -> str:
    q = getattr(req, "qos", None)
    return q.tenant if q is not None else ""


class QueueDiscipline:
    """Interface: pick the next waiting request, get charged for it."""

    name = "fifo"

    def select(self, waiting: List, now: float) -> int:
        """Index into ``waiting`` of the request to admit next."""
        raise NotImplementedError

    def on_admit(self, req, cost: float) -> None:
        """Called once the selected request is actually admitted."""


class FifoDiscipline(QueueDiscipline):
    """Arrival order — the seed engine's behavior."""

    name = "fifo"

    def select(self, waiting: List, now: float) -> int:
        return 0

    def on_admit(self, req, cost: float) -> None:
        pass


class PriorityDiscipline(QueueDiscipline):
    """Class-weight tiers, workflow-aware urgency within a tier.

    Deadline classes are served strictly by descending class weight (a
    gold burst can never be starved by a bronze one); within a tier,
    salvageable requests (deadline slack minus estimated remaining work
    still non-negative) go most-urgent-first, so a workflow request one
    call from completion jumps a same-tier fan-out burst.  Requests
    whose tier SLO is already lost (negative slack) are demoted behind
    their tier's salvageable ones — pure least-slack-first under deep
    overload is the classic EDF pathology of serving the most hopeless
    request first, which destroys goodput for everyone.  Best-effort
    requests (no deadline, or degraded by admission control) always
    queue last.

    Requests without QoS metadata sort as best-effort at unit weight in
    arrival order, so a priority engine fed unclassified traffic behaves
    exactly like FIFO.
    """

    name = "priority"

    @staticmethod
    def _key(req, now: float):
        q = getattr(req, "qos", None)
        if q is None:
            return (1, -1.0, 0.0, 0.0, req.arrival)
        if not math.isfinite(q.deadline) or q.degraded:
            return (1, -q.weight, 0.0, 0.0, req.arrival)
        slack = q.slack(now)
        if slack < 0:  # tier SLO already lost: its salvageable go first
            return (0, -q.weight, 1.0, req.arrival, req.arrival)
        return (0, -q.weight, 0.0, slack, req.arrival)

    def select(self, waiting: List, now: float) -> int:
        return min(range(len(waiting)),
                   key=lambda i: self._key(waiting[i], now))

    def on_admit(self, req, cost: float) -> None:
        pass


class DRRDiscipline(QueueDiscipline):
    """Deficit round robin over tenants (weighted fair queueing).

    ``weights`` maps tenant -> share weight on *this* replica (e.g. the
    workflow's routing weight into it); unknown tenants get weight 1.
    Weights are floored at ``min_weight`` so a mis-routed zero-weight
    tenant degrades to a tiny share instead of starving.  Within a
    tenant, requests are served in arrival order (the waiting list is
    arrival-ordered).
    """

    name = "wfq"

    def __init__(self, weights: Optional[Dict[str, float]] = None, *,
                 quantum: float = 512.0, min_weight: float = 1e-3):
        self.weights = dict(weights or {})
        self.quantum = quantum
        self.min_weight = min_weight
        self.deficit: Dict[str, float] = {}
        self.order: List[str] = []  # round-robin rotation
        self._cursor = 0
        self._in_turn: Optional[str] = None  # tenant currently being served

    def _weight(self, tenant: str) -> float:
        return max(self.weights.get(tenant, 1.0), self.min_weight)

    def _heads(self, waiting: List) -> Dict[str, int]:
        heads: Dict[str, int] = {}
        for i, r in enumerate(waiting):
            t = _tenant(r)
            if t not in heads:
                heads[t] = i
        return heads

    def select(self, waiting: List, now: float) -> int:
        heads = self._heads(waiting)
        for t in heads:
            if t not in self.deficit:
                self.deficit[t] = 0.0
                self.order.append(t)
        # resume the tenant mid-turn if it still has backlog
        spins = 0
        max_cost = max(request_cost(waiting[i]) for i in heads.values())
        # each full rotation adds >= quantum*min_weight to some backlogged
        # tenant, so this many rotations always suffice to cover max_cost
        max_spins = len(self.order) * (
            int(max_cost / (self.quantum * self.min_weight)) + 2)
        while True:
            if self._in_turn is None:
                t = self.order[self._cursor % len(self.order)]
                if t not in heads:
                    # idle tenant: deficit resets (classic DRR), turn skipped
                    self.deficit[t] = 0.0
                    self._cursor += 1
                    spins += 1
                    if spins > max_spins:  # defensive; cannot happen
                        return next(iter(heads.values()))
                    continue
                self.deficit[t] += self.quantum * self._weight(t)
                self._in_turn = t
            t = self._in_turn
            if t in heads and self.deficit[t] >= request_cost(waiting[heads[t]]):
                return heads[t]
            # turn over: head too expensive (or queue drained mid-turn)
            self._in_turn = None
            self._cursor += 1
            spins += 1
            if spins > max_spins:  # defensive; cannot happen
                return next(iter(heads.values()))

    def on_admit(self, req, cost: float) -> None:
        t = _tenant(req)
        if t in self.deficit:
            self.deficit[t] -= cost


DISCIPLINES = ("fifo", "priority", "wfq")


def make_policy(kind: str, *, weights: Optional[Dict[str, float]] = None,
                quantum: float = 512.0) -> Optional[QueueDiscipline]:
    """One fresh discipline instance (engines must not share DRR state).

    ``kind="fifo"`` returns None — the engine's built-in arrival-order
    fast path — so the seed simulator behavior stays bit-identical.
    """
    if kind == "fifo":
        return None
    if kind == "priority":
        return PriorityDiscipline()
    if kind == "wfq":
        return DRRDiscipline(weights, quantum=quantum)
    raise ValueError(f"unknown queue discipline {kind!r}; known: {DISCIPLINES}")
