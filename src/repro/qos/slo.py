"""Per-workflow SLO classes and the pipeline-derived work model.

An :class:`SLOClass` states what a workflow's operator promised its
users: a latency target for each workflow-level request, a priority
weight (which doubles as the fair-share weight in ``wfq`` mode), and a
shed policy for overload.  Classes are attached to
:class:`repro.workflows.runtime.Workflow` objects and threaded through
``deploy`` / ``deploy_multi``.

A class can carry a *relative* target (``target_factor`` x the
workflow's unloaded mean latency) so the registry can assign meaningful
classes before anything has been traced; :meth:`SLOClass.resolve` pins
the absolute target once the traced baseline is known.

The :class:`WorkModel` is the piece Scepsy uniquely contributes to
request-level scheduling: the aggregate pipeline's per-stage call counts
and unloaded latencies give an *expected remaining work* estimate for
every in-flight workflow request, which the priority discipline uses as
deadline slack minus remaining work (so a request one call from
completion jumps a fresh fan-out burst).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, Optional

SHED_POLICIES = ("never", "reject", "degrade")


@dataclass(frozen=True)
class SLOClass:
    """One service tier.

    ``latency_target_s`` is the absolute per-request latency target
    (None = unresolved or best-effort); ``target_factor`` expresses the
    target as a multiple of the workflow's unloaded mean latency and is
    resolved against traced stats by :meth:`resolve`.  ``weight`` is the
    priority / fair-share weight; ``shed_policy`` says what admission
    control may do under overload: ``never`` (always admit), ``reject``
    (drop the request at the front door), or ``degrade`` (admit it as
    best-effort — it keeps running but yields to every deadline class).
    """

    name: str
    latency_target_s: Optional[float] = None
    target_factor: Optional[float] = None
    weight: float = 1.0
    shed_policy: str = "never"

    def __post_init__(self):
        if self.shed_policy not in SHED_POLICIES:
            raise ValueError(
                f"unknown shed policy {self.shed_policy!r}; "
                f"known: {SHED_POLICIES}"
            )
        if self.weight <= 0:
            raise ValueError(f"SLO weight must be positive, got {self.weight}")

    @property
    def best_effort(self) -> bool:
        return self.latency_target_s is None and self.target_factor is None

    def resolve(self, base_latency_s: float) -> "SLOClass":
        """Pin a relative (``target_factor``) target to an absolute one
        using the workflow's unloaded mean latency."""
        if self.latency_target_s is not None or self.target_factor is None:
            return self
        return dataclasses.replace(
            self,
            latency_target_s=base_latency_s * self.target_factor,
            target_factor=None,
        )

    @property
    def deadline_s(self) -> float:
        """Relative deadline (inf for best-effort / unresolved)."""
        if self.latency_target_s is None:
            return math.inf
        return self.latency_target_s


# Standard tiers; targets are relative so they mean something for any
# workflow (2x unloaded latency is tight once queueing appears).
GOLD = SLOClass("gold", target_factor=2.0, weight=4.0, shed_policy="never")
SILVER = SLOClass("silver", target_factor=4.0, weight=2.0, shed_policy="degrade")
BRONZE = SLOClass("bronze", target_factor=8.0, weight=1.0, shed_policy="reject")
BEST_EFFORT = SLOClass("best_effort", weight=0.5, shed_policy="reject")


@dataclass(frozen=True)
class RequestQoS:
    """Per-engine-request QoS metadata the queue disciplines read.

    ``deadline`` is absolute simulation time (inf = best-effort);
    ``remaining_s`` is the estimated LLM work still ahead of this
    request's *workflow*-level request once this call finishes, from the
    :class:`WorkModel`.  ``tenant`` is the fair-queueing identity (the
    workflow name).
    """

    tenant: str
    slo: str = ""
    weight: float = 1.0
    deadline: float = math.inf
    remaining_s: float = 0.0
    degraded: bool = False

    def slack(self, now: float) -> float:
        """Deadline slack minus estimated remaining work — the priority
        discipline's urgency key (smaller = more urgent)."""
        return (self.deadline - now) - self.remaining_s


@dataclass(frozen=True)
class WorkModel:
    """Expected per-request work of one workflow, from its aggregate
    pipeline (per-stage n_m, p_m and unloaded latency).

    ``per_call_s[m]`` — expected unloaded seconds of one call to stage m;
    ``total_s`` — expected total LLM-busy seconds per workflow request
    (Σ n_m · per_call_s[m], the remaining-work budget);
    ``serial_s`` — expected critical-path seconds (Σ n_m/p_m · ...), the
    service-time part of the admission delay estimate;
    ``sec_per_token[m]`` — per-token service-time proxy used to convert
    a replica's queued tokens into queueing seconds.
    """

    per_call_s: Dict[str, float]
    total_s: float
    serial_s: float
    sec_per_token: Dict[str, float] = field(default_factory=dict)

    @classmethod
    def from_pipeline(cls, pipeline, stats=None,
                      percentile: str = "mean") -> "WorkModel":
        """Build from an :class:`AggregateLLMPipeline` (optionally with
        the traced :class:`WorkflowStats` for token-level calibration)."""
        per_call: Dict[str, float] = {}
        spt: Dict[str, float] = {}
        total = 0.0
        serial = 0.0
        for m, st in pipeline.stages.items():
            tp0 = st.profile.tps()[0]
            cap = st.profile.max_throughput(tp0)
            rate = 0.05 * cap if math.isfinite(cap) and cap > 0 else 0.0
            lm = st.profile.latency(rate, tp0, percentile=percentile)
            if not math.isfinite(lm):
                lm = 0.0
            per_call[m] = lm
            total += lm * st.n
            serial += lm * st.n / max(st.p, 1.0)
            tokens = 0.0
            if stats is not None and m in stats.per_llm:
                s = stats.per_llm[m]
                tokens = s.mean_prompt_tokens + s.mean_output_tokens
            if tokens <= 0:
                tokens = 1024.0
            spt[m] = lm / tokens
        return cls(per_call_s=per_call, total_s=total, serial_s=serial,
                   sec_per_token=spt)

    def remaining_after(self, issued_s: float) -> float:
        """Remaining-work estimate once ``issued_s`` seconds of expected
        call work have been dispatched."""
        return max(self.total_s - issued_s, 0.0)


@dataclass
class WorkflowQoS:
    """Everything the runtime needs to enforce one workflow's QoS:
    the (resolved) SLO class, the work model, and optionally a
    cluster-front admission controller."""

    slo: SLOClass
    work: WorkModel
    admission: Optional[object] = None  # AdmissionController, duck-typed
