"""Request-level QoS: SLO classes, queue disciplines, admission control.

The allocation layer (scheduler + placement) decides *where replicas
live*; this package decides *which request a replica serves next* and
*whether the cluster front door accepts a request at all*:

* :mod:`repro.qos.slo` — per-workflow SLO classes (latency target,
  priority weight, shed policy) plus the aggregate-pipeline-derived
  :class:`~repro.qos.slo.WorkModel` that estimates a workflow request's
  remaining work;
* :mod:`repro.qos.policy` — pluggable :class:`EngineSim` queue
  disciplines (``fifo`` | ``priority`` | ``wfq``);
* :mod:`repro.qos.admission` — cluster-front admission control and load
  shedding driven by the pipeline predictor's delay estimate.
"""

from repro.qos.admission import AdmissionController, fleet_admission
from repro.qos.policy import (
    DRRDiscipline,
    FifoDiscipline,
    PriorityDiscipline,
    QueueDiscipline,
    make_policy,
)
from repro.qos.slo import (
    BEST_EFFORT,
    BRONZE,
    GOLD,
    SILVER,
    RequestQoS,
    SLOClass,
    WorkModel,
    WorkflowQoS,
)

__all__ = [
    "AdmissionController",
    "fleet_admission",
    "QueueDiscipline",
    "FifoDiscipline",
    "PriorityDiscipline",
    "DRRDiscipline",
    "make_policy",
    "SLOClass",
    "RequestQoS",
    "WorkModel",
    "WorkflowQoS",
    "GOLD",
    "SILVER",
    "BRONZE",
    "BEST_EFFORT",
]
