"""Cluster-front admission control and load shedding.

The controller sits in front of the routed replica set: each workflow-
level arrival asks :meth:`AdmissionController.admit` before its first
LLM call is dispatched.  The delay estimate is the aggregate pipeline's
— the same predictor the scheduler searched with — evaluated two ways
and combined pessimistically:

* **model**: the pipeline's predicted workflow latency at the *observed*
  arrival rate (EWMA over inter-arrivals), which prices sustained
  overload the way the scheduler would;
* **live**: the critical-path service time plus the current queued work
  ahead of this request — each stage's best replica's backlog in tokens,
  converted to seconds with the work model's per-token service-time
  proxy — which prices bursts the rate EWMA has not caught up with.

When the combined estimate blows the workflow's SLO target (times
``headroom``), the request is shed per its class's policy: ``reject``
drops it at the door, ``degrade`` admits it as best-effort (it runs but
yields to every deadline class), ``never`` always admits.

Just-in-time model substitution sits between "admit" and "shed": a
workflow registered with ``substitutes`` (workflow-local LLM name ->
the substitute tier's Router, per ``ArchConfig.substitute``) re-prices
an over-deadline arrival against the substitute replicas' live backlog
and — when the cheaper tier still makes the deadline — admits it as
``SUBSTITUTE`` instead of shedding.  A substituted request keeps its
own SLO class and deadline (substitution never upgrades a request);
the driver reroutes its calls to the substitute's replicas.  Observed
per-workflow substitution rates (:meth:`substitution_rates`) feed back
into :meth:`repro.core.pipeline.MergedPipeline.with_substitution` so
the pooled share attribution follows the traffic that actually moved.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.qos.slo import SLOClass, WorkModel, WorkflowQoS

ADMIT = "admit"
REJECT = "reject"
DEGRADE = "degrade"
SUBSTITUTE = "substitute"


@dataclass
class AdmissionStats:
    arrived: int = 0
    admitted: int = 0
    rejected: int = 0
    degraded: int = 0
    substituted: int = 0

    def as_dict(self) -> dict:
        return {
            "arrived": self.arrived,
            "admitted": self.admitted,
            "rejected": self.rejected,
            "degraded": self.degraded,
            "substituted": self.substituted,
        }


@dataclass
class _Entry:
    slo: SLOClass
    work: WorkModel
    routers: Dict[str, object] = field(default_factory=dict)
    predictor: Optional[Callable[[float], float]] = None
    stats: AdmissionStats = field(default_factory=AdmissionStats)
    # JIT substitution: workflow-local llm name -> the substitute tier's
    # Router (empty = this workflow never substitutes)
    substitutes: Dict[str, object] = field(default_factory=dict)
    # observed-rate EWMA state
    last_arrival: Optional[float] = None
    ia_ewma: Optional[float] = None
    n_samples: int = 0


class AdmissionController:
    """Per-fleet admission control keyed by workflow name.

    ``register`` wires one workflow: its (resolved) SLO class, work
    model, the live routers its calls will be submitted to (for the
    backlog estimate; optional), and optionally a ``predictor`` mapping
    an observed arrival rate to the pipeline's predicted workflow
    latency (for the model estimate).  A workflow that never registered
    is always admitted.
    """

    def __init__(self, *, headroom: float = 1.0, ia_alpha: float = 0.1,
                 min_rate_samples: int = 8):
        self.headroom = headroom
        self.ia_alpha = ia_alpha
        self.min_rate_samples = min_rate_samples
        self._entries: Dict[str, _Entry] = {}
        # observability hook (repro.obs.spans.Tracer); None = untraced
        self.tracer = None

    def register(self, workflow: str, slo: SLOClass, work: WorkModel, *,
                 routers: Optional[Dict[str, object]] = None,
                 predictor: Optional[Callable[[float], float]] = None,
                 substitutes: Optional[Dict[str, object]] = None) -> None:
        self._entries[workflow] = _Entry(
            slo=slo, work=work, routers=dict(routers or {}),
            predictor=predictor, substitutes=dict(substitutes or {}))

    def stats(self) -> Dict[str, dict]:
        return {w: e.stats.as_dict() for w, e in self._entries.items()}

    def substitution_rates(self) -> Dict[str, float]:
        """Observed substituted/arrived fraction per workflow — the rates
        :meth:`MergedPipeline.with_substitution` re-attributes shares
        with."""
        return {w: (e.stats.substituted / e.stats.arrived
                    if e.stats.arrived else 0.0)
                for w, e in self._entries.items()}

    # -- delay estimation --------------------------------------------------

    def _observed_rate(self, e: _Entry, now: float) -> Optional[float]:
        last, e.last_arrival = e.last_arrival, now
        if last is not None:
            dt = max(now - last, 1e-9)
            if e.ia_ewma is None:
                e.ia_ewma = dt
            else:
                e.ia_ewma += self.ia_alpha * (dt - e.ia_ewma)
            e.n_samples += 1
        if (e.ia_ewma is None or e.ia_ewma <= 0
                or e.n_samples < self.min_rate_samples):
            return None
        return 1.0 / e.ia_ewma

    @staticmethod
    def _queue_delay(e: _Entry, llm: str,
                     router: Optional[object] = None) -> float:
        """Queued-work seconds ahead of a new call to ``llm``: the least
        backlog across that stage's live replicas, in tokens, priced at
        the work model's per-token service time.  Only replicas the
        workflow can actually route to count — a weighted Router view
        never submits to zero-weight replicas, so an idle replica in
        another tenant's block must not mask this workflow's backlog.
        ``router`` overrides the registered one (the substitution path
        prices the substitute tier's replicas instead)."""
        router = router if router is not None else e.routers.get(llm)
        if router is None:
            return 0.0
        replicas = getattr(router, "replicas", None)
        if not replicas:
            return 0.0
        weights = getattr(router, "weights", None)
        loads: List[float] = [
            r.load
            for i, r in enumerate(replicas)
            if not getattr(r, "failed", False)
            and (weights is None or weights.get(i, 0.0) > 0)
        ]
        if not loads:
            return math.inf
        spt = e.work.sec_per_token.get(llm, 0.0)
        return min(loads) * spt

    def predicted_delay(self, workflow: str, now: float, *,
                        update_rate: bool = False) -> float:
        """Predicted latency of a request arriving now (inf = hopeless)."""
        e = self._entries.get(workflow)
        if e is None:
            return 0.0
        rate = self._observed_rate(e, now) if update_rate else (
            1.0 / e.ia_ewma if e.ia_ewma else None)
        model_est = 0.0
        if e.predictor is not None and rate is not None:
            try:
                model_est = e.predictor(rate)
            except (ValueError, KeyError):
                model_est = 0.0
            if not math.isfinite(model_est):
                model_est = math.inf
        live_est = e.work.serial_s + sum(
            self._queue_delay(e, m) for m in e.work.per_call_s
        )
        return max(model_est, live_est)

    def _substituted_delay(self, e: _Entry) -> float:
        """Live delay estimate with substitutable stages re-priced
        against their substitute tier's replicas.  Model (rate-EWMA)
        pricing is skipped: the pipeline predictor knows nothing about
        the substitute's capacity, and substitution exists precisely for
        bursts where the substitute tier has live headroom."""
        return e.work.serial_s + sum(
            self._queue_delay(e, m, router=e.substitutes.get(m))
            for m in e.work.per_call_s
        )

    # -- the front door ----------------------------------------------------

    def admit(self, workflow: str, now: float) -> str:
        """Decide one arrival:
        ``admit`` | ``substitute`` | ``reject`` | ``degrade``."""
        decision = self._decide(workflow, now)
        if self.tracer is not None:
            self.tracer.on_admission_decision(workflow, decision, now)
        return decision

    def _decide(self, workflow: str, now: float) -> str:
        e = self._entries.get(workflow)
        if e is None:
            return ADMIT
        e.stats.arrived += 1
        predicted = self.predicted_delay(workflow, now, update_rate=True)
        target = e.slo.deadline_s
        if (e.slo.shed_policy == "never" or not math.isfinite(target)
                or predicted <= target * self.headroom):
            e.stats.admitted += 1
            return ADMIT
        # JIT substitution: before shedding, re-price against the
        # substitute tier — admit there when it still makes the deadline
        # (at the request's OWN class; substitution never upgrades it)
        if e.substitutes and \
                self._substituted_delay(e) <= target * self.headroom:
            e.stats.substituted += 1
            return SUBSTITUTE
        if e.slo.shed_policy == "reject":
            e.stats.rejected += 1
            return REJECT
        e.stats.degraded += 1
        return DEGRADE


def fleet_admission(qos: Dict[str, WorkflowQoS],
                    routers: Dict[str, Dict[str, object]], *,
                    predictors: Optional[Dict[str, Callable[[float], float]]] = None,
                    substitutes: Optional[Dict[str, Dict[str, object]]] = None,
                    headroom: float = 1.0) -> AdmissionController:
    """One controller for a deployed fleet.

    ``qos`` is per-workflow (slo + work model), ``routers`` is each
    workflow's router dict (workflow -> local llm name -> Router, the
    same object handed to its ClusterDriver), ``predictors`` optionally
    maps a workflow to a rate -> predicted-latency callable (e.g.
    ``lambda lam: pipeline.predict(alloc, lam).latency``).
    ``substitutes`` maps workflow -> local llm name -> the substitute
    tier's Router (mirror the driver's ``substitute_map``); workflows
    with an entry get the JIT-substitution degrade path.  The
    controller is also installed on each ``WorkflowQoS.admission``.
    """
    ctrl = AdmissionController(headroom=headroom)
    for w, q in qos.items():
        ctrl.register(
            w, q.slo, q.work,
            routers=routers.get(w, {}),
            predictor=(predictors or {}).get(w),
            substitutes=(substitutes or {}).get(w))
        q.admission = ctrl
    return ctrl
