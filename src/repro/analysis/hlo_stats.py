"""Static analyzer for optimized HLO text: FLOPs, HBM bytes, collective
bytes — with while-loop (scan) bodies multiplied by their trip counts.

Why: ``compiled.cost_analysis()`` counts a while body ONCE, so any model
that scans over layers (all of ours) under-reports FLOPs by ~num_layers.
This parser rebuilds the call graph (entry -> fusion/call/while/cond) and
multiplies every computation's cost by its execution count; while trip
counts are recovered from the loop condition's comparison constant.

Conventions:
  * FLOPs: 2*M*N*K per dot (batch dims folded into M), convolutions
    counted via output x kernel size; elementwise ignored (<1% for LMs);
  * HBM bytes: for every *top-level* instruction of an executed
    computation, operands + results (fusions count their boundary only —
    the same approximation XLA's cost model uses);
  * collective bytes: result-shape bytes per op kind (ring-traffic proxy),
    also multiplied by execution count.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
               "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1,
               "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
               "s8": 1, "u8": 1, "pred": 1, "token": 0, "s4": 1, "u4": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def shape_bytes(text: str) -> int:
    """Total bytes of all array shapes mentioned in a type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def shape_elems(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


@dataclass
class Instruction:
    name: str
    result_type: str
    op: str
    operands: List[str]
    raw: str


@dataclass
class Computation:
    name: str
    instructions: Dict[str, Instruction] = field(default_factory=dict)
    order: List[str] = field(default_factory=list)


_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_NAME_EQ = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")
_OP_CALL = re.compile(r"\s*([\w\-]+)\(")
_OPERAND = re.compile(r"%([\w\.\-]+)")


def _parse_instr_line(line: str):
    """Parse '%name = <type> op(operands...), attrs' robustly.

    Handles tuple result types with nested parens and /*index=N*/ comments.
    Returns (name, result_type, op, operand_str) or None.
    """
    m = _NAME_EQ.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    # result type: balanced-paren tuple or a single shape token
    if i < len(line) and line[i] == "(":
        depth = 0
        j = i
        while j < len(line):
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    j += 1
                    break
            j += 1
        rtype = line[i:j]
    else:
        j = i
        while j < len(line) and not line[j].isspace():
            j += 1
        rtype = line[i:j]
    mo = _OP_CALL.match(line, j)
    if not mo:
        return None
    op = mo.group(1)
    k = mo.end()  # position just after the op's '('
    depth = 1
    ops_chars = []
    while k < len(line) and depth > 0:
        ch = line[k]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        ops_chars.append(ch)
        k += 1
    return name, rtype, op, "".join(ops_chars)


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HEAD.match(stripped)
            if m and stripped.endswith("{"):
                cur = Computation(m.group(1))
                if stripped.startswith("ENTRY"):
                    entry = cur.name
                continue
        else:
            if stripped == "}" or stripped.startswith("}"):
                comps[cur.name] = cur
                cur = None
                continue
            parsed = _parse_instr_line(line)
            if parsed:
                name, rtype, op, ops_str = parsed
                operands = _OPERAND.findall(ops_str)
                cur.instructions[name] = Instruction(
                    name, rtype, op, operands, stripped)
                cur.order.append(name)
    return comps, entry


def _operand_type(comp: Computation, comps: Dict[str, Computation],
                  name: str) -> str:
    ins = comp.instructions.get(name)
    return ins.result_type if ins else ""


_ATTR_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_ATTR_BODY = re.compile(r"body=%?([\w\.\-]+)")
_ATTR_COND = re.compile(r"condition=%?([\w\.\-]+)")
_ATTR_BRANCHES = re.compile(r"branch_computations={([^}]*)}")
_ATTR_TODEF = re.compile(r"to_apply=%?([\w\.\-]+)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims={([0-9,]*)}")
_LHS_BDIMS = re.compile(r"lhs_batch_dims={([0-9,]*)}")
_CONST_INT = re.compile(r"constant\((\d+)\)")


def while_trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Heuristic: largest int constant in the cond computation (+ callees)."""
    best = 1
    seen = set()
    stack = [cond_name]
    while stack:
        cn = stack.pop()
        if cn in seen or cn not in comps:
            continue
        seen.add(cn)
        for ins in comps[cn].instructions.values():
            for m in _CONST_INT.finditer(ins.raw):
                best = max(best, int(m.group(1)))
            for attr in (_ATTR_CALLS, _ATTR_TODEF):
                am = attr.search(ins.raw)
                if am:
                    stack.append(am.group(1))
    return best


def dot_flops(comp: Computation, ins: Instruction) -> float:
    """2*M*N*K from the result shape and lhs contracting dims."""
    res = shape_elems(ins.result_type)
    if not res:
        return 0.0
    out_elems = math.prod(res[0][1]) if res[0][1] else 1
    lhs_type = _operand_type(comp, {}, ins.operands[0]) if ins.operands else ""
    lhs = shape_elems(lhs_type)
    cd = _LHS_CDIMS.search(ins.raw)
    k = 1
    if lhs and cd:
        dims = lhs[0][1]
        for d in cd.group(1).split(","):
            if d and int(d) < len(dims):
                k *= dims[int(d)]
    return 2.0 * out_elems * k


_PARAM_IDX = re.compile(r"parameter\((\d+)\)")


def _fusion_bytes(comps: Dict[str, "Computation"], fc_name: str,
                  operand_types: List[str], result_type: str) -> float:
    """Boundary bytes of a fusion, recognizing in-place patterns:

    * a fused-computation parameter consumed only by dynamic-slice ops
      contributes the slice bytes, not the full array (the array stays in
      HBM; only the slice is read) — this is how scan bodies read their
      per-layer cache/param slices;
    * a parameter that is the in-place target of a root dynamic-update-
      slice contributes the update-slice bytes (read+write), not two full
      copies of the carried array.
    """
    fc = comps.get(fc_name)
    if fc is None:
        return sum(shape_bytes(t) for t in operand_types) + shape_bytes(result_type)
    params: Dict[int, str] = {}
    for ins in fc.instructions.values():
        if ins.op == "parameter":
            m = _PARAM_IDX.search(ins.raw)
            if m:
                params[int(m.group(1))] = ins.name
    consumers: Dict[str, List[Instruction]] = {}
    for ins in fc.instructions.values():
        for o in ins.operands:
            consumers.setdefault(o, []).append(ins)

    def effective_consumers(name: str, depth: int = 6) -> List[Instruction]:
        """Consumers reached through pure passthrough ops."""
        out: List[Instruction] = []
        for c in consumers.get(name, []):
            if c.op in _PASSTHROUGH_OPS and depth > 0:
                out.extend(effective_consumers(c.name, depth - 1))
            else:
                out.append(c)
        return out

    total = 0.0
    inplace_params = set()
    for idx, ptype in enumerate(operand_types):
        pname = params.get(idx)
        cons = effective_consumers(pname) if pname else []
        if cons and all(c.op == "dynamic-slice" for c in cons):
            total += sum(shape_bytes(c.result_type) for c in cons)
        elif cons and all(c.op == "dynamic-update-slice"
                          and c.operands and c.operands[0] == pname
                          for c in cons):
            inplace_params.add(pname)
            for c in cons:
                if len(c.operands) >= 2:
                    upd = fc.instructions.get(c.operands[1])
                    total += 2 * shape_bytes(upd.result_type if upd else "")
        else:
            total += shape_bytes(ptype)

    root = next((i for i in fc.instructions.values()
                 if i.raw.startswith("ROOT")), None)

    def _root_elem_bytes(name: str) -> float:
        oi = fc.instructions.get(name)
        if oi is not None and oi.op == "dynamic-update-slice" \
                and oi.operands and oi.operands[0] in inplace_params:
            return 0.0  # in-place write already counted
        return shape_bytes(oi.result_type) if oi else 0.0

    if root is None:
        total += shape_bytes(result_type)
    elif root.op == "dynamic-update-slice" and root.operands \
            and root.operands[0] in inplace_params:
        pass  # in-place
    elif root.op == "tuple":
        total += sum(_root_elem_bytes(o) for o in root.operands)
    else:
        total += shape_bytes(root.result_type)
    return total


@dataclass
class HLOStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, float] = field(default_factory=dict)
    while_trip_counts: List[int] = field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "iota", "while", "conditional", "call",
                   # TPU-fusion approximation: the CPU backend materializes
                   # layout/legalization ops (notably f32 upcasts of bf16
                   # dot operands — the MXU consumes bf16 natively) that a
                   # TPU compilation fuses away; counting them inflates the
                   # memory roofline term several-fold.
                   "convert", "copy", "transpose", "reshape", "broadcast",
                   "bitcast-convert"}

_PASSTHROUGH_OPS = {"convert", "copy", "bitcast", "bitcast-convert",
                    "transpose", "reshape"}


def _resolve_type(comp: "Computation", name: str, depth: int = 8) -> str:
    """Follow convert/copy chains to the producing instruction's type, so
    a bf16 tensor read through an f32 legalization convert counts bf16."""
    for _ in range(depth):
        ins = comp.instructions.get(name)
        if ins is None:
            return ""
        if ins.op in _PASSTHROUGH_OPS and ins.operands:
            name = ins.operands[0]
            continue
        return ins.result_type
    return comp.instructions[name].result_type if name in comp.instructions else ""


def analyze(text: str) -> HLOStats:
    comps, entry = parse_hlo(text)
    stats = HLOStats()
    memo: Dict[str, Tuple[float, float, Dict[str, float], Dict[str, float]]] = {}

    def comp_cost(name: str) -> Tuple[float, float, Dict[str, float],
                                      Dict[str, float]]:
        if name in memo:
            return memo[name]
        memo[name] = (0.0, 0.0, {}, {})  # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        flops = 0.0
        hbm = 0.0
        coll: Dict[str, float] = {}
        ccnt: Dict[str, float] = {}

        def add_sub(mult: float, sub: str):
            nonlocal flops, hbm
            f, b, c, k = comp_cost(sub)
            flops += mult * f
            hbm += mult * b
            for op, v in c.items():
                coll[op] = coll.get(op, 0.0) + mult * v
            for op, v in k.items():
                ccnt[op] = ccnt.get(op, 0.0) + mult * v

        for iname in comp.order:
            ins = comp.instructions[iname]
            op = ins.op
            if op == "while":
                bm = _ATTR_BODY.search(ins.raw)
                cm = _ATTR_COND.search(ins.raw)
                trips = while_trip_count(comps, cm.group(1)) if cm else 1
                stats.while_trip_counts.append(trips)
                if bm:
                    add_sub(trips, bm.group(1))
                if cm:
                    add_sub(trips, cm.group(1))
                continue
            if op == "conditional":
                bm = _ATTR_BRANCHES.search(ins.raw)
                if bm:
                    branches = _OPERAND.findall(bm.group(1))
                    for b in branches:  # upper bound: all branches once
                        add_sub(1.0 / max(len(branches), 1), b)
                continue
            m = _ATTR_CALLS.search(ins.raw) or _ATTR_TODEF.search(ins.raw)
            if m and op in ("fusion", "call", "map", "reduce", "sort",
                            "reduce-window", "scatter", "custom-call"):
                if op in ("call",):
                    add_sub(1.0, m.group(1))
                else:
                    # fusion: dots inside fused computations still count
                    f, _, c, k = comp_cost(m.group(1))
                    flops += f
                    for o, v in c.items():
                        coll[o] = coll.get(o, 0.0) + v
                    for o, v in k.items():
                        ccnt[o] = ccnt.get(o, 0.0) + v
            if op == "dot":
                flops += dot_flops(comp, ins)
            base_op = op
            for cop in COLLECTIVES:
                if base_op.startswith(cop) and not base_op.endswith("-done"):
                    b = shape_bytes(ins.result_type)
                    coll[cop] = coll.get(cop, 0.0) + b
                    ccnt[cop] = ccnt.get(cop, 0.0) + 1
                    break
            if op == "dynamic-slice":
                # reads only the slice (the operand stays in HBM); result
                # bytes ~= slice read + write
                hbm += 2 * shape_bytes(ins.result_type)
            elif op == "dynamic-update-slice":
                # in-place (donated) update: traffic ~= the update slice,
                # not the full carried array
                if len(ins.operands) >= 2:
                    hbm += 2 * shape_bytes(_resolve_type(comp, ins.operands[1]))
            elif op == "fusion" and m:
                hbm += _fusion_bytes(
                    comps, m.group(1),
                    [_resolve_type(comp, o) for o in ins.operands],
                    ins.result_type)
            elif op not in _SKIP_BYTES_OPS:
                hbm += shape_bytes(ins.result_type)
                for o in ins.operands:
                    hbm += shape_bytes(_resolve_type(comp, o))
        memo[name] = (flops, hbm, coll, ccnt)
        return memo[name]

    f, b, c, k = comp_cost(entry)
    stats.flops = f
    stats.hbm_bytes = b
    stats.collective_bytes = c
    stats.collective_counts = k
    return stats


def analyze_compiled(compiled) -> HLOStats:
    return analyze(compiled.as_text())
