"""Segment-level radix cache for the fleet simulator.

The discrete-event simulator never materializes token ids — a workflow
call's prompt is modeled as a sequence of *segments*: ``(segment_id,
token_length)`` pairs, where a segment id is a deterministic synthetic
identifier for an atomic token run (a system prompt, a prior call's
prompt delta, a prior call's generated output).  Two calls share a
prefix exactly when their segment sequences share a leading run of ids;
truncated reuse (a child re-sending only part of a parent segment, as in
beam-search verify calls) shares a *partial* final segment.

:class:`RadixCache` is the per-replica model of which KV bytes are live
in HBM:

* ``match(seq)`` — longest cached prefix, in tokens (token-accurate,
  including partial final segments);
* ``insert(seq)`` — register a sequence's KV as resident, creating one
  node per new segment span and splitting nodes on partial overlap;
* ``pin``/``unpin`` — running requests pin their path so eviction can
  never drop KV that is still referenced;
* capacity is a **token budget** (the caller converts the replica's HBM
  byte budget via the cost model's KV-bytes-per-token); LRU leaves are
  evicted until under budget, so ``match`` stops reporting hits for KV
  a real engine would have discarded.

Everything is host-side, deterministic (LRU clock + insertion-ordered
tie-breaks), and O(path length) per operation.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from heapq import heapify, heappop, heappush
from itertools import count
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

Segment = Tuple[Hashable, int]  # (segment id, token length)


@dataclass
class _Node:
    seg: Hashable = None
    start: int = 0          # offset of this span within its segment
    length: int = 0         # tokens covered by this node
    parent: Optional["_Node"] = None
    children: Dict[Tuple[Hashable, int], "_Node"] = field(default_factory=dict)
    pins: int = 0
    stamp: int = 0

    def key(self) -> Tuple[Hashable, int]:
        return (self.seg, self.start)


class RadixCache:
    """``head_listeners`` get called as ``cb(op, seg)`` whenever the set
    of resident *head segments* (root children — every cached sequence
    starts at one) changes: ``("add", seg)`` when a head becomes
    resident, ``("del", seg)`` when eviction drops one, and
    ``("reset", None)`` on :meth:`clear`.  Splitting a head node keeps
    its ``(seg, 0)`` key, so no event fires.  Routers index replicas by
    head segment with these hooks (see ``simulator._ReplicaIndex``)."""

    def __init__(self, capacity_tokens: int = 1 << 30):
        self.root = _Node()
        self.capacity_tokens = int(capacity_tokens)
        self.tokens = 0  # total cached tokens across all nodes
        self.clock = 0
        self.head_listeners: List = []
        # lazy LRU heap over evictable leaves: (stamp, tie, node)
        # entries; an entry is fresh iff the node is still an attached
        # childless node carrying that stamp (every leaf-stamp change
        # and every become-a-leaf event pushes a fresh entry, so each
        # current leaf always has one)
        self._lru: List[Tuple[int, int, _Node]] = []
        self._lru_seq = count()
        self.n_nodes = 0
        # measurement/parity knob: evict via the seed's full-tree DFS
        # walk instead of the LRU heap (bench_scale's legacy baseline)
        self.legacy_evict = False

    def _offer(self, node: _Node) -> None:
        if node is not self.root and not node.children:
            heappush(self._lru, (node.stamp, next(self._lru_seq), node))

    def _lru_compact(self) -> None:
        """Drop stale heap entries (rebuild from the live leaves)."""
        fresh = {}
        for stamp, seq, node in self._lru:
            if (node.parent is not None and not node.children
                    and node.stamp == stamp
                    and node.parent.children.get(node.key()) is node):
                cur = fresh.get(id(node))
                if cur is None or (stamp, seq) < cur[:2]:
                    fresh[id(node)] = (stamp, seq, node)
        self._lru = list(fresh.values())
        heapify(self._lru)

    def _head_event(self, op: str, seg) -> None:
        for cb in self.head_listeners:
            cb(op, seg)

    # -- queries -----------------------------------------------------------
    def match(self, seq: Sequence[Segment], touch: bool = True) -> int:
        """Longest cached prefix of ``seq`` in tokens."""
        if touch:
            self.clock += 1
        node, matched, _, _ = self._descend(seq, touch=touch)
        if touch:
            self._offer(node)  # its old heap entry is stale now
        return matched

    # -- updates -----------------------------------------------------------
    def insert(self, seq: Sequence[Segment]) -> int:
        """Make ``seq``'s KV resident; returns the previously-cached
        prefix length in tokens.  Evicts LRU leaves (never the inserted
        path, never pinned paths) until back under the token budget."""
        self.clock += 1
        node, matched, i, off = self._descend(seq, touch=True, split=True)
        for j in range(i, len(seq)):
            seg, slen = seq[j]
            o = off if j == i else 0
            if o >= slen:
                continue
            child = _Node(seg=seg, start=o, length=slen - o, parent=node,
                          stamp=self.clock)
            node.children[child.key()] = child
            self.tokens += child.length
            self.n_nodes += 1
            if node is self.root and self.head_listeners:
                self._head_event("add", seg)
            node = child
        self._offer(node)
        if len(self._lru) > max(1024, 4 * self.n_nodes):
            self._lru_compact()
        path = set()
        walk = node
        while walk is not None:
            path.add(id(walk))
            walk = walk.parent
        while self.tokens > self.capacity_tokens:
            if not self._evict_one(path):
                break
        return matched

    def pin(self, seq: Sequence[Segment]) -> None:
        for n in self._path_nodes(seq):
            n.pins += 1

    def unpin(self, seq: Sequence[Segment]) -> None:
        for n in self._path_nodes(seq):
            if n.pins > 0:
                n.pins -= 1

    def clear(self) -> None:
        self.root = _Node()
        self.tokens = 0
        self.n_nodes = 0
        self._lru = []
        if self.head_listeners:
            self._head_event("reset", None)

    # -- internals ---------------------------------------------------------
    def _descend(self, seq: Sequence[Segment], touch: bool,
                 split: bool = False):
        """Walk as deep as the cache matches ``seq``.

        Returns (deepest node, matched tokens, next segment index,
        offset within that segment).  With ``split=True`` a partial
        match of a node splits it so the returned node ends exactly at
        the match boundary (insert needs an exact attachment point).
        """
        node, matched = self.root, 0
        i, off = 0, 0
        while i < len(seq):
            seg, slen = seq[i]
            if off >= slen:
                i, off = i + 1, 0
                continue
            child = node.children.get((seg, off))
            if child is None:
                break
            take = min(child.length, slen - off)
            if take < child.length:
                # sequence boundary falls inside this node
                if split:
                    child = self._split(child, take)
                matched += take
                off += take
                if touch:
                    child.stamp = self.clock
                node = child
                break
            matched += take
            off += take
            if touch:
                child.stamp = self.clock
            node = child
            if off >= slen:
                i, off = i + 1, 0
        return node, matched, i, off

    def _path_nodes(self, seq: Sequence[Segment]) -> List[_Node]:
        node, _, _, _ = self._descend(seq, touch=False)
        out = []
        while node is not self.root and node is not None:
            out.append(node)
            node = node.parent
        return out

    def _split(self, child: _Node, take: int) -> _Node:
        """Split ``child`` at ``take`` tokens; returns the upper half."""
        parent = child.parent
        upper = _Node(seg=child.seg, start=child.start, length=take,
                      parent=parent, pins=child.pins, stamp=child.stamp)
        del parent.children[child.key()]
        parent.children[upper.key()] = upper
        child.start += take
        child.length -= take
        child.parent = upper
        upper.children[child.key()] = child
        self.n_nodes += 1
        return upper

    def _evict_one(self, protect) -> bool:
        """Drop the least-recently-touched unpinned leaf not on the
        protected path.  Returns False when nothing is evictable.

        Served from the lazy LRU heap in O(log leaves) amortized (the
        seed walked the whole tree per eviction); stale entries are
        discarded on pop, pinned/protected candidates are deferred and
        re-pushed so they stay eligible for later evictions.
        """
        if self.legacy_evict:
            return self._evict_one_walk(protect)
        heap, best, deferred = self._lru, None, []
        while heap:
            stamp, seq, node = heappop(heap)
            parent = node.parent
            if (parent is None or node.children or node.stamp != stamp
                    or parent.children.get(node.key()) is not node):
                continue  # stale: detached, re-touched, or grew children
            if node.pins > 0 or id(node) in protect:
                deferred.append((stamp, seq, node))
                continue
            best = node
            break
        for entry in deferred:
            heappush(heap, entry)
        if best is None:
            return False
        parent = best.parent
        del parent.children[best.key()]
        self.tokens -= best.length
        self.n_nodes -= 1
        if parent is self.root and self.head_listeners:
            self._head_event("del", best.seg)
        self._offer(parent)  # parent may have just become a leaf
        return True

    def _evict_one_walk(self, protect) -> bool:
        """The seed's eviction: DFS the whole tree for the LRU leaf."""
        best = None
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            elif (n is not self.root and n.pins == 0
                    and id(n) not in protect):
                if best is None or n.stamp < best.stamp:
                    best = n
        if best is None:
            return False
        del best.parent.children[best.key()]
        self.tokens -= best.length
        self.n_nodes -= 1
        if best.parent is self.root and self.head_listeners:
            self._head_event("del", best.seg)
        return True
