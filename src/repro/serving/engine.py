"""A real JAX serving engine for the model zoo (executes on this host).

Slot-based continuous batching on an actual :class:`ModelBundle`:

  * prefill admits a waiting request into a free slot (logits for its
    last token seed decoding); exact-prefix cache reuse via
    :class:`PrefixCache` + :meth:`SlotKVCache.copy_prefix` — the longest
    cached prefix of the prompt is *copied* from the slot that already
    holds its KV and only the suffix is computed (dense-KV models);
  * decode runs one jitted step for ALL active slots with per-slot
    positions (ragged continuous batching — the (B,) position path of
    ``attention_block_decode``);
  * greedy sampling; requests complete at EOS-budget exhaustion.

This is the executable end-to-end serving driver (examples/serve_model.py
batches requests through it).  The fleet-scale behavior is the discrete-
event simulator; this engine proves the numerics and batching logic on
real models.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import ModelBundle
from repro.serving.kv_cache import SlotKVCache
from repro.serving.prefix_cache import PrefixCache


@dataclass
class ServeRequest:
    req_id: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    slot: int = -1
    done: bool = False
    cached_tokens: int = 0  # prompt tokens served from the prefix cache


class ServingEngine:
    def __init__(self, bundle: ModelBundle, params, *, slots: int = 8,
                 max_len: int = 256, prefix_caching: bool = True,
                 min_prefix: int = 8):
        self.bundle = bundle
        self.params = params
        self.cfg = bundle.cfg
        self.slots = slots
        self.max_len = max_len
        self.min_prefix = min_prefix
        self.cache = bundle.init_cache(slots, max_len)
        # prefix reuse needs a positional (L, slots, KV, S, D) KV layout
        # (dense/MoE attention); recurrent-state caches (rwkv, hymba
        # groups) have no per-token prefix to copy.
        self._dense_kv = self._is_dense_kv(self.cache)
        self.prefix_cache = (PrefixCache()
                             if prefix_caching and self._dense_kv else None)
        self.lengths = np.zeros(slots, np.int32)
        self.active: Dict[int, ServeRequest] = {}  # slot -> request
        self.waiting: List[ServeRequest] = []
        self.free_slots = list(range(slots))
        self.stats = {"prefill_tokens": 0, "cached_tokens": 0,
                      "decode_steps": 0}

        self._prefill_one = jax.jit(self._prefill_fn)
        self._decode = jax.jit(self.bundle.decode_step)

    def _is_dense_kv(self, cache) -> bool:
        if not (isinstance(cache, tuple) and len(cache) == 2):
            return False
        k, v = cache
        return (hasattr(k, "ndim") and hasattr(v, "ndim")
                and k.ndim == 5 and v.ndim == 5
                and k.shape[1] == self.slots and k.shape[3] == self.max_len)

    # -- model-facing helpers --
    def _prefill_fn(self, params, tokens):
        return self.bundle.prefill(params, {"tokens": tokens})

    def submit(self, req: ServeRequest) -> None:
        self.waiting.append(req)

    # -- engine iterations --
    def step(self) -> List[ServeRequest]:
        """One engine iteration; returns requests completed this step."""
        self._admit()
        return self._decode_step()

    def run_to_completion(self, max_steps: int = 10_000) -> List[ServeRequest]:
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.waiting and not self.active:
                break
        return out

    def _admit(self) -> None:
        while self.waiting and self.free_slots:
            req = self.waiting.pop(0)
            slot = self.free_slots.pop()
            req.slot = slot
            plen = len(req.prompt)
            tokens = [int(t) for t in req.prompt]

            matched, src = 0, None
            if self.prefix_cache is not None:
                matched, src = self.prefix_cache.longest_prefix(tokens)
                matched = min(matched, plen - 1)
                if matched < self.min_prefix:
                    matched, src = 0, None
            # the slot's old KV is about to be overwritten: every cache
            # entry still pointing at it is stale from here on (the
            # lookup above may legitimately have matched it — the bytes
            # are still in place until we write)
            if self.prefix_cache is not None:
                self.prefix_cache.invalidate_slot(slot)

            if src is not None:
                first_tok = self._prefill_from_prefix(
                    req, slot, src, matched, tokens)
                req.cached_tokens = matched
                self.stats["cached_tokens"] += matched
                self.stats["prefill_tokens"] += plen - matched
            else:
                logits, cache = self._prefill_one(
                    self.params, jnp.asarray(req.prompt)[None])
                self.stats["prefill_tokens"] += plen
                # write the prefill cache into the slot (dense layouts)
                self.cache = _merge_slot(self.cache, cache, slot, plen,
                                         self.max_len)
                first_tok = int(jnp.argmax(logits[0]))
            self.lengths[slot] = plen
            if self.prefix_cache is not None:
                self.prefix_cache.insert(tokens, slot)
            req.generated.append(first_tok)
            self.active[slot] = req

    def _prefill_from_prefix(self, req: ServeRequest, slot: int, src: int,
                             matched: int, tokens: List[int]) -> int:
        """Prefix-cache hit: copy the shared KV out of ``src`` and run
        only the suffix through the model (token-at-a-time decode on an
        isolated batch=1 view of the slot), returning the first sampled
        token."""
        kv = SlotKVCache(k=self.cache[0], v=self.cache[1],
                         lengths=self.lengths)
        kv.copy_prefix(src, slot, matched)
        cache = (kv.k, kv.v)
        k1 = jax.lax.dynamic_slice_in_dim(cache[0], slot, 1, axis=1)
        v1 = jax.lax.dynamic_slice_in_dim(cache[1], slot, 1, axis=1)
        logits = None
        for pos in range(matched, len(tokens)):
            logits, (k1, v1) = self._decode(
                self.params, (k1, v1),
                jnp.asarray([tokens[pos]], jnp.int32),
                jnp.asarray([pos], jnp.int32))
        self.cache = (
            jax.lax.dynamic_update_slice(cache[0], k1, (0, slot, 0, 0, 0)),
            jax.lax.dynamic_update_slice(cache[1], v1, (0, slot, 0, 0, 0)))
        return int(jnp.argmax(logits[0]))

    def _decode_step(self) -> List[ServeRequest]:
        if not self.active:
            return []
        slots = sorted(self.active)
        tokens = np.zeros(self.slots, np.int32)
        for s in slots:
            tokens[s] = self.active[s].generated[-1]
        pos = jnp.asarray(self.lengths, jnp.int32)
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens), pos)
        self.stats["decode_steps"] += 1
        completed = []
        toks = np.asarray(jnp.argmax(logits, axis=-1))
        for s in slots:
            req = self.active[s]
            self.lengths[s] += 1
            req.generated.append(int(toks[s]))
            if (len(req.generated) >= req.max_new_tokens
                    or self.lengths[s] >= self.max_len - 1):
                req.done = True
                completed.append(req)
                del self.active[s]
                # the slot's KV (prompt + all but the final generated
                # token) stays valid until the slot is reused; register
                # the full sequence for exact-prefix reuse
                if self.prefix_cache is not None:
                    seq = [int(t) for t in req.prompt] + req.generated[:-1]
                    self.prefix_cache.insert(seq[:self.max_len - 1], s)
                self.lengths[s] = 0
                self.free_slots.append(s)
        return completed


def _merge_slot(cache, prefill_cache, slot: int, plen: int, max_len: int):
    """Insert one sequence's prefill cache (batch=1) into slot ``slot``.

    Works structurally: any leaf with a batch dim of 1 at the engine's
    slot axis gets written.  Dense caches are (L, B, KV, S, D); rwkv
    states are (L, B, ...); hymba groups are nested dicts/tuples.
    """

    def merge(big, small):
        if big.ndim >= 2 and small.shape[0] == big.shape[0] \
                and small.shape[1] == 1:
            # (L, 1, ...) -> write into (L, slots, ...) at [*, slot]
            if big.ndim >= 4 and small.ndim == big.ndim \
                    and small.shape[-2] != big.shape[-2]:
                # seq axis shorter in prefill: pad to max_len
                pad = [(0, 0)] * small.ndim
                pad[-2] = (0, big.shape[-2] - small.shape[-2])
                small = jnp.pad(small, pad)
            return jax.lax.dynamic_update_slice(
                big, small.astype(big.dtype),
                (0, slot) + (0,) * (big.ndim - 2))
        if small.shape[0] == 1 and big.ndim == small.ndim:
            # (1, ...) leaves without layer dim (hymba singleton layers)
            if big.ndim >= 3 and small.shape[-2] != big.shape[-2]:
                pad = [(0, 0)] * small.ndim
                pad[-2] = (0, big.shape[-2] - small.shape[-2])
                small = jnp.pad(small, pad)
            return jax.lax.dynamic_update_slice(
                big, small.astype(big.dtype), (slot,) + (0,) * (big.ndim - 1))
        raise ValueError(f"cannot merge {small.shape} into {big.shape}")

    return jax.tree.map(merge, cache, prefill_cache)
