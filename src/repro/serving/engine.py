"""A real JAX serving engine for the model zoo (executes on this host).

Slot-based continuous batching on an actual :class:`ModelBundle`:

  * prefill admits a waiting request into a free slot (logits for its
    last token seed decoding); exact-prefix cache reuse via
    :class:`PrefixCache` + ``SlotKVCache.copy_prefix``;
  * decode runs one jitted step for ALL active slots with per-slot
    positions (ragged continuous batching — the (B,) position path of
    ``attention_block_decode``);
  * greedy sampling; requests complete at EOS-budget exhaustion.

This is the executable end-to-end serving driver (examples/serve_model.py
batches requests through it).  The fleet-scale behavior is the discrete-
event simulator; this engine proves the numerics and batching logic on
real models.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import ModelBundle
from repro.serving.prefix_cache import PrefixCache


@dataclass
class ServeRequest:
    req_id: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    slot: int = -1
    done: bool = False


class ServingEngine:
    def __init__(self, bundle: ModelBundle, params, *, slots: int = 8,
                 max_len: int = 256, prefix_caching: bool = True):
        self.bundle = bundle
        self.params = params
        self.cfg = bundle.cfg
        self.slots = slots
        self.max_len = max_len
        self.prefix_cache = PrefixCache() if prefix_caching else None
        self.cache = bundle.init_cache(slots, max_len)
        self.lengths = np.zeros(slots, np.int32)
        self.active: Dict[int, ServeRequest] = {}  # slot -> request
        self.waiting: List[ServeRequest] = []
        self.free_slots = list(range(slots))
        self.stats = {"prefill_tokens": 0, "cached_tokens": 0,
                      "decode_steps": 0}

        self._prefill_one = jax.jit(self._prefill_fn)
        self._decode = jax.jit(self.bundle.decode_step)

    # -- model-facing helpers --
    def _prefill_fn(self, params, tokens):
        return self.bundle.prefill(params, {"tokens": tokens})

    def submit(self, req: ServeRequest) -> None:
        self.waiting.append(req)

    # -- engine iterations --
    def step(self) -> List[ServeRequest]:
        """One engine iteration; returns requests completed this step."""
        self._admit()
        return self._decode_step()

    def run_to_completion(self, max_steps: int = 10_000) -> List[ServeRequest]:
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.waiting and not self.active:
                break
        return out

    def _admit(self) -> None:
        while self.waiting and self.free_slots:
            req = self.waiting.pop(0)
            slot = self.free_slots.pop()
            req.slot = slot
            plen = len(req.prompt)
            logits, cache = self._prefill_one(
                self.params, jnp.asarray(req.prompt)[None])
            self.stats["prefill_tokens"] += plen
            # write the prefill cache into the slot (dense-layout caches)
            self.cache = _merge_slot(self.cache, cache, slot, plen,
                                     self.max_len)
            self.lengths[slot] = plen
            first_tok = int(jnp.argmax(logits[0]))
            req.generated.append(first_tok)
            self.active[slot] = req

    def _decode_step(self) -> List[ServeRequest]:
        if not self.active:
            return []
        slots = sorted(self.active)
        tokens = np.zeros(self.slots, np.int32)
        for s in slots:
            tokens[s] = self.active[s].generated[-1]
        pos = jnp.asarray(self.lengths, jnp.int32)
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens), pos)
        self.stats["decode_steps"] += 1
        completed = []
        toks = np.asarray(jnp.argmax(logits, axis=-1))
        for s in slots:
            req = self.active[s]
            self.lengths[s] += 1
            req.generated.append(int(toks[s]))
            if (len(req.generated) >= req.max_new_tokens
                    or self.lengths[s] >= self.max_len - 1):
                req.done = True
                completed.append(req)
                del self.active[s]
                self.lengths[s] = 0
                self.free_slots.append(s)
        return completed


def _merge_slot(cache, prefill_cache, slot: int, plen: int, max_len: int):
    """Insert one sequence's prefill cache (batch=1) into slot ``slot``.

    Works structurally: any leaf with a batch dim of 1 at the engine's
    slot axis gets written.  Dense caches are (L, B, KV, S, D); rwkv
    states are (L, B, ...); hymba groups are nested dicts/tuples.
    """

    def merge(big, small):
        if big.ndim >= 2 and small.shape[0] == big.shape[0] \
                and small.shape[1] == 1:
            # (L, 1, ...) -> write into (L, slots, ...) at [*, slot]
            if big.ndim >= 4 and small.ndim == big.ndim \
                    and small.shape[-2] != big.shape[-2]:
                # seq axis shorter in prefill: pad to max_len
                pad = [(0, 0)] * small.ndim
                pad[-2] = (0, big.shape[-2] - small.shape[-2])
                small = jnp.pad(small, pad)
            return jax.lax.dynamic_update_slice(
                big, small.astype(big.dtype),
                (0, slot) + (0,) * (big.ndim - 2))
        if small.shape[0] == 1 and big.ndim == small.ndim:
            # (1, ...) leaves without layer dim (hymba singleton layers)
            if big.ndim >= 3 and small.shape[-2] != big.shape[-2]:
                pad = [(0, 0)] * small.ndim
                pad[-2] = (0, big.shape[-2] - small.shape[-2])
                small = jnp.pad(small, pad)
            return jax.lax.dynamic_update_slice(
                big, small.astype(big.dtype), (slot,) + (0,) * (big.ndim - 1))
        raise ValueError(f"cannot merge {small.shape} into {big.shape}")

    return jax.tree.map(merge, cache, prefill_cache)
