"""Analytical step-cost model for LLM serving on TPU-class chips.

One implementation shared by (a) the discrete-event cluster simulator that
the Scepsy profiler replays traces through, and (b) the §Roofline report —
so scheduling decisions and the roofline are mutually consistent
(DESIGN.md decision 6).

Every step time is the classic three-term roofline:

    t = max(FLOPs / (chips·peak·eff), bytes / (chips·bw·eff)) + t_collective

with TP collectives modeled explicitly (2 all-reduces per layer, ring
over the `model` axis inside one ICI domain).

Every public cost function takes a keyword-only ``chip`` — a
:class:`repro.hw.ChipClass` supplying the roofline constants and
efficiency knobs.  ``chip=None`` means ``hw.DEFAULT_CHIP_CLASS`` (the
v5e-class part), which reproduces the legacy uniform-cluster numbers
bit-for-bit.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from repro import hw
from repro.configs.base import ArchConfig
from repro.hw import ChipClass

BYTES_PER_PARAM = 2  # bf16 weights
KV_BYTES = 2  # bf16 cache


@dataclass(frozen=True)
class StepCost:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def total(self) -> float:
        return max(self.compute_s, self.memory_s) + self.collective_s

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)


# Per-config derived constants, memoized by object identity (hashing a
# ~30-field frozen dataclass per call is slower than the loops it would
# replace; the entry holds a strong ref to cfg so its id can't be
# recycled).  The model zoo is small, so the cache never grows large.
#
# The closed forms below are BIT-EXACT equivalents of the original
# per-layer accumulation loops: every per-layer term is an
# integer-valued float, so as long as the totals stay below 2**53 (they
# do by ~9 orders of magnitude for real configs) iterated addition and
# one multiplication produce the identical float.
_cfg_cache: dict = {}


def _cfg_consts(cfg: ArchConfig) -> tuple:
    """(cfg, active_params, params, n_full_attn_layers)"""
    entry = _cfg_cache.get(id(cfg))
    if entry is None:
        n_full = (sum(1 for layer in cfg.full_attn_layers
                      if 0 <= layer < cfg.num_layers)
                  if (cfg.sliding_window and cfg.full_attn_layers)
                  else cfg.num_layers)
        entry = (cfg, cfg.active_param_count(), cfg.param_count(), n_full)
        _cfg_cache[id(cfg)] = entry
    return entry


def _span_sum(cfg: ArchConfig, n_full: int, context: int) -> float:
    """Sum over layers of each layer's attention span at ``context``."""
    if cfg.sliding_window and cfg.full_attn_layers:
        return (n_full * context
                + (cfg.num_layers - n_full) * min(context,
                                                  cfg.sliding_window))
    return cfg.num_layers * context


def flops_per_token(cfg: ArchConfig, context: int) -> float:
    """Forward FLOPs for one token at the given attention context length."""
    _, active_params, _, n_full = _cfg_consts(cfg)
    base = 2.0 * active_params
    if cfg.attn_free:
        # WKV state update+readout: ~4*D ops per channel per token
        return base + 4.0 * cfg.num_layers * cfg.d_model * cfg.head_dim
    attn = 4.0 * cfg.num_heads * cfg.head_dim * _span_sum(cfg, n_full,
                                                          context)
    if cfg.ssm_state and not cfg.attn_free:  # hymba mamba heads
        attn += 6.0 * cfg.num_layers * cfg.q_dim * cfg.ssm_state
    return base + attn


def kv_bytes_per_seq(cfg: ArchConfig, context: int) -> float:
    """KV-cache bytes held (and streamed per decode step) for one sequence."""
    if cfg.attn_free:
        return (cfg.num_layers * cfg.num_heads * cfg.head_dim ** 2 * 4
                + 2 * cfg.num_layers * cfg.d_model * KV_BYTES)
    _, _, _, n_full = _cfg_consts(cfg)
    per_layer = 2 * cfg.kv_dim * KV_BYTES
    total = float(per_layer) * _span_sum(cfg, n_full, context)
    if cfg.ssm_state and not cfg.attn_free:
        total += cfg.num_layers * cfg.q_dim * cfg.ssm_state * 4
    return total


def model_bytes(cfg: ArchConfig) -> float:
    return _cfg_consts(cfg)[2] * BYTES_PER_PARAM


def tp_collective_time(cfg: ArchConfig, tokens: int, tp: int, *,
                       chip: Optional[ChipClass] = None) -> float:
    """2 ring all-reduces of (tokens, d_model) bf16 per layer over TP."""
    if tp <= 1:
        return 0.0
    chip = chip or hw.DEFAULT_CHIP_CLASS
    payload = tokens * cfg.d_model * BYTES_PER_PARAM
    ring = 2.0 * (tp - 1) / tp * payload / chip.ici_link_bw
    n_coll = 2 * (cfg.num_layers + cfg.encoder_layers)
    return n_coll * (ring + chip.collective_latency)


def prefill_cost(cfg: ArchConfig, prompt_tokens: int, *, tp: int = 1,
                 fraction: float = 1.0, cached_tokens: int = 0,
                 chip: Optional[ChipClass] = None) -> StepCost:
    """Cost of prefilling one sequence (processed as one chunked pass)."""
    chip = chip or hw.DEFAULT_CHIP_CLASS
    new = max(prompt_tokens - cached_tokens, 1)
    # attention span grows with position; integrate: avg span ~ prompt/2
    flops = 0.0
    avg_ctx = cached_tokens + new / 2
    flops = new * flops_per_token(cfg, int(avg_ctx))
    compute = flops / (tp * fraction * chip.peak_flops_bf16
                       * chip.mxu_efficiency)
    # prefill is compute-bound; weight reads amortize over tokens
    bytes_ = model_bytes(cfg) / max(new / 256.0, 1.0)
    memory = bytes_ / (tp * fraction * chip.hbm_bw * chip.hbm_efficiency)
    coll = tp_collective_time(cfg, new, tp, chip=chip)
    return StepCost(compute, memory, coll)


def decode_step_cost(cfg: ArchConfig, batch: int, avg_context: int, *,
                     tp: int = 1, fraction: float = 1.0,
                     chip: Optional[ChipClass] = None) -> StepCost:
    """Cost of one engine iteration decoding ``batch`` sequences."""
    chip = chip or hw.DEFAULT_CHIP_CLASS
    batch = max(batch, 1)
    flops = batch * flops_per_token(cfg, avg_context)
    compute = flops / (tp * fraction * chip.peak_flops_bf16
                       * chip.mxu_efficiency)
    bytes_ = (model_bytes(cfg)
              + batch * kv_bytes_per_seq(cfg, avg_context))
    memory = bytes_ / (tp * fraction * chip.hbm_bw * chip.hbm_efficiency)
    coll = tp_collective_time(cfg, batch, tp, chip=chip)
    return StepCost(compute, memory, coll)


def max_batch_size(cfg: ArchConfig, avg_context: int, *, tp: int = 1,
                   fraction: float = 1.0, headroom: float = 0.9,
                   chip: Optional[ChipClass] = None) -> int:
    """KV-capacity-limited max concurrent sequences per replica."""
    chip = chip or hw.DEFAULT_CHIP_CLASS
    budget = tp * fraction * chip.hbm_bytes * headroom - model_bytes(cfg)
    if budget <= 0:
        return 0
    per_seq = kv_bytes_per_seq(cfg, avg_context)
    return max(int(budget / max(per_seq, 1.0)), 0)


def min_fraction_units(cfg: ArchConfig, spec, avg_context: int = 2048,
                       min_seqs: int = 1,
                       chip: Optional[ChipClass] = None) -> int:
    """Minimum GPU-fraction units to load params + a minimal KV cache
    (the scheduler's per-LLM lower bound, paper §5)."""
    chip = chip or hw.DEFAULT_CHIP_CLASS
    need = (model_bytes(cfg)
            + min_seqs * kv_bytes_per_seq(cfg, avg_context)) / 0.9
    units = math.ceil(need / chip.hbm_bytes * spec.fractions_per_chip)
    return max(units, 1)


def fits_on_class(cfg: ArchConfig, chip: ChipClass, *, max_tp: int = 1,
                  avg_context: int = 2048) -> bool:
    """Whether the model fits (params + one sequence's KV) on ``chip``
    at some TP degree up to ``max_tp``."""
    need = (model_bytes(cfg) + kv_bytes_per_seq(cfg, avg_context)) / 0.9
    return need <= max_tp * chip.hbm_bytes


def swap_cost(cfg: ArchConfig, *, chip: Optional[ChipClass] = None) -> float:
    """Model-swap (weight reload) time — Aegaeon baseline overhead."""
    chip = chip or hw.DEFAULT_CHIP_CLASS
    return model_bytes(cfg) / chip.host_to_hbm_bw
