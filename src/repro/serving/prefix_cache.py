"""Host-side radix-style prefix cache (token-id trie).

Maps token prefixes to (slot, length) of a sequence whose KV covers that
prefix; the engine copies the prefix KV instead of recomputing prefill.
Eviction is LRU over leaves.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple


@dataclass
class _Node:
    children: Dict[int, "_Node"] = field(default_factory=dict)
    slot: Optional[int] = None  # slot whose cache covers the path to here
    depth: int = 0
    stamp: int = 0


class PrefixCache:
    def __init__(self, max_entries: int = 1024):
        self.root = _Node()
        self.max_entries = max_entries
        self.entries = 0
        self.clock = 0

    def insert(self, tokens: Sequence[int], slot: int) -> None:
        self.clock += 1
        node = self.root
        for t in tokens:
            if t not in node.children:
                node.children[t] = _Node(depth=node.depth + 1)
                self.entries += 1
            node = node.children[t]
            node.stamp = self.clock
        node.slot = slot
        if self.entries > self.max_entries:
            self._evict()

    def longest_prefix(self, tokens: Sequence[int]) -> Tuple[int, Optional[int]]:
        """Returns (matched_length, slot) of the deepest cached ancestor."""
        self.clock += 1
        node = self.root
        best = (0, None)
        for t in tokens:
            nxt = node.children.get(t)
            if nxt is None:
                break
            node = nxt
            node.stamp = self.clock
            if node.slot is not None:
                best = (node.depth, node.slot)
        return best

    def invalidate_slot(self, slot: int) -> None:
        def walk(n: _Node):
            if n.slot == slot:
                n.slot = None
            for c in n.children.values():
                walk(c)

        walk(self.root)

    def _evict(self) -> None:
        # drop the oldest leaf chain
        def oldest_leaf(n: _Node, path):
            if not n.children:
                return (n.stamp, path)
            return min((oldest_leaf(c, path + [t])
                        for t, c in n.children.items()),
                       key=lambda x: x[0])

        _, path = oldest_leaf(self.root, [])
        if not path:
            return
        node = self.root
        for t in path[:-1]:
            node = node.children[t]
        node.children.pop(path[-1], None)
        self.entries -= 1
