"""Host-side radix-style prefix cache (token-id trie).

Maps token prefixes to (slot, length) of a sequence whose KV covers that
prefix; the engine copies the prefix KV instead of recomputing prefill.
Eviction is LRU over leaf chains and runs in a loop until the trie is
back under ``max_entries`` (one ``insert`` may add one node per token).
``invalidate_slot`` prunes dead slotless chains so the trie never
accumulates unreachable nodes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple


@dataclass
class _Node:
    children: Dict[int, "_Node"] = field(default_factory=dict)
    slot: Optional[int] = None  # slot whose cache covers the path to here
    depth: int = 0
    stamp: int = 0


class PrefixCache:
    def __init__(self, max_entries: int = 1024):
        self.root = _Node()
        self.max_entries = max_entries
        self.entries = 0
        self.clock = 0

    def insert(self, tokens: Sequence[int], slot: int) -> None:
        self.clock += 1
        node = self.root
        fresh = []  # nodes created by THIS insert (never evicted below)
        for t in tokens:
            if t not in node.children:
                node.children[t] = _Node(depth=node.depth + 1)
                self.entries += 1
                fresh.append(node.children[t])
            node = node.children[t]
            node.stamp = self.clock
        node.slot = slot
        protect = set(map(id, fresh))
        while self.entries > self.max_entries:
            if not self._evict(protect):
                break  # only the just-inserted chain remains

    def longest_prefix(self, tokens: Sequence[int]) -> Tuple[int, Optional[int]]:
        """Returns (matched_length, slot) of the deepest cached ancestor."""
        self.clock += 1
        node = self.root
        best = (0, None)
        for t in tokens:
            nxt = node.children.get(t)
            if nxt is None:
                break
            node = nxt
            node.stamp = self.clock
            if node.slot is not None:
                best = (node.depth, node.slot)
        return best

    def invalidate_slot(self, slot: int) -> None:
        """Forget every entry backed by ``slot`` and prune the now-dead
        chains: a childless node with no slot serves no lookup and would
        otherwise live in the trie (and count against ``entries``)
        forever."""

        def walk(n: _Node) -> bool:
            """Returns True when ``n`` is prunable after the sweep."""
            if n.slot == slot:
                n.slot = None
            for t in list(n.children):
                if walk(n.children[t]):
                    del n.children[t]
                    self.entries -= 1
            return not n.children and n.slot is None and n is not self.root

        walk(self.root)

    def _evict(self, protect=frozenset()) -> bool:
        """Drop the oldest evictable leaf and its exclusive (childless
        once the leaf is gone, slotless) ancestor chain.  Returns False
        when nothing outside ``protect`` can be evicted."""

        def oldest_leaf(n: _Node, path):
            if not n.children:
                stamp = n.stamp if id(n) not in protect else float("inf")
                return (stamp, path)
            return min((oldest_leaf(c, path + [t])
                        for t, c in n.children.items()),
                       key=lambda x: x[0])

        stamp, path = oldest_leaf(self.root, [])
        if not path or stamp == float("inf"):
            return False
        # walk down recording the chain, then prune from the leaf up
        chain = [self.root]
        for t in path:
            chain.append(chain[-1].children[t])
        for i in range(len(path), 0, -1):
            node, parent = chain[i], chain[i - 1]
            if node.children or id(node) in protect:
                break
            del parent.children[path[i - 1]]
            self.entries -= 1
            if parent.slot is not None or parent is self.root:
                break
        return True
