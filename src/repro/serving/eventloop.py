"""Discrete-event loop for the cluster simulator — million-event scale.

Two interchangeable engines behind one API (``EventLoop(kind=...)``):

``calendar`` (default)
    A calendar queue (Brown 1988): a wheel of time buckets of width
    ``w`` covering one revolution ``[day, day + nbuckets)`` of bucket
    numbers (``bucket_no = floor(t / w)``), plus a binary-heap
    *overflow* for events beyond the revolution horizon.  Scheduling an
    event inside the horizon is an O(1) list append; popping sorts one
    bucket at a time (amortized O(1) per event for stable event
    densities).  As the wheel advances into new bucket numbers, due
    overflow events are drained into the wheel, so far-future events
    (e.g. a drain horizon or a calibration callback hours ahead) never
    slow the hot path.  The wheel *resizes itself*: the width tracks an
    EWMA of observed inter-event gaps and the bucket count tracks the
    pending-event population, with an O(n) rebuild whenever either is
    off by ~4x — n is the *pending* count, which lazy arrival sources
    keep O(in-flight), so rebuilds are cheap and rare.

``heap``
    The legacy binary heap (`heapq` over ``(t, seq, fn, args)``), kept
    for parity tests and as the measured baseline in
    ``benchmarks/bench_scale.py``.

Both engines pop events in exactly ``(t, seq)`` order, where ``seq`` is
the global schedule counter — so same-timestamp events run in FIFO
schedule order and the two engines produce *identical* execution traces
(gated by ``tests/test_event_core.py``).

Events are closure-free: ``schedule(t, fn, *args)`` stores the callable
and its argument tuple directly (one small tuple per event, no lambda
allocation); plain ``schedule(t, fn)`` still accepts any thunk, so
legacy call sites keep working.
"""
from __future__ import annotations

import heapq
import math
from typing import Callable, List, Optional, Tuple

_Event = Tuple[float, int, Callable, tuple]

# wheel sizing defaults (see docs/scale.md for the model behind them)
_MIN_BUCKETS = 256
_MAX_BUCKETS = 1 << 16
_TARGET_PER_BUCKET = 2.0  # aim for ~2 pending events per bucket
_RESIZE_FACTOR = 4.0      # rebuild when width/count are off by >= 4x
_RESIZE_CHECK = 4096      # pops between resize checks
_GAP_ALPHA = 0.01         # EWMA weight for the inter-event gap estimate


class EventLoop:
    """Simulation clock + pending-event queue.

    ``schedule(t, fn, *args)`` enqueues ``fn(*args)`` at simulated time
    ``max(t, now)``; ``run(until)`` pops events in ``(t, seq)`` order.
    ``kind`` selects the engine: ``"calendar"`` (default) or ``"heap"``
    (the legacy binary heap, kept for parity tests / baselines).

    Counters: ``events_processed`` (total pops), ``pending`` (events
    queued now), ``peak_pending`` (high-water mark — the memory gate in
    ``bench_scale`` asserts this stays O(in-flight), not O(total)).
    """

    def __init__(self, kind: str = "calendar", *,
                 bucket_width: Optional[float] = None,
                 nbuckets: int = _MIN_BUCKETS):
        if kind not in ("calendar", "heap"):
            raise ValueError(f"unknown EventLoop kind {kind!r}")
        self.kind = kind
        self.now = 0.0
        self._seq = 0
        self.events_processed = 0
        self.pending = 0
        self.peak_pending = 0
        # heap engine state
        self._heap: List[_Event] = []
        # calendar engine state
        self._width = bucket_width if bucket_width else 1e-3
        self._width_fixed = bucket_width is not None
        self._nbuckets = max(int(nbuckets), 1)
        self._buckets: List[List[_Event]] = [[] for _ in range(self._nbuckets)]
        self._day = 0               # bucket_no currently being consumed
        self._active: List[_Event] = []  # current bucket, heapified
        self._overflow: List[_Event] = []  # beyond-horizon events
        self._wheel_count = 0       # events in buckets + active
        self._gap_est: Optional[float] = None
        self._last_t = 0.0
        self._since_check = 0

    # -- public API --------------------------------------------------------

    def schedule(self, t: float, fn: Callable, *args) -> None:
        """Enqueue ``fn(*args)`` at time ``max(t, now)``."""
        t = t if t > self.now else self.now
        seq = self._seq
        self._seq = seq + 1
        self.pending += 1
        if self.pending > self.peak_pending:
            self.peak_pending = self.pending
        ev = (t, seq, fn, args)
        if self.kind == "heap":
            heapq.heappush(self._heap, ev)
            return
        b_no = int(t / self._width)
        if b_no < self._day:          # float-boundary guard
            b_no = self._day
        if b_no == self._day:
            heapq.heappush(self._active, ev)
            self._wheel_count += 1
        elif b_no < self._day + self._nbuckets:
            self._buckets[b_no % self._nbuckets].append(ev)
            self._wheel_count += 1
        else:
            heapq.heappush(self._overflow, ev)

    def run(self, until: float = math.inf) -> None:
        """Execute pending events with ``t <= until`` in (t, seq) order."""
        if self.kind == "heap":
            heap = self._heap
            while heap and heap[0][0] <= until:
                t, _, fn, args = heapq.heappop(heap)
                self.pending -= 1
                self.events_processed += 1
                self.now = t
                fn(*args)
            return
        while True:
            ev = self._peek()
            if ev is None or ev[0] > until:
                return
            heapq.heappop(self._active)
            self._wheel_count -= 1
            self.pending -= 1
            self.events_processed += 1
            t, _, fn, args = ev
            # update the gap estimate driving adaptive bucket width
            gap = t - self._last_t
            if gap > 0.0:
                g = self._gap_est
                self._gap_est = gap if g is None else g + _GAP_ALPHA * (gap - g)
            self._last_t = t
            self._since_check += 1
            if self._since_check >= _RESIZE_CHECK:
                self._since_check = 0
                self._maybe_resize()
            self.now = t
            fn(*args)

    def empty(self) -> bool:
        return self.pending == 0

    def peek_time(self) -> Optional[float]:
        """Timestamp of the next pending event, or None when empty."""
        if self.kind == "heap":
            return self._heap[0][0] if self._heap else None
        ev = self._peek()
        return ev[0] if ev is not None else None

    # -- calendar internals ------------------------------------------------

    def _peek(self) -> Optional[_Event]:
        """Earliest pending event (left in place), or None."""
        while True:
            if self._active:
                return self._active[0]
            if self._wheel_count == 0:
                if not self._overflow:
                    return None
                # wheel empty: jump straight to the next overflow event
                self._day = int(self._overflow[0][0] / self._width)
            else:
                self._day += 1
            self._admit_overflow()
            slot = self._buckets[self._day % self._nbuckets]
            if slot:
                self._buckets[self._day % self._nbuckets] = []
                heapq.heapify(slot)
                self._active = slot

    def _admit_overflow(self) -> None:
        """Move overflow events that now fall inside the wheel horizon
        into their buckets (they stay heap-ordered until consumed)."""
        horizon_t = (self._day + self._nbuckets) * self._width
        ovf = self._overflow
        while ovf and ovf[0][0] < horizon_t:
            ev = heapq.heappop(ovf)
            b_no = int(ev[0] / self._width)
            if b_no < self._day:
                b_no = self._day
            if b_no == self._day and self._active:
                heapq.heappush(self._active, ev)
            else:
                self._buckets[b_no % self._nbuckets].append(ev)
            self._wheel_count += 1

    def _maybe_resize(self) -> None:
        """Rebuild the wheel when the width has drifted >= 4x from the
        observed inter-event gap or the bucket count is badly sized for
        the pending population.  O(pending), amortized over
        ``_RESIZE_CHECK`` pops."""
        target_w = self._width
        if not self._width_fixed and self._gap_est is not None:
            target_w = max(self._gap_est * _TARGET_PER_BUCKET, 1e-12)
        target_n = min(max(_MIN_BUCKETS, 1 << max(self.pending, 1).bit_length()),
                       _MAX_BUCKETS)
        width_off = (max(target_w, self._width) / max(min(target_w, self._width), 1e-300)
                     >= _RESIZE_FACTOR)
        count_off = (max(target_n, self._nbuckets)
                     >= _RESIZE_FACTOR * min(target_n, self._nbuckets))
        if not (width_off or count_off):
            return
        events: List[_Event] = list(self._active) + list(self._overflow)
        for slot in self._buckets:
            events.extend(slot)
        self._width = target_w
        self._nbuckets = target_n
        self._buckets = [[] for _ in range(self._nbuckets)]
        self._active = []
        self._overflow = []
        self._wheel_count = 0
        self._day = int(self.now / self._width)
        for t, seq, fn, args in events:
            b_no = int(t / self._width)
            if b_no < self._day:
                b_no = self._day
            if b_no < self._day + self._nbuckets:
                self._buckets[b_no % self._nbuckets].append((t, seq, fn, args))
                self._wheel_count += 1
            else:
                heapq.heappush(self._overflow, (t, seq, fn, args))
        # re-enter the current bucket so _peek resumes correctly
        slot = self._buckets[self._day % self._nbuckets]
        if slot:
            self._buckets[self._day % self._nbuckets] = []
            heapq.heapify(slot)
            self._active = slot
