"""Bridge: Scepsy scheduler output -> simulated serving deployment.

Two shapes: per-workflow private replicas (partitioned fleet, one Router
per workflow-local LLM name), and pooled tenants (one shared replica set
per canonical model, each workflow holding a weighted routing view into
it).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs.base import ArchConfig
from repro.core.pipeline import Allocation
from repro.serving.simulator import EngineSim, EventLoop, Router
from repro.workflows.runtime import Workflow


def routers_from_allocations(wf: Workflow, allocations: Dict[str, Allocation],
                             loop: EventLoop, *, prefix_caching: bool = True,
                             avg_context: int = 1024) -> Dict[str, Router]:
    routers: Dict[str, Router] = {}
    for llm, alloc in allocations.items():
        cfg = wf.llms[llm]
        engines = [
            EngineSim(cfg, loop, tp=alloc.tp, fraction=alloc.fraction,
                      name=f"{llm}/{r}", prefix_caching=prefix_caching,
                      avg_context=avg_context)
            for r in range(alloc.replicas)
        ]
        routers[llm] = Router(engines)
    return routers


def tenant_routers(allocations: Dict[str, Allocation],
                   cfgs: Dict[str, ArchConfig], loop: EventLoop, *,
                   prefix_caching: bool = True,
                   avg_context: int = 1024) -> Dict[str, Router]:
    """One shared Router per tenant (canonical model id)."""
    routers: Dict[str, Router] = {}
    for cid, alloc in allocations.items():
        engines = [
            EngineSim(cfgs[cid], loop, tp=alloc.tp, fraction=alloc.fraction,
                      name=f"{cid}/{r}", prefix_caching=prefix_caching,
                      avg_context=avg_context)
            for r in range(alloc.replicas)
        ]
        routers[cid] = Router(engines)
    return routers


def pooled_fleet_routers(
        tenants: Dict[str, Router],
        members: Dict[str, List[Tuple[str, str]]],
        routing: Dict[str, Dict[str, Dict[int, float]]],
) -> Dict[str, Dict[str, Router]]:
    """Per-workflow router dicts over *shared* tenant replicas.

    ``members`` maps canonical id -> [(workflow, local llm name)];
    ``routing`` is each workflow's routing table (local llm -> replica
    index -> weight).  The returned dict is keyed workflow -> local llm
    name -> weighted Router view, directly usable as a ClusterDriver's
    ``routers``.
    """
    out: Dict[str, Dict[str, Router]] = {}
    for cid, mem in members.items():
        base = tenants[cid]
        for workflow, llm in mem:
            weights = routing.get(workflow, {}).get(llm)
            view = base.view(weights) if weights is not None else base
            out.setdefault(workflow, {})[llm] = view
    return out


def rebalance_pooled_drivers(drivers, tenants: Dict[str, Router],
                             members: Dict[str, List[Tuple[str, str]]],
                             routing: Dict[str, Dict[str, Dict[int, float]]]
                             ) -> None:
    """Apply a rung-1 routing rebalance to *live* drivers.

    Swaps each driver's router dict for fresh weighted views over the
    SAME engine replicas — queues, KV caches and in-flight requests are
    untouched, which is exactly what "no re-placement" means.  Safe to
    call from a scheduled event mid-simulation.
    """
    per_wf = pooled_fleet_routers(tenants, members, routing)
    for name, drv in drivers.items():
        if name in per_wf:
            drv.routers = per_wf[name]
