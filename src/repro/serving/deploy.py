"""Bridge: Scepsy scheduler output -> simulated serving deployment."""
from __future__ import annotations

from typing import Dict, Optional

from repro.core.pipeline import Allocation
from repro.serving.simulator import EngineSim, EventLoop, Router
from repro.workflows.runtime import Workflow


def routers_from_allocations(wf: Workflow, allocations: Dict[str, Allocation],
                             loop: EventLoop, *, prefix_caching: bool = True,
                             avg_context: int = 1024) -> Dict[str, Router]:
    routers: Dict[str, Router] = {}
    for llm, alloc in allocations.items():
        cfg = wf.llms[llm]
        engines = [
            EngineSim(cfg, loop, tp=alloc.tp, fraction=alloc.fraction,
                      name=f"{llm}/{r}", prefix_caching=prefix_caching,
                      avg_context=avg_context)
            for r in range(alloc.replicas)
        ]
        routers[llm] = Router(engines)
    return routers
