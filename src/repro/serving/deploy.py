"""Bridge: Scepsy scheduler output -> simulated serving deployment.

Two shapes: per-workflow private replicas (partitioned fleet, one Router
per workflow-local LLM name), and pooled tenants (one shared replica set
per canonical model, each workflow holding a weighted routing view into
it).

Every builder accepts a queue ``discipline`` (``fifo`` | ``priority`` |
``wfq``, see :mod:`repro.qos.policy`): each engine replica gets its own
discipline instance, and in pooled ``wfq`` mode the per-replica tenant
weights are derived from the fleet's routing tables so deficit-round-
robin hands each workflow its routing-weight share of the replica.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro import hw
from repro.configs.base import ArchConfig
from repro.core.pipeline import Allocation
from repro.qos.policy import make_policy
from repro.serving.simulator import EngineSim, EventLoop, Router
from repro.workflows.runtime import Workflow


def _alloc_chip(alloc: Allocation):
    """The hw.ChipClass an allocation is bound to (None = default)."""
    cc = getattr(alloc, "chip_class", None)
    return hw.chip_class(cc) if cc else None


def routers_from_allocations(wf: Workflow, allocations: Dict[str, Allocation],
                             loop: EventLoop, *, prefix_caching: bool = True,
                             avg_context: int = 1024,
                             discipline: str = "fifo",
                             preemption: bool = False) -> Dict[str, Router]:
    routers: Dict[str, Router] = {}
    for llm, alloc in allocations.items():
        cfg = wf.llms[llm]
        engines = [
            EngineSim(cfg, loop, tp=alloc.tp, fraction=alloc.fraction,
                      name=f"{llm}/{r}", prefix_caching=prefix_caching,
                      avg_context=avg_context,
                      policy=make_policy(discipline),
                      preemption=preemption, chip=_alloc_chip(alloc))
            for r in range(alloc.replicas)
        ]
        routers[llm] = Router(engines)
    return routers


def fleet_routers_from_placement(
        wfs: Dict[str, "Workflow"], placement,
        loop: EventLoop, *, prefix_caching: bool = True,
        avg_context: int = 1024,
        discipline: str = "fifo",
        preemption: bool = False) -> Dict[str, Dict[str, Router]]:
    """Per-workflow routers over a co-placed partitioned fleet.

    ``placement`` is a global ``workflow/llm``-keyed
    :class:`~repro.core.placement.Placement` (from
    :func:`~repro.core.placement.place_fleet` or a fleet deployment's
    ``fleet_placement``): one :class:`EngineSim` is built per placed
    instance with the instance's own TP degree and chip fraction, so
    the simulated replica set is exactly what the placement says is on
    the cluster.  Returned dict is keyed workflow -> local llm name ->
    Router, directly usable as a ClusterDriver's ``routers``.
    """
    F = placement.spec.fractions_per_chip
    table = placement.spec.chip_table()
    groups: Dict[Tuple[str, str], List[EngineSim]] = {}
    for inst in placement.instances:
        wf_name, _, llm = inst.llm.partition("/")
        cfg = wfs[wf_name].llms[llm]
        # each replica runs at the class of the chip it actually landed
        # on — for class-bound instances that is the binding; for
        # unbound instances on a heterogeneous cluster it is whatever
        # the packer picked (the class-blind penalty is real)
        cc = getattr(inst, "chip_class", None)
        if cc is None and inst.chips and inst.chips[0] < len(table):
            cc = table[inst.chips[0]][2]
        groups.setdefault((wf_name, llm), []).append(
            EngineSim(cfg, loop, tp=inst.tp,
                      fraction=inst.units_per_chip / F,
                      name=f"{inst.llm}-r{inst.replica}",
                      prefix_caching=prefix_caching,
                      avg_context=avg_context,
                      policy=make_policy(discipline),
                      preemption=preemption,
                      chip=hw.chip_class(cc) if cc else None))
    out: Dict[str, Dict[str, Router]] = {}
    for (wf_name, llm), engines in groups.items():
        out.setdefault(wf_name, {})[llm] = Router(engines)
    return out


def wfq_replica_weights(members: Dict[str, List[Tuple[str, str]]],
                        routing: Dict[str, Dict[str, Dict[int, float]]]
                        ) -> Dict[str, Dict[int, Dict[str, float]]]:
    """Per-replica tenant weights from the fleet's routing tables:
    canonical id -> replica index -> {workflow: weight}.  A workflow
    pointing several local stages at one tenant contributes the sum of
    those stages' weights on each replica."""
    out: Dict[str, Dict[int, Dict[str, float]]] = {}
    for cid, mem in members.items():
        per_replica: Dict[int, Dict[str, float]] = {}
        for workflow, llm in mem:
            for r, w in routing.get(workflow, {}).get(llm, {}).items():
                if w <= 0:
                    continue
                row = per_replica.setdefault(r, {})
                row[workflow] = row.get(workflow, 0.0) + w
        out[cid] = per_replica
    return out


def tenant_routers(allocations: Dict[str, Allocation],
                   cfgs: Dict[str, ArchConfig], loop: EventLoop, *,
                   prefix_caching: bool = True,
                   avg_context: int = 1024,
                   discipline: str = "fifo",
                   preemption: bool = False,
                   members: Optional[Dict[str, List[Tuple[str, str]]]] = None,
                   routing: Optional[Dict[str, Dict[str, Dict[int, float]]]] = None
                   ) -> Dict[str, Router]:
    """One shared Router per tenant (canonical model id).

    In ``wfq`` mode, pass the pooled schedule's ``members`` and
    ``routing`` so each replica's deficit-round-robin weights match the
    workflows' routing-weight shares of that replica.
    """
    wfq_weights: Dict[str, Dict[int, Dict[str, float]]] = {}
    if discipline == "wfq" and members is not None and routing is not None:
        wfq_weights = wfq_replica_weights(members, routing)
    routers: Dict[str, Router] = {}
    for cid, alloc in allocations.items():
        engines = [
            EngineSim(cfgs[cid], loop, tp=alloc.tp, fraction=alloc.fraction,
                      name=f"{cid}/{r}", prefix_caching=prefix_caching,
                      avg_context=avg_context,
                      policy=make_policy(
                          discipline,
                          weights=wfq_weights.get(cid, {}).get(r)),
                      preemption=preemption, chip=_alloc_chip(alloc))
            for r in range(alloc.replicas)
        ]
        routers[cid] = Router(engines)
    return routers


def pooled_fleet_routers(
        tenants: Dict[str, Router],
        members: Dict[str, List[Tuple[str, str]]],
        routing: Dict[str, Dict[str, Dict[int, float]]],
) -> Dict[str, Dict[str, Router]]:
    """Per-workflow router dicts over *shared* tenant replicas.

    ``members`` maps canonical id -> [(workflow, local llm name)];
    ``routing`` is each workflow's routing table (local llm -> replica
    index -> weight).  The returned dict is keyed workflow -> local llm
    name -> weighted Router view, directly usable as a ClusterDriver's
    ``routers``.
    """
    out: Dict[str, Dict[str, Router]] = {}
    for cid, mem in members.items():
        base = tenants[cid]
        for workflow, llm in mem:
            weights = routing.get(workflow, {}).get(llm)
            view = base.view(weights) if weights is not None else base
            out.setdefault(workflow, {})[llm] = view
    return out


def rebalance_pooled_drivers(drivers, tenants: Dict[str, Router],
                             members: Dict[str, List[Tuple[str, str]]],
                             routing: Dict[str, Dict[str, Dict[int, float]]]
                             ) -> None:
    """Apply a rung-1 routing rebalance to *live* drivers.

    Swaps each driver's router dict for fresh weighted views over the
    SAME engine replicas — queues, KV caches and in-flight requests are
    untouched, which is exactly what "no re-placement" means.  Safe to
    call from a scheduled event mid-simulation.
    """
    per_wf = pooled_fleet_routers(tenants, members, routing)
    for name, drv in drivers.items():
        if name in per_wf:
            # set_routers (not a bare attribute write) so the driver's
            # sticky-prune bookkeeping follows the new views: sessions
            # that end after the rebalance must still forget() their
            # sticky entries on the routers now recording them
            drv.set_routers(per_wf[name])
