"""Discrete-event simulation of LLM serving engines on a (fractional) TPU
cluster.

The container is CPU-only, so Scepsy's per-LLM profiling (paper §4 step 3)
replays traced requests through this simulator instead of a live vLLM
deployment; per-iteration costs come from the analytical roofline cost
model (`repro.serving.costmodel`) — the same model the §Roofline report
uses, so predictions and the roofline are consistent.

Fidelity notes (what is modeled):
  * continuous batching at iteration granularity with chunked prefill
    (Sarathi-style): each engine iteration admits waiting prefills up to a
    token budget and decodes the running batch; decode advances in quanta
    of ``decode_quantum`` tokens between scheduling points;
  * KV-capacity admission control (max concurrent sequences from HBM
    budget), queueing, and per-request latency accounting;
  * prefix caching: a request whose parent was served by the same replica
    skips prefill FLOPs for the shared prefix (radix-cache effect that
    dominates beam search);
  * fractional chip shares scale compute/bandwidth linearly (static
    MPS-like partitioning); TP scales per the cost model incl. collectives;
  * model swapping (for the Aegaeon-like baseline) pays the weight reload.
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro.configs.base import ArchConfig
from repro.serving import costmodel as cm


class EventLoop:
    def __init__(self):
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []
        self._counter = itertools.count()
        self.now = 0.0

    def schedule(self, t: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (max(t, self.now), next(self._counter), fn))

    def run(self, until: float = math.inf) -> None:
        while self._heap and self._heap[0][0] <= until:
            t, _, fn = heapq.heappop(self._heap)
            self.now = t
            fn()

    def empty(self) -> bool:
        return not self._heap


@dataclass
class EngineRequest:
    req_id: int
    prompt_tokens: int
    output_tokens: int
    arrival: float
    on_complete: Optional[Callable[["EngineRequest"], None]] = None
    parent_id: Optional[int] = None  # for prefix caching
    workflow_request: Optional[int] = None
    qos: Optional[object] = None  # repro.qos.slo.RequestQoS, duck-typed
    # filled by the engine:
    cached_prefix: int = 0
    t_start_service: float = -1.0
    t_first_token: float = -1.0
    t_done: float = -1.0
    remaining: int = 0

    @property
    def latency(self) -> float:
        return self.t_done - self.arrival


class EngineSim:
    """One serving-engine replica (one LLM, one TP group, one fraction).

    ``policy`` (a :class:`repro.qos.policy.QueueDiscipline`, duck-typed)
    reorders admission out of the waiting queue: it is asked which
    waiting request to admit next and charged the admitted request's
    token cost.  ``policy=None`` is the built-in FIFO fast path.
    """

    def __init__(self, cfg: ArchConfig, loop: EventLoop, *, tp: int = 1,
                 fraction: float = 1.0, name: str = "",
                 prefix_caching: bool = True, avg_context: int = 1024,
                 prefill_chunk: int = 2048, decode_quantum: int = 8,
                 max_batch_override: Optional[int] = None,
                 policy: Optional[object] = None):
        self.cfg = cfg
        self.policy = policy
        self.loop = loop
        self.tp = tp
        self.fraction = fraction
        self.name = name or cfg.name
        self.prefix_caching = prefix_caching
        self.prefill_chunk = prefill_chunk
        self.decode_quantum = decode_quantum
        mb = cm.max_batch_size(cfg, avg_context, tp=tp, fraction=fraction)
        self.max_batch = max_batch_override or max(min(mb, 256), 1)
        self.waiting: List[EngineRequest] = []
        self.running: List[EngineRequest] = []
        self.done: List[EngineRequest] = []
        self.busy = False
        self.busy_time = 0.0
        self._served: Dict[int, None] = {}  # request ids with live KV here
        self.current_model: Optional[str] = cfg.name  # for swap modeling
        self.swap_overhead_pending = 0.0
        self.failed = False

    # -- queue introspection (router) --
    @property
    def load(self) -> float:
        return (sum(r.remaining + r.prompt_tokens for r in self.waiting)
                + sum(r.remaining for r in self.running))

    def has_parent(self, parent_id: Optional[int]) -> bool:
        return parent_id is not None and parent_id in self._served

    # -- submission --
    def submit(self, req: EngineRequest) -> None:
        if self.prefix_caching and self.has_parent(req.parent_id):
            req.cached_prefix = min(int(req.prompt_tokens * 0.85),
                                    req.prompt_tokens - 1)
        req.remaining = req.output_tokens
        self.waiting.append(req)
        if not self.busy:
            self.busy = True
            self.loop.schedule(self.loop.now, self._iterate)

    def request_swap(self, seconds: float) -> None:
        self.swap_overhead_pending += seconds

    def fail(self, resubmit: Optional[Callable[[EngineRequest], None]] = None
             ) -> List[EngineRequest]:
        """Chip/host failure: drop this replica; in-flight work is lost
        (KV gone) and re-dispatched via ``resubmit`` (router failover)."""
        self.failed = True
        orphans = self.waiting + self.running
        self.waiting, self.running = [], []
        self._served.clear()
        for r in orphans:
            r.cached_prefix = 0  # KV lost; full prefill elsewhere
            r.remaining = r.output_tokens
            if resubmit is not None:
                resubmit(r)
        return orphans

    # -- engine loop --
    def _iterate(self) -> None:
        if self.failed or (not self.waiting and not self.running):
            self.busy = False
            return
        t0 = self.loop.now
        duration = 0.0
        if self.swap_overhead_pending > 0:
            duration += self.swap_overhead_pending
            self.swap_overhead_pending = 0.0

        # 1) admit prefills within chunk budget and batch capacity; the
        #    queue discipline picks which waiting request goes next
        budget = self.prefill_chunk
        admitted: List[EngineRequest] = []
        while (self.waiting and len(self.running) + len(admitted) < self.max_batch
               and budget > 0):
            idx = self.policy.select(self.waiting, t0) if self.policy else 0
            req = self.waiting[idx]
            new_tokens = req.prompt_tokens - req.cached_prefix
            if new_tokens > budget and admitted:
                break
            self.waiting.pop(idx)
            if self.policy:
                self.policy.on_admit(req, new_tokens + req.output_tokens)
            admitted.append(req)
            budget -= new_tokens
            cost = cm.prefill_cost(self.cfg, req.prompt_tokens, tp=self.tp,
                                   fraction=self.fraction,
                                   cached_tokens=req.cached_prefix)
            duration += cost.total
            req.t_start_service = t0

        # 2) decode quantum for the (new) running batch
        batch = self.running + admitted
        self.running = batch  # committed now so fail() can re-dispatch
        if batch:
            q = min(self.decode_quantum, min(r.remaining for r in batch))
            q = max(q, 1)
            ctx = sum(r.prompt_tokens + (r.output_tokens - r.remaining)
                      for r in batch) / len(batch)
            step = cm.decode_step_cost(self.cfg, len(batch), int(ctx),
                                       tp=self.tp, fraction=self.fraction)
            duration += q * step.total
            for r in batch:
                r.remaining -= q
                if r.t_first_token < 0:
                    r.t_first_token = t0 + duration

        t1 = t0 + max(duration, 1e-6)
        self.busy_time += t1 - t0

        def finish():
            if self.failed:  # iteration died with the chip; work was
                return       # already re-dispatched by fail()
            still: List[EngineRequest] = []
            for r in batch:
                if r.remaining <= 0:
                    r.t_done = t1
                    self.done.append(r)
                    self._served[r.req_id] = None
                    if r.on_complete:
                        r.on_complete(r)
                else:
                    still.append(r)
            self.running = still
            self._iterate()

        self.loop.schedule(t1, finish)


class Router:
    """KV-cache-aware + least-loaded routing across one LLM's replicas.

    ``weights`` (replica index -> weight) biases the least-loaded choice
    to the workflow's routing table in pooled multi-tenant deployments:
    a replica's effective load is load/weight, and zero-weight replicas
    are never chosen.  Several routers may *share* one replica list (one
    per tenant workflow — see :meth:`view`); queue state then reflects
    cross-workflow contention automatically.
    """

    def __init__(self, replicas: List[EngineSim], *, affinity: bool = True,
                 weights: Optional[Dict[int, float]] = None):
        assert replicas
        self.replicas = replicas
        self.affinity = affinity
        self.weights = weights

    def view(self, weights: Dict[int, float]) -> "Router":
        """A per-tenant view over the same physical replicas."""
        return Router(self.replicas, affinity=self.affinity, weights=weights)

    def _weight(self, idx: int) -> float:
        if self.weights is None:
            return 1.0
        return self.weights.get(idx, 0.0)

    def submit(self, req: EngineRequest) -> None:
        live = [(i, r) for i, r in enumerate(self.replicas)
                if not getattr(r, "failed", False) and self._weight(i) > 0]
        if not live:
            raise RuntimeError("no live replicas")
        target = None
        if self.affinity and req.parent_id is not None:
            for _, r in live:
                if r.has_parent(req.parent_id):
                    target = r
                    break
        if target is None:
            _, target = min(live, key=lambda ir: ir[1].load / self._weight(ir[0]))
        target.submit(req)

    def fail_replica(self, idx: int) -> None:
        """Kill one replica and re-dispatch its in-flight requests."""
        self.replicas[idx].fail(resubmit=self.submit)


@dataclass
class ReplicaSpec:
    """One deployed replica of an LLM (scheduler output, simulator input)."""

    llm: str
    cfg: ArchConfig
    tp: int = 1
    fraction: float = 1.0  # per-chip share (1.0 = whole chip(s))


def build_llm_service(specs: List[ReplicaSpec], loop: EventLoop, *,
                      prefix_caching: bool = True,
                      avg_context: int = 1024) -> Router:
    engines = [EngineSim(s.cfg, loop, tp=s.tp, fraction=s.fraction,
                         name=f"{s.llm}/{i}", prefix_caching=prefix_caching,
                         avg_context=avg_context)
               for i, s in enumerate(specs)]
    return Router(engines)
