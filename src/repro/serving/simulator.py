"""Discrete-event simulation of LLM serving engines on a (fractional) TPU
cluster.

The container is CPU-only, so Scepsy's per-LLM profiling (paper §4 step 3)
replays traced requests through this simulator instead of a live vLLM
deployment; per-iteration costs come from the analytical roofline cost
model (`repro.serving.costmodel`) — the same model the §Roofline report
uses, so predictions and the roofline are consistent.

Fidelity notes (what is modeled):
  * continuous batching at iteration granularity with chunked prefill
    (Sarathi-style): each engine iteration admits waiting prefills up to a
    token budget and decodes the running batch; decode advances in quanta
    of ``decode_quantum`` tokens between scheduling points;
  * KV-capacity admission control (max concurrent sequences from HBM
    budget), queueing, and per-request latency accounting;
  * prefix caching: requests carry their prompt's *segment sequence*
    (``EngineRequest.prefix``, see :mod:`repro.serving.radix`) and each
    replica tracks resident KV in a token-budgeted radix cache — the
    cached-prefix discount is the *measured* shared-prefix length, and
    evicted KV stops producing hits.  Requests without segments fall
    back to the legacy parent-id heuristic (85% of the prompt), bounded
    by the same KV budget via an LRU over completed requests;
  * QoS preemption (opt-in): at an iteration boundary, a waiting request
    of a strictly higher SLO weight may preempt a running lower-weight
    decode when the batch is full; the victim requeues with its decoded
    progress retained and its KV re-registered in the radix cache (so it
    is "retained" exactly while the budget keeps it resident);
  * fractional chip shares scale compute/bandwidth linearly (static
    MPS-like partitioning); TP scales per the cost model incl. collectives;
  * model swapping (for the Aegaeon-like baseline) pays the weight reload.
"""
from __future__ import annotations

import heapq
import math
from collections import OrderedDict
from dataclasses import dataclass
from functools import partial
from typing import Callable, Dict, Hashable, List, Optional, Set, Tuple

from repro import hw
from repro.configs.base import ArchConfig
from repro.serving import costmodel as cm
from repro.serving.eventloop import EventLoop
from repro.serving.radix import RadixCache, Segment

__all__ = ["EventLoop", "EngineRequest", "EngineSim", "Router",
           "ReplicaSpec", "build_llm_service", "output_segment"]


def output_segment(req_id: int, tokens: int) -> Segment:
    """The synthetic segment id for a request's generated output — the
    driver and the engine must agree on it so a child call's prompt
    segments match what the engine registered at the parent's finish."""
    return (("o", req_id), tokens)


@dataclass
class EngineRequest:
    req_id: int
    prompt_tokens: int
    output_tokens: int
    arrival: float
    on_complete: Optional[Callable[["EngineRequest"], None]] = None
    parent_id: Optional[int] = None  # legacy prefix-caching heuristic
    workflow_request: Optional[int] = None
    qos: Optional[object] = None  # repro.qos.slo.RequestQoS, duck-typed
    # token-accurate prefix model: the prompt as (segment id, length)
    # pairs (None = legacy heuristic path)
    prefix: Optional[Tuple[Segment, ...]] = None
    # driver-computed ground-truth shared-prefix tokens (bench gate)
    true_prefix: int = 0
    # filled by the engine:
    cached_prefix: int = 0
    t_start_service: float = -1.0
    t_first_token: float = -1.0
    t_done: float = -1.0
    remaining: int = 0
    progress: int = 0       # tokens already decoded (kept across preemption)
    preemptions: int = 0
    pinned_seq: Optional[Tuple[Segment, ...]] = None

    @property
    def latency(self) -> float:
        return self.t_done - self.arrival


def _qos_weight(req) -> float:
    """Effective preemption weight: best-effort (no QoS, degraded, or no
    deadline) sits at the BEST_EFFORT weight."""
    q = getattr(req, "qos", None)
    if q is None or q.degraded or not math.isfinite(q.deadline):
        return 0.5
    return q.weight


def _can_preempt(req) -> bool:
    """Only deadline-carrying, non-degraded requests may preempt."""
    q = getattr(req, "qos", None)
    return (q is not None and not q.degraded
            and math.isfinite(q.deadline))


class EngineSim:
    """One serving-engine replica (one LLM, one TP group, one fraction).

    ``policy`` (a :class:`repro.qos.policy.QueueDiscipline`, duck-typed)
    reorders admission out of the waiting queue: it is asked which
    waiting request to admit next and charged the admitted request's
    token cost.  ``policy=None`` is the built-in FIFO fast path.

    ``preemption=True`` additionally lets the head-of-queue request (per
    the discipline) preempt a strictly-lower-weight running decode when
    the batch is full; every event is logged in ``preempt_log`` as
    ``(preemptor_weight, victim_weight, time)``.
    """

    def __init__(self, cfg: ArchConfig, loop: EventLoop, *, tp: int = 1,
                 fraction: float = 1.0, name: str = "",
                 prefix_caching: bool = True, avg_context: int = 1024,
                 prefill_chunk: int = 2048, decode_quantum: int = 8,
                 max_batch_override: Optional[int] = None,
                 policy: Optional[object] = None,
                 preemption: bool = False,
                 kv_capacity_override: Optional[int] = None,
                 keep_done: bool = True,
                 chip: Optional[hw.ChipClass] = None):
        self.cfg = cfg
        self.policy = policy
        self.loop = loop
        self.tp = tp
        self.fraction = fraction
        self.chip = chip or hw.DEFAULT_CHIP_CLASS
        self.name = name or cfg.name
        self.prefix_caching = prefix_caching
        self.preemption = preemption
        self.prefill_chunk = prefill_chunk
        self.decode_quantum = decode_quantum
        mb = cm.max_batch_size(cfg, avg_context, tp=tp, fraction=fraction,
                               chip=self.chip)
        self.max_batch = max_batch_override or max(min(mb, 256), 1)
        # modeled KV residency budget in tokens: the replica's HBM share
        # minus weights, divided by per-token KV bytes
        if kv_capacity_override is not None:
            self.kv_capacity_tokens = int(kv_capacity_override)
        else:
            budget = (tp * fraction * self.chip.hbm_bytes * 0.9
                      - cm.model_bytes(cfg))
            per_tok = max(cm.kv_bytes_per_seq(cfg, 1), 1.0)
            self.kv_capacity_tokens = max(int(budget / per_tok), 1)
        self.radix = RadixCache(self.kv_capacity_tokens)
        self.waiting: List[EngineRequest] = []
        self.running: List[EngineRequest] = []
        # completed requests: ``keep_done=False`` keeps only the counter
        # (million-request runs must not retain one object per request)
        self.keep_done = keep_done
        self.done: List[EngineRequest] = []
        self.n_done = 0
        # incremental queue-load (see ``load``); listeners are notified
        # once per state-changing event so routers can index replicas
        # without O(queue) scans
        self._load = 0
        self._load_notified = 0
        self._load_listeners: List[Callable[[int], None]] = []
        self.busy = False
        self.busy_time = 0.0
        self.prefill_tokens = 0  # prompt tokens actually computed
        self.cached_tokens = 0   # prompt tokens served from cached KV
        self.preempt_log: List[Tuple[float, float, float]] = []
        # legacy parent-id prefix path: completed request ids with live
        # KV, LRU-bounded by the same token budget (token cost = prompt
        # + output per entry)
        self._served: "OrderedDict[int, int]" = OrderedDict()
        self._served_tokens = 0
        self.current_model: Optional[str] = cfg.name  # for swap modeling
        self.swap_overhead_pending = 0.0
        self.failed = False
        # observability hook (repro.obs.spans.Tracer); None = untraced
        self.tracer = None
        self._obs_tick = 0  # iteration counter for stride-sampled hooks

    # -- queue introspection (router) --
    @property
    def load(self) -> int:
        """Outstanding token work, maintained incrementally (O(1)):
        waiting requests count ``remaining + prompt``, running requests
        count ``remaining`` (see :meth:`recompute_load`)."""
        return self._load

    def recompute_load(self) -> int:
        """O(queue) recomputation of :attr:`load` (test invariant)."""
        return (sum(r.remaining + r.prompt_tokens for r in self.waiting)
                + sum(r.remaining for r in self.running))

    def add_load_listener(self, cb: Callable[[int], None]) -> None:
        self._load_listeners.append(cb)

    def _notify_load(self) -> None:
        if self._load_listeners and self._load != self._load_notified:
            self._load_notified = self._load
            for cb in self._load_listeners:
                cb(self._load)

    def has_parent(self, parent_id: Optional[int]) -> bool:
        if parent_id is None or parent_id not in self._served:
            return False
        self._served.move_to_end(parent_id)  # LRU touch
        return True

    def prefix_lookup(self, req: EngineRequest) -> int:
        """Live cached-prefix tokens this replica would grant ``req``
        (router probe; does not touch LRU state)."""
        if self.failed or not self.prefix_caching:
            return 0
        if req.prefix is not None:
            return min(self.radix.match(req.prefix, touch=False),
                       max(req.prompt_tokens - 1, 0))
        if req.parent_id is not None and req.parent_id in self._served:
            return min(int(req.prompt_tokens * 0.85),
                       req.prompt_tokens - 1)
        return 0

    # -- submission --
    def submit(self, req: EngineRequest) -> None:
        # estimate the discount now (queue disciplines cost by it); the
        # engine re-measures against live KV at admission
        req.cached_prefix = self._measure_prefix(req) \
            if self.prefix_caching else 0
        req.remaining = req.output_tokens - req.progress
        self.waiting.append(req)
        self._load += req.remaining + req.prompt_tokens
        self._notify_load()
        if not self.busy:
            self.busy = True
            self.loop.schedule(self.loop.now, self._iterate)

    def request_swap(self, seconds: float) -> None:
        self.swap_overhead_pending += seconds

    def fail(self, resubmit: Optional[Callable[[EngineRequest], None]] = None
             ) -> List[EngineRequest]:
        """Chip/host failure: drop this replica; in-flight work is lost
        (KV gone) and re-dispatched via ``resubmit`` (router failover)."""
        self.failed = True
        orphans = self.waiting + self.running
        self.waiting, self.running = [], []
        self._load = 0
        self._notify_load()
        self._served.clear()
        self._served_tokens = 0
        self.radix.clear()
        for r in orphans:
            r.cached_prefix = 0  # KV lost; full prefill elsewhere
            r.progress = 0
            r.remaining = r.output_tokens
            r.pinned_seq = None
            if resubmit is not None:
                resubmit(r)
        return orphans

    # -- prefix bookkeeping --
    def _eff_seq(self, req: EngineRequest) -> Optional[Tuple[Segment, ...]]:
        """The request's resident-KV sequence: prompt segments plus any
        decoded progress retained across a preemption."""
        if req.prefix is None:
            return None
        if req.progress > 0:
            return req.prefix + (output_segment(req.req_id, req.progress),)
        return req.prefix

    def _measure_prefix(self, req: EngineRequest) -> int:
        eff_prompt = req.prompt_tokens + req.progress
        seq = self._eff_seq(req)
        if seq is not None:
            return min(self.radix.match(seq, touch=False), eff_prompt - 1)
        if self.has_parent(req.parent_id):
            return min(int(req.prompt_tokens * 0.85), req.prompt_tokens - 1)
        return 0

    def _on_admitted(self, req: EngineRequest) -> None:
        seq = self._eff_seq(req)
        if seq is not None and self.prefix_caching:
            self.radix.insert(seq)
            self.radix.pin(seq)
            req.pinned_seq = seq

    def _on_finished(self, req: EngineRequest) -> None:
        if req.pinned_seq is not None:
            self.radix.unpin(req.pinned_seq)
            req.pinned_seq = None
        if req.prefix is not None and self.prefix_caching:
            self.radix.insert(
                req.prefix + (output_segment(req.req_id, req.output_tokens),))
        # legacy LRU registry, bounded by the same modeled KV budget
        self._served[req.req_id] = req.prompt_tokens + req.output_tokens
        self._served_tokens += self._served[req.req_id]
        while self._served_tokens > self.kv_capacity_tokens \
                and len(self._served) > 1:
            _, cost = self._served.popitem(last=False)
            self._served_tokens -= cost

    def _preempt_one(self, t0: float) -> bool:
        """Let the discipline's head-of-queue request bump the weakest
        strictly-lower-weight running request out of a full batch."""
        idx = self.policy.select(self.waiting, t0) if self.policy else 0
        cand = self.waiting[idx]
        if not _can_preempt(cand):
            return False
        cw = _qos_weight(cand)
        victims = [r for r in self.running if _qos_weight(r) < cw]
        if not victims:
            return False
        victim = min(victims, key=lambda r: (_qos_weight(r), -r.arrival))
        self.running.remove(victim)
        victim.preemptions += 1
        victim.progress = victim.output_tokens - victim.remaining
        if victim.pinned_seq is not None:
            self.radix.unpin(victim.pinned_seq)
            victim.pinned_seq = None
        seq = self._eff_seq(victim)
        if seq is not None and self.prefix_caching:
            # decoded-so-far KV stays resident while the budget allows —
            # re-admission re-measures, so "retained vs dropped" is
            # decided by eviction pressure, not a flag
            self.radix.insert(seq)
        victim.cached_prefix = self._measure_prefix(victim) \
            if self.prefix_caching else 0
        self.waiting.append(victim)
        self._load += victim.prompt_tokens  # waiting counts the prompt again
        self.preempt_log.append((cw, _qos_weight(victim), t0))
        if self.tracer is not None:
            self.tracer.on_engine_preempt(self, victim, t0)
        return True

    # -- engine loop --
    def _iterate(self) -> None:
        if self.failed or (not self.waiting and not self.running):
            self.busy = False
            return
        t0 = self.loop.now
        duration = 0.0
        if self.swap_overhead_pending > 0:
            duration += self.swap_overhead_pending
            self.swap_overhead_pending = 0.0

        # 0) QoS preemption: a high-weight arrival stuck behind a full
        #    batch bumps one weaker decode per iteration
        if (self.preemption and self.waiting
                and len(self.running) >= self.max_batch):
            self._preempt_one(t0)

        # 1) admit prefills within chunk budget and batch capacity; the
        #    queue discipline picks which waiting request goes next
        budget = self.prefill_chunk
        admitted: List[EngineRequest] = []
        while (self.waiting and len(self.running) + len(admitted) < self.max_batch
               and budget > 0):
            idx = self.policy.select(self.waiting, t0) if self.policy else 0
            req = self.waiting[idx]
            # re-measure against live KV (submit-time value is a queue-
            # ordering estimate; residency may have changed since)
            if self.prefix_caching:
                req.cached_prefix = self._measure_prefix(req)
            eff_prompt = req.prompt_tokens + req.progress
            new_tokens = eff_prompt - req.cached_prefix
            if new_tokens > budget and admitted:
                break
            self.waiting.pop(idx)
            self._load -= req.prompt_tokens  # running counts remaining only
            if self.policy:
                self.policy.on_admit(req, new_tokens + req.remaining)
            admitted.append(req)
            self._on_admitted(req)
            budget -= new_tokens
            self.prefill_tokens += new_tokens
            self.cached_tokens += req.cached_prefix
            cost = cm.prefill_cost(self.cfg, eff_prompt, tp=self.tp,
                                   fraction=self.fraction,
                                   cached_tokens=req.cached_prefix,
                                   chip=self.chip)
            duration += cost.total
            req.t_start_service = t0
            if self.tracer is not None:
                self.tracer.on_engine_admit(req, t0, new_tokens, cost.total)

        # 2) decode quantum for the (new) running batch
        batch = self.running + admitted
        self.running = batch  # committed now so fail() can re-dispatch
        if batch:
            q = min(self.decode_quantum, min(r.remaining for r in batch))
            q = max(q, 1)
            ctx = sum(r.prompt_tokens + (r.output_tokens - r.remaining)
                      for r in batch) / len(batch)
            step = cm.decode_step_cost(self.cfg, len(batch), int(ctx),
                                       tp=self.tp, fraction=self.fraction,
                                       chip=self.chip)
            duration += q * step.total
            for r in batch:
                r.remaining -= q
                if r.t_first_token < 0:
                    r.t_first_token = t0 + duration
            self._load -= q * len(batch)

        t1 = t0 + max(duration, 1e-6)
        self.busy_time += t1 - t0
        self._notify_load()
        tr = self.tracer
        if tr is not None:
            # stride-sampled (tracer.iter_mask is 2^k - 1): the hook and
            # its argument evaluation run for one iteration in 2^k
            n = self._obs_tick + 1
            self._obs_tick = n
            if not (n & tr.iter_mask):
                tr.on_engine_iteration(self, t0, t1 - t0, len(batch),
                                       len(self.waiting))
        self.loop.schedule(t1, self._finish_batch, batch, t1)

    def _finish_batch(self, batch: List[EngineRequest], t1: float) -> None:
        if self.failed:  # iteration died with the chip; work was
            return       # already re-dispatched by fail()
        still: List[EngineRequest] = []
        for r in batch:
            if r.remaining <= 0:
                r.t_done = t1
                self._load -= r.remaining
                self.n_done += 1
                if self.keep_done:
                    self.done.append(r)
                self._on_finished(r)
                if r.on_complete:
                    r.on_complete(r)
            else:
                still.append(r)
        self.running = still
        self._notify_load()
        self._iterate()


class _ReplicaIndex:
    """Incremental routing index over one replica list, shared by the
    base :class:`Router` and all of its tenant views.

    Two structures, both updated by callbacks (never rebuilt per call):

    * ``owners`` — head-segment id → indices of replicas whose radix
      cache holds KV for that segment.  Fed by the caches' head-listener
      hooks (:attr:`RadixCache.head_listeners`): a replica owns a head
      exactly while ``(seg, 0)`` is a root child, which is exactly when
      ``match()`` can return > 0 for a prompt starting with that
      segment — so probing only owners is *equivalent* to scanning all
      replicas (non-owners would report 0).
    * a lazy least-loaded min-heap of ``(load, idx)`` entries, pushed by
      the engines' load listeners on every load change.  Entries are
      validated on pop: an entry is fresh iff its recorded load equals
      the engine's current load — every live engine always has one
      fresh entry (each change pushes one), so after discarding stale
      heads the top is the true ``(min load, min idx)``, matching the
      legacy ``min()`` scan's lowest-index tie-break.

    The heap orders by *raw* load, so it serves unweighted routers only
    (weighted tenant views fall back to the O(R) scan — R is small in
    pooled deployments and effective load is per-view).
    """

    def __init__(self, replicas: List["EngineSim"]):
        self.replicas = replicas
        self.owners: Dict[Hashable, Set[int]] = {}
        self._heap: List[Tuple[float, int]] = []
        self._max_heap = max(64, 16 * len(replicas))
        for i, eng in enumerate(replicas):
            radix = getattr(eng, "radix", None)
            if radix is not None:
                radix.head_listeners.append(partial(self._head_event, i))
                for seg, _start in radix.root.children:
                    self.owners.setdefault(seg, set()).add(i)
            if hasattr(eng, "add_load_listener"):
                eng.add_load_listener(partial(self._load_event, i))
            self._heap.append((eng.load, i))
        heapq.heapify(self._heap)

    # radix head callback: op is "add" | "del" | "reset"
    def _head_event(self, idx: int, op: str, seg: Hashable) -> None:
        if op == "add":
            self.owners.setdefault(seg, set()).add(idx)
        elif op == "del":
            s = self.owners.get(seg)
            if s is not None:
                s.discard(idx)
                if not s:
                    del self.owners[seg]
        else:  # reset (cache cleared / replica failed)
            dead = [k for k, s in self.owners.items() if idx in s]
            for k in dead:
                s = self.owners[k]
                s.discard(idx)
                if not s:
                    del self.owners[k]

    def _load_event(self, idx: int, load: float) -> None:
        heapq.heappush(self._heap, (load, idx))
        if len(self._heap) > self._max_heap:
            self._compact()

    def _compact(self) -> None:
        self._heap = [(r.load, i) for i, r in enumerate(self.replicas)
                      if not getattr(r, "failed", False)]
        heapq.heapify(self._heap)

    def least_loaded(self) -> Optional[int]:
        """Live replica with minimal load (ties → lowest index)."""
        for _attempt in range(2):
            heap = self._heap
            while heap:
                load, idx = heap[0]
                eng = self.replicas[idx]
                if getattr(eng, "failed", False) or eng.load != load:
                    heapq.heappop(heap)  # stale
                    continue
                return idx
            self._compact()  # all entries consumed: rebuild once
        return None


class Router:
    """Prefix-affinity + least-loaded routing across one LLM's replicas.

    Target selection, in order:

    1. **longest live prefix** — the replica whose radix cache (or
       legacy parent registry) holds the longest cached prefix of the
       request's prompt;
    2. **sticky** (pooled tenant views, i.e. ``weights`` set) — the
       replica this workflow instance last used, while it is alive and
       positively weighted, so one instance's calls keep landing where
       its KV lives even before the first parent completes;
    3. **weighted least-loaded** — effective load is load/weight and
       zero-weight replicas are never chosen.

    Several routers may *share* one replica list (one per tenant
    workflow — see :meth:`view`); queue state then reflects
    cross-workflow contention automatically.

    ``indexed=True`` (default) serves the common case — unweighted
    router, segment-carrying request — from a :class:`_ReplicaIndex` in
    O(owners + log R) instead of O(R) scans per call, with selection
    semantics identical to the scan (gated by a parity test);
    ``indexed=False`` keeps the legacy full-scan path.
    """

    def __init__(self, replicas: List[EngineSim], *, affinity: bool = True,
                 weights: Optional[Dict[int, float]] = None,
                 indexed: bool = True,
                 index: Optional[_ReplicaIndex] = None,
                 legacy_load: bool = False):
        assert replicas
        self.replicas = replicas
        self.affinity = affinity
        self.weights = weights
        self.indexed = indexed
        # measurement/parity knob: re-sum each candidate's queues per
        # call (the seed's O(queue) hot path) instead of reading the
        # incrementally-maintained load; bench_scale's legacy baseline
        self.legacy_load = legacy_load
        self._sticky: Dict[int, int] = {}  # workflow instance -> replica
        if index is None and indexed:
            index = _ReplicaIndex(replicas)
        self._index = index
        # observability hook (repro.obs.spans.Tracer); None = untraced
        self.tracer = None

    def view(self, weights: Dict[int, float]) -> "Router":
        """A per-tenant view over the same physical replicas (shares the
        base router's index rather than re-registering listeners)."""
        r = Router(self.replicas, affinity=self.affinity, weights=weights,
                   indexed=self.indexed, index=self._index,
                   legacy_load=self.legacy_load)
        r.tracer = self.tracer
        return r

    def _weight(self, idx: int) -> float:
        if self.weights is None:
            return 1.0
        return self.weights.get(idx, 0.0)

    def forget(self, workflow_request: int) -> None:
        """Drop sticky state for a completed workflow instance (the
        driver calls this from its done path so ``_sticky`` stays
        bounded by in-flight instances)."""
        self._sticky.pop(workflow_request, None)

    def submit(self, req: EngineRequest) -> None:
        if self.indexed and self.weights is None:
            self._submit_indexed(req)
        else:
            self._submit_scan(req)

    def _submit_indexed(self, req: EngineRequest) -> None:
        """Index-served fast path (unweighted router): probe prefix
        owners only, then the load heap.  Sticky (tier 2) never fires
        here — it is only consulted when ``weights`` is set."""
        idx = self._index
        replicas = self.replicas
        choice = None
        if self.affinity:
            if req.prefix is not None:
                head = None
                for seg_id, length in req.prefix:
                    if length > 0:
                        head = seg_id
                        break
                owners = idx.owners.get(head)
                cands = sorted(owners) if owners else ()
            else:
                # legacy parent-id heuristic carries no segment id to
                # index on; rare (drivers always attach segments)
                cands = range(len(replicas))
            best_len = 0
            for i in cands:
                r = replicas[i]
                if getattr(r, "failed", False):
                    continue
                pl = r.prefix_lookup(req)
                if pl > best_len:
                    best_len, choice = pl, i
        tier = "prefix"
        if choice is None:
            tier = "least_loaded"
            choice = idx.least_loaded()
            if choice is None:
                raise RuntimeError("no live replicas")
        if self.tracer is not None:
            self.tracer.on_route(tier)
        replicas[choice].submit(req)

    def _submit_scan(self, req: EngineRequest) -> None:
        live = [(i, r) for i, r in enumerate(self.replicas)
                if not getattr(r, "failed", False) and self._weight(i) > 0]
        if not live:
            raise RuntimeError("no live replicas")
        choice = None
        tier = "prefix"
        if self.affinity:
            best_len = 0
            for i, r in live:
                pl = r.prefix_lookup(req)
                if pl > best_len:
                    best_len, choice = pl, (i, r)
        if choice is None and self.weights is not None \
                and req.workflow_request is not None:
            idx = self._sticky.get(req.workflow_request)
            if idx is not None:
                for i, r in live:
                    if i == idx:
                        choice = (i, r)
                        tier = "sticky"
                        break
        if choice is None:
            tier = "least_loaded"
            if self.legacy_load:
                choice = min(live, key=lambda ir: ir[1].recompute_load()
                             / self._weight(ir[0]))
            else:
                choice = min(live,
                             key=lambda ir: ir[1].load / self._weight(ir[0]))
        idx, target = choice
        if self.weights is not None and req.workflow_request is not None:
            self._sticky[req.workflow_request] = idx
        if self.tracer is not None:
            self.tracer.on_route(tier)
        target.submit(req)

    def fail_replica(self, idx: int) -> None:
        """Kill one replica and re-dispatch its in-flight requests."""
        self.replicas[idx].fail(resubmit=self.submit)


@dataclass
class ReplicaSpec:
    """One deployed replica of an LLM (scheduler output, simulator input)."""

    llm: str
    cfg: ArchConfig
    tp: int = 1
    fraction: float = 1.0  # per-chip share (1.0 = whole chip(s))


def build_llm_service(specs: List[ReplicaSpec], loop: EventLoop, *,
                      prefix_caching: bool = True,
                      avg_context: int = 1024,
                      preemption: bool = False) -> Router:
    engines = [EngineSim(s.cfg, loop, tp=s.tp, fraction=s.fraction,
                         name=f"{s.llm}/{i}", prefix_caching=prefix_caching,
                         avg_context=avg_context, preemption=preemption)
               for i, s in enumerate(specs)]
    return Router(engines)
