"""Discrete-event simulation of LLM serving engines on a (fractional) TPU
cluster.

The container is CPU-only, so Scepsy's per-LLM profiling (paper §4 step 3)
replays traced requests through this simulator instead of a live vLLM
deployment; per-iteration costs come from the analytical roofline cost
model (`repro.serving.costmodel`) — the same model the §Roofline report
uses, so predictions and the roofline are consistent.

Fidelity notes (what is modeled):
  * continuous batching at iteration granularity with chunked prefill
    (Sarathi-style): each engine iteration admits waiting prefills up to a
    token budget and decodes the running batch; decode advances in quanta
    of ``decode_quantum`` tokens between scheduling points;
  * KV-capacity admission control (max concurrent sequences from HBM
    budget), queueing, and per-request latency accounting;
  * prefix caching: requests carry their prompt's *segment sequence*
    (``EngineRequest.prefix``, see :mod:`repro.serving.radix`) and each
    replica tracks resident KV in a token-budgeted radix cache — the
    cached-prefix discount is the *measured* shared-prefix length, and
    evicted KV stops producing hits.  Requests without segments fall
    back to the legacy parent-id heuristic (85% of the prompt), bounded
    by the same KV budget via an LRU over completed requests;
  * QoS preemption (opt-in): at an iteration boundary, a waiting request
    of a strictly higher SLO weight may preempt a running lower-weight
    decode when the batch is full; the victim requeues with its decoded
    progress retained and its KV re-registered in the radix cache (so it
    is "retained" exactly while the budget keeps it resident);
  * fractional chip shares scale compute/bandwidth linearly (static
    MPS-like partitioning); TP scales per the cost model incl. collectives;
  * model swapping (for the Aegaeon-like baseline) pays the weight reload.
"""
from __future__ import annotations

import heapq
import itertools
import math
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro import hw
from repro.configs.base import ArchConfig
from repro.serving import costmodel as cm
from repro.serving.radix import RadixCache, Segment


def output_segment(req_id: int, tokens: int) -> Segment:
    """The synthetic segment id for a request's generated output — the
    driver and the engine must agree on it so a child call's prompt
    segments match what the engine registered at the parent's finish."""
    return (("o", req_id), tokens)


class EventLoop:
    def __init__(self):
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []
        self._counter = itertools.count()
        self.now = 0.0

    def schedule(self, t: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (max(t, self.now), next(self._counter), fn))

    def run(self, until: float = math.inf) -> None:
        while self._heap and self._heap[0][0] <= until:
            t, _, fn = heapq.heappop(self._heap)
            self.now = t
            fn()

    def empty(self) -> bool:
        return not self._heap


@dataclass
class EngineRequest:
    req_id: int
    prompt_tokens: int
    output_tokens: int
    arrival: float
    on_complete: Optional[Callable[["EngineRequest"], None]] = None
    parent_id: Optional[int] = None  # legacy prefix-caching heuristic
    workflow_request: Optional[int] = None
    qos: Optional[object] = None  # repro.qos.slo.RequestQoS, duck-typed
    # token-accurate prefix model: the prompt as (segment id, length)
    # pairs (None = legacy heuristic path)
    prefix: Optional[Tuple[Segment, ...]] = None
    # driver-computed ground-truth shared-prefix tokens (bench gate)
    true_prefix: int = 0
    # filled by the engine:
    cached_prefix: int = 0
    t_start_service: float = -1.0
    t_first_token: float = -1.0
    t_done: float = -1.0
    remaining: int = 0
    progress: int = 0       # tokens already decoded (kept across preemption)
    preemptions: int = 0
    pinned_seq: Optional[Tuple[Segment, ...]] = None

    @property
    def latency(self) -> float:
        return self.t_done - self.arrival


def _qos_weight(req) -> float:
    """Effective preemption weight: best-effort (no QoS, degraded, or no
    deadline) sits at the BEST_EFFORT weight."""
    q = getattr(req, "qos", None)
    if q is None or q.degraded or not math.isfinite(q.deadline):
        return 0.5
    return q.weight


def _can_preempt(req) -> bool:
    """Only deadline-carrying, non-degraded requests may preempt."""
    q = getattr(req, "qos", None)
    return (q is not None and not q.degraded
            and math.isfinite(q.deadline))


class EngineSim:
    """One serving-engine replica (one LLM, one TP group, one fraction).

    ``policy`` (a :class:`repro.qos.policy.QueueDiscipline`, duck-typed)
    reorders admission out of the waiting queue: it is asked which
    waiting request to admit next and charged the admitted request's
    token cost.  ``policy=None`` is the built-in FIFO fast path.

    ``preemption=True`` additionally lets the head-of-queue request (per
    the discipline) preempt a strictly-lower-weight running decode when
    the batch is full; every event is logged in ``preempt_log`` as
    ``(preemptor_weight, victim_weight, time)``.
    """

    def __init__(self, cfg: ArchConfig, loop: EventLoop, *, tp: int = 1,
                 fraction: float = 1.0, name: str = "",
                 prefix_caching: bool = True, avg_context: int = 1024,
                 prefill_chunk: int = 2048, decode_quantum: int = 8,
                 max_batch_override: Optional[int] = None,
                 policy: Optional[object] = None,
                 preemption: bool = False,
                 kv_capacity_override: Optional[int] = None):
        self.cfg = cfg
        self.policy = policy
        self.loop = loop
        self.tp = tp
        self.fraction = fraction
        self.name = name or cfg.name
        self.prefix_caching = prefix_caching
        self.preemption = preemption
        self.prefill_chunk = prefill_chunk
        self.decode_quantum = decode_quantum
        mb = cm.max_batch_size(cfg, avg_context, tp=tp, fraction=fraction)
        self.max_batch = max_batch_override or max(min(mb, 256), 1)
        # modeled KV residency budget in tokens: the replica's HBM share
        # minus weights, divided by per-token KV bytes
        if kv_capacity_override is not None:
            self.kv_capacity_tokens = int(kv_capacity_override)
        else:
            budget = tp * fraction * hw.HBM_BYTES * 0.9 - cm.model_bytes(cfg)
            per_tok = max(cm.kv_bytes_per_seq(cfg, 1), 1.0)
            self.kv_capacity_tokens = max(int(budget / per_tok), 1)
        self.radix = RadixCache(self.kv_capacity_tokens)
        self.waiting: List[EngineRequest] = []
        self.running: List[EngineRequest] = []
        self.done: List[EngineRequest] = []
        self.busy = False
        self.busy_time = 0.0
        self.prefill_tokens = 0  # prompt tokens actually computed
        self.cached_tokens = 0   # prompt tokens served from cached KV
        self.preempt_log: List[Tuple[float, float, float]] = []
        # legacy parent-id prefix path: completed request ids with live
        # KV, LRU-bounded by the same token budget (token cost = prompt
        # + output per entry)
        self._served: "OrderedDict[int, int]" = OrderedDict()
        self._served_tokens = 0
        self.current_model: Optional[str] = cfg.name  # for swap modeling
        self.swap_overhead_pending = 0.0
        self.failed = False

    # -- queue introspection (router) --
    @property
    def load(self) -> float:
        return (sum(r.remaining + r.prompt_tokens for r in self.waiting)
                + sum(r.remaining for r in self.running))

    def has_parent(self, parent_id: Optional[int]) -> bool:
        if parent_id is None or parent_id not in self._served:
            return False
        self._served.move_to_end(parent_id)  # LRU touch
        return True

    def prefix_lookup(self, req: EngineRequest) -> int:
        """Live cached-prefix tokens this replica would grant ``req``
        (router probe; does not touch LRU state)."""
        if self.failed or not self.prefix_caching:
            return 0
        if req.prefix is not None:
            return min(self.radix.match(req.prefix, touch=False),
                       max(req.prompt_tokens - 1, 0))
        if req.parent_id is not None and req.parent_id in self._served:
            return min(int(req.prompt_tokens * 0.85),
                       req.prompt_tokens - 1)
        return 0

    # -- submission --
    def submit(self, req: EngineRequest) -> None:
        # estimate the discount now (queue disciplines cost by it); the
        # engine re-measures against live KV at admission
        req.cached_prefix = self._measure_prefix(req) \
            if self.prefix_caching else 0
        req.remaining = req.output_tokens - req.progress
        self.waiting.append(req)
        if not self.busy:
            self.busy = True
            self.loop.schedule(self.loop.now, self._iterate)

    def request_swap(self, seconds: float) -> None:
        self.swap_overhead_pending += seconds

    def fail(self, resubmit: Optional[Callable[[EngineRequest], None]] = None
             ) -> List[EngineRequest]:
        """Chip/host failure: drop this replica; in-flight work is lost
        (KV gone) and re-dispatched via ``resubmit`` (router failover)."""
        self.failed = True
        orphans = self.waiting + self.running
        self.waiting, self.running = [], []
        self._served.clear()
        self._served_tokens = 0
        self.radix.clear()
        for r in orphans:
            r.cached_prefix = 0  # KV lost; full prefill elsewhere
            r.progress = 0
            r.remaining = r.output_tokens
            r.pinned_seq = None
            if resubmit is not None:
                resubmit(r)
        return orphans

    # -- prefix bookkeeping --
    def _eff_seq(self, req: EngineRequest) -> Optional[Tuple[Segment, ...]]:
        """The request's resident-KV sequence: prompt segments plus any
        decoded progress retained across a preemption."""
        if req.prefix is None:
            return None
        if req.progress > 0:
            return req.prefix + (output_segment(req.req_id, req.progress),)
        return req.prefix

    def _measure_prefix(self, req: EngineRequest) -> int:
        eff_prompt = req.prompt_tokens + req.progress
        seq = self._eff_seq(req)
        if seq is not None:
            return min(self.radix.match(seq, touch=False), eff_prompt - 1)
        if self.has_parent(req.parent_id):
            return min(int(req.prompt_tokens * 0.85), req.prompt_tokens - 1)
        return 0

    def _on_admitted(self, req: EngineRequest) -> None:
        seq = self._eff_seq(req)
        if seq is not None and self.prefix_caching:
            self.radix.insert(seq)
            self.radix.pin(seq)
            req.pinned_seq = seq

    def _on_finished(self, req: EngineRequest) -> None:
        if req.pinned_seq is not None:
            self.radix.unpin(req.pinned_seq)
            req.pinned_seq = None
        if req.prefix is not None and self.prefix_caching:
            self.radix.insert(
                req.prefix + (output_segment(req.req_id, req.output_tokens),))
        # legacy LRU registry, bounded by the same modeled KV budget
        self._served[req.req_id] = req.prompt_tokens + req.output_tokens
        self._served_tokens += self._served[req.req_id]
        while self._served_tokens > self.kv_capacity_tokens \
                and len(self._served) > 1:
            _, cost = self._served.popitem(last=False)
            self._served_tokens -= cost

    def _preempt_one(self, t0: float) -> bool:
        """Let the discipline's head-of-queue request bump the weakest
        strictly-lower-weight running request out of a full batch."""
        idx = self.policy.select(self.waiting, t0) if self.policy else 0
        cand = self.waiting[idx]
        if not _can_preempt(cand):
            return False
        cw = _qos_weight(cand)
        victims = [r for r in self.running if _qos_weight(r) < cw]
        if not victims:
            return False
        victim = min(victims, key=lambda r: (_qos_weight(r), -r.arrival))
        self.running.remove(victim)
        victim.preemptions += 1
        victim.progress = victim.output_tokens - victim.remaining
        if victim.pinned_seq is not None:
            self.radix.unpin(victim.pinned_seq)
            victim.pinned_seq = None
        seq = self._eff_seq(victim)
        if seq is not None and self.prefix_caching:
            # decoded-so-far KV stays resident while the budget allows —
            # re-admission re-measures, so "retained vs dropped" is
            # decided by eviction pressure, not a flag
            self.radix.insert(seq)
        victim.cached_prefix = self._measure_prefix(victim) \
            if self.prefix_caching else 0
        self.waiting.append(victim)
        self.preempt_log.append((cw, _qos_weight(victim), t0))
        return True

    # -- engine loop --
    def _iterate(self) -> None:
        if self.failed or (not self.waiting and not self.running):
            self.busy = False
            return
        t0 = self.loop.now
        duration = 0.0
        if self.swap_overhead_pending > 0:
            duration += self.swap_overhead_pending
            self.swap_overhead_pending = 0.0

        # 0) QoS preemption: a high-weight arrival stuck behind a full
        #    batch bumps one weaker decode per iteration
        if (self.preemption and self.waiting
                and len(self.running) >= self.max_batch):
            self._preempt_one(t0)

        # 1) admit prefills within chunk budget and batch capacity; the
        #    queue discipline picks which waiting request goes next
        budget = self.prefill_chunk
        admitted: List[EngineRequest] = []
        while (self.waiting and len(self.running) + len(admitted) < self.max_batch
               and budget > 0):
            idx = self.policy.select(self.waiting, t0) if self.policy else 0
            req = self.waiting[idx]
            # re-measure against live KV (submit-time value is a queue-
            # ordering estimate; residency may have changed since)
            if self.prefix_caching:
                req.cached_prefix = self._measure_prefix(req)
            eff_prompt = req.prompt_tokens + req.progress
            new_tokens = eff_prompt - req.cached_prefix
            if new_tokens > budget and admitted:
                break
            self.waiting.pop(idx)
            if self.policy:
                self.policy.on_admit(req, new_tokens + req.remaining)
            admitted.append(req)
            self._on_admitted(req)
            budget -= new_tokens
            self.prefill_tokens += new_tokens
            self.cached_tokens += req.cached_prefix
            cost = cm.prefill_cost(self.cfg, eff_prompt, tp=self.tp,
                                   fraction=self.fraction,
                                   cached_tokens=req.cached_prefix)
            duration += cost.total
            req.t_start_service = t0

        # 2) decode quantum for the (new) running batch
        batch = self.running + admitted
        self.running = batch  # committed now so fail() can re-dispatch
        if batch:
            q = min(self.decode_quantum, min(r.remaining for r in batch))
            q = max(q, 1)
            ctx = sum(r.prompt_tokens + (r.output_tokens - r.remaining)
                      for r in batch) / len(batch)
            step = cm.decode_step_cost(self.cfg, len(batch), int(ctx),
                                       tp=self.tp, fraction=self.fraction)
            duration += q * step.total
            for r in batch:
                r.remaining -= q
                if r.t_first_token < 0:
                    r.t_first_token = t0 + duration

        t1 = t0 + max(duration, 1e-6)
        self.busy_time += t1 - t0

        def finish():
            if self.failed:  # iteration died with the chip; work was
                return       # already re-dispatched by fail()
            still: List[EngineRequest] = []
            for r in batch:
                if r.remaining <= 0:
                    r.t_done = t1
                    self.done.append(r)
                    self._on_finished(r)
                    if r.on_complete:
                        r.on_complete(r)
                else:
                    still.append(r)
            self.running = still
            self._iterate()

        self.loop.schedule(t1, finish)


class Router:
    """Prefix-affinity + least-loaded routing across one LLM's replicas.

    Target selection, in order:

    1. **longest live prefix** — the replica whose radix cache (or
       legacy parent registry) holds the longest cached prefix of the
       request's prompt;
    2. **sticky** (pooled tenant views, i.e. ``weights`` set) — the
       replica this workflow instance last used, while it is alive and
       positively weighted, so one instance's calls keep landing where
       its KV lives even before the first parent completes;
    3. **weighted least-loaded** — effective load is load/weight and
       zero-weight replicas are never chosen.

    Several routers may *share* one replica list (one per tenant
    workflow — see :meth:`view`); queue state then reflects
    cross-workflow contention automatically.
    """

    def __init__(self, replicas: List[EngineSim], *, affinity: bool = True,
                 weights: Optional[Dict[int, float]] = None):
        assert replicas
        self.replicas = replicas
        self.affinity = affinity
        self.weights = weights
        self._sticky: Dict[int, int] = {}  # workflow instance -> replica

    def view(self, weights: Dict[int, float]) -> "Router":
        """A per-tenant view over the same physical replicas."""
        return Router(self.replicas, affinity=self.affinity, weights=weights)

    def _weight(self, idx: int) -> float:
        if self.weights is None:
            return 1.0
        return self.weights.get(idx, 0.0)

    def submit(self, req: EngineRequest) -> None:
        live = [(i, r) for i, r in enumerate(self.replicas)
                if not getattr(r, "failed", False) and self._weight(i) > 0]
        if not live:
            raise RuntimeError("no live replicas")
        choice = None
        if self.affinity:
            best_len = 0
            for i, r in live:
                pl = r.prefix_lookup(req)
                if pl > best_len:
                    best_len, choice = pl, (i, r)
        if choice is None and self.weights is not None \
                and req.workflow_request is not None:
            idx = self._sticky.get(req.workflow_request)
            if idx is not None:
                for i, r in live:
                    if i == idx:
                        choice = (i, r)
                        break
        if choice is None:
            choice = min(live,
                         key=lambda ir: ir[1].load / self._weight(ir[0]))
        idx, target = choice
        if req.workflow_request is not None:
            self._sticky[req.workflow_request] = idx
        target.submit(req)

    def fail_replica(self, idx: int) -> None:
        """Kill one replica and re-dispatch its in-flight requests."""
        self.replicas[idx].fail(resubmit=self.submit)


@dataclass
class ReplicaSpec:
    """One deployed replica of an LLM (scheduler output, simulator input)."""

    llm: str
    cfg: ArchConfig
    tp: int = 1
    fraction: float = 1.0  # per-chip share (1.0 = whole chip(s))


def build_llm_service(specs: List[ReplicaSpec], loop: EventLoop, *,
                      prefix_caching: bool = True,
                      avg_context: int = 1024,
                      preemption: bool = False) -> Router:
    engines = [EngineSim(s.cfg, loop, tp=s.tp, fraction=s.fraction,
                         name=f"{s.llm}/{i}", prefix_caching=prefix_caching,
                         avg_context=avg_context, preemption=preemption)
               for i, s in enumerate(specs)]
    return Router(engines)
