"""KV-cache data structures for the JAX serving engine.

* :class:`PagedKVCache` — vLLM-style paged cache: a global page pool per
  layer plus per-sequence block tables; pages are allocated/freed by a
  host-side free list.  ``gather_seq`` materializes a sequence's
  contiguous view (the pure-jnp oracle the paged decode path is tested
  against).
* :class:`SlotKVCache` — contiguous per-slot cache used by the engine's
  lockstep decode (simpler layout, same semantics).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class PagedKVCache:
    """Page pool: k/v are (L, num_pages, KV, page, D)."""

    k: jax.Array
    v: jax.Array
    page_size: int
    free_pages: List[int] = field(default_factory=list)
    tables: Dict[int, List[int]] = field(default_factory=dict)  # seq -> pages
    lengths: Dict[int, int] = field(default_factory=dict)

    @classmethod
    def create(cls, layers: int, num_pages: int, kv_heads: int,
               page_size: int, head_dim: int, dtype=jnp.bfloat16):
        shape = (layers, num_pages, kv_heads, page_size, head_dim)
        return cls(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   page_size=page_size,
                   free_pages=list(range(num_pages)))

    # -- host-side allocator --
    def alloc_seq(self, seq_id: int) -> None:
        self.tables[seq_id] = []
        self.lengths[seq_id] = 0

    def free_seq(self, seq_id: int) -> None:
        self.free_pages.extend(self.tables.pop(seq_id, []))
        self.lengths.pop(seq_id, None)

    def _ensure_capacity(self, seq_id: int, new_len: int) -> None:
        need = (new_len + self.page_size - 1) // self.page_size
        table = self.tables[seq_id]
        while len(table) < need:
            if not self.free_pages:
                raise MemoryError("KV page pool exhausted")
            table.append(self.free_pages.pop())

    # -- device-side writes --
    def append(self, seq_id: int, k_new: jax.Array, v_new: jax.Array) -> None:
        """k_new/v_new: (L, KV, T, D) — T new tokens for one sequence.

        Writes are batched per page: each touched page gets ONE
        ``dynamic_update_slice`` covering its contiguous run of new
        tokens (O(T / page_size) device dispatches, not O(T)).
        """
        T = k_new.shape[2]
        start = self.lengths[seq_id]
        self._ensure_capacity(seq_id, start + T)
        table = self.tables[seq_id]
        ps = self.page_size
        t = 0
        while t < T:
            pos = start + t
            page = table[pos // ps]
            off = pos % ps
            n = min(ps - off, T - t)
            # (L, KV, n, D) -> (L, 1, KV, n, D) at (0, page, 0, off, 0)
            k_chunk = k_new[:, None, :, t:t + n, :].astype(self.k.dtype)
            v_chunk = v_new[:, None, :, t:t + n, :].astype(self.v.dtype)
            idx = (0, page, 0, off, 0)
            self.k = jax.lax.dynamic_update_slice(self.k, k_chunk, idx)
            self.v = jax.lax.dynamic_update_slice(self.v, v_chunk, idx)
            t += n
        self.lengths[seq_id] = start + T

    def gather_seq(self, seq_id: int) -> Tuple[jax.Array, jax.Array, int]:
        """Contiguous (L, KV, len_padded, D) view of a sequence."""
        table = jnp.asarray(self.tables[seq_id], jnp.int32)
        k = jnp.take(self.k, table, axis=1)  # (L, n_pages, KV, ps, D)
        v = jnp.take(self.v, table, axis=1)
        L, n, KV, ps, D = k.shape
        k = k.transpose(0, 2, 1, 3, 4).reshape(L, KV, n * ps, D)
        v = v.transpose(0, 2, 1, 3, 4).reshape(L, KV, n * ps, D)
        return k, v, self.lengths[seq_id]


@dataclass
class SlotKVCache:
    """Contiguous (L, slots, KV, Smax, D) cache with per-slot lengths."""

    k: jax.Array
    v: jax.Array
    lengths: np.ndarray  # host-side (slots,) int32
    free_slots: List[int] = field(default_factory=list)

    @classmethod
    def create(cls, layers: int, slots: int, kv_heads: int, max_len: int,
               head_dim: int, dtype=jnp.bfloat16):
        shape = (layers, slots, kv_heads, max_len, head_dim)
        return cls(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   lengths=np.zeros(slots, np.int32),
                   free_slots=list(range(slots)))

    def alloc(self) -> int:
        return self.free_slots.pop()

    def free(self, slot: int) -> None:
        self.lengths[slot] = 0
        self.free_slots.append(slot)

    def write_prefill(self, slot: int, k_new: jax.Array, v_new: jax.Array,
                      length: int) -> None:
        """k_new/v_new: (L, KV, S, D) from a prefill."""
        S = k_new.shape[2]
        self.k = jax.lax.dynamic_update_slice(
            self.k, k_new[:, None].astype(self.k.dtype), (0, slot, 0, 0, 0))
        self.v = jax.lax.dynamic_update_slice(
            self.v, v_new[:, None].astype(self.v.dtype), (0, slot, 0, 0, 0))
        self.lengths[slot] = length

    def copy_prefix(self, src_slot: int, dst_slot: int, length: int) -> None:
        """Prefix-cache hit: duplicate the first ``length`` tokens."""
        sl = self.k[:, src_slot, :, :length, :]
        self.k = self.k.at[:, dst_slot, :, :length, :].set(sl)
        self.v = self.v.at[:, dst_slot, :, :length, :].set(
            self.v[:, src_slot, :, :length, :])
        self.lengths[dst_slot] = length
