"""Analytical Pallas block-size autotuning per (chip class, TP, batch).

The flash-attention kernels hardcoded ``block_q = block_kv = 128``; on a
heterogeneous fleet the right tile depends on the chip class (peak/BW
ratio, VMEM capacity).  Running real sweeps per class inside the
CPU-only profiler is not possible, so this module searches the block
space *analytically* with the same roofline physics the cost model
uses:

    t(bq, bkv) = max(flops / (peak·mxu_eff), bytes(bq) / (bw·hbm_eff))
                 + n_tiles(bq, bkv) · t_tile_overhead

where K/V traffic is re-streamed once per query tile
(``bytes`` shrinks as ``block_q`` grows) and the candidate is feasible
only if its working set fits the class's VMEM budget.  Candidates
respect the TPU tiling rules (see the Pallas guide): the lane dimension
is a multiple of 128 and bf16 sublanes come in multiples of 16, so all
candidate blocks are multiples of 128 clamped to the (padded) sequence.

Results are memoized per ``(chip_class, tp, batch, seq, head_dim)`` —
the per-class profile pass calls this once per TP degree, and the
engine replicas reuse the cached plan.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Optional, Tuple

from repro import hw

LANE = 128  # MXU/VPU lane width: last-dim tile multiple
BF16_SUBLANE = 16  # min second-to-last-dim tile for bf16
TILE_OVERHEAD_S = 1e-6  # per-grid-step launch/prologue cost
VMEM_HEADROOM = 0.8  # leave room for double-buffering + compiler spill

_CANDIDATES = (128, 256, 512, 1024, 2048)


@dataclass(frozen=True)
class BlockPlan:
    """One autotuned attention tiling."""

    block_q: int
    block_kv: int
    est_time_s: float  # modeled per-(batch·head) kernel time
    vmem_bytes: int  # modeled working set


def _pad_up(n: int, multiple: int) -> int:
    return -(-n // multiple) * multiple


def _vmem_working_set(bq: int, bkv: int, head_dim: int,
                      dtype_bytes: int) -> int:
    """Q/K/V tiles + f32 score tile + f32 output accumulator."""
    q = bq * head_dim * dtype_bytes
    kv = 2 * bkv * head_dim * dtype_bytes
    scores = bq * bkv * 4
    acc = bq * head_dim * 4
    return q + kv + scores + acc


def _estimate(chip: hw.ChipClass, bq: int, bkv: int, *, seq: int,
              head_dim: int, batch_heads: int, tp: int,
              dtype_bytes: int) -> float:
    """Modeled wall time of one full attention pass over the grid."""
    n_q = -(-seq // bq)
    n_kv = -(-seq // bkv)
    # QK^T + PV: 4·S²·D flops per (batch, head), split over TP cores
    flops = 4.0 * seq * seq * head_dim * batch_heads / tp
    compute = flops / (chip.peak_flops_bf16 * chip.mxu_efficiency)
    # Q and O stream once; K/V re-stream once per query tile
    qo = 2.0 * seq * head_dim * dtype_bytes
    kv = 2.0 * seq * head_dim * dtype_bytes * n_q
    memory = ((qo + kv) * batch_heads / tp
              / (chip.hbm_bw * chip.hbm_efficiency))
    overhead = n_q * n_kv * batch_heads / tp * TILE_OVERHEAD_S
    return max(compute, memory) + overhead


@lru_cache(maxsize=4096)
def _autotune_cached(chip_name: str, tp: int, batch: int, seq: int,
                     head_dim: int, num_heads: int,
                     dtype_bytes: int) -> BlockPlan:
    chip = hw.chip_class(chip_name)
    seq_p = _pad_up(max(seq, 1), LANE)
    head_dim_p = _pad_up(max(head_dim, 1), LANE)
    batch_heads = max(batch, 1) * max(num_heads, 1)
    budget = int(chip.vmem_bytes * VMEM_HEADROOM)
    best: Optional[BlockPlan] = None
    for bq in _CANDIDATES:
        if bq > seq_p and bq != _CANDIDATES[0]:
            continue
        for bkv in _CANDIDATES:
            if bkv > seq_p and bkv != _CANDIDATES[0]:
                continue
            use = _vmem_working_set(min(bq, seq_p), min(bkv, seq_p),
                                    head_dim_p, dtype_bytes)
            if use > budget:
                continue
            t = _estimate(chip, min(bq, seq_p), min(bkv, seq_p),
                          seq=seq_p, head_dim=head_dim_p,
                          batch_heads=batch_heads, tp=max(tp, 1),
                          dtype_bytes=dtype_bytes)
            if best is None or t < best.est_time_s - 1e-15:
                best = BlockPlan(block_q=min(bq, seq_p),
                                 block_kv=min(bkv, seq_p),
                                 est_time_s=t, vmem_bytes=use)
    if best is None:  # pathological VMEM budget: fall back to min tile
        best = BlockPlan(block_q=LANE, block_kv=LANE,
                         est_time_s=float("inf"),
                         vmem_bytes=_vmem_working_set(
                             LANE, LANE, head_dim_p, dtype_bytes))
    return best


def autotune_attention_blocks(chip: Optional[hw.ChipClass] = None, *,
                              tp: int = 1, batch: int = 1,
                              seq_len: int = 2048, head_dim: int = 128,
                              num_heads: int = 8,
                              dtype_bytes: int = 2) -> BlockPlan:
    """Best (block_q, block_kv) for flash attention on ``chip``.

    Pure analytical search (roofline + VMEM feasibility), memoized per
    ``(chip_class, tp, batch, seq, head_dim, num_heads)`` — the profiler
    calls this once per ``(chip_class, tp)`` sweep point.
    """
    chip = chip or hw.DEFAULT_CHIP_CLASS
    return _autotune_cached(chip.name, int(tp), int(batch), int(seq_len),
                            int(head_dim), int(num_heads), int(dtype_bytes))


def autotune_cache_info() -> Tuple[int, int]:
    """(hits, misses) of the memo cache — test/telemetry hook."""
    info = _autotune_cached.cache_info()
    return info.hits, info.misses


def clear_autotune_cache() -> None:
    _autotune_cached.cache_clear()
