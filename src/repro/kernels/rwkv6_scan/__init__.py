from repro.kernels.rwkv6_scan.ops import wkv6_op  # noqa: F401
from repro.kernels.rwkv6_scan.ref import wkv6_scan_ref  # noqa: F401
