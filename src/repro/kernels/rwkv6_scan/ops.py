"""Jit'd public wrapper for the WKV6 kernel (model layout + fallback)."""
from __future__ import annotations

import jax

from repro.kernels.common import use_interpret
from repro.kernels.rwkv6_scan.kernel import wkv6_scan


def wkv6_op(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
            u: jax.Array, state: jax.Array, *, chunk: int = 64):
    """Model-layout entry point.

    r/k/v/logw: (B, H, S, D); u: (H, D); state: (B, H, D, D) f32.
    Returns (out (B, H, S, D), state').
    """
    B, H, S, D = r.shape
    flat = lambda a: a.reshape(B * H, S, D)
    out, s1 = wkv6_scan(flat(r), flat(k), flat(v), flat(logw), u,
                        state.reshape(B * H, D, D), num_heads=H, chunk=chunk,
                        interpret=use_interpret())
    return out.reshape(B, H, S, D), s1.reshape(B, H, D, D)
