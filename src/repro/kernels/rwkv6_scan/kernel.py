"""Chunked WKV6 linear recurrence — Pallas TPU kernel (rwkv6-7b hot-spot).

Per (batch, head): S_t = diag(w_t) S_{t-1} + k_t^T v_t,
                   o_t = r_t S_{t-1} + (r_t . (u (.) k_t)) v_t.

TPU-native chunking (mirrors the jnp oracle in repro.models.rwkv):
  * grid (B*H, n_chunks); chunks are the ARBITRARY inner dim so the
    (D, D) f32 state lives in VMEM scratch across chunk steps;
  * within a chunk, pairwise decays are masked exponentials with all
    exponents <= 0 — numerically stable without the overflow-prone
    1/decay factorisation used by CUDA implementations (hardware
    adaptation note: GPU kernels serialise t inside a warp; on TPU we
    trade that for (C, C) MXU matmuls);
  * per-head bonus ``u`` is indexed via ``bh % H`` in the index_map.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import tpu_compiler_params


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
                 o_ref, sout_ref, s_scr, *, chunk: int):
    ic = pl.program_id(1)
    n_c = pl.num_programs(1)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)  # (C, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)  # (C, D), <= 0
    u = u_ref[0].astype(jnp.float32)  # (1, D) -> broadcast

    cum = jnp.cumsum(lw, axis=0)  # inclusive
    excl = cum - lw  # exclusive

    s0 = s_scr[...]
    # inter-chunk
    r_dec = r * jnp.exp(excl)
    out_inter = jax.lax.dot_general(r_dec, s0, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    # intra-chunk pairwise (t attends tau < t)
    diff = excl[:, None, :] - cum[None, :, :]  # (Ct, Ctau, D)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    mask = (t_idx > s_idx)[:, :, None]
    decay = jnp.exp(jnp.where(mask, diff, -jnp.inf))
    A = jnp.einsum("tk,sk,tsk->ts", r, k, decay,
                   preferred_element_type=jnp.float32)
    a_diag = jnp.sum(r * u * k, axis=1, keepdims=True)  # (C, 1)
    out_intra = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    out_intra = out_intra + a_diag * v
    o_ref[0] = (out_inter + out_intra).astype(o_ref.dtype)

    # state to chunk end
    k_dec = k * jnp.exp(cum[-1:, :] - cum)
    s_scr[...] = (s0 * jnp.exp(cum[-1, :])[:, None]
                  + jax.lax.dot_general(k_dec, v, (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))

    @pl.when(ic == n_c - 1)
    def _final():
        sout_ref[0] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("num_heads", "chunk", "interpret"))
def wkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
              u: jax.Array, state: jax.Array, *, num_heads: int,
              chunk: int = 64, interpret: bool = False):
    """r/k/v/logw: (BH, S, D); u: (H, D); state: (BH, D, D) f32.

    Returns (out (BH, S, D), final_state (BH, D, D) f32).
    """
    BH, S, D = r.shape
    H = num_heads
    chunk = min(chunk, S)
    n_c = pl.cdiv(S, chunk)

    kernel = functools.partial(_wkv6_kernel, chunk=chunk)
    out, sout = pl.pallas_call(
        kernel,
        grid=(BH, n_c),
        in_specs=[
            pl.BlockSpec((1, chunk, D), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, D), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, D), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, D), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, D), lambda bh, ic: (bh % H, 0)),
            pl.BlockSpec((1, D, D), lambda bh, ic: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, D), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, D, D), lambda bh, ic: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, D), r.dtype),
            jax.ShapeDtypeStruct((BH, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name=f"wkv6_scan_c{chunk}",
    )(r, k, v, logw, u, state)
    return out, sout
