"""Pure-jnp oracle for the WKV6 scan: naive sequential recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_scan_ref(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
                  u: jax.Array, state: jax.Array, *, num_heads: int):
    """Token-by-token reference. Same signature as the kernel.

    r/k/v/logw: (BH, S, D); u: (H, D); state: (BH, D, D) f32.
    """
    BH, S, D = r.shape
    H = num_heads
    u_full = jnp.tile(u, (BH // H, 1))  # (BH, D) per bh row

    def step(s, xs):
        r_t, k_t, v_t, lw_t = (a.astype(jnp.float32) for a in xs)
        out = (jnp.einsum("bk,bkv->bv", r_t, s)
               + jnp.sum(r_t * u_full.astype(jnp.float32) * k_t,
                         axis=-1, keepdims=True) * v_t)
        s = (s * jnp.exp(lw_t)[..., None]
             + k_t[..., None] * v_t[..., None, :])
        return s, out

    xs = tuple(a.transpose(1, 0, 2) for a in (r, k, v, logw))
    state, outs = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return outs.transpose(1, 0, 2).astype(r.dtype), state
