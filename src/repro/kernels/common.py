"""Shared kernel helpers."""
from __future__ import annotations

import jax


def use_interpret() -> bool:
    """Pallas TPU kernels execute via the interpreter off-TPU (CPU CI)."""
    return jax.default_backend() != "tpu"


def pad_to(x, multiple: int, axis: int):
    """Zero-pad ``axis`` of x up to a multiple; returns (padded, orig_len)."""
    import jax.numpy as jnp

    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x, n
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads), n
