"""Shared kernel helpers."""
from __future__ import annotations

import jax


def use_interpret() -> bool:
    """Pallas TPU kernels execute via the interpreter off-TPU (CPU CI)."""
    return jax.default_backend() != "tpu"


def tpu_compiler_params(**kwargs):
    """Pallas TPU compiler params across JAX versions.

    Newer JAX exposes ``pltpu.CompilerParams``; older releases call the
    same dataclass ``TPUCompilerParams``.
    """
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)


def pad_to(x, multiple: int, axis: int):
    """Zero-pad ``axis`` of x up to a multiple; returns (padded, orig_len)."""
    import jax.numpy as jnp

    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x, n
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads), n
