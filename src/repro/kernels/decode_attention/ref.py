"""Pure-jnp oracle for decode attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         cache_len) -> jax.Array:
    """q: (B, KV, G, D); caches: (B, KV, Smax, D). Returns (B, KV, G, D)."""
    B, KV, G, D = q.shape
    Smax = k_cache.shape[2]
    scale = 1.0 / math.sqrt(D)
    scores = jnp.einsum("bkgd,bksd->bkgs", q.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    valid = jnp.arange(Smax) < cache_len
    scores = jnp.where(valid[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", probs, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)
