"""GQA decode attention over a KV cache — Pallas TPU kernel.

The decode hot-spot for serving: one query token per sequence attends over
a long cache.  This op is *memory-bound* (arithmetic intensity ~ 2·G for
group size G), so the kernel's job is streaming the KV cache HBM->VMEM at
line rate while the grouped queries ride along in registers:

  * grid (B, KV, n_s): the cache seq dim is the ARBITRARY inner dim; the
    flash accumulators (m, l, acc per (group, head_dim)) sit in VMEM
    scratch across cache blocks;
  * the q block is (G, D) for one (batch, kv_head) pair — all grouped
    query heads share the same streamed K/V block (GQA reuse is the whole
    perf story for kv=2 archs like glm4);
  * ``cache_len`` arrives via scalar prefetch (SMEM) and masks the tail
    block; fully-invalid blocks are predicated away with pl.when.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import tpu_compiler_params

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, block_s: int, scale: float):
    isb = pl.program_id(2)
    n_s = pl.num_programs(2)
    cache_len = len_ref[0]
    s_lo = isb * block_s

    @pl.when(isb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(s_lo < cache_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bs, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = s_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < cache_len, s, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = corr * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * corr
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(isb == n_s - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *, block_s: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: (B, KV, G, D); k_cache/v_cache: (B, KV, Smax, D); cache_len: ().

    Returns (B, KV, G, D).
    """
    B, KV, G, D = q.shape
    Smax = k_cache.shape[2]
    block_s = min(block_s, Smax)
    n_s = pl.cdiv(Smax, block_s)
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_decode_kernel, block_s=block_s, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, isb, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, isb, lens: (b, h, isb, 0)),
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, isb, lens: (b, h, isb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, isb, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name=f"decode_attention_bs{block_s}",
    )(jnp.asarray(cache_len, jnp.int32).reshape(1), q, k_cache, v_cache)
