"""Jit'd public wrapper: GQA layout + group padding + interpret fallback."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.decode_attention.kernel import decode_attention


def decode_attention_op(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                        cache_len, *, block_s: int = 512) -> jax.Array:
    """Model-layout entry point.

    q: (B, H, D); caches: (B, KV, Smax, D).  Returns (B, H, D).
    Pads the GQA group dim up to 8 sublanes when needed.
    """
    B, H, D = q.shape
    KV = k_cache.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, D)
    Gp = max(G, 8)
    if Gp != G:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, Gp - G), (0, 0)))
    out = decode_attention(qg, k_cache, v_cache, cache_len, block_s=block_s,
                           interpret=use_interpret())
    return out[:, :, :G].reshape(B, H, D)
