"""Pallas TPU kernels for the serving hot-spots.

flash_attention  — blocked causal/SWA prefill attention (MXU-tiled)
decode_attention — GQA decode over a KV cache (HBM-streaming bound)
rwkv6_scan       — chunked WKV6 linear recurrence (rwkv6-7b)

Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper, interpret fallback off-TPU), ref.py (pure-jnp oracle).
"""
