"""Blocked causal (sliding-window) flash attention — Pallas TPU kernel.

Prefill hot-spot for every attention arch.  TPU-native tiling:
  * grid (B, H, n_q, n_kv); the kv dim is ARBITRARY (sequential) so the
    online-softmax accumulators live in VMEM scratch across kv steps;
  * q/k/v blocks are (block_q, head_dim) / (block_kv, head_dim) VMEM tiles,
    MXU-aligned (block sizes multiples of 128 on the contraction layout);
  * GQA without materialising repeats: the k/v index_map folds the query
    head onto its kv head (h // group);
  * causal (and sliding-window) *block skipping*: fully-masked kv blocks
    are predicated out with pl.when, matching the causal-optimal FLOPs the
    jnp oracle (and the dry-run roofline) count.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, block_q: int, block_kv: int, seq_len: int,
                  window: int, scale: float):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)
    n_kv = pl.num_programs(3)

    q_lo = iq * block_q
    kv_lo = ikv * block_kv

    # --- block-level skip predicates (causal + window band) ---
    below_diag = kv_lo <= q_lo + block_q - 1  # some kv not in the future
    if window > 0:
        in_window = kv_lo + block_kv - 1 > q_lo - window
        live = jnp.logical_and(below_diag, in_window)
    else:
        live = below_diag

    @pl.when(ikv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bkv, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        k_pos = kv_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = k_pos <= q_pos
        if window > 0:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        correction = jnp.exp(m_prev - m_new)
        l_new = correction * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * correction
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ikv == n_kv - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "block_q", "block_kv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: int = 0, block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, S, D); k/v: (B, KV, S, D). Causal. Returns (B, H, S, D)."""
    B, H, S, D = q.shape
    KV = k.shape[1]
    G = H // KV
    block_q = min(block_q, S)
    block_kv = min(block_kv, S)
    n_q = pl.cdiv(S, block_q)
    n_kv = pl.cdiv(S, block_kv)
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_kv=block_kv, seq_len=S,
        window=window, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ikv: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, D), lambda b, h, iq, ikv: (b, h // G, ikv, 0)),
            pl.BlockSpec((1, 1, block_kv, D), lambda b, h, iq, ikv: (b, h // G, ikv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ikv: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name=f"flash_attention_bq{block_q}_bkv{block_kv}",
    )(q, k, v)
