"""Jit'd public wrapper: layout adaptation + interpret fallback."""
from __future__ import annotations

import jax

from repro.kernels.common import use_interpret
from repro.kernels.flash_attention.kernel import flash_attention


def flash_attention_op(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       window: int = 0, block_q: int = 128,
                       block_kv: int = 128) -> jax.Array:
    """Model-layout entry point.

    q: (B, S, H, D); k/v: (B, S, KV, D) — as produced by attention_qkv.
    Returns (B, S, H, D).
    """
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention(qt, kt, vt, window=window, block_q=block_q,
                          block_kv=block_kv, interpret=use_interpret())
    return out.transpose(0, 2, 1, 3)
