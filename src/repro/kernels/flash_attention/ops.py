"""Jit'd public wrapper: layout adaptation + interpret fallback."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.autotune import autotune_attention_blocks
from repro.kernels.common import use_interpret
from repro.kernels.flash_attention.kernel import flash_attention


def flash_attention_op(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       window: int = 0, block_q: Optional[int] = None,
                       block_kv: Optional[int] = None,
                       chip=None) -> jax.Array:
    """Model-layout entry point.

    q: (B, S, H, D); k/v: (B, S, KV, D) — as produced by attention_qkv.
    Returns (B, S, H, D).  Block sizes default to the analytical
    autotuner's pick for ``chip`` (the default chip class when None);
    pass explicit ``block_q``/``block_kv`` to override.
    """
    if block_q is None or block_kv is None:
        B, S, H, D = q.shape
        plan = autotune_attention_blocks(chip, batch=B, seq_len=S,
                                         head_dim=D, num_heads=H)
        block_q = block_q or plan.block_q
        block_kv = block_kv or plan.block_kv
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention(qt, kt, vt, window=window, block_q=block_q,
                          block_kv=block_kv, interpret=use_interpret())
    return out.transpose(0, 2, 1, 3)
