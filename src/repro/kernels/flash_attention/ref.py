"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        window: int = 0) -> jax.Array:
    """q: (B, H, S, D); k/v: (B, KV, S, D). Causal softmax attention."""
    B, H, S, D = q.shape
    KV = k.shape[1]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, KV, G, S, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgqd,bksd->bkgqs", qg, kf) * scale
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", probs, vf)
    return out.reshape(B, H, S, D).astype(q.dtype)
