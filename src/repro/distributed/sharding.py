"""Sharding conventions + helpers (the framework's distribution contract).

Axes: ``pod`` (DCI), ``data`` (DP batch), ``model`` (TP / EP / sequence).
Per-family parameter rules live with the ParamDefs in repro.models; this
module centralizes the cross-cutting utilities:

  * ``fit_spec`` / ``tree_shardings`` — divisibility-safe NamedShardings
    (re-exported from the dry-run so launchers share one implementation);
  * ``zero_opt_specs`` — ZeRO-1 style optimizer-state sharding: moments
    additionally sharded over ``data`` on their largest divisible dim
    (a §Perf option that cuts optimizer HBM ~data_ways x).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.training.optimizer import AdamWState


def adapt_spec(spec, mesh):
    """Drop mesh axes a spec references that this mesh doesn't have
    (single-pod meshes have no 'pod' axis)."""
    names = set(mesh.axis_names)

    def fix_entry(e):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(x for x in e if x in names)
            if not kept:
                return None
            return kept if len(kept) > 1 else kept[0]
        return e if e in names else None

    if spec is None:
        return P()
    return P(*(fix_entry(e) for e in spec))


def fit_spec(spec: P, shape, mesh) -> P:
    """adapt_spec + divisibility: drop axes whose product doesn't divide
    the dim (pjit arguments require even sharding, e.g. global_batch=1 for
    long_500k cannot shard over 'data')."""
    spec = adapt_spec(spec, mesh)
    entries = list(spec)
    while len(entries) < len(shape):
        entries.append(None)
    fixed = []
    for dim, e in zip(shape, entries[:len(shape)]):
        if e is None:
            fixed.append(None)
            continue
        axes = list(e) if isinstance(e, (tuple, list)) else [e]
        while axes:
            prod = 1
            for a in axes:
                prod *= mesh.shape[a]
            if dim % prod == 0:
                break
            axes.pop()  # drop innermost-last axis first
        if not axes:
            fixed.append(None)
        elif len(axes) == 1:
            fixed.append(axes[0])
        else:
            fixed.append(tuple(axes))
    return P(*fixed)


def tree_shardings(spec_tree, mesh, shape_tree=None):
    if shape_tree is None:
        return jax.tree.map(
            lambda s: NamedSharding(mesh, adapt_spec(s, mesh)), spec_tree,
            is_leaf=lambda x: isinstance(x, P))
    return jax.tree.map(
        lambda s, shp: NamedSharding(mesh, fit_spec(s, shp.shape, mesh)),
        spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P))


def zero_opt_specs(param_specs: Any, param_shapes: Any,
                   data_ways: int) -> AdamWState:
    """Shard AdamW moments over `data` too (ZeRO-1)."""

    def widen(spec: P, shp) -> P:
        entries = list(spec) + [None] * (len(shp.shape) - len(tuple(spec)))
        for i, (e, dim) in enumerate(zip(entries, shp.shape)):
            if e is None and dim % data_ways == 0 and dim >= data_ways:
                entries[i] = "data"
                return P(*entries)
        return P(*entries)

    m = jax.tree.map(widen, param_specs, param_shapes,
                     is_leaf=lambda x: isinstance(x, P))
    return AdamWState(step=P(), m=m, v=m)
