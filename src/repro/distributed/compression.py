"""Gradient compression for the data-parallel all-reduce.

int8 block-quantized gradients with error feedback: the quantization
residual is carried to the next step, so compression error doesn't bias
the descent direction (1-bit-Adam-style argument).  Cuts DP all-reduce
bytes 4x (f32->int8); used as a distributed-optimization option in
``train_loop`` and measured in the §Perf iterations.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8 quantization. Returns (q, scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_int8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    blocks = q.astype(jnp.float32) * scale[:, None]
    flat = blocks.reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_tree(grads: Any, error: Any) -> Tuple[Any, Any]:
    """Quantize grads+error; returns (quantized pytree, new error pytree).

    The caller all-reduces the (q, scale) pairs over the data axis and
    dequantizes; here we model the lossy round-trip locally.
    """

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = quantize_int8(target)
        deq = dequantize_int8(q, s, g.shape)
        return deq.astype(g.dtype), target - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
