"""Fault tolerance + elastic scaling controller (1000+-node design).

On a real multi-pod deployment this wraps the training/serving launcher:

  * heartbeat monitor — every worker (host) reports liveness; a missed
    ``grace`` window marks it failed;
  * straggler detection — per-step durations; a worker slower than
    ``straggler_factor`` x median for ``patience`` consecutive steps is
    treated like a failure (preemptive re-mesh beats waiting);
  * elastic re-mesh — on failure, drop the affected `data` slice(s) and
    rebuild the mesh with the largest power-of-two data axis that the
    survivors support; training resumes from the last checkpoint (the
    data pipeline is seekable by step, so no sample is lost or repeated);
  * serving side: the router already fails over (failed replica removed
    from the replica list); placement re-runs on the survivor cluster.

The container has one host, so the unit tests drive this with a simulated
clock — the controller is pure logic over (worker, timestamp) streams.
"""
from __future__ import annotations

import math
import statistics
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple


@dataclass
class WorkerState:
    last_heartbeat: float = 0.0
    step_durations: List[float] = field(default_factory=list)
    slow_streak: int = 0
    alive: bool = True


@dataclass
class MeshPlan:
    """What the launcher should rebuild after an elasticity event."""

    data_ways: int
    model_ways: int
    dropped_workers: Tuple[int, ...]
    restart_from_checkpoint: bool


class FaultToleranceController:
    def __init__(self, num_workers: int, *, grace: float = 30.0,
                 straggler_factor: float = 2.0, patience: int = 3,
                 model_ways: int = 16):
        self.workers: Dict[int, WorkerState] = {
            i: WorkerState() for i in range(num_workers)}
        self.grace = grace
        self.straggler_factor = straggler_factor
        self.patience = patience
        self.model_ways = model_ways

    # --- telemetry ingestion ---
    def heartbeat(self, worker: int, t: float) -> None:
        self.workers[worker].last_heartbeat = t

    def report_step(self, worker: int, duration: float) -> None:
        w = self.workers[worker]
        w.step_durations.append(duration)
        if len(w.step_durations) > 50:
            w.step_durations.pop(0)

    # --- failure / straggler detection ---
    def check(self, now: float) -> Optional[MeshPlan]:
        alive = [i for i, w in self.workers.items() if w.alive]
        failed: Set[int] = set()
        for i in alive:
            w = self.workers[i]
            if now - w.last_heartbeat > self.grace:
                failed.add(i)
        medians = [w.step_durations[-1] for i, w in self.workers.items()
                   if w.alive and w.step_durations and i not in failed]
        if medians:
            med = statistics.median(medians)
            for i in alive:
                w = self.workers[i]
                if not w.step_durations or i in failed:
                    continue
                if w.step_durations[-1] > self.straggler_factor * med:
                    w.slow_streak += 1
                    if w.slow_streak >= self.patience:
                        failed.add(i)  # persistent straggler == failure
                else:
                    w.slow_streak = 0
        if not failed:
            return None
        for i in failed:
            self.workers[i].alive = False
        return self.remesh_plan(tuple(sorted(failed)))

    def remesh_plan(self, dropped: Tuple[int, ...]) -> MeshPlan:
        survivors = sum(1 for w in self.workers.values() if w.alive)
        # keep the model axis (TP needs its full ICI ring); shrink data
        data_ways = max(1, 2 ** int(math.log2(
            max(survivors * 0 + survivors, 1))))
        # survivors hosts each drive (chips_per_host) chips; data axis is
        # the largest power of two <= survivors
        data_ways = 2 ** int(math.log2(survivors)) if survivors else 1
        return MeshPlan(data_ways=data_ways, model_ways=self.model_ways,
                        dropped_workers=dropped,
                        restart_from_checkpoint=True)

    def alive_workers(self) -> List[int]:
        return [i for i, w in self.workers.items() if w.alive]


def backup_dispatch(latencies: Dict[int, float], deadline: float
                    ) -> List[int]:
    """Serving-side straggler mitigation: replicas whose in-flight request
    age exceeds the deadline get a backup dispatch elsewhere (first
    completion wins).  Returns replica ids needing a backup."""
    return [r for r, age in latencies.items() if age > deadline]
