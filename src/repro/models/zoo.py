"""Model zoo dispatcher: ArchConfig -> ModelBundle."""
from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.models.encdec import make_encdec
from repro.models.hymba import make_hymba
from repro.models.rwkv import make_rwkv
from repro.models.transformer import ModelBundle, make_dense_lm, make_encoder
from repro.models.vision import make_vlm


def build_model(cfg: ArchConfig, *, num_microbatches: int = 1) -> ModelBundle:
    if cfg.family in ("dense", "moe"):
        return make_dense_lm(cfg, num_microbatches=num_microbatches)
    if cfg.family == "vlm":
        return make_vlm(cfg, num_microbatches=num_microbatches)
    if cfg.family == "audio":
        return make_encdec(cfg, num_microbatches=num_microbatches)
    if cfg.family == "hybrid":
        return make_hymba(cfg, num_microbatches=num_microbatches)
    if cfg.family == "ssm":
        return make_rwkv(cfg, num_microbatches=num_microbatches)
    if cfg.family == "encoder":
        return make_encoder(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
