"""Top-k routed MoE FFN (granite-moe, olmoe).

Dispatch strategy (TPU-native, collective-free):
  * routing, capacity and scatter/gather run *per batch row* — under pjit
    the batch dim is sharded over (pod, data), so dispatch is local to a
    data shard by construction; no distributed sort, no cross-shard
    all-to-all in the baseline.  (EP over the `model` axis is a perf
    iteration, see EXPERIMENTS.md §Perf.)
  * capacity per row C = ceil(S*K/E * capacity_factor); tokens routed past
    capacity are dropped (scattered to a dummy slot), standard
    GShard/Switch semantics.
  * expert weights are TP-sharded on the d_ff dim over `model`; the second
    grouped matmul contracts d_ff so SPMD inserts the row-parallel
    all-reduce, exactly like the dense MLP.
  * grouped matmuls run over an (E, C+1, d) dispatch buffer — compiled
    FLOPs ≈ S·K·cf active-expert compute, honest for the roofline.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import (ACC_DTYPE, AXIS_MODEL, BATCH_AXES, ParamDef,
                                 activate, einsum_acc, shard_hint)


def moe_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    defs = {
        "router": ParamDef((d, E), P(None, None), dtype=jnp.float32),
        "w_up": ParamDef((E, d, f), P(None, None, AXIS_MODEL)),
        "w_down": ParamDef((E, f, d), P(None, AXIS_MODEL, None)),
    }
    if cfg.activation in ("swiglu", "geglu"):
        defs["w_gate"] = ParamDef((E, d, f), P(None, None, AXIS_MODEL))
    return defs


def capacity_per_row(seq: int, cfg: ArchConfig) -> int:
    c = math.ceil(seq * cfg.experts_per_token / cfg.num_experts
                  * cfg.capacity_factor)
    return max(cfg.experts_per_token, min(c, seq))


def moe_block(p: Dict[str, jax.Array], x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """x: (B, S, d) -> (B, S, d); also accepts (B, d) single-token decode."""
    if x.ndim == 2:
        return moe_block(p, x[:, None, :], cfg)[:, 0]
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    C = capacity_per_row(S, cfg)

    # --- routing (f32) ---
    logits = jnp.einsum("bsd,de->bse", x.astype(ACC_DTYPE),
                        p["router"].astype(ACC_DTYPE))
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(gates, K)  # (B, S, K)
    top_g = top_g / jnp.sum(top_g, axis=-1, keepdims=True)

    # --- position-in-expert via exclusive cumsum over flattened (S*K) ---
    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.int32)  # (B,S,K,E)
    oh_flat = onehot.reshape(B, S * K, E)
    cum = jnp.cumsum(oh_flat, axis=1)  # inclusive
    pos = jnp.sum(oh_flat * (cum - 1), axis=-1)  # (B, S*K) position in expert
    e_flat = top_e.reshape(B, S * K)
    g_flat = top_g.reshape(B, S * K)
    keep = pos < C
    dest = jnp.where(keep, pos, C)  # dummy slot C for dropped tokens
    combined = e_flat * (C + 1) + dest  # (B, S*K) flat dispatch index

    # --- scatter tokens into (B, E*(C+1), d) dispatch buffer ---
    # (sharding hints are load-bearing: without them SPMD replicates the
    # scatter output over `data`, and every device runs the full-batch
    # expert GEMMs — a ~data_ways x FLOP/memory blowup, see §Perf)
    x_rep = jnp.broadcast_to(x[:, :, None, :], (B, S, K, d)).reshape(B, S * K, d)
    x_rep = shard_hint(x_rep, BATCH_AXES, None, None)
    buf = jnp.zeros((B, E * (C + 1), d), x.dtype)
    b_idx = jnp.arange(B)[:, None]
    buf = buf.at[b_idx, combined].add(x_rep)
    buf = shard_hint(buf, BATCH_AXES, None, None)
    buf = buf.reshape(B, E, C + 1, d)

    # --- grouped expert matmuls (d_ff TP-sharded over `model`) ---
    up = einsum_acc("becd,edf->becf", buf, p["w_up"]).astype(x.dtype)
    if "w_gate" in p:
        gate = einsum_acc("becd,edf->becf", buf, p["w_gate"]).astype(x.dtype)
        h = activate(gate, cfg.activation) * up
    else:
        h = activate(up, cfg.activation)
    out_buf = einsum_acc("becf,efd->becd", h, p["w_down"]).astype(x.dtype)

    # --- gather back + weighted combine over K ---
    out_flat = shard_hint(out_buf.reshape(B, E * (C + 1), d),
                          BATCH_AXES, None, None)
    picked = jnp.take_along_axis(out_flat, combined[:, :, None], axis=1)
    picked = shard_hint(picked, BATCH_AXES, None, None)
    picked = picked * (g_flat * keep.astype(g_flat.dtype))[:, :, None].astype(x.dtype)
    return picked.reshape(B, S, K, d).sum(axis=2)


def load_balance_loss(logits: jax.Array, top_e: jax.Array, E: int) -> jax.Array:
    """Switch-style auxiliary load-balancing loss (optional add-on)."""
    probs = jax.nn.softmax(logits.astype(ACC_DTYPE), axis=-1)
    me = jnp.mean(probs.reshape(-1, E), axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(top_e.reshape(-1), E, dtype=ACC_DTYPE), axis=0)
    return E * jnp.sum(me * ce)
