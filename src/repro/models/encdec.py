"""seamless-m4t-large-v2 backbone: encoder-decoder transformer.

The speech frontend is a STUB per the assignment: the encoder consumes
``audio_frames`` — (B, num_audio_frames, d_model) precomputed frame
embeddings (see ``input_specs``).  The decoder is a standard causal stack
with per-layer cross-attention to the encoder output; cross K/V are
computed once at prefill and cached.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.layers import (
    AXIS_MODEL, BATCH_AXES, ParamDef, attention_block_decode,
    attention_block_prefill, attention_defs, bidirectional_attention,
    cross_entropy_from_logits, embed_lookup, lm_head_logits, matmul,
    mlp_block, mlp_defs, rms_norm, stacked,
)
from repro.models.transformer import encoder_layer, encoder_layer_defs


def dec_layer_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    return {
        "ln1": ParamDef((d,), P(None), init="zeros"),
        "self_attn": attention_defs(cfg),
        "ln_x": ParamDef((d,), P(None), init="zeros"),
        "wq_x": ParamDef((d, cfg.q_dim), P(None, AXIS_MODEL)),
        "wk_x": ParamDef((d, cfg.kv_dim), P(None, AXIS_MODEL)),
        "wv_x": ParamDef((d, cfg.kv_dim), P(None, AXIS_MODEL)),
        "wo_x": ParamDef((cfg.q_dim, d), P(AXIS_MODEL, None)),
        "ln2": ParamDef((d,), P(None), init="zeros"),
        "mlp": mlp_defs(cfg),
    }


def _cross_apply_seq(lp, x, ck, cv, cfg):
    """x: (B, S, d); ck/cv: (B, F, KV, D)."""
    B, S, _ = x.shape
    h = rms_norm(x, lp["ln_x"], cfg.norm_eps)
    q = matmul(h, lp["wq_x"]).reshape(B, S, cfg.num_heads, cfg.head_dim)
    attn = bidirectional_attention(q, ck, cv).reshape(B, S, cfg.q_dim)
    return x + matmul(attn, lp["wo_x"])


def make_encdec(cfg: ArchConfig, *, num_microbatches: int = 1):
    from repro.models.transformer import ModelBundle

    d, v = cfg.d_model, cfg.padded_vocab
    defs = {
        "embed": ParamDef((v, d), P(AXIS_MODEL, None), scale=1.0),
        "enc_layers": stacked(encoder_layer_defs(cfg), cfg.encoder_layers),
        "enc_norm": ParamDef((d,), P(None), init="zeros"),
        "dec_layers": stacked(dec_layer_defs(cfg), cfg.num_layers),
        "final_norm": ParamDef((d,), P(None), init="zeros"),
        "lm_head": ParamDef((v, d), P(AXIS_MODEL, None)),
    }

    def encode(params, audio_frames):
        def body(x, lp):
            return encoder_layer(lp, x, cfg), None

        x, _ = jax.lax.scan(body, audio_frames, params["enc_layers"])
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def dec_layer_prefill(lp, x, enc_out):
        h, kv = attention_block_prefill(
            lp["self_attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg)
        x = x + h
        B, F = enc_out.shape[0], enc_out.shape[1]
        ck = matmul(enc_out, lp["wk_x"]).reshape(B, F, cfg.num_kv_heads,
                                                 cfg.head_dim)
        cv = matmul(enc_out, lp["wv_x"]).reshape(B, F, cfg.num_kv_heads,
                                                 cfg.head_dim)
        x = _cross_apply_seq(lp, x, ck, cv, cfg)
        x = x + mlp_block(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps),
                          cfg.activation)
        return x, kv, (ck.transpose(0, 2, 1, 3), cv.transpose(0, 2, 1, 3))

    def forward_loss(params, batch):
        enc_out = encode(params, batch["audio_frames"])
        x = embed_lookup(params["embed"], batch["tokens"])

        def body(x, lp):
            x, _, _ = dec_layer_prefill(lp, x, enc_out)
            return x, None

        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, x, params["dec_layers"])
        logits = lm_head_logits(rms_norm(x, params["final_norm"], cfg.norm_eps),
                                params["lm_head"], valid_vocab=cfg.vocab_size)
        return cross_entropy_from_logits(logits, batch["labels"])

    from repro.models.transformer import make_microbatched_loss
    loss_fn = make_microbatched_loss(forward_loss, num_microbatches)

    def prefill(params, batch):
        tokens, audio = batch["tokens"], batch["audio_frames"]
        enc_out = encode(params, audio)
        x = embed_lookup(params["embed"], tokens)

        def body(x, lp):
            x, kv, ckv = dec_layer_prefill(lp, x, enc_out)
            return x, (kv, ckv)

        x, (self_kv, cross_kv) = jax.lax.scan(body, x, params["dec_layers"])
        logits = lm_head_logits(
            rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps),
            params["lm_head"], valid_vocab=cfg.vocab_size)[..., :cfg.vocab_size]
        return logits, {"self": self_kv, "cross": cross_kv}

    def decode_step(params, cache, tokens, pos):
        x = embed_lookup(params["embed"], tokens)

        def body(x, xs):
            lp, kv, ckv = xs
            h, kv = attention_block_decode(
                lp["self_attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), kv,
                pos, cfg)
            x = x + h
            ck, cv = ckv
            B = x.shape[0]
            hq = rms_norm(x, lp["ln_x"], cfg.norm_eps)
            q = matmul(hq, lp["wq_x"]).reshape(B, cfg.num_heads, cfg.head_dim)
            attn = L.decode_attention(q, ck, cv, ck.shape[2])
            x = x + matmul(attn.reshape(B, cfg.q_dim), lp["wo_x"])
            x = x + mlp_block(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps),
                              cfg.activation)
            return x, (kv, ckv)

        x, (self_kv, cross_kv) = jax.lax.scan(
            body, x, (params["dec_layers"], cache["self"], cache["cross"]))
        logits = lm_head_logits(rms_norm(x, params["final_norm"], cfg.norm_eps),
                                params["lm_head"],
                                valid_vocab=cfg.vocab_size)[..., :cfg.vocab_size]
        return logits, {"self": self_kv, "cross": cross_kv}

    def cache_shape_fn(batch, max_len):
        s = jax.ShapeDtypeStruct(
            (cfg.num_layers, batch, cfg.num_kv_heads, max_len, cfg.head_dim),
            L.DEFAULT_DTYPE)
        c = jax.ShapeDtypeStruct(
            (cfg.num_layers, batch, cfg.num_kv_heads, cfg.num_audio_frames,
             cfg.head_dim), L.DEFAULT_DTYPE)
        return {"self": (s, s), "cross": (c, c)}

    def cache_spec_fn():
        s = P(None, BATCH_AXES, None, AXIS_MODEL, None)
        return {"self": (s, s), "cross": (s, s)}

    def audio_spec(batch):
        return jax.ShapeDtypeStruct(
            (batch, cfg.num_audio_frames, cfg.d_model), L.DEFAULT_DTYPE)

    return ModelBundle(cfg, defs, loss_fn, prefill, decode_step,
                       cache_shape_fn, cache_spec_fn,
                       {"audio_frames": audio_spec})
