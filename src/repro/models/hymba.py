"""Hymba — hybrid layers with *parallel* attention + Mamba heads.

Each layer runs, on the same normed input:
  * GQA attention heads (sliding-window on most layers; layers
    ``cfg.full_attn_layers`` use global attention), and
  * Mamba-style selective-scan heads (state ``cfg.ssm_state``),
then fuses ``x + (norm(attn) + norm(ssm)) / 2`` (the paper's mean fusion)
followed by a SwiGLU MLP.

Layer layout: full-attn layers are *unscanned* singletons, SWA layers are
scanned groups, so each layer group carries exactly the KV cache it needs
(full caches only for the 3 global layers — what makes long_500k decode
fit).  Mamba prefill uses a chunked associative scan (sequential over
chunks of 128, log-depth within).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.layers import (
    ACC_DTYPE, AXIS_MODEL, BATCH_AXES, ParamDef, attention_block_decode,
    attention_block_prefill, attention_defs, cross_entropy_from_logits,
    embed_lookup, lm_head_logits, matmul, mlp_block, mlp_defs, rms_norm,
    stacked,
)

SSM_CHUNK = 128
CONV_K = 4
DT_RANK = 48


# ---------------------------------------------------------------------------
# Mamba head block
# ---------------------------------------------------------------------------


def mamba_defs(cfg: ArchConfig) -> dict:
    d, h, n = cfg.d_model, cfg.q_dim, cfg.ssm_state
    return {
        "w_in": ParamDef((d, 2 * h), P(None, AXIS_MODEL)),
        "conv_w": ParamDef((h, CONV_K), P(AXIS_MODEL, None), scale=0.5),
        "w_xdbc": ParamDef((h, DT_RANK + 2 * n), P(AXIS_MODEL, None)),
        "w_dt": ParamDef((DT_RANK, h), P(None, AXIS_MODEL), scale=0.1),
        "dt_bias": ParamDef((h,), P(AXIS_MODEL), init="zeros"),
        "a_log": ParamDef((h, n), P(AXIS_MODEL, None), init="decay_init",
                          dtype=jnp.float32),
        "d_skip": ParamDef((h,), P(AXIS_MODEL), init="ones"),
        "w_out": ParamDef((h, d), P(AXIS_MODEL, None)),
    }


def _mamba_proj(p: dict, xz: jax.Array, n: int):
    """Shared projections. xz: (..., 2h) -> (x, z, dt, Bc, Cc)."""
    h = xz.shape[-1] // 2
    x, z = xz[..., :h], xz[..., h:]
    dbc = matmul(x, p["w_xdbc"])
    dt_r, Bc, Cc = (dbc[..., :DT_RANK], dbc[..., DT_RANK:DT_RANK + n],
                    dbc[..., DT_RANK + n:])
    dt = jax.nn.softplus(matmul(dt_r, p["w_dt"]).astype(ACC_DTYPE)
                         + p["dt_bias"].astype(ACC_DTYPE))
    return x, z, dt, Bc, Cc


def mamba_prefill(p: dict, xin: jax.Array, conv_state: jax.Array,
                  ssm_state: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """xin: (B, S, d). conv_state: (B, h, K-1). ssm_state: (B, h, n) f32."""
    B, S, d = xin.shape
    n = ssm_state.shape[-1]
    xz = matmul(xin, p["w_in"])
    h = xz.shape[-1] // 2
    x, z = xz[..., :h], xz[..., h:]
    # causal depthwise conv over time
    x_t = x.transpose(0, 2, 1)  # (B, h, S)
    x_pad = jnp.concatenate([conv_state, x_t], axis=-1)
    conv = sum(x_pad[:, :, i:i + S] * p["conv_w"][None, :, i:i + 1]
               for i in range(CONV_K))
    new_conv_state = x_pad[:, :, -(CONV_K - 1):]
    x = jax.nn.silu(conv.transpose(0, 2, 1))  # (B, S, h)
    dbc = matmul(x, p["w_xdbc"])
    dt = jax.nn.softplus(matmul(dbc[..., :DT_RANK], p["w_dt"]).astype(ACC_DTYPE)
                         + p["dt_bias"].astype(ACC_DTYPE))  # (B,S,h)
    Bc = dbc[..., DT_RANK:DT_RANK + n].astype(ACC_DTYPE)  # (B,S,n)
    Cc = dbc[..., DT_RANK + n:].astype(ACC_DTYPE)
    A = -jnp.exp(p["a_log"].astype(ACC_DTYPE))  # (h, n)

    C_ = min(SSM_CHUNK, S)
    assert S % C_ == 0
    n_chunks = S // C_

    def chunk_body(s0, xs):
        x_c, dt_c, b_c, c_c = xs  # (B,C,h) / (B,C,h) / (B,C,n) / (B,C,n)
        decay = jnp.exp(dt_c[..., None] * A)  # (B,C,h,n)
        add = (dt_c * x_c.astype(ACC_DTYPE))[..., None] * b_c[:, :, None, :]

        def combine(a, b):
            return (b[0] * a[0], b[0] * a[1] + b[1])

        cumdecay, s_intra = jax.lax.associative_scan(combine, (decay, add), axis=1)
        s_all = s_intra + cumdecay * s0[:, None]  # (B,C,h,n)
        y = jnp.einsum("bchn,bcn->bch", s_all, c_c)
        y = y + p["d_skip"].astype(ACC_DTYPE) * x_c.astype(ACC_DTYPE)
        return s_all[:, -1], y.astype(xin.dtype)

    xs = tuple(a.reshape(B, n_chunks, C_, -1).transpose(1, 0, 2, 3)
               for a in (x, dt, Bc, Cc))
    ssm_state, ys = jax.lax.scan(chunk_body, ssm_state.astype(ACC_DTYPE), xs)
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, h)
    y = y * jax.nn.silu(z)
    return matmul(y, p["w_out"]), new_conv_state, ssm_state


def mamba_decode(p: dict, xin: jax.Array, conv_state: jax.Array,
                 ssm_state: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """xin: (B, d) one token."""
    B, d = xin.shape
    n = ssm_state.shape[-1]
    xz = matmul(xin, p["w_in"])
    h = xz.shape[-1] // 2
    x, z = xz[..., :h], xz[..., h:]
    x_hist = jnp.concatenate([conv_state, x[..., None]], axis=-1)  # (B,h,K)
    conv = jnp.sum(x_hist * p["conv_w"][None], axis=-1)
    new_conv_state = x_hist[:, :, 1:]
    x = jax.nn.silu(conv)
    dbc = matmul(x, p["w_xdbc"])
    dt = jax.nn.softplus(matmul(dbc[..., :DT_RANK], p["w_dt"]).astype(ACC_DTYPE)
                         + p["dt_bias"].astype(ACC_DTYPE))  # (B,h)
    Bc = dbc[..., DT_RANK:DT_RANK + n].astype(ACC_DTYPE)
    Cc = dbc[..., DT_RANK + n:].astype(ACC_DTYPE)
    A = -jnp.exp(p["a_log"].astype(ACC_DTYPE))
    decay = jnp.exp(dt[..., None] * A)  # (B,h,n)
    ssm_state = (ssm_state * decay
                 + (dt * x.astype(ACC_DTYPE))[..., None] * Bc[:, None, :])
    y = jnp.einsum("bhn,bn->bh", ssm_state, Cc)
    y = y + p["d_skip"].astype(ACC_DTYPE) * x.astype(ACC_DTYPE)
    y = y.astype(xin.dtype) * jax.nn.silu(z)
    return matmul(y, p["w_out"]), new_conv_state, ssm_state


# ---------------------------------------------------------------------------
# Hymba layer (parallel attn + mamba, mean fusion)
# ---------------------------------------------------------------------------


def hymba_layer_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    return {
        "ln1": ParamDef((d,), P(None), init="zeros"),
        "attn": attention_defs(cfg),
        "mamba": mamba_defs(cfg),
        "fuse_na": ParamDef((d,), P(None), init="zeros"),
        "fuse_ns": ParamDef((d,), P(None), init="zeros"),
        "ln2": ParamDef((d,), P(None), init="zeros"),
        "mlp": mlp_defs(cfg),
    }


def hymba_layer_prefill(lp: dict, x: jax.Array, cfg: ArchConfig, window: int,
                        conv_state, ssm_state):
    h_in = rms_norm(x, lp["ln1"], cfg.norm_eps)
    attn_out, kv = attention_block_prefill(lp["attn"], h_in, cfg, window=window)
    ssm_out, conv_state, ssm_state = mamba_prefill(lp["mamba"], h_in,
                                                   conv_state, ssm_state)
    fused = 0.5 * (rms_norm(attn_out, lp["fuse_na"], cfg.norm_eps)
                   + rms_norm(ssm_out, lp["fuse_ns"], cfg.norm_eps))
    x = x + fused
    x = x + mlp_block(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps),
                      cfg.activation)
    return x, kv, conv_state, ssm_state


def hymba_layer_decode(lp: dict, x: jax.Array, cfg: ArchConfig, window: int,
                       kv, pos, conv_state, ssm_state):
    h_in = rms_norm(x, lp["ln1"], cfg.norm_eps)
    attn_out, kv = attention_block_decode(lp["attn"], h_in, kv, pos, cfg,
                                          window=window)
    ssm_out, conv_state, ssm_state = mamba_decode(lp["mamba"], h_in,
                                                  conv_state, ssm_state)
    fused = 0.5 * (rms_norm(attn_out, lp["fuse_na"], cfg.norm_eps)
                   + rms_norm(ssm_out, lp["fuse_ns"], cfg.norm_eps))
    x = x + fused
    x = x + mlp_block(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps),
                      cfg.activation)
    return x, kv, conv_state, ssm_state


# ---------------------------------------------------------------------------
# Group structure: full-attn singletons + scanned SWA groups
# ---------------------------------------------------------------------------


def _groups(cfg: ArchConfig):
    """Split layer indices into alternating (is_full, count) groups."""
    full = set(cfg.full_attn_layers)
    groups = []
    i = 0
    while i < cfg.num_layers:
        if i in full:
            groups.append(("full", 1))
            i += 1
        else:
            j = i
            while j < cfg.num_layers and j not in full:
                j += 1
            groups.append(("swa", j - i))
            i = j
    return groups


def make_hymba(cfg: ArchConfig, *, num_microbatches: int = 1):
    from repro.models.transformer import ModelBundle

    d, v = cfg.d_model, cfg.padded_vocab
    groups = _groups(cfg)
    defs = {"embed": ParamDef((v, d), P(AXIS_MODEL, None), scale=1.0),
            "final_norm": ParamDef((d,), P(None), init="zeros"),
            "lm_head": ParamDef((v, d), P(AXIS_MODEL, None)),
            "groups": []}
    for kind, count in groups:
        ld = hymba_layer_defs(cfg)
        defs["groups"].append(ld if kind == "full" else stacked(ld, count))
    defs["groups"] = tuple(defs["groups"])

    h, n = cfg.q_dim, cfg.ssm_state

    def group_cache_shapes(kind, count, batch, max_len):
        kvlen = max_len if kind == "full" else min(cfg.sliding_window, max_len)
        lead = () if kind == "full" else (count,)
        mk = lambda s, dt=L.DEFAULT_DTYPE: jax.ShapeDtypeStruct(lead + s, dt)
        return {
            "k": mk((batch, cfg.num_kv_heads, kvlen, cfg.head_dim)),
            "v": mk((batch, cfg.num_kv_heads, kvlen, cfg.head_dim)),
            "conv": mk((batch, h, CONV_K - 1)),
            "ssm": mk((batch, h, n), jnp.float32),
        }

    def cache_shape_fn(batch, max_len):
        return tuple(group_cache_shapes(kind, count, batch, max_len)
                     for kind, count in groups)

    def cache_spec_fn():
        out = []
        for kind, count in groups:
            lead = () if kind == "full" else (None,)
            out.append({
                "k": P(*(lead + (BATCH_AXES, None, AXIS_MODEL, None))),
                "v": P(*(lead + (BATCH_AXES, None, AXIS_MODEL, None))),
                "conv": P(*(lead + (BATCH_AXES, AXIS_MODEL, None))),
                "ssm": P(*(lead + (BATCH_AXES, AXIS_MODEL, None))),
            })
        return tuple(out)

    def fresh_group_states(batch, count=None):
        lead = () if count is None else (count,)
        return (jnp.zeros(lead + (batch, h, CONV_K - 1), L.DEFAULT_DTYPE),
                jnp.zeros(lead + (batch, h, n), jnp.float32))

    def run_prefill(params, x, collect_cache: bool):
        B, S = x.shape[0], x.shape[1]
        caches = []
        for gi, (kind, count) in enumerate(groups):
            gp = params["groups"][gi]
            window = 0 if kind == "full" else cfg.sliding_window
            if kind == "full":
                conv0, ssm0 = fresh_group_states(B)
                x, kv, conv, ssm = hymba_layer_prefill(gp, x, cfg, window,
                                                       conv0, ssm0)
                if collect_cache:
                    kvlen = S
                    caches.append({"k": kv[0], "v": kv[1], "conv": conv,
                                   "ssm": ssm})
            else:
                conv0, ssm0 = fresh_group_states(B, count)

                if collect_cache:
                    def body(x, xs):
                        lp, c0, s0 = xs
                        x, kv, c1, s1 = hymba_layer_prefill(lp, x, cfg, window,
                                                            c0, s0)
                        W = min(cfg.sliding_window, S)
                        return x, (kv[0][:, :, -W:], kv[1][:, :, -W:], c1, s1)

                    x, (ks, vs, convs, ssms) = jax.lax.scan(
                        body, x, (gp, conv0, ssm0))
                    caches.append({"k": ks, "v": vs, "conv": convs,
                                   "ssm": ssms})
                else:
                    def body(x, xs):
                        lp, c0, s0 = xs
                        x, _, _, _ = hymba_layer_prefill(lp, x, cfg, window,
                                                         c0, s0)
                        return x, None

                    body = jax.checkpoint(
                        body, policy=jax.checkpoint_policies.nothing_saveable)
                    x, _ = jax.lax.scan(body, x, (gp, conv0, ssm0))
        return x, tuple(caches)

    def forward_loss(params, batch):
        x = embed_lookup(params["embed"], batch["tokens"])
        x, _ = run_prefill(params, x, collect_cache=False)
        logits = lm_head_logits(rms_norm(x, params["final_norm"], cfg.norm_eps),
                                params["lm_head"], valid_vocab=cfg.vocab_size)
        return cross_entropy_from_logits(logits, batch["labels"])

    from repro.models.transformer import make_microbatched_loss
    loss_fn = make_microbatched_loss(forward_loss, num_microbatches)

    def prefill(params, batch):
        tokens = batch["tokens"]
        x = embed_lookup(params["embed"], tokens)
        x, caches = run_prefill(params, x, collect_cache=True)
        logits = lm_head_logits(
            rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps),
            params["lm_head"], valid_vocab=cfg.vocab_size)[..., :cfg.vocab_size]
        return logits, caches

    def decode_step(params, cache, tokens, pos):
        x = embed_lookup(params["embed"], tokens)
        new_cache = []
        for gi, (kind, count) in enumerate(groups):
            gp = params["groups"][gi]
            gc = cache[gi]
            window = 0 if kind == "full" else cfg.sliding_window
            if kind == "full":
                x, kv, conv, ssm = hymba_layer_decode(
                    gp, x, cfg, window, (gc["k"], gc["v"]), pos,
                    gc["conv"], gc["ssm"])
                new_cache.append({"k": kv[0], "v": kv[1], "conv": conv,
                                  "ssm": ssm})
            else:
                def body(x, xs):
                    lp, k, v_, c0, s0 = xs
                    x, kv, c1, s1 = hymba_layer_decode(
                        lp, x, cfg, window, (k, v_), pos, c0, s0)
                    return x, (kv[0], kv[1], c1, s1)

                x, (ks, vs, convs, ssms) = jax.lax.scan(
                    body, x, (gp, gc["k"], gc["v"], gc["conv"], gc["ssm"]))
                new_cache.append({"k": ks, "v": vs, "conv": convs,
                                  "ssm": ssms})
        logits = lm_head_logits(rms_norm(x, params["final_norm"], cfg.norm_eps),
                                params["lm_head"],
                                valid_vocab=cfg.vocab_size)[..., :cfg.vocab_size]
        return logits, tuple(new_cache)

    return ModelBundle(cfg, defs, loss_fn, prefill, decode_step,
                       cache_shape_fn, cache_spec_fn, {})
