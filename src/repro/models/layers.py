"""Core model building blocks (pure JAX).

Conventions
-----------
* Params are pytrees of arrays; every leaf is declared via :class:`ParamDef`
  which carries shape, init and its TP :class:`PartitionSpec` — a single
  source of truth for ``init``, ``jax.eval_shape`` and pjit shardings.
* Layer-stacked params carry a leading ``L`` dim and are consumed by
  ``jax.lax.scan`` so HLO size is O(1) in depth.
* Attention is implemented as *chunked causal flash* in pure jnp: a static
  unrolled loop over query chunks, each attending to its (static) KV prefix
  slice.  This keeps memory O(S·chunk), achieves causal-optimal FLOPs, and
  lowers on any XLA backend — the Pallas kernels in ``repro.kernels`` are
  the TPU-native implementations of the same contractions and are validated
  against these functions.
* Matmuls accumulate in f32 (``preferred_element_type``); params default
  bf16.
"""
from __future__ import annotations

import math
import os
from dataclasses import dataclass
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

DEFAULT_DTYPE = jnp.bfloat16
ACC_DTYPE = jnp.float32

# The CPU backend's batched DotThunk cannot *execute* bf16 x bf16 -> f32
# dots (compilation is fine).  Anything that actually runs on this
# container (smoke tests, the serving engine, examples) therefore upcasts
# to f32 before accumulating dots; the dry-run — which only lowers and
# compiles for the TPU-shaped mesh — sets REPRO_EXEC_SAFE=0 to keep
# TPU-faithful bf16 dots with f32 accumulation in the compiled HLO.
EXEC_SAFE = os.environ.get("REPRO_EXEC_SAFE", "1") == "1"


def einsum_acc(spec: str, a: jax.Array, b: jax.Array) -> jax.Array:
    """einsum with f32 accumulation, f32 output; CPU-executable."""
    if EXEC_SAFE:
        return jnp.einsum(spec, a.astype(ACC_DTYPE), b.astype(ACC_DTYPE))
    return jnp.einsum(spec, a, b, preferred_element_type=ACC_DTYPE)

# Mesh axis names used across the framework (see repro/launch/mesh.py).
AXIS_POD = "pod"
AXIS_DATA = "data"
AXIS_MODEL = "model"
# Batch dims shard over (pod, data) jointly.
BATCH_AXES = (AXIS_POD, AXIS_DATA)


def _ambient_mesh_axis_names() -> set:
    """Axis names of the ambient mesh, across JAX versions.

    ``jax.sharding.get_abstract_mesh`` only exists in newer JAX; older
    releases expose the ambient mesh via the pxla thread-resources env.
    Outside any mesh context (or if neither API exists) returns the empty
    set, making :func:`shard_hint` a no-op hint.
    """
    get_am = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_am is not None:
        mesh = get_am()
        return set(getattr(mesh, "axis_names", ()) or ())
    try:
        from jax.interpreters import pxla

        mesh = pxla.thread_resources.env.physical_mesh
        if mesh is not None and not mesh.empty:
            return set(mesh.axis_names)
    except (ImportError, AttributeError):
        pass
    return set()


def shard_hint(x: jax.Array, *entries) -> jax.Array:
    """with_sharding_constraint against whatever mesh axes exist.

    Entries may name axes ('model', ('pod','data')) or be None; axes absent
    from the ambient mesh are dropped, and with no mesh this is a no-op —
    so model code can carry sharding hints without breaking CPU tests.
    """
    names = _ambient_mesh_axis_names()
    if not names:
        return x

    def fix(e):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a in names)
            return (kept if len(kept) > 1 else (kept[0] if kept else None))
        return e if e in names else None

    spec = P(*(fix(e) for e in entries))
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamDef:
    """Declarative parameter: shape + init + partition spec."""

    shape: Tuple[int, ...]
    spec: P
    init: str = "normal"  # normal | zeros | ones | decay_init
    scale: Optional[float] = None
    dtype: Any = DEFAULT_DTYPE

    def instantiate(self, key: jax.Array) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "decay_init":
            # log-spaced decay init for SSM/RWKV A/w params, in (-8, -4]
            n = self.shape[-1]
            base = -5.0 + 4.0 * (jnp.arange(n, dtype=jnp.float32) / max(n - 1, 1))
            return jnp.broadcast_to(base, self.shape).astype(self.dtype)
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        scale = self.scale if self.scale is not None else 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(key, self.shape, jnp.float32) * scale).astype(self.dtype)


def init_params(defs, rng: jax.Array):
    """Instantiate a pytree of ParamDef with per-leaf folded keys."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(rng, len(leaves))
    vals = [d.instantiate(k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def param_specs(defs):
    return jax.tree.map(lambda d: d.spec, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def param_shapes(defs):
    return jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def stack_layer_defs(d: ParamDef, n_layers: int) -> ParamDef:
    """Prepend a layer dim to a ParamDef (for scan-stacked params)."""
    return ParamDef((n_layers,) + d.shape, P(*((None,) + tuple(d.spec))),
                    d.init, d.scale, d.dtype)


def stacked(defs, n_layers: int):
    return jax.tree.map(lambda d: stack_layer_defs(d, n_layers), defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


# ---------------------------------------------------------------------------
# Norms / activations / embeddings
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(ACC_DTYPE)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + scale.astype(ACC_DTYPE))).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(ACC_DTYPE)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(ACC_DTYPE)
            + bias.astype(ACC_DTYPE)).astype(x.dtype)


def activate(x: jax.Array, kind: str) -> jax.Array:
    if kind in ("swiglu", "silu"):
        return jax.nn.silu(x)
    if kind in ("geglu", "gelu"):
        return jax.nn.gelu(x)
    if kind == "relu_sq":
        return jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {kind!r}")


# §Perf knob: when a TP-contracted matmul's partial sums cross chips,
# reducing them in bf16 halves the dominant train-cell collective bytes
# (per-chip accumulation inside the MXU stays f32 either way).  XLA
# places the all-reduce at the dot's output dtype, so emitting bf16 dots
# for row-parallel matmuls moves the reduction to bf16.
BF16_ALLREDUCE = os.environ.get("REPRO_BF16_AR", "0") == "1"


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """x @ w with f32 accumulation, output in x.dtype."""
    if EXEC_SAFE:  # CPU DotThunk can't execute some bf16 dot shapes
        out = jax.lax.dot_general(
            x.astype(ACC_DTYPE), w.astype(ACC_DTYPE),
            (((x.ndim - 1,), (0,)), ((), ())))
        return out.astype(x.dtype)
    if BF16_ALLREDUCE and x.dtype == jnp.bfloat16:
        return jax.lax.dot_general(
            x, w, (((x.ndim - 1,), (0,)), ((), ())))
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=ACC_DTYPE).astype(x.dtype)


def embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    """Vocab-sharded embedding lookup (take; SPMD inserts collectives)."""
    return jnp.take(table, tokens, axis=0)


def lm_head_logits(x: jax.Array, table: jax.Array,
                   valid_vocab: Optional[int] = None) -> jax.Array:
    """Project to vocab; table is (V_padded, d) embedding (possibly tied).

    Output logits are pinned vocab-sharded over `model` (and batch over
    (pod, data)) — without the hint SPMD sometimes materializes the full
    vocab per device, which is a ~50 GiB/device blowup at V=256k.
    Padded vocab rows (table rows >= valid_vocab) are masked to -1e30.
    """
    if EXEC_SAFE:
        logits = jax.lax.dot_general(
            x.astype(ACC_DTYPE), table.astype(ACC_DTYPE),
            (((x.ndim - 1,), (1,)), ((), ())))
    else:
        logits = jax.lax.dot_general(
            x, table, (((x.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=ACC_DTYPE)
    if valid_vocab is not None and valid_vocab < table.shape[0]:
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                        logits.ndim - 1)
        logits = jnp.where(iota < valid_vocab, logits, -1e30)
    hint = [BATCH_AXES] + [None] * (logits.ndim - 2) + [AXIS_MODEL]
    return shard_hint(logits, *hint)


def cross_entropy_from_logits(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over (B, S) without materializing one-hot.

    ``logits`` may be vocab-sharded; the reductions over vocab induce
    all-reduces under SPMD.
    """
    logits = logits.astype(ACC_DTYPE)
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    picked = jnp.sum(jnp.where(iota == labels[..., None], logits, 0.0), axis=-1)
    return jnp.mean(lse - picked)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n_heads, head_dim); positions: (..., S) or (S,)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=ACC_DTYPE) / half)
    angles = positions.astype(ACC_DTYPE)[..., None] * freq  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(ACC_DTYPE), x[..., half:].astype(ACC_DTYPE)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention — chunked causal flash (pure jnp, causal-FLOP-honest)
# ---------------------------------------------------------------------------


def _attn_one_chunk(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_pos: jax.Array, k_pos: jax.Array,
                    window: int, scale: float,
                    logit_softcap: float = 0.0) -> jax.Array:
    """Full softmax attention of a query chunk over a KV slice.

    q: (B, KV, G, Q, D); k/v: (B, KV, S, D). Returns (B, KV, G, Q, D).
    """
    scores = einsum_acc("bkgqd,bksd->bkgqs", q, k) * scale
    if logit_softcap > 0.0:
        scores = logit_softcap * jnp.tanh(scores / logit_softcap)
    mask = k_pos[None, :] <= q_pos[:, None]  # causal
    if window > 0:
        mask &= k_pos[None, :] > (q_pos[:, None] - window)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return einsum_acc("bkgqs,bksd->bkgqd", probs, v).astype(q.dtype)


# §Perf knob: query-chunk size of the jnp flash path (smaller = less
# f32 score transient per chunk, more HLO). The Pallas kernel supersedes
# this on real TPU.
Q_CHUNK = int(os.environ.get("REPRO_Q_CHUNK", "1024"))


def causal_flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                           *, window: int = 0, q_chunk: int = 0,
                           logit_softcap: float = 0.0) -> jax.Array:
    """Causal (optionally sliding-window) attention, GQA-aware.

    q: (B, S, H, D);  k, v: (B, S, KV, D).  Returns (B, S, H, D).

    Statically unrolls over query chunks; chunk *i* attends only to its KV
    prefix (or window band), so compiled FLOPs match the causal optimum
    instead of paying the full dense S^2.
    """
    B, S, H, D = q.shape
    if q_chunk <= 0:
        q_chunk = Q_CHUNK
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, S, KV, G, D).transpose(0, 2, 3, 1, 4)  # (B,KV,G,S,D)
    kt = k.transpose(0, 2, 1, 3)  # (B,KV,S,D)
    vt = v.transpose(0, 2, 1, 3)
    q_chunk = min(q_chunk, S)
    n_chunks = (S + q_chunk - 1) // q_chunk
    outs = []
    for i in range(n_chunks):
        lo, hi = i * q_chunk, min((i + 1) * q_chunk, S)
        if window > 0:
            k_lo = max(0, lo - (window - 1))
        else:
            k_lo = 0
        q_i = qg[:, :, :, lo:hi]
        k_i = kt[:, :, k_lo:hi]
        v_i = vt[:, :, k_lo:hi]
        q_pos = jnp.arange(lo, hi)
        k_pos = jnp.arange(k_lo, hi)
        outs.append(_attn_one_chunk(q_i, k_i, v_i, q_pos, k_pos, window,
                                    scale, logit_softcap))
    out = jnp.concatenate(outs, axis=3) if len(outs) > 1 else outs[0]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, D)


def bidirectional_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Full bidirectional attention (encoder / cross-attention).

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D).
    """
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, KV, G, D)
    scores = einsum_acc("bqkgd,bskd->bkgqs", qg, k) * scale
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = einsum_acc("bkgqs,bskd->bqkgd", probs, v).astype(q.dtype)
    return out.reshape(B, Sq, H, D)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *, window: int = 0,
                     pos: Optional[jax.Array] = None,
                     logit_softcap: float = 0.0) -> jax.Array:
    """Single-token decode attention over a (possibly seq-sharded) cache.

    q: (B, H, D); k_cache/v_cache: (B, KV, Smax, D); cache_len: () or (B,)
    number of valid entries.  Softmax over the cache axis; when the cache
    is sharded over `model` on Smax, SPMD inserts the flash-decoding style
    all-reduce merges automatically.
    """
    B, H, D = q.shape
    KV, Smax = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, KV, G, D)
    scores = einsum_acc("bkgd,bksd->bkgs", qg, k_cache) * scale
    if logit_softcap > 0.0:
        scores = logit_softcap * jnp.tanh(scores / logit_softcap)
    idx = jnp.arange(Smax)
    valid = idx[None] < jnp.broadcast_to(jnp.asarray(cache_len), (B,))[:, None]
    if window > 0 and pos is not None:
        # ring-buffer semantics handled by caller; here mask positions
        valid &= idx[None] > (jnp.broadcast_to(jnp.asarray(pos), (B,))[:, None] - window)
    scores = jnp.where(valid[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = einsum_acc("bkgs,bksd->bkgd", probs, v_cache).astype(q.dtype)
    return out.reshape(B, H, D)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + flash / decode)
# ---------------------------------------------------------------------------


def attention_defs(cfg) -> dict:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    defs = {
        "wq": ParamDef((d, qd), P(None, AXIS_MODEL)),
        "wk": ParamDef((d, kvd), P(None, AXIS_MODEL)),
        "wv": ParamDef((d, kvd), P(None, AXIS_MODEL)),
        "wo": ParamDef((qd, d), P(AXIS_MODEL, None)),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((qd,), P(AXIS_MODEL), init="zeros")
        defs["bk"] = ParamDef((kvd,), P(AXIS_MODEL), init="zeros")
        defs["bv"] = ParamDef((kvd,), P(AXIS_MODEL), init="zeros")
    return defs


def attention_qkv(p: dict, x: jax.Array, positions: jax.Array, cfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, d) -> q (B,S,H,D), k/v (B,S,KV,D), rope applied."""
    B, S, _ = x.shape
    q = matmul(x, p["wq"])
    k = matmul(x, p["wk"])
    v = matmul(x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block_prefill(p: dict, x: jax.Array, cfg, *, window: int = 0
                            ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Returns (output (B,S,d), (k,v) for the cache, layout (B,KV,S,D))."""
    B, S, _ = x.shape
    positions = jnp.arange(S)
    q, k, v = attention_qkv(p, x, positions, cfg)
    out = causal_flash_attention(q, k, v, window=window)
    out = matmul(out.reshape(B, S, cfg.q_dim), p["wo"])
    kc = k.transpose(0, 2, 1, 3)
    vc = v.transpose(0, 2, 1, 3)
    return out, (kc, vc)


def write_kv(cache: jax.Array, new: jax.Array, idx: jax.Array) -> jax.Array:
    """Write ``new`` (B, KV, 1, D) into ``cache`` (B, KV, Smax, D) at ``idx``.

    ``idx`` is a scalar (uniform position — dry-run / lockstep decode) or a
    per-sequence (B,) vector (continuous batching).
    """
    new = new.astype(cache.dtype)
    idx = jnp.asarray(idx)
    if idx.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(cache, new, idx, axis=2)
    return jax.vmap(
        lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, axis=1)
    )(cache, new, idx)


def attention_block_decode(p: dict, x: jax.Array, kv_cache: Tuple[jax.Array, jax.Array],
                           pos: jax.Array, cfg, *, window: int = 0
                           ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """x: (B, d) single token at position ``pos`` (scalar or (B,) int32).

    Writes K/V at ``pos`` (mod Smax for sliding-window ring buffers) and
    attends over the valid cache prefix.
    """
    B, _ = x.shape
    k_cache, v_cache = kv_cache
    Smax = k_cache.shape[2]
    positions = jnp.broadcast_to(jnp.asarray(pos).reshape(-1, 1) if jnp.asarray(pos).ndim
                                 else pos, (B, 1))
    q, k, v = attention_qkv(p, x[:, None, :], positions, cfg)
    write_idx = pos % Smax if window > 0 else pos
    kc = write_kv(k_cache, k.transpose(0, 2, 1, 3), write_idx)
    vc = write_kv(v_cache, v.transpose(0, 2, 1, 3), write_idx)
    cache_len = jnp.minimum(jnp.asarray(pos) + 1, Smax)
    out = decode_attention(q[:, 0], kc, vc, cache_len,
                           window=0)  # ring buffer: all Smax entries valid once full
    out = matmul(out.reshape(B, cfg.q_dim), p["wo"])
    return out, (kc, vc)


# ---------------------------------------------------------------------------
# Dense MLP block
# ---------------------------------------------------------------------------


def mlp_defs(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.activation in ("swiglu", "geglu"):
        return {
            "w_gate": ParamDef((d, f), P(None, AXIS_MODEL)),
            "w_up": ParamDef((d, f), P(None, AXIS_MODEL)),
            "w_down": ParamDef((f, d), P(AXIS_MODEL, None)),
        }
    return {
        "w_up": ParamDef((d, f), P(None, AXIS_MODEL)),
        "w_down": ParamDef((f, d), P(AXIS_MODEL, None)),
    }


def mlp_block(p: dict, x: jax.Array, activation: str) -> jax.Array:
    if "w_gate" in p:
        h = activate(matmul(x, p["w_gate"]), activation) * matmul(x, p["w_up"])
    else:
        h = activate(matmul(x, p["w_up"]), activation)
    return matmul(h, p["w_down"])
