"""RWKV6 "Finch" — attention-free, data-dependent decay (rwkv6-7b).

The WKV6 recurrence per head (head_dim = 64):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (state: head_dim x head_dim)
    o_t = r_t S_{t-1} + (r_t . (u (.) k_t)) v_t   (bonus for current token)

Prefill runs a *chunked* formulation (sequential lax.scan over chunks of
``CHUNK`` tokens; within a chunk the pairwise decays are computed directly
as masked exponentials, all exponents <= 0 so it is numerically stable in
f32 without the overflow-prone 1/decay factorisation).  The Pallas kernel
in ``repro.kernels.rwkv6_scan`` implements the same chunked contraction
for TPU; this module is its jnp oracle and the dry-run path.

Decode is the O(1) recurrence; long_500k is native for this arch.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.layers import (ACC_DTYPE,
                                 AXIS_MODEL,
                                 BATCH_AXES,
                                 ParamDef,
                                 activate,
                                 cross_entropy_from_logits,
                                 embed_lookup,
                                 lm_head_logits,
                                 matmul,
                                 rms_norm,
                                 stacked)

CHUNK = 64
LORA_R = 64  # decay lora rank
DDLERP_R = 32  # data-dependent lerp rank


# ---------------------------------------------------------------------------
# Chunked WKV6
# ---------------------------------------------------------------------------


def wkv6_chunked(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
                 u: jax.Array, state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Chunked WKV6 over a full sequence.

    r, k, v: (B, H, S, D); logw: (B, H, S, D) (<= 0); u: (H, D);
    state: (B, H, D, D) f32 (k-dim x v-dim).  Returns (out (B,H,S,D), state').
    """
    B, H, S, D = r.shape
    C = min(CHUNK, S)
    assert S % C == 0, f"seq {S} not divisible by chunk {C}"
    n_chunks = S // C

    def chunk_body(carry, xs):
        S0 = carry
        rc, kc, vc, lwc = xs  # (B, H, C, D)
        rc32, kc32, vc32 = (a.astype(ACC_DTYPE) for a in (rc, kc, vc))
        cum = jnp.cumsum(lwc, axis=2)  # inclusive cumulative log-decay
        excl = cum - lwc  # exclusive (= cum at t-1)
        # inter-chunk: r_t decayed to chunk start, applied to carried state
        r_dec = rc32 * jnp.exp(excl)
        out_inter = jnp.einsum("bhck,bhkv->bhcv", r_dec, S0)
        # intra-chunk pairwise: decay[t, tau] = exp(excl_t - cum_tau), tau < t
        diff = excl[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,H,Ct,Ctau,D)
        t_idx = jnp.arange(C)
        mask = (t_idx[:, None] > t_idx[None, :])[None, None, :, :, None]
        decay = jnp.exp(jnp.where(mask, diff, -jnp.inf))
        A = jnp.einsum("bhtk,bhsk,bhtsk->bhts", rc32, kc32, decay)
        a_diag = jnp.einsum("bhtk,hk,bhtk->bht", rc32,
                            u.astype(ACC_DTYPE), kc32)
        out_intra = (jnp.einsum("bhts,bhsv->bhtv", A, vc32)
                     + a_diag[..., None] * vc32)
        # state update to chunk end
        k_dec = kc32 * jnp.exp(cum[:, :, -1:, :] - cum)
        S1 = (S0 * jnp.exp(cum[:, :, -1, :])[..., None]
              + jnp.einsum("bhck,bhcv->bhkv", k_dec, vc32))
        return S1, (out_inter + out_intra).astype(r.dtype)

    xs = tuple(a.reshape(B, H, n_chunks, C, D).transpose(2, 0, 1, 3, 4)
               for a in (r, k, v, logw.astype(ACC_DTYPE)))
    state, outs = jax.lax.scan(chunk_body, state.astype(ACC_DTYPE), xs)
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, D)
    return out, state


def wkv6_step(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
              u: jax.Array, state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single decode step. r,k,v,logw: (B, H, D); state: (B, H, D, D)."""
    r32, k32, v32 = (a.astype(ACC_DTYPE) for a in (r, k, v))
    out = (jnp.einsum("bhk,bhkv->bhv", r32, state)
           + jnp.einsum("bhk,hk,bhk->bh", r32, u.astype(ACC_DTYPE), k32)[..., None] * v32)
    state = (state * jnp.exp(logw.astype(ACC_DTYPE))[..., None]
             + k32[..., None] * v32[..., None, :])
    return out.astype(r.dtype), state


# ---------------------------------------------------------------------------
# Layer definitions
# ---------------------------------------------------------------------------


def rwkv_layer_defs(cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "ln1": ParamDef((d,), P(None), init="zeros"),
        "tm": {
            # data-dependent lerp (5 targets: r, k, v, w, g)
            "mu_x": ParamDef((d,), P(None), init="zeros"),
            "mu": ParamDef((5, d), P(None, None), init="zeros"),
            "ddl_w1": ParamDef((d, 5 * DDLERP_R), P(None, None), scale=1e-2),
            "ddl_w2": ParamDef((5, DDLERP_R, d), P(None, None, None), scale=1e-2),
            "wr": ParamDef((d, d), P(None, AXIS_MODEL)),
            "wk": ParamDef((d, d), P(None, AXIS_MODEL)),
            "wv": ParamDef((d, d), P(None, AXIS_MODEL)),
            "wg": ParamDef((d, d), P(None, AXIS_MODEL)),
            "wo": ParamDef((d, d), P(AXIS_MODEL, None)),
            "w0": ParamDef((d,), P(AXIS_MODEL), init="decay_init", dtype=jnp.float32),
            "w_lora1": ParamDef((d, LORA_R), P(None, None), scale=1e-2),
            "w_lora2": ParamDef((LORA_R, d), P(None, AXIS_MODEL), scale=1e-2),
            "u": ParamDef((d,), P(AXIS_MODEL), init="zeros", dtype=jnp.float32),
            "gn_scale": ParamDef((d,), P(AXIS_MODEL), init="ones"),
            "gn_bias": ParamDef((d,), P(AXIS_MODEL), init="zeros"),
        },
        "ln2": ParamDef((d,), P(None), init="zeros"),
        "cm": {
            "mu_k": ParamDef((d,), P(None), init="zeros"),
            "mu_r": ParamDef((d,), P(None), init="zeros"),
            "wk": ParamDef((d, f), P(None, AXIS_MODEL)),
            "wv": ParamDef((f, d), P(AXIS_MODEL, None)),
            "wr": ParamDef((d, d), P(None, None)),
        },
    }


def _ddlerp(tm: dict, x: jax.Array, xx: jax.Array) -> Tuple[jax.Array, ...]:
    """RWKV6 data-dependent lerp producing the 5 mixed inputs (r,k,v,w,g)."""
    base = x + (xx - x) * tm["mu_x"]
    lora = jnp.tanh(matmul(base, tm["ddl_w1"]))
    B_, S_ = x.shape[0], x.shape[1] if x.ndim == 3 else None
    r = lora.shape[-1] // 5
    lora = lora.reshape(lora.shape[:-1] + (5, r))
    delta = jnp.einsum("...nr,nrd->...nd", lora.astype(ACC_DTYPE),
                       tm["ddl_w2"].astype(ACC_DTYPE)).astype(x.dtype)
    mixed = []
    for i in range(5):
        mu_i = tm["mu"][i] + delta[..., i, :]
        mixed.append(x + (xx - x) * mu_i)
    return tuple(mixed)


def _group_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
                eps: float = 64e-5) -> jax.Array:
    """Per-head groupnorm over head_dim. x: (..., H, D) flattened to (..., H*D)."""
    xf = x.astype(ACC_DTYPE)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    normed = (xf - mu) * jax.lax.rsqrt(var + eps)
    return normed


def time_mix_prefill(tm: dict, x: jax.Array, cfg: ArchConfig,
                     tm_state: jax.Array, wkv_state: jax.Array):
    """x: (B, S, d). Returns (out, (last_x, wkv_state'))."""
    B, S, d = x.shape
    H, D = cfg.num_heads, cfg.head_dim
    xx = jnp.concatenate([tm_state[:, None, :], x[:, :-1]], axis=1)
    xr, xk, xv, xw, xg = _ddlerp(tm, x, xx)
    r = matmul(xr, tm["wr"]).reshape(B, S, H, D).transpose(0, 2, 1, 3)
    k = matmul(xk, tm["wk"]).reshape(B, S, H, D).transpose(0, 2, 1, 3)
    v = matmul(xv, tm["wv"]).reshape(B, S, H, D).transpose(0, 2, 1, 3)
    g = activate(matmul(xg, tm["wg"]), "silu")
    logw = -jnp.exp(tm["w0"].astype(ACC_DTYPE)
                    + matmul(jnp.tanh(matmul(xw, tm["w_lora1"])),
                             tm["w_lora2"]).astype(ACC_DTYPE))
    logw = logw.reshape(B, S, H, D).transpose(0, 2, 1, 3)
    u = tm["u"].reshape(H, D)
    out, wkv_state = wkv6_chunked(r, k, v, logw, u, wkv_state)
    out = out.transpose(0, 2, 1, 3)  # (B, S, H, D)
    normed = _group_norm(out, None, None).reshape(B, S, H * D)
    normed = (normed * tm["gn_scale"].astype(ACC_DTYPE)
              + tm["gn_bias"].astype(ACC_DTYPE)).astype(x.dtype)
    return matmul(normed * g, tm["wo"]), (x[:, -1], wkv_state)


def time_mix_decode(tm: dict, x: jax.Array, cfg: ArchConfig,
                    tm_state: jax.Array, wkv_state: jax.Array):
    """x: (B, d) one token."""
    B, d = x.shape
    H, D = cfg.num_heads, cfg.head_dim
    xr, xk, xv, xw, xg = _ddlerp(tm, x, tm_state)
    r = matmul(xr, tm["wr"]).reshape(B, H, D)
    k = matmul(xk, tm["wk"]).reshape(B, H, D)
    v = matmul(xv, tm["wv"]).reshape(B, H, D)
    g = activate(matmul(xg, tm["wg"]), "silu")
    logw = -jnp.exp(tm["w0"].astype(ACC_DTYPE)
                    + matmul(jnp.tanh(matmul(xw, tm["w_lora1"])),
                             tm["w_lora2"]).astype(ACC_DTYPE))
    logw = logw.reshape(B, H, D)
    u = tm["u"].reshape(H, D)
    out, wkv_state = wkv6_step(r, k, v, logw, u, wkv_state)
    normed = _group_norm(out, None, None).reshape(B, H * D)
    normed = (normed * tm["gn_scale"].astype(ACC_DTYPE)
              + tm["gn_bias"].astype(ACC_DTYPE)).astype(x.dtype)
    return matmul(normed * g, tm["wo"]), (x, wkv_state)


def channel_mix(cm: dict, x: jax.Array, cm_state: jax.Array, prefill: bool):
    if prefill:
        xx = jnp.concatenate([cm_state[:, None, :], x[:, :-1]], axis=1)
        new_state = x[:, -1]
    else:
        xx = cm_state
        new_state = x
    xk = x + (xx - x) * cm["mu_k"]
    xr = x + (xx - x) * cm["mu_r"]
    kk = activate(matmul(xk, cm["wk"]), "relu_sq")
    kv = matmul(kk, cm["wv"])
    return jax.nn.sigmoid(matmul(xr, cm["wr"]).astype(ACC_DTYPE)).astype(x.dtype) * kv, new_state


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def rwkv_state_shapes(cfg: ArchConfig, batch: int):
    Lr = cfg.num_layers
    d, H, D = cfg.d_model, cfg.num_heads, cfg.head_dim
    return {
        "wkv": jax.ShapeDtypeStruct((Lr, batch, H, D, D), jnp.float32),
        "tm_x": jax.ShapeDtypeStruct((Lr, batch, d), L.DEFAULT_DTYPE),
        "cm_x": jax.ShapeDtypeStruct((Lr, batch, d), L.DEFAULT_DTYPE),
    }


def rwkv_state_specs():
    return {
        "wkv": P(None, BATCH_AXES, AXIS_MODEL, None, None),
        "tm_x": P(None, BATCH_AXES, None),
        "cm_x": P(None, BATCH_AXES, None),
    }


def make_rwkv(cfg: ArchConfig, *, num_microbatches: int = 1):
    from repro.models.transformer import ModelBundle  # circular-safe

    d, v = cfg.d_model, cfg.padded_vocab
    defs = {
        "embed": ParamDef((v, d), P(AXIS_MODEL, None), scale=1.0),
        "layers": stacked(rwkv_layer_defs(cfg), cfg.num_layers),
        "final_norm": ParamDef((d,), P(None), init="zeros"),
        "lm_head": ParamDef((v, d), P(AXIS_MODEL, None)),
    }

    def layer_prefill(lp, x, states):
        tm_x, cm_x, wkv = states
        h, (tm_x, wkv) = time_mix_prefill(
            lp["tm"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg, tm_x, wkv)
        x = x + h
        h, cm_x = channel_mix(lp["cm"], rms_norm(x, lp["ln2"], cfg.norm_eps),
                              cm_x, prefill=True)
        return x + h, (tm_x, cm_x, wkv)

    def run_stack_prefill(params, x, states):
        def body(x, xs):
            lp, tm_x, cm_x, wkv = xs
            x, (tm_x, cm_x, wkv) = layer_prefill(lp, x, (tm_x, cm_x, wkv))
            return x, (tm_x, cm_x, wkv)

        x, (tm_x, cm_x, wkv) = jax.lax.scan(
            body, x, (params["layers"], states["tm_x"], states["cm_x"], states["wkv"]))
        return x, {"tm_x": tm_x, "cm_x": cm_x, "wkv": wkv}

    def fresh_states(params, B):
        shapes = rwkv_state_shapes(cfg, B)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)

    remat_prefill = jax.checkpoint(
        layer_prefill, policy=jax.checkpoint_policies.nothing_saveable)

    def forward_loss(params, batch):
        tokens = batch["tokens"]
        B = tokens.shape[0]
        x = embed_lookup(params["embed"], tokens)
        states = fresh_states(params, B)

        def body(x, xs):
            lp, tm_x, cm_x, wkv = xs
            x, _ = remat_prefill(lp, x, (tm_x, cm_x, wkv))
            return x, None

        x, _ = jax.lax.scan(body, x, (params["layers"], states["tm_x"],
                                      states["cm_x"], states["wkv"]))
        logits = lm_head_logits(rms_norm(x, params["final_norm"], cfg.norm_eps),
                                params["lm_head"], valid_vocab=cfg.vocab_size)
        return cross_entropy_from_logits(logits, batch["labels"])

    from repro.models.transformer import make_microbatched_loss
    loss_fn = make_microbatched_loss(forward_loss, num_microbatches)

    def prefill(params, batch):
        tokens = batch["tokens"]
        B = tokens.shape[0]
        x = embed_lookup(params["embed"], tokens)
        x, states = run_stack_prefill(params, x, fresh_states(params, B))
        last = x[:, -1]
        logits = lm_head_logits(rms_norm(last, params["final_norm"], cfg.norm_eps),
                                params["lm_head"],
                                valid_vocab=cfg.vocab_size)[..., :cfg.vocab_size]
        return logits, states

    def decode_step(params, cache, tokens, pos):
        del pos  # recurrence carries position implicitly
        x = embed_lookup(params["embed"], tokens)

        def body(x, xs):
            lp, tm_x, cm_x, wkv = xs
            h, (tm_x, wkv) = time_mix_decode(
                lp["tm"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg, tm_x, wkv)
            x = x + h
            h, cm_x = channel_mix(lp["cm"], rms_norm(x, lp["ln2"], cfg.norm_eps),
                                  cm_x, prefill=False)
            return x + h, (tm_x, cm_x, wkv)

        x, (tm_x, cm_x, wkv) = jax.lax.scan(
            body, x, (params["layers"], cache["tm_x"], cache["cm_x"], cache["wkv"]))
        logits = lm_head_logits(rms_norm(x, params["final_norm"], cfg.norm_eps),
                                params["lm_head"],
                                valid_vocab=cfg.vocab_size)[..., :cfg.vocab_size]
        return logits, {"tm_x": tm_x, "cm_x": cm_x, "wkv": wkv}

    def cache_shape_fn(batch, max_len):
        del max_len  # O(1) state
        return rwkv_state_shapes(cfg, batch)

    return ModelBundle(cfg, defs, loss_fn, prefill, decode_step,
                       cache_shape_fn, rwkv_state_specs, {})
