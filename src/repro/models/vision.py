"""llama-3.2-vision-11b backbone: decoder with interleaved cross-attention.

The image frontend is a STUB per the assignment: ``image_embeds`` —
(B, num_image_tokens, vision_d_model) precomputed patch embeddings — are a
model *input* (see ``input_specs``).  Structure: groups of
``cross_attn_every`` self-attention layers followed by one gated
cross-attention layer; the whole model is a nested scan
(outer: groups, inner: self layers) so HLO stays O(1) in depth.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.layers import (
    AXIS_MODEL, BATCH_AXES, ParamDef, bidirectional_attention,
    cross_entropy_from_logits, embed_lookup, lm_head_logits, matmul,
    mlp_block, mlp_defs, rms_norm, stacked,
)
from repro.models.transformer import (
    decoder_layer_decode, decoder_layer_defs, decoder_layer_prefill,
    decoder_layer_train,
)


def cross_layer_defs(cfg: ArchConfig) -> dict:
    d, vd = cfg.d_model, cfg.vision_d_model
    return {
        "ln": ParamDef((d,), P(None), init="zeros"),
        "wq": ParamDef((d, cfg.q_dim), P(None, AXIS_MODEL)),
        "wk": ParamDef((vd, cfg.kv_dim), P(None, AXIS_MODEL)),
        "wv": ParamDef((vd, cfg.kv_dim), P(None, AXIS_MODEL)),
        "wo": ParamDef((cfg.q_dim, d), P(AXIS_MODEL, None)),
        "gate": ParamDef((), P(), init="zeros", dtype=jnp.float32),
        "ln_mlp": ParamDef((d,), P(None), init="zeros"),
        "mlp": mlp_defs(cfg),
        "gate_mlp": ParamDef((), P(), init="zeros", dtype=jnp.float32),
    }


def cross_kv(cp: dict, image_embeds: jax.Array, cfg: ArchConfig):
    """(B, I, vd) -> k, v (B, I, KV, D)."""
    B, I, _ = image_embeds.shape
    k = matmul(image_embeds, cp["wk"]).reshape(B, I, cfg.num_kv_heads, cfg.head_dim)
    v = matmul(image_embeds, cp["wv"]).reshape(B, I, cfg.num_kv_heads, cfg.head_dim)
    return k, v


def cross_layer_apply(cp: dict, x: jax.Array, k: jax.Array, v: jax.Array,
                      cfg: ArchConfig) -> jax.Array:
    """x: (B, S, d); k/v: (B, I, KV, D) precomputed from image embeds."""
    B, S, _ = x.shape
    h = rms_norm(x, cp["ln"], cfg.norm_eps)
    q = matmul(h, cp["wq"]).reshape(B, S, cfg.num_heads, cfg.head_dim)
    attn = bidirectional_attention(q, k, v).reshape(B, S, cfg.q_dim)
    x = x + jnp.tanh(cp["gate"]).astype(x.dtype) * matmul(attn, cp["wo"])
    h = mlp_block(cp["mlp"], rms_norm(x, cp["ln_mlp"], cfg.norm_eps),
                  cfg.activation)
    return x + jnp.tanh(cp["gate_mlp"]).astype(x.dtype) * h


def make_vlm(cfg: ArchConfig, *, num_microbatches: int = 1):
    from repro.models.transformer import ModelBundle

    d, v = cfg.d_model, cfg.padded_vocab
    G = cfg.num_layers // cfg.cross_attn_every  # groups
    per = cfg.cross_attn_every

    self_defs = stacked(stacked(decoder_layer_defs(cfg), per), G)
    defs = {
        "embed": ParamDef((v, d), P(AXIS_MODEL, None), scale=1.0),
        "self_layers": self_defs,  # (G, per, ...)
        "cross_layers": stacked(cross_layer_defs(cfg), G),
        "final_norm": ParamDef((d,), P(None), init="zeros"),
        "lm_head": ParamDef((v, d), P(AXIS_MODEL, None)),
    }

    remat_self = jax.checkpoint(
        lambda lp, x: decoder_layer_train(lp, x, cfg),
        policy=jax.checkpoint_policies.nothing_saveable)

    def forward_loss(params, batch):
        image_embeds = batch["image_embeds"]
        x = embed_lookup(params["embed"], batch["tokens"])

        def outer(x, xs):
            sp, cp = xs

            def inner(x, lp):
                return remat_self(lp, x), None

            x, _ = jax.lax.scan(inner, x, sp)
            k, v_ = cross_kv(cp, image_embeds, cfg)
            return cross_layer_apply(cp, x, k, v_, cfg), None

        x, _ = jax.lax.scan(outer, x, (params["self_layers"],
                                       params["cross_layers"]))
        logits = lm_head_logits(rms_norm(x, params["final_norm"], cfg.norm_eps),
                                params["lm_head"], valid_vocab=cfg.vocab_size)
        return cross_entropy_from_logits(logits, batch["labels"])

    from repro.models.transformer import make_microbatched_loss
    loss_fn = make_microbatched_loss(forward_loss, num_microbatches)

    def prefill(params, batch):
        tokens, img = batch["tokens"], batch["image_embeds"]
        x = embed_lookup(params["embed"], tokens)

        def outer(x, xs):
            sp, cp = xs

            def inner(x, lp):
                return decoder_layer_prefill(lp, x, cfg)

            x, kv = jax.lax.scan(inner, x, sp)
            ck, cv = cross_kv(cp, img, cfg)
            x = cross_layer_apply(cp, x, ck, cv, cfg)
            return x, (kv, (ck.transpose(0, 2, 1, 3), cv.transpose(0, 2, 1, 3)))

        x, (self_kv, cross_cache) = jax.lax.scan(
            outer, x, (params["self_layers"], params["cross_layers"]))
        logits = lm_head_logits(
            rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps),
            params["lm_head"], valid_vocab=cfg.vocab_size)[..., :cfg.vocab_size]
        return logits, {"self": self_kv, "cross": cross_cache}

    def decode_step(params, cache, tokens, pos):
        x = embed_lookup(params["embed"], tokens)

        def outer(x, xs):
            sp, cp, skv, ckv = xs

            def inner(x, xs2):
                lp, kv = xs2
                x, kv = decoder_layer_decode(lp, x, kv, pos, cfg)
                return x, kv

            x, skv = jax.lax.scan(inner, x, (sp, skv))
            ck, cv = ckv  # (B, KV, I, D) cached
            B = x.shape[0]
            h = rms_norm(x, cp["ln"], cfg.norm_eps)
            q = matmul(h, cp["wq"]).reshape(B, cfg.num_heads, cfg.head_dim)
            attn = L.decode_attention(q, ck, cv, ck.shape[2])
            x = x + jnp.tanh(cp["gate"]).astype(x.dtype) * matmul(
                attn.reshape(B, cfg.q_dim), cp["wo"])
            hm = mlp_block(cp["mlp"], rms_norm(x, cp["ln_mlp"], cfg.norm_eps),
                           cfg.activation)
            x = x + jnp.tanh(cp["gate_mlp"]).astype(x.dtype) * hm
            return x, (skv, ckv)

        x, (self_kv, cross_cache) = jax.lax.scan(
            outer, x, (params["self_layers"], params["cross_layers"],
                       cache["self"], cache["cross"]))
        logits = lm_head_logits(rms_norm(x, params["final_norm"], cfg.norm_eps),
                                params["lm_head"],
                                valid_vocab=cfg.vocab_size)[..., :cfg.vocab_size]
        return logits, {"self": self_kv, "cross": cross_cache}

    def cache_shape_fn(batch, max_len):
        s = jax.ShapeDtypeStruct(
            (G, per, batch, cfg.num_kv_heads, max_len, cfg.head_dim),
            L.DEFAULT_DTYPE)
        c = jax.ShapeDtypeStruct(
            (G, batch, cfg.num_kv_heads, cfg.num_image_tokens, cfg.head_dim),
            L.DEFAULT_DTYPE)
        return {"self": (s, s), "cross": (c, c)}

    def cache_spec_fn():
        s = P(None, None, BATCH_AXES, None, AXIS_MODEL, None)
        c = P(None, BATCH_AXES, None, None, None)  # image KV replicated
        return {"self": (s, s), "cross": (c, c)}

    def image_embeds_spec(batch):
        return jax.ShapeDtypeStruct(
            (batch, cfg.num_image_tokens, cfg.vision_d_model), L.DEFAULT_DTYPE)

    return ModelBundle(cfg, defs, loss_fn, prefill, decode_step,
                       cache_shape_fn, cache_spec_fn,
                       {"image_embeds": image_embeds_spec})
