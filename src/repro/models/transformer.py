"""Decoder-only LM stack (dense + MoE) and encoder-only models.

The stack scans over layer-stacked params (HLO O(1) in depth).  Exposes a
:class:`ModelBundle` with a uniform API consumed by the training loop, the
serving engine and the dry-run:

    loss_fn(params, batch)                       train_4k
    prefill(params, tokens[, lengths])           prefill_32k
    decode_step(params, cache, tokens, pos)      decode_32k / long_500k
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.layers import (AXIS_MODEL,
                                 BATCH_AXES,
                                 ParamDef,
                                 attention_block_decode,
                                 attention_block_prefill,
                                 attention_defs,
                                 bidirectional_attention,
                                 cross_entropy_from_logits,
                                 embed_lookup,
                                 init_params,
                                 lm_head_logits,
                                 matmul,
                                 mlp_block,
                                 mlp_defs,
                                 param_shapes,
                                 param_specs,
                                 rms_norm,
                                 stacked)
from repro.models.moe import moe_block, moe_defs

# Cache partition: (B, KV, S, D) -> batch over (pod,data), seq over model
# (flash-decoding style merge; uniform across archs incl. kv=2).
CACHE_SPEC = P(BATCH_AXES, None, AXIS_MODEL, None)
ACT_SPEC = P(BATCH_AXES, None, None)  # (B, S, d)
TOK_SPEC = P(BATCH_AXES, None)  # (B, S)


def make_microbatched_loss(forward_loss: Callable, num_microbatches: int
                           ) -> Callable:
    """Gradient-accumulation wrapper shared by all model families.

    Two essentials for the memory plan to hold:
      * the per-microbatch forward is itself rematerialized — otherwise
        grad-of-scan saves every microbatch's layer-scan residuals
        (O(µ · L · B · S · d), a ~100+ GiB/device blowup);
      * microbatch slices are sharding-constrained back onto the batch
        axes — a bare reshape assigns each microbatch to a few data
        shards (contiguous-block split) and SPMD then replicates.
    """
    if num_microbatches <= 1:
        return forward_loss

    remat_fwd = jax.checkpoint(
        forward_loss, policy=jax.checkpoint_policies.nothing_saveable)

    def loss_fn(params, batch: Dict[str, jax.Array]):
        names = sorted(batch)

        def split(a):
            mb = a.shape[0] // num_microbatches
            a = a.reshape((num_microbatches, mb) + a.shape[1:])
            return L.shard_hint(a, None, BATCH_AXES,
                                *([None] * (a.ndim - 2)))

        xs = tuple(split(batch[n]) for n in names)

        def body(acc, mbs):
            mb_batch = dict(zip(names, mbs))
            return acc + remat_fwd(params, mb_batch), None

        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs)
        return total / num_microbatches

    return loss_fn


# ---------------------------------------------------------------------------
# Model bundle
# ---------------------------------------------------------------------------


@dataclass
class ModelBundle:
    cfg: ArchConfig
    defs: Any  # pytree of ParamDef
    loss_fn: Callable  # (params, batch) -> scalar
    prefill: Callable  # (params, batch) -> (last_logits, cache)
    decode_step: Optional[Callable]  # (params, cache, tokens, pos) -> (logits, cache)
    cache_shape_fn: Optional[Callable]  # (batch, max_len) -> pytree of ShapeDtypeStruct
    cache_spec_fn: Optional[Callable]  # () -> pytree of P
    extra_inputs: Dict[str, Callable] = None  # name -> (batch)->ShapeDtypeStruct (stub frontends)

    def init(self, rng: jax.Array):
        return init_params(self.defs, rng)

    def specs(self):
        return param_specs(self.defs)

    def shapes(self):
        return param_shapes(self.defs)

    def init_cache(self, batch: int, max_len: int):
        shapes = self.cache_shape_fn(batch, max_len)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


# ---------------------------------------------------------------------------
# Dense / MoE decoder layer
# ---------------------------------------------------------------------------


def decoder_layer_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    defs = {
        "ln1": ParamDef((d,), P(None), init="zeros"),
        "attn": attention_defs(cfg),
        "ln2": ParamDef((d,), P(None), init="zeros"),
    }
    defs["mlp"] = moe_defs(cfg) if cfg.is_moe else mlp_defs(cfg)
    return defs


def _ffn(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.is_moe:
        return moe_block(p["mlp"], x, cfg)
    return mlp_block(p["mlp"], x, cfg.activation)


def decoder_layer_train(p: dict, x: jax.Array, cfg: ArchConfig,
                        window: int = 0) -> jax.Array:
    h, _ = attention_block_prefill(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                                   cfg, window=window)
    x = x + h
    x = x + _ffn(p, rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return x


def decoder_layer_prefill(p: dict, x: jax.Array, cfg: ArchConfig,
                          window: int = 0):
    h, kv = attention_block_prefill(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                                    cfg, window=window)
    x = x + h
    x = x + _ffn(p, rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return x, kv


def decoder_layer_decode(p: dict, x: jax.Array, kv, pos, cfg: ArchConfig,
                         window: int = 0):
    h, kv = attention_block_decode(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                                   kv, pos, cfg, window=window)
    x = x + h
    x = x + _ffn(p, rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return x, kv


# ---------------------------------------------------------------------------
# Dense decoder LM
# ---------------------------------------------------------------------------


def dense_lm_defs(cfg: ArchConfig) -> dict:
    d, v = cfg.d_model, cfg.padded_vocab
    defs = {
        "embed": ParamDef((v, d), P(AXIS_MODEL, None), scale=1.0),
        "layers": stacked(decoder_layer_defs(cfg), cfg.num_layers),
        "final_norm": ParamDef((d,), P(None), init="zeros"),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((v, d), P(AXIS_MODEL, None))
    return defs


def _embed_in(params, tokens, cfg):
    x = embed_lookup(params["embed"], tokens)
    if cfg.name.startswith("gemma"):
        x = x * math.sqrt(cfg.d_model)
    return x


def _logits_out(params, x, cfg):
    table = params.get("lm_head", params["embed"])
    return lm_head_logits(rms_norm(x, params["final_norm"], cfg.norm_eps),
                          table, valid_vocab=cfg.vocab_size)


def make_dense_lm(cfg: ArchConfig, *, num_microbatches: int = 1) -> ModelBundle:
    defs = dense_lm_defs(cfg)
    remat_layer = jax.checkpoint(
        partial(decoder_layer_train, cfg=cfg),
        policy=jax.checkpoint_policies.nothing_saveable)

    def forward_loss(params, batch):
        x = _embed_in(params, batch["tokens"], cfg)

        def body(x, lp):
            return remat_layer(lp, x), None

        x, _ = jax.lax.scan(body, x, params["layers"])
        logits = _logits_out(params, x, cfg)
        return cross_entropy_from_logits(logits, batch["labels"])

    loss_fn = make_microbatched_loss(forward_loss, num_microbatches)

    def prefill(params, batch):
        tokens = batch["tokens"]
        x = _embed_in(params, tokens, cfg)

        def body(x, lp):
            x, kv = decoder_layer_prefill(lp, x, cfg)
            return x, kv

        x, cache = jax.lax.scan(body, x, params["layers"])
        lengths = batch.get("lengths")
        if lengths is None:
            last = x[:, -1]
        else:
            last = jnp.take_along_axis(
                x, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        logits = _logits_out(params, last, cfg)[..., :cfg.vocab_size]
        return logits, cache

    def decode_step(params, cache, tokens, pos):
        x = _embed_in(params, tokens, cfg)

        def body(x, xs):
            lp, kv = xs
            x, kv = decoder_layer_decode(lp, x, kv, pos, cfg)
            return x, kv

        x, cache = jax.lax.scan(body, x, (params["layers"], cache))
        logits = _logits_out(params, x, cfg)[..., :cfg.vocab_size]
        return logits, cache

    def cache_shape_fn(batch, max_len):
        s = jax.ShapeDtypeStruct(
            (cfg.num_layers, batch, cfg.num_kv_heads, max_len, cfg.head_dim),
            L.DEFAULT_DTYPE)
        return (s, s)

    def cache_spec_fn(layout: str = "seq"):
        """KV-cache partitioning for decode.

        "seq"   — sequence dim over `model` (flash-decoding merges; works
                  for any head count incl. kv=2);
        "heads" — kv heads over `model` (fully local decode attention, no
                  softmax all-reduces, in-place cache update on an
                  unsharded seq dim) — the §Perf choice when
                  num_kv_heads divides the model axis.
        """
        if layout == "heads":
            spec = P(None, BATCH_AXES, AXIS_MODEL, None, None)
        else:
            spec = P(None, BATCH_AXES, None, AXIS_MODEL, None)
        return (spec, spec)

    return ModelBundle(cfg, defs, loss_fn, prefill, decode_step,
                       cache_shape_fn, cache_spec_fn, {})


# ---------------------------------------------------------------------------
# Encoder-only model (e5 embedder, reranker; bidirectional)
# ---------------------------------------------------------------------------


def encoder_layer_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    return {
        "ln1": ParamDef((d,), P(None), init="zeros"),
        "attn": attention_defs(cfg),
        "ln2": ParamDef((d,), P(None), init="zeros"),
        "mlp": mlp_defs(cfg),
    }


def encoder_layer(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    B, S, _ = x.shape
    positions = jnp.arange(S)
    q, k, v = L.attention_qkv(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                              positions, cfg)
    h = bidirectional_attention(q, k, v)
    x = x + matmul(h.reshape(B, S, cfg.q_dim), p["attn"]["wo"])
    x = x + mlp_block(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg.activation)
    return x


def make_encoder(cfg: ArchConfig) -> ModelBundle:
    d, v = cfg.d_model, cfg.padded_vocab
    defs = {
        "embed": ParamDef((v, d), P(AXIS_MODEL, None), scale=1.0),
        "layers": stacked(encoder_layer_defs(cfg), cfg.num_layers),
        "final_norm": ParamDef((d,), P(None), init="zeros"),
    }

    def encode(params, tokens):
        x = embed_lookup(params["embed"], tokens)

        def body(x, lp):
            return encoder_layer(lp, x, cfg), None

        x, _ = jax.lax.scan(body, x, params["layers"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return jnp.mean(x, axis=1)  # mean-pooled embedding

    def loss_fn(params, batch):
        # contrastive-style surrogate: match pooled embedding to target
        emb = encode(params, batch["tokens"])
        return jnp.mean(jnp.square(emb.astype(jnp.float32)))

    def prefill(params, batch):
        return encode(params, batch["tokens"]), None

    return ModelBundle(cfg, defs, loss_fn, prefill, None, None, None, {})
