"""rwkv6-7b "Finch" — attention-free, data-dependent decay linear recurrence.

Decode state is O(1) per layer (heads × head_dim × head_dim matrix-valued
WKV state + token-shift states), so long_500k runs natively.
[arXiv:2404.05892; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,  # head_size 64 => 4096/64 heads
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65_536,
    activation="relu_sq",  # rwkv channel-mix uses squared relu
    ssm_state=64,
    attn_free=True,
    source="arXiv:2404.05892; hf",
)
