"""granite-moe-3b-a800m — fine-grained MoE, 40 experts top-8, GQA kv=8.

[hf:ibm-granite/granite-3.0-1b-a400m-base family; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,  # per-expert
    vocab_size=49_155,
    activation="swiglu",
    num_experts=40,
    experts_per_token=8,
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
