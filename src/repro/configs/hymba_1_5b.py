"""hymba-1.5b — hybrid: parallel attention + mamba heads per layer.

Sliding-window attention on most layers; 3 global-attention layers
(first/middle/last) per the Hymba paper — which is what makes long_500k
decode feasible (SWA KV is bounded; SSM state is O(1)).
[arXiv:2411.13676; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32_001,
    activation="swiglu",
    ssm_state=16,
    sliding_window=1024,
    full_attn_layers=(0, 15, 31),
    rope_theta=10_000.0,
    source="arXiv:2411.13676; hf",
)
