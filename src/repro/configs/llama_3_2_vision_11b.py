"""llama-3.2-vision-11b — decoder backbone w/ cross-attention image layers.

Modality frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings; only the transformer backbone is modeled.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128_256,
    activation="swiglu",
    rope_theta=500_000.0,
    cross_attn_every=5,  # a cross-attn layer after every 5th self-attn layer
    num_image_tokens=1601,  # 1 tile of 448x448 @ patch 14 + cls
    vision_d_model=1280,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
