"""Workload LLMs: the paper's own (§7.1) plus the agentic-fleet models.

RAG+reranker: e5-base-v2 (embedder) + a reranker + Llama-3-8B (generator).
Beam search:  Llama-3.2-1B (generator) + Llama-3.1-8B-PRM (verifier).
Fleet workloads (ReAct agent / map-reduce / debate) additionally use a
mid-size Qwen2.5-3B-shaped agent model.

These are the models the Scepsy scheduler allocates in the end-to-end
benchmarks.  The exact public configs are used so the analytical cost
model produces realistic per-request costs.
"""
from repro.configs.base import ArchConfig

LLAMA_3_2_1B = ArchConfig(
    name="llama-3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128_256,
    activation="swiglu",
    rope_theta=500_000.0,
    tie_embeddings=True,
    source="hf:meta-llama/Llama-3.2-1B",
)

LLAMA_3_1_8B = ArchConfig(
    name="llama-3.1-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128_256,
    activation="swiglu",
    rope_theta=500_000.0,
    substitute="qwen2.5-3b-agent",  # quality tier below (JIT substitution)
    source="hf:meta-llama/Llama-3.1-8B",
)

# Verifier / process-reward model: same backbone as 8B (PRM head is tiny).
LLAMA_3_1_8B_PRM = ArchConfig(
    name="llama-3.1-8b-prm",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128_256,
    activation="swiglu",
    rope_theta=500_000.0,
    source="hf:RLHFlow/Llama3.1-8B-PRM-Mistral-Data",
)

# Embedder (encoder-only, BERT-base shape).
E5_BASE_V2 = ArchConfig(
    name="e5-base-v2",
    family="encoder",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=30_522,
    activation="gelu",
    source="hf:intfloat/e5-base-v2",
)

# Cross-encoder reranker (MiniLM shape).
RERANKER_MINILM = ArchConfig(
    name="reranker-minilm",
    family="encoder",
    num_layers=6,
    d_model=384,
    num_heads=12,
    num_kv_heads=12,
    d_ff=1536,
    vocab_size=30_522,
    activation="gelu",
    source="hf:cross-encoder/ms-marco-MiniLM-L-6-v2",
)

# Mid-size tool-calling agent (Qwen2.5-3B shape) for the agentic-fleet
# workloads — sits between the 1B drafters and the 8B generators so the
# scheduler has three distinct model sizes to pack.
QWEN_2_5_3B_AGENT = ArchConfig(
    name="qwen2.5-3b-agent",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11008,
    vocab_size=151_936,
    activation="swiglu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    substitute="llama-3.2-1b",  # quality tier below (JIT substitution)
    source="hf:Qwen/Qwen2.5-3B-Instruct",
)

PAPER_LLMS = {
    c.name: c
    for c in (LLAMA_3_2_1B, LLAMA_3_1_8B, LLAMA_3_1_8B_PRM, E5_BASE_V2,
              RERANKER_MINILM, QWEN_2_5_3B_AGENT)
}
