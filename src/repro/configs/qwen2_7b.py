"""qwen2-7b — dense, GQA kv=4, QKV bias. [arXiv:2407.10671; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152_064,
    activation="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    substitute="qwen2.5-3b",  # quality tier below (JIT substitution)
    source="arXiv:2407.10671; hf",
)
