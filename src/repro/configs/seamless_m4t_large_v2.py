"""seamless-m4t-large-v2 — encoder-decoder, multimodal (audio frontend STUB).

``input_specs()`` supplies precomputed frame embeddings for the encoder;
the enc-dec transformer backbone is what we model. [arXiv:2308.11596; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,  # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256_206,
    activation="gelu",
    num_audio_frames=1024,  # precomputed speech-frontend frames per request
    rope_theta=10_000.0,
    source="arXiv:2308.11596; hf",
)
