"""Architecture configuration system.

Every serveable / trainable model in the framework is described by an
``ArchConfig``.  The Scepsy layer treats models as black boxes (it only
needs throughput-latency profiles), but the model zoo, the sharding
rules, the analytical cost model and the dry-run all read these fields.

All 10 assigned architectures (plus the paper's own workload LLMs) are
registered in :mod:`repro.configs.registry`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes (assigned shape set for the LM family)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    """One (seq_len, global_batch) cell plus which step it lowers."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

ALL_SHAPES: Tuple[InputShape, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# Architecture configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    """A single model architecture.

    The field set is the union over families; family-specific fields are
    zero/empty when unused.  ``family`` selects the model builder.
    """

    name: str
    family: str  # dense | moe | vlm | audio | hybrid | ssm | encoder
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None  # default: d_model // num_heads
    activation: str = "swiglu"  # swiglu | geglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25

    # --- VLM (cross-attention image layers; frontend stubbed) ---
    cross_attn_every: int = 0  # a cross-attn layer every N layers
    num_image_tokens: int = 0  # precomputed patch embeddings per request
    vision_d_model: int = 0

    # --- encoder-decoder (audio frontend stubbed) ---
    encoder_layers: int = 0
    num_audio_frames: int = 0  # precomputed frame embeddings per request

    # --- hybrid / SSM ---
    ssm_state: int = 0
    attn_free: bool = False  # rwkv6: no attention at all
    sliding_window: int = 0  # 0 = full attention
    full_attn_layers: Tuple[int, ...] = ()  # hybrid: layers w/ global attention

    # --- quality tiers (just-in-time model substitution) ---
    # name of the next-smaller zoo tier admission may substitute this
    # model with under overload (e.g. 9B -> 7B -> 3B); None = no tier
    substitute: Optional[str] = None

    # --- provenance ---
    source: str = ""

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        assert self.num_kv_heads == 0 or self.num_heads % max(self.num_kv_heads, 1) == 0 or self.attn_free, (
            f"{self.name}: num_heads={self.num_heads} not divisible by kv={self.num_kv_heads}"
        )

    # ------------------------------------------------------------------
    # Derived quantities (used by cost model, roofline, scheduler)
    # ------------------------------------------------------------------

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 (Megatron-style): embedding
        tables must divide evenly over the 16-way `model` axis and MXU
        lanes; padded logits are masked in the loss and sliced off in
        prefill/decode outputs."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic attention (SSM / hybrid w/ sliding window)."""
        return self.attn_free or self.ssm_state > 0

    def layer_param_count(self) -> int:
        """Parameters of one decoder layer (attention + FFN + norms)."""
        d = self.d_model
        attn = 0
        if not self.attn_free:
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qkv_bias:
                attn += self.q_dim + 2 * self.kv_dim
        ssm = 0
        if self.ssm_state > 0 or self.attn_free:
            if self.attn_free:  # rwkv6: r,k,v,g,o (d*d each) + w lora + mixes
                ssm = 5 * d * d + 2 * d * 64 + 6 * d
            else:  # hymba mamba heads: in/out proj + dt/B/C projections
                h = self.q_dim
                ssm = d * 2 * h + h * d + h * (2 * self.ssm_state + 2)
        n_mlp_mats = 3 if self.activation in ("swiglu", "geglu") else 2
        if self.is_moe:
            ffn = self.num_experts * n_mlp_mats * d * self.d_ff + d * self.num_experts
        else:
            ffn = n_mlp_mats * d * self.d_ff
        norms = 2 * d
        return attn + ssm + ffn + norms

    def param_count(self) -> int:
        """Total parameter count (embeddings + layers [+ encoder, + cross])."""
        total = self.vocab_size * self.d_model  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * self.d_model  # lm head
        total += self.num_layers * self.layer_param_count() + self.d_model
        if self.cross_attn_every:
            n_cross = self.num_layers // self.cross_attn_every
            d = self.d_model
            cross = d * self.q_dim + 2 * max(self.vision_d_model, d) * self.kv_dim + self.q_dim * d
            total += n_cross * cross
        if self.encoder_layers:
            total += self.encoder_layers * self.layer_param_count()
            # decoder cross-attention in every decoder layer
            d = self.d_model
            total += self.num_layers * (d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d)
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        n_mlp_mats = 3 if self.activation in ("swiglu", "geglu") else 2
        all_exp = self.num_layers * self.num_experts * n_mlp_mats * self.d_model * self.d_ff
        act_exp = self.num_layers * self.experts_per_token * n_mlp_mats * self.d_model * self.d_ff
        return full - all_exp + act_exp

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """KV-cache bytes per token per sequence (all layers)."""
        if self.attn_free:
            return 0  # constant state, not per-token
        if self.sliding_window and self.full_attn_layers:
            # hybrid: sliding layers cap at window; approx with full here,
            # the cache builder applies the cap per layer.
            pass
        return self.num_layers * 2 * self.kv_dim * dtype_bytes

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# Shapes each family actually runs (assignment rules):
#  - long_500k only for sub-quadratic archs,
#  - decode shapes for all (no encoder-only archs among the 10).
def shapes_for(cfg: ArchConfig) -> Tuple[InputShape, ...]:
    out = []
    for s in ALL_SHAPES:
        if s.name == "long_500k" and not cfg.supports_long_context:
            continue  # documented skip (DESIGN.md §4)
        if s.kind == "decode" and cfg.family == "encoder":
            continue  # encoder-only: no decode step
        out.append(s)
    return tuple(out)
