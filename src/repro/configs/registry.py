"""Registry of all selectable architectures (``--arch <id>``)."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig
from repro.configs.gemma_7b import CONFIG as GEMMA_7B
from repro.configs.glm4_9b import CONFIG as GLM4_9B
from repro.configs.qwen2_7b import CONFIG as QWEN2_7B
from repro.configs.qwen2_5_3b import CONFIG as QWEN2_5_3B
from repro.configs.granite_moe_3b_a800m import CONFIG as GRANITE_MOE
from repro.configs.olmoe_1b_7b import CONFIG as OLMOE
from repro.configs.llama_3_2_vision_11b import CONFIG as LLAMA_VISION
from repro.configs.seamless_m4t_large_v2 import CONFIG as SEAMLESS
from repro.configs.hymba_1_5b import CONFIG as HYMBA
from repro.configs.rwkv6_7b import CONFIG as RWKV6
from repro.configs.paper_workloads import PAPER_LLMS

ASSIGNED: Dict[str, ArchConfig] = {
    c.name: c
    for c in (
        GEMMA_7B,
        GLM4_9B,
        QWEN2_7B,
        QWEN2_5_3B,
        GRANITE_MOE,
        OLMOE,
        LLAMA_VISION,
        SEAMLESS,
        HYMBA,
        RWKV6,
    )
}

REGISTRY: Dict[str, ArchConfig] = {**ASSIGNED, **PAPER_LLMS}


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def reduced_config(cfg: ArchConfig, *, layers: int = 2, d_model: int = 64,
                   vocab: int = 256) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests.

    Keeps family, activation, attention ratios and MoE/SSM structure;
    shrinks width, depth and embedding tables.
    """
    heads = max(2, min(cfg.num_heads, 4))
    ratio = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1)) if not cfg.attn_free else 1
    kv = max(1, heads // min(ratio, heads))
    head_dim = max(8, d_model // heads)
    updates = dict(
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=heads if cfg.attn_free else kv,
        head_dim=head_dim,
        d_ff=d_model * 2 if not cfg.is_moe else 32,
        vocab_size=vocab,
    )
    if cfg.is_moe:
        updates["num_experts"] = min(cfg.num_experts, 8)
        updates["experts_per_token"] = min(cfg.experts_per_token, 2)
    if cfg.cross_attn_every:
        updates["cross_attn_every"] = 2
        updates["num_image_tokens"] = 16
        updates["vision_d_model"] = 32
    if cfg.encoder_layers:
        updates["encoder_layers"] = layers
        updates["num_audio_frames"] = 16
    if cfg.sliding_window:
        updates["sliding_window"] = 8
        updates["full_attn_layers"] = (0,)
    if cfg.ssm_state:
        updates["ssm_state"] = 4 if not cfg.attn_free else head_dim
    return dataclasses.replace(cfg, **updates)
