"""Deterministic synthetic token pipeline.

Generates reproducible pseudo-corpus batches (Zipfian token draw with a
Markov flavor so the loss actually decreases) — sharded per data-parallel
host, seekable by step for fault-tolerant restart (the pipeline state IS
the step counter, so restoring a checkpoint restores the data stream).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_a: float = 1.2


class SyntheticCorpus:
    """Seekable, shardable token stream."""

    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        assert cfg.global_batch % num_shards == 0
        self.local_batch = cfg.global_batch // num_shards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Batch for a given step (pure function of (step, shard, seed))."""
        c = self.cfg
        rng = np.random.default_rng(
            (c.seed * 1_000_003 + step) * 97 + self.shard)
        # Zipf-ish marginals, clipped into vocab
        raw = rng.zipf(c.zipf_a, size=(self.local_batch, c.seq_len + 1))
        tokens = np.minimum(raw, c.vocab_size - 1).astype(np.int32)
        # inject local structure: with p=0.35 repeat previous token + 1
        rep = rng.random((self.local_batch, c.seq_len + 1)) < 0.35
        shifted = np.roll(tokens, 1, axis=1) + 1
        tokens = np.where(rep, np.minimum(shifted, c.vocab_size - 1), tokens)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
