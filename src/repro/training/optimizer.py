"""AdamW optimizer on pytrees (pure JAX; no optax dependency).

bf16 params, f32 first/second moments.  Optimizer state inherits each
param's partition spec (m/v shard like the param).  Optional ZeRO-style
sharding of optimizer state over the data axis is a perf iteration
(EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # () int32
    m: Any  # pytree like params, f32
    v: Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_state(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def state_specs(param_specs):
    from jax.sharding import PartitionSpec as P

    return AdamWState(step=P(), m=param_specs, v=param_specs)


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def apply_updates(params, grads, state: AdamWState,
                  cfg: AdamWConfig = AdamWConfig()) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    lr = cfg.lr * warm

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m1 = cfg.b1 * m + (1 - cfg.b1) * g
        v1 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m1 / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v1 / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m1, v1

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
