"""Training step assembly: loss -> grads -> AdamW update.

The same ``make_train_step`` is used by the CPU smoke tests, the
end-to-end training example, and the multi-pod dry-run (where it is
lowered with ShapeDtypeStructs and never executed).
"""
from __future__ import annotations

from typing import Callable, Dict

import jax

from repro.models.transformer import ModelBundle
from repro.training.optimizer import AdamWConfig, AdamWState, apply_updates


def make_train_step(bundle: ModelBundle,
                    opt_cfg: AdamWConfig = AdamWConfig()) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state: AdamWState, batch: Dict[str, jax.Array]):
        loss, grads = jax.value_and_grad(bundle.loss_fn)(params, batch)
        params, opt_state = apply_updates(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, "step": opt_state.step}
        return params, opt_state, metrics

    return train_step
