"""Sharded checkpointing for fault-tolerant training.

Format: one ``.npz`` shard per (host) writer plus a JSON manifest with the
pytree structure, step and data-pipeline cursor.  Atomic via
write-to-temp + rename; ``latest_step`` scans for the newest complete
manifest, so a crashed run restarts from the last durable step.
"""
from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, state: Any,
                    extra: Optional[Dict] = None, writer: int = 0) -> str:
    d = Path(ckpt_dir) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(state)

    def to_np(x):
        a = np.asarray(x)
        if a.dtype.name == "bfloat16":  # npz can't serialize bf16; f32 is lossless
            a = a.astype(np.float32)
        return a

    arrays = {f"leaf_{i}": to_np(x) for i, x in enumerate(leaves)}
    shard_path = d / f"shard_{writer}.npz"
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, shard_path)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "num_leaves": len(leaves),
        "extra": extra or {},
        "writers": 1,
    }
    mtmp = d / "manifest.json.tmp"
    mtmp.write_text(json.dumps(manifest))
    os.replace(mtmp, d / "manifest.json")  # commit point
    return str(d)


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = Path(ckpt_dir)
    if not p.exists():
        return None
    steps = []
    for d in p.iterdir():
        if d.name.startswith("step_") and (d / "manifest.json").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, like: Any,
                       step: Optional[int] = None) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``like`` (a pytree template)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "shard_0.npz")
    leaves, treedef = _flatten(like)
    assert manifest["num_leaves"] == len(leaves), "structure mismatch"
    restored = [data[f"leaf_{i}"] for i in range(len(leaves))]
    restored = [np.asarray(r).astype(l.dtype) if hasattr(l, "dtype") else r
                for r, l in zip(restored, leaves)]
    return jax.tree.unflatten(treedef, restored), step, manifest["extra"]


def prune_old(ckpt_dir: str, keep: int = 3) -> None:
    p = Path(ckpt_dir)
    if not p.exists():
        return
    steps = sorted(d for d in p.iterdir() if d.name.startswith("step_"))
    for d in steps[:-keep]:
        for f in d.iterdir():
            f.unlink()
        d.rmdir()
