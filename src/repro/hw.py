"""TPU v5e hardware constants — single source of truth.

Used by the analytical cost model (serving simulator / profiler), the
roofline analysis, and the scheduler's memory feasibility checks.
"""
from __future__ import annotations

from dataclasses import dataclass

PEAK_FLOPS_BF16 = 197e12  # FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
HBM_BYTES = 16 * 1024**3  # 16 GiB per chip
ICI_LINK_BW = 50e9  # bytes/s per link
ICI_LINKS_PER_CHIP = 4  # 2D torus
DCI_BW = 25e9  # bytes/s per chip cross-pod (data-center interconnect)

# empirical efficiency knobs for the *cost model* (not the roofline —
# the roofline uses raw peaks).
MXU_EFFICIENCY = 0.6  # sustained matmul fraction of peak in serving
HBM_EFFICIENCY = 0.8  # sustained HBM stream fraction
COLLECTIVE_LATENCY = 5e-6  # per-collective latency floor (s)
HOST_TO_HBM_BW = 30e9  # weight-loading path (model swap cost)


@dataclass(frozen=True)
class ClusterSpec:
    """Serving-cluster topology (paper's placement problem, TPU terms).

    A *host* groups ``chips_per_host`` chips; ``hb_domain_size`` chips share
    a high-bandwidth ICI domain (the NVLink-domain analogue) — TP groups
    must stay inside one domain.  Each chip is divisible into
    ``fractions_per_chip`` units (enforced by the engine's slot scheduler +
    static HBM budgeting; the MPS analogue).

    ``tail_chips`` models a partially-populated final host: a sub-cluster
    of 9 chips on a 4-chip/host topology is 2 full hosts plus one tail
    chip.  Tail chips hold TP=1 replicas only when they cannot complete an
    hb domain, which placement enforces via the usual domain check.
    """

    num_hosts: int = 4
    chips_per_host: int = 4
    hb_domain_size: int = 2  # paper cluster: NVLink pairs
    fractions_per_chip: int = 10
    tail_chips: int = 0  # chips on one extra, partially-filled host

    @property
    def num_chips(self) -> int:
        return self.num_hosts * self.chips_per_host + self.tail_chips

    @property
    def total_units(self) -> int:
        return self.num_chips * self.fractions_per_chip

    def domains_per_host(self) -> int:
        return self.chips_per_host // self.hb_domain_size


# paper-equivalent cluster sizes used across benchmarks (16 chips =
# 4 hosts x 4) plus TPU-pod-scale variants for scale tests.
PAPER_CLUSTER_4 = ClusterSpec(num_hosts=1, chips_per_host=4)
PAPER_CLUSTER_8 = ClusterSpec(num_hosts=2, chips_per_host=4)
PAPER_CLUSTER_16 = ClusterSpec(num_hosts=4, chips_per_host=4)
POD_CLUSTER_256 = ClusterSpec(num_hosts=32, chips_per_host=8,
                              hb_domain_size=8)
