"""Hardware model — chip classes and cluster topology.

Single source of truth for the analytical cost model (serving
simulator / profiler), the roofline analysis, and the scheduler's
memory feasibility checks.

A :class:`ChipClass` bundles one accelerator generation's roofline
constants (peak flops, HBM bytes/bandwidth, interconnect bandwidth)
with the empirical efficiency knobs the cost model applies on top.
``DEFAULT_CHIP_CLASS`` is the TPU v5e-class part the paper's uniform
cluster assumed; the module-level constants below remain as aliases of
its fields so legacy call sites keep reading the same numbers.

Heterogeneous clusters are expressed by giving :class:`ClusterSpec` a
tuple of :class:`HostGroup`s — contiguous runs of identical hosts, each
bound to one chip class.  ``chip_table()`` flattens the groups into
per-chip ``(host, domain, class)`` rows; high-bandwidth domains are
numbered per host, so a domain can never span two hosts, two groups, or
the tail boundary (TP groups therefore never span chip classes by
construction).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class ChipClass:
    """One accelerator generation's constants for the cost model.

    The first block is the raw roofline (peaks — used as-is by the
    roofline analysis); the second block is the empirical efficiency
    knobs the *cost model* multiplies in (sustained fractions, latency
    floors, the weight-loading path for model swaps).
    """

    name: str
    peak_flops_bf16: float  # FLOP/s per chip
    hbm_bw: float  # bytes/s per chip
    hbm_bytes: int  # HBM capacity per chip
    ici_link_bw: float = 50e9  # bytes/s per ICI link
    ici_links_per_chip: int = 4  # torus degree
    dci_bw: float = 25e9  # bytes/s per chip cross-pod
    vmem_bytes: int = 16 * 2**20  # on-core scratch (Pallas tile budget)

    # empirical efficiency knobs for the *cost model* (not the roofline)
    mxu_efficiency: float = 0.6  # sustained matmul fraction of peak
    hbm_efficiency: float = 0.8  # sustained HBM stream fraction
    collective_latency: float = 5e-6  # per-collective latency floor (s)
    host_to_hbm_bw: float = 30e9  # weight-loading path (model swap cost)


# The v5e-class default: exactly the constants the uniform-cluster code
# has always used.  V5P is the bigger-HBM class (a 9B-at-TP=1 home);
# V4I is the small-memory inference part that cannot hold a 9B at all.
V5E = ChipClass(
    name="v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    hbm_bytes=16 * 1024**3,
)
V5P = ChipClass(
    name="v5p",
    peak_flops_bf16=459e12,
    hbm_bw=2765e9,
    hbm_bytes=95 * 1024**3,
    ici_link_bw=90e9,
    ici_links_per_chip=6,  # 3D torus
    vmem_bytes=32 * 2**20,
    mxu_efficiency=0.55,  # bigger MXUs sustain a slightly lower fraction
    host_to_hbm_bw=60e9,
)
V4I = ChipClass(
    name="v4i",
    peak_flops_bf16=138e12,
    hbm_bw=614e9,
    hbm_bytes=8 * 1024**3,
    ici_link_bw=25e9,
    ici_links_per_chip=2,
    hbm_efficiency=0.75,
)

DEFAULT_CHIP_CLASS = V5E

CHIP_CLASSES: Dict[str, ChipClass] = {c.name: c for c in (V5E, V5P, V4I)}


def register_chip_class(cls: ChipClass) -> ChipClass:
    """Register a (possibly synthetic) chip class for name lookup.

    Benchmarks use this for the class-blind baseline: one averaged
    "blend" class standing in for a mixed cluster.
    """
    CHIP_CLASSES[cls.name] = cls
    return cls


def chip_class(name: str) -> ChipClass:
    try:
        return CHIP_CLASSES[name]
    except KeyError:
        raise KeyError(
            f"unknown chip class {name!r}; known: {sorted(CHIP_CLASSES)}"
        ) from None


def blend_classes(parts: List[Tuple[ChipClass, int]], name: str = "blend") -> ChipClass:
    """Chip-count-weighted average of several classes (class-blind view)."""
    total = sum(n for _, n in parts)
    if total <= 0:
        raise ValueError("blend_classes needs at least one chip")

    def avg(attr: str) -> float:
        return sum(getattr(c, attr) * n for c, n in parts) / total

    return ChipClass(
        name=name,
        peak_flops_bf16=avg("peak_flops_bf16"),
        hbm_bw=avg("hbm_bw"),
        hbm_bytes=int(avg("hbm_bytes")),
        ici_link_bw=avg("ici_link_bw"),
        ici_links_per_chip=max(1, round(avg("ici_links_per_chip"))),
        dci_bw=avg("dci_bw"),
        vmem_bytes=int(avg("vmem_bytes")),
        mxu_efficiency=avg("mxu_efficiency"),
        hbm_efficiency=avg("hbm_efficiency"),
        collective_latency=avg("collective_latency"),
        host_to_hbm_bw=avg("host_to_hbm_bw"),
    )


# Module-level aliases (v5e-class values).  Legacy call sites — and any
# code that has not been made chip-class-aware — read these; they are
# byte-identical to the pre-ChipClass constants.
PEAK_FLOPS_BF16 = V5E.peak_flops_bf16  # FLOP/s per chip
HBM_BW = V5E.hbm_bw  # bytes/s per chip
HBM_BYTES = V5E.hbm_bytes  # 16 GiB per chip
ICI_LINK_BW = V5E.ici_link_bw  # bytes/s per link
ICI_LINKS_PER_CHIP = V5E.ici_links_per_chip  # 2D torus
DCI_BW = V5E.dci_bw  # bytes/s per chip cross-pod

MXU_EFFICIENCY = V5E.mxu_efficiency  # sustained matmul fraction of peak
HBM_EFFICIENCY = V5E.hbm_efficiency  # sustained HBM stream fraction
COLLECTIVE_LATENCY = V5E.collective_latency  # per-collective floor (s)
HOST_TO_HBM_BW = V5E.host_to_hbm_bw  # weight-loading path


@dataclass(frozen=True)
class HostGroup:
    """A contiguous run of identical hosts bound to one chip class.

    ``num_hosts`` may be a partial tail: a group with ``chips_per_host``
    smaller than its neighbours models a partially-populated host
    explicitly, so packing can never stretch an hb domain (and hence a
    TP group) across the tail boundary.
    """

    num_hosts: int
    chips_per_host: int
    chip_class: str = DEFAULT_CHIP_CLASS.name

    @property
    def num_chips(self) -> int:
        return self.num_hosts * self.chips_per_host

    def cls(self) -> ChipClass:
        return chip_class(self.chip_class)


@dataclass(frozen=True)
class ClusterSpec:
    """Serving-cluster topology (paper's placement problem, TPU terms).

    A *host* groups ``chips_per_host`` chips; ``hb_domain_size`` chips
    share a high-bandwidth ICI domain (the NVLink-domain analogue) — TP
    groups must stay inside one domain.  Each chip is divisible into
    ``fractions_per_chip`` units (enforced by the engine's slot
    scheduler + static HBM budgeting; the MPS analogue).

    Uniform clusters use the scalar fields; ``host_groups`` (when
    non-empty) overrides them with an explicit heterogeneous layout.
    ``tail_chips`` models a partially-populated final host; internally
    it is materialised as an explicit partial :class:`HostGroup`, so
    domains (and hence TP groups) cannot span the tail boundary —
    that is enforced structurally by ``chip_table()``, not by a
    docstring promise.
    """

    num_hosts: int = 4
    chips_per_host: int = 4
    hb_domain_size: int = 2  # paper cluster: NVLink pairs
    fractions_per_chip: int = 10
    tail_chips: int = 0  # chips on one extra, partially-filled host
    host_groups: Tuple[HostGroup, ...] = field(default=())

    def __post_init__(self) -> None:
        if self.host_groups and self.tail_chips:
            raise ValueError(
                "host_groups and tail_chips are mutually exclusive: "
                "model the tail as an explicit partial HostGroup"
            )

    def groups(self) -> Tuple[HostGroup, ...]:
        """The host groups, with scalar fields (and tail) materialised."""
        if self.host_groups:
            return self.host_groups
        groups: Tuple[HostGroup, ...] = ()
        if self.num_hosts:
            groups += (HostGroup(self.num_hosts, self.chips_per_host),)
        if self.tail_chips:
            groups += (HostGroup(1, self.tail_chips),)
        return groups

    @property
    def num_chips(self) -> int:
        return sum(g.num_chips for g in self.groups())

    @property
    def total_hosts(self) -> int:
        return sum(g.num_hosts for g in self.groups())

    @property
    def total_units(self) -> int:
        return self.num_chips * self.fractions_per_chip

    def domains_per_host(self) -> int:
        return self.chips_per_host // self.hb_domain_size

    # -- chip classes ----------------------------------------------------

    def classes(self) -> Tuple[str, ...]:
        """Distinct chip-class names, in group order."""
        seen: List[str] = []
        for g in self.groups():
            if g.chip_class not in seen:
                seen.append(g.chip_class)
        return tuple(seen)

    @property
    def is_uniform(self) -> bool:
        return len(self.classes()) <= 1

    def chips_of_class(self, name: str) -> int:
        return sum(g.num_chips for g in self.groups() if g.chip_class == name)

    def units_of_class(self, name: str) -> int:
        return self.chips_of_class(name) * self.fractions_per_chip

    def chip_table(self) -> Tuple[Tuple[int, int, str], ...]:
        """Per-chip ``(host, domain, chip_class)`` rows.

        Domains are numbered per host — the domain counter advances by
        ``ceil(chips_in_host / hb_domain_size)`` after each host — so a
        domain never spans two hosts, two groups, or the tail boundary.
        For uniform specs whose ``chips_per_host`` is a multiple of
        ``hb_domain_size`` this reproduces the legacy global
        ``chip_index // hb_domain_size`` numbering exactly.
        """
        hb = self.hb_domain_size
        rows: List[Tuple[int, int, str]] = []
        host = 0
        next_domain = 0
        for g in self.groups():
            for _ in range(g.num_hosts):
                for j in range(g.chips_per_host):
                    rows.append((host, next_domain + j // hb, g.chip_class))
                next_domain += -(-g.chips_per_host // hb)  # ceil div
                host += 1
        return tuple(rows)


def hetero_cluster(
    groups: Tuple[HostGroup, ...],
    *,
    hb_domain_size: int = 2,
    fractions_per_chip: int = 10,
) -> ClusterSpec:
    """A heterogeneous cluster from explicit host groups.

    The scalar ``num_hosts``/``chips_per_host`` fields are zeroed so the
    layout comes from ``host_groups`` alone.
    """
    return ClusterSpec(
        num_hosts=0,
        chips_per_host=max((g.chips_per_host for g in groups), default=0),
        hb_domain_size=hb_domain_size,
        fractions_per_chip=fractions_per_chip,
        host_groups=tuple(groups),
    )


# paper-equivalent cluster sizes used across benchmarks (16 chips =
# 4 hosts x 4) plus TPU-pod-scale variants for scale tests.
PAPER_CLUSTER_4 = ClusterSpec(num_hosts=1, chips_per_host=4)
PAPER_CLUSTER_8 = ClusterSpec(num_hosts=2, chips_per_host=4)
PAPER_CLUSTER_16 = ClusterSpec(num_hosts=4, chips_per_host=4)
POD_CLUSTER_256 = ClusterSpec(num_hosts=32, chips_per_host=8,
                              hb_domain_size=8)
