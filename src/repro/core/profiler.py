"""Per-LLM profiling (paper §4 step 3).

Replays all traced requests of a given LLM — across all workflow-level
requests, *maintaining inter-request dependencies within each trace* — at
swept arrival rates and tensor-parallel degrees, through the discrete-
event engine simulator.  Produces the throughput-latency profiles the
Aggregate LLM Pipeline is synthesized from.

Replica counts are NOT swept (paper: replicas don't change latency;
throughput scales linearly).  Fractional shares are NOT swept either: a
fraction ``f`` scales the engine's service rate, which maps a profile
exactly as  L(rate; f) = (1/f) · L(rate/f; 1)  and  T(f) = f · T(1).

On heterogeneous clusters each LLM is profiled once per
``(chip_class, tp)``: the replay engine's roofline costs (and the
Pallas block plan the autotuner picks) depend on the class, so a
profile on v5p-class chips is a different curve from the same model on
v4i-class chips.  ``LLMProfile.by_class`` holds the per-class curves;
``by_tp`` stays the default-class view so every uniform-cluster caller
is untouched.  (chip_class, tp) sweeps are memoized process-wide —
re-profiling the same architecture on the same class and trace shape is
a cache hit.
"""
from __future__ import annotations

import math
import random
from bisect import bisect_left
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro import hw
from repro.configs.base import ArchConfig
from repro.core.trace import TraceStore
from repro.kernels.autotune import BlockPlan, autotune_attention_blocks
from repro.serving import costmodel as cm
from repro.serving.simulator import EngineRequest, EngineSim, EventLoop

DEP_EPS = 1e-9
RATE_GRID = (0.10, 0.30, 0.50, 0.65, 0.80, 0.90, 0.95)


@dataclass
class ReplayCall:
    prompt_tokens: int
    output_tokens: int
    preds: List[int]  # indices within the group that must finish first
    parent: Optional[int]  # index used for prefix-cache affinity


@dataclass
class ReplayGroup:
    """The calls one workflow-level request issued to one LLM."""

    calls: List[ReplayCall]


def extract_groups(store: TraceStore, llm: str) -> List[ReplayGroup]:
    groups: List[ReplayGroup] = []
    for tr in store.traces:
        calls = sorted(tr.calls_for(llm), key=lambda c: c.t_start)
        if not calls:
            continue
        rcs: List[ReplayCall] = []
        for i, c in enumerate(calls):
            preds = [j for j in range(i)
                     if calls[j].t_end <= c.t_start + DEP_EPS]
            # direct predecessors only: drop preds dominated by later preds
            direct = [j for j in preds
                      if not any(calls[j].t_end < calls[k].t_start + DEP_EPS
                                 for k in preds if k != j)]
            parent = max(direct, key=lambda j: calls[j].t_end) if direct else None
            rcs.append(ReplayCall(c.prompt_tokens, c.output_tokens,
                                  direct, parent))
        groups.append(ReplayGroup(rcs))
    return groups


def _run_replay(cfg: ArchConfig, groups: Sequence[ReplayGroup], *,
                tp: int, group_rate: float, seed: int = 0,
                prefix_caching: bool = True,
                avg_context: int = 1024,
                chip: Optional[hw.ChipClass] = None) -> List[EngineRequest]:
    """Replay groups at Poisson ``group_rate`` through one engine replica."""
    loop = EventLoop()
    engine = EngineSim(cfg, loop, tp=tp, fraction=1.0,
                       prefix_caching=prefix_caching, avg_context=avg_context,
                       chip=chip)
    rng = random.Random(seed)
    completed: List[EngineRequest] = []
    next_id = [0]

    def submit_group(g: ReplayGroup, arrival: float):
        remaining = {i: len(c.preds) for i, c in enumerate(g.calls)}
        dependents: Dict[int, List[int]] = {i: [] for i in range(len(g.calls))}
        ids: Dict[int, int] = {}
        for i, c in enumerate(g.calls):
            for j in c.preds:
                dependents[j].append(i)

        def launch(i: int, t: float):
            c = g.calls[i]
            next_id[0] += 1
            rid = next_id[0]
            ids[i] = rid

            def on_done(req: EngineRequest, i=i):
                completed.append(req)
                for k in dependents[i]:
                    remaining[k] -= 1
                    if remaining[k] == 0:
                        launch(k, loop.now)

            req = EngineRequest(
                req_id=rid, prompt_tokens=c.prompt_tokens,
                output_tokens=max(c.output_tokens, 1), arrival=t,
                on_complete=on_done,
                parent_id=ids.get(c.parent) if c.parent is not None else None)
            engine.submit(req)

        for i, c in enumerate(g.calls):
            if not c.preds:
                loop.schedule(arrival, lambda i=i: launch(i, loop.now))

    t = 0.0
    for g in groups:
        loop.schedule(t, lambda g=g, t=t: submit_group(g, t))
        if group_rate == math.inf:
            continue
        t += rng.expovariate(group_rate)
    loop.run()
    return completed


def _percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    s = sorted(xs)
    idx = min(int(q * len(s)), len(s) - 1)
    return s[idx]


@dataclass
class TPProfile:
    tp: int
    rates: List[float]  # call arrival rates (calls/s)
    latency: Dict[str, List[float]]  # percentile -> latencies
    max_throughput: float  # calls/s
    chip_class: str = ""  # chip class the sweep ran on ("" = default)
    block_plan: Optional[BlockPlan] = None  # autotuned Pallas tiling

    def lookup(self, rate: float, percentile: str = "mean") -> float:
        if rate >= self.max_throughput:
            return math.inf
        xs, ys = self.rates, self.latency[percentile]
        if rate <= xs[0]:
            return ys[0]
        i = bisect_left(xs, rate)
        if i >= len(xs):
            # extrapolate toward saturation with an M/M/1-style blowup
            base = ys[-1]
            return base * (self.max_throughput - xs[-1]) / max(
                self.max_throughput - rate, 1e-9)
        x0, x1 = xs[i - 1], xs[i]
        y0, y1 = ys[i - 1], ys[i]
        w = (rate - x0) / max(x1 - x0, 1e-12)
        return y0 + w * (y1 - y0)


@dataclass
class LLMProfile:
    llm: str
    arch: str
    calls_per_group: float
    by_tp: Dict[int, TPProfile]
    # chip-class name -> tp -> profile; ``by_tp`` aliases the default
    # class's entry, so uniform-cluster callers never look in here
    by_class: Dict[str, Dict[int, TPProfile]] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if not self.by_class:
            self.by_class = {hw.DEFAULT_CHIP_CLASS.name: self.by_tp}

    def classes(self) -> List[str]:
        return sorted(self.by_class)

    def tps(self, chip_class: Optional[str] = None) -> List[int]:
        return sorted(self._table(chip_class))

    def _table(self, chip_class: Optional[str]) -> Dict[int, TPProfile]:
        if chip_class is None:
            return self.by_tp
        try:
            return self.by_class[chip_class]
        except KeyError:
            raise KeyError(
                f"{self.llm}: no profile for chip class {chip_class!r} "
                f"(profiled: {self.classes()})") from None

    def latency(self, rate: float, tp: int, *, fraction: float = 1.0,
                percentile: str = "mean",
                chip_class: Optional[str] = None) -> float:
        prof = self._table(chip_class)[tp]
        if fraction <= 0:
            return math.inf
        return prof.lookup(rate / fraction, percentile) / fraction

    def max_throughput(self, tp: int, *, fraction: float = 1.0,
                       chip_class: Optional[str] = None) -> float:
        return self._table(chip_class)[tp].max_throughput * fraction


# Per-(arch, chip_class, tp) sweep memo.  Keyed by the replayed trace
# shape as well (group/call counts + context), so two workflows with
# different traces never share an entry, but re-profiling the same
# architecture on another host group of the same class is a hit.
_sweep_cache: Dict[tuple, TPProfile] = {}
_sweep_stats = {"hits": 0, "misses": 0}


def profile_cache_stats() -> Tuple[int, int]:
    """(hits, misses) of the per-(chip_class, tp) sweep memo."""
    return _sweep_stats["hits"], _sweep_stats["misses"]


def clear_profile_cache() -> None:
    _sweep_cache.clear()
    _sweep_stats["hits"] = _sweep_stats["misses"] = 0


def _sweep_tp(cfg: ArchConfig, groups: Sequence[ReplayGroup],
              calls_per_group: float, *, tp: int, chip: hw.ChipClass,
              avg_context: int, prefix_caching: bool, seed: int,
              trace_key: tuple) -> TPProfile:
    key = (cfg.name, chip.name, tp, prefix_caching, seed) + trace_key
    hit = _sweep_cache.get(key)
    if hit is not None:
        _sweep_stats["hits"] += 1
        return hit
    _sweep_stats["misses"] += 1

    # --- capacity run: all groups at t=0 ---
    done = _run_replay(cfg, groups, tp=tp, group_rate=math.inf,
                       prefix_caching=prefix_caching,
                       avg_context=avg_context, seed=seed, chip=chip)
    makespan = max(r.t_done for r in done)
    t_max = len(done) / max(makespan, 1e-9)

    # --- latency sweep at fractions of capacity ---
    rates, lat = [], {"mean": [], "p50": [], "p90": [], "p99": []}
    for fr in RATE_GRID:
        call_rate = fr * t_max
        group_rate = call_rate / calls_per_group
        done = _run_replay(cfg, groups, tp=tp, group_rate=group_rate,
                           prefix_caching=prefix_caching,
                           avg_context=avg_context, seed=seed + 1,
                           chip=chip)
        ls = [r.latency for r in done]
        rates.append(call_rate)
        lat["mean"].append(sum(ls) / len(ls))
        lat["p50"].append(_percentile(ls, 0.50))
        lat["p90"].append(_percentile(ls, 0.90))
        lat["p99"].append(_percentile(ls, 0.99))
    # the Pallas tiling this (chip_class, tp) point would deploy with:
    # batch = the engine's KV-bound batch at the traced context length
    batch = max(cm.max_batch_size(cfg, avg_context, tp=tp, chip=chip), 1)
    plan = autotune_attention_blocks(
        chip, tp=tp, batch=min(batch, 256), seq_len=max(avg_context, 1),
        head_dim=cfg.head_dim or 128, num_heads=max(cfg.num_heads, 1))
    prof = TPProfile(tp=tp, rates=rates, latency=lat, max_throughput=t_max,
                     chip_class=chip.name, block_plan=plan)
    _sweep_cache[key] = prof
    return prof


def profile_llm(cfg: ArchConfig, store: TraceStore, llm: str, *,
                tp_degrees: Sequence[int] = (1, 2, 4),
                max_groups: int = 120, prefix_caching: bool = True,
                seed: int = 0,
                chip_classes: Sequence[hw.ChipClass] = ()) -> LLMProfile:
    """Profile one LLM per (chip_class, tp).

    ``chip_classes`` defaults to the default (v5e) class only — the
    uniform-cluster path.  A (class, tp) point is skipped when the model
    does not fit the class's HBM at that TP degree; a class where no TP
    degree fits is omitted from ``by_class`` entirely (the scheduler
    then never binds this LLM to that class).
    """
    groups = extract_groups(store, llm)[:max_groups]
    if not groups:
        raise ValueError(f"no traced calls for LLM {llm!r}")
    n_calls = sum(len(g.calls) for g in groups)
    calls_per_group = n_calls / len(groups)
    prompts = [c.prompt_tokens for g in groups for c in g.calls]
    outs = [c.output_tokens for g in groups for c in g.calls]
    avg_context = int(sum(prompts) / len(prompts) + sum(outs) / len(outs))
    trace_key = (llm, len(groups), n_calls, avg_context, max_groups)

    classes = tuple(chip_classes) or (hw.DEFAULT_CHIP_CLASS,)
    by_class: Dict[str, Dict[int, TPProfile]] = {}
    for chip in classes:
        table: Dict[int, TPProfile] = {}
        for tp in tp_degrees:
            # legacy behavior on the default class: always sweep (the
            # engine clamps to batch 1); other classes skip infeasible
            # points so the scheduler never binds a model to a class it
            # cannot load on
            if (chip.name != hw.DEFAULT_CHIP_CLASS.name
                    and not cm.fits_on_class(cfg, chip, max_tp=tp,
                                             avg_context=avg_context)):
                continue
            table[tp] = _sweep_tp(cfg, groups, calls_per_group, tp=tp,
                                  chip=chip, avg_context=avg_context,
                                  prefix_caching=prefix_caching, seed=seed,
                                  trace_key=trace_key)
        if table:
            by_class[chip.name] = table
    if not by_class:
        raise ValueError(
            f"{llm}: model fits no profiled chip class "
            f"({[c.name for c in classes]}) at TP degrees {tuple(tp_degrees)}")
    by_tp = by_class.get(hw.DEFAULT_CHIP_CLASS.name)
    if by_tp is None:  # default class absent: alias the first profiled one
        first = next(c.name for c in classes if c.name in by_class)
        by_tp = by_class[first]
    return LLMProfile(llm=llm, arch=cfg.name,
                      calls_per_group=calls_per_group, by_tp=by_tp,
                      by_class=by_class)
