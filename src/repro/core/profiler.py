"""Per-LLM profiling (paper §4 step 3).

Replays all traced requests of a given LLM — across all workflow-level
requests, *maintaining inter-request dependencies within each trace* — at
swept arrival rates and tensor-parallel degrees, through the discrete-
event engine simulator.  Produces the throughput-latency profiles the
Aggregate LLM Pipeline is synthesized from.

Replica counts are NOT swept (paper: replicas don't change latency;
throughput scales linearly).  Fractional shares are NOT swept either: a
fraction ``f`` scales the engine's service rate, which maps a profile
exactly as  L(rate; f) = (1/f) · L(rate/f; 1)  and  T(f) = f · T(1).
"""
from __future__ import annotations

import math
import random
from bisect import bisect_left
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.configs.base import ArchConfig
from repro.core.trace import TraceStore
from repro.serving.simulator import EngineRequest, EngineSim, EventLoop

DEP_EPS = 1e-9
RATE_GRID = (0.10, 0.30, 0.50, 0.65, 0.80, 0.90, 0.95)


@dataclass
class ReplayCall:
    prompt_tokens: int
    output_tokens: int
    preds: List[int]  # indices within the group that must finish first
    parent: Optional[int]  # index used for prefix-cache affinity


@dataclass
class ReplayGroup:
    """The calls one workflow-level request issued to one LLM."""

    calls: List[ReplayCall]


def extract_groups(store: TraceStore, llm: str) -> List[ReplayGroup]:
    groups: List[ReplayGroup] = []
    for tr in store.traces:
        calls = sorted(tr.calls_for(llm), key=lambda c: c.t_start)
        if not calls:
            continue
        rcs: List[ReplayCall] = []
        for i, c in enumerate(calls):
            preds = [j for j in range(i)
                     if calls[j].t_end <= c.t_start + DEP_EPS]
            # direct predecessors only: drop preds dominated by later preds
            direct = [j for j in preds
                      if not any(calls[j].t_end < calls[k].t_start + DEP_EPS
                                 for k in preds if k != j)]
            parent = max(direct, key=lambda j: calls[j].t_end) if direct else None
            rcs.append(ReplayCall(c.prompt_tokens, c.output_tokens,
                                  direct, parent))
        groups.append(ReplayGroup(rcs))
    return groups


def _run_replay(cfg: ArchConfig, groups: Sequence[ReplayGroup], *,
                tp: int, group_rate: float, seed: int = 0,
                prefix_caching: bool = True,
                avg_context: int = 1024) -> List[EngineRequest]:
    """Replay groups at Poisson ``group_rate`` through one engine replica."""
    loop = EventLoop()
    engine = EngineSim(cfg, loop, tp=tp, fraction=1.0,
                       prefix_caching=prefix_caching, avg_context=avg_context)
    rng = random.Random(seed)
    completed: List[EngineRequest] = []
    next_id = [0]

    def submit_group(g: ReplayGroup, arrival: float):
        remaining = {i: len(c.preds) for i, c in enumerate(g.calls)}
        dependents: Dict[int, List[int]] = {i: [] for i in range(len(g.calls))}
        ids: Dict[int, int] = {}
        for i, c in enumerate(g.calls):
            for j in c.preds:
                dependents[j].append(i)

        def launch(i: int, t: float):
            c = g.calls[i]
            next_id[0] += 1
            rid = next_id[0]
            ids[i] = rid

            def on_done(req: EngineRequest, i=i):
                completed.append(req)
                for k in dependents[i]:
                    remaining[k] -= 1
                    if remaining[k] == 0:
                        launch(k, loop.now)

            req = EngineRequest(
                req_id=rid, prompt_tokens=c.prompt_tokens,
                output_tokens=max(c.output_tokens, 1), arrival=t,
                on_complete=on_done,
                parent_id=ids.get(c.parent) if c.parent is not None else None)
            engine.submit(req)

        for i, c in enumerate(g.calls):
            if not c.preds:
                loop.schedule(arrival, lambda i=i: launch(i, loop.now))

    t = 0.0
    for g in groups:
        loop.schedule(t, lambda g=g, t=t: submit_group(g, t))
        if group_rate == math.inf:
            continue
        t += rng.expovariate(group_rate)
    loop.run()
    return completed


def _percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    s = sorted(xs)
    idx = min(int(q * len(s)), len(s) - 1)
    return s[idx]


@dataclass
class TPProfile:
    tp: int
    rates: List[float]  # call arrival rates (calls/s)
    latency: Dict[str, List[float]]  # percentile -> latencies
    max_throughput: float  # calls/s

    def lookup(self, rate: float, percentile: str = "mean") -> float:
        if rate >= self.max_throughput:
            return math.inf
        xs, ys = self.rates, self.latency[percentile]
        if rate <= xs[0]:
            return ys[0]
        i = bisect_left(xs, rate)
        if i >= len(xs):
            # extrapolate toward saturation with an M/M/1-style blowup
            base = ys[-1]
            return base * (self.max_throughput - xs[-1]) / max(
                self.max_throughput - rate, 1e-9)
        x0, x1 = xs[i - 1], xs[i]
        y0, y1 = ys[i - 1], ys[i]
        w = (rate - x0) / max(x1 - x0, 1e-12)
        return y0 + w * (y1 - y0)


@dataclass
class LLMProfile:
    llm: str
    arch: str
    calls_per_group: float
    by_tp: Dict[int, TPProfile]

    def tps(self) -> List[int]:
        return sorted(self.by_tp)

    def latency(self, rate: float, tp: int, *, fraction: float = 1.0,
                percentile: str = "mean") -> float:
        prof = self.by_tp[tp]
        if fraction <= 0:
            return math.inf
        return prof.lookup(rate / fraction, percentile) / fraction

    def max_throughput(self, tp: int, *, fraction: float = 1.0) -> float:
        return self.by_tp[tp].max_throughput * fraction


def profile_llm(cfg: ArchConfig, store: TraceStore, llm: str, *,
                tp_degrees: Sequence[int] = (1, 2, 4),
                max_groups: int = 120, prefix_caching: bool = True,
                seed: int = 0) -> LLMProfile:
    groups = extract_groups(store, llm)[:max_groups]
    if not groups:
        raise ValueError(f"no traced calls for LLM {llm!r}")
    n_calls = sum(len(g.calls) for g in groups)
    calls_per_group = n_calls / len(groups)
    prompts = [c.prompt_tokens for g in groups for c in g.calls]
    outs = [c.output_tokens for g in groups for c in g.calls]
    avg_context = int(sum(prompts) / len(prompts) + sum(outs) / len(outs))

    by_tp: Dict[int, TPProfile] = {}
    for tp in tp_degrees:
        # --- capacity run: all groups at t=0 ---
        done = _run_replay(cfg, groups, tp=tp, group_rate=math.inf,
                           prefix_caching=prefix_caching,
                           avg_context=avg_context, seed=seed)
        makespan = max(r.t_done for r in done)
        t_max = len(done) / max(makespan, 1e-9)

        # --- latency sweep at fractions of capacity ---
        rates, lat = [], {"mean": [], "p50": [], "p90": [], "p99": []}
        for fr in RATE_GRID:
            call_rate = fr * t_max
            group_rate = call_rate / calls_per_group
            done = _run_replay(cfg, groups, tp=tp, group_rate=group_rate,
                               prefix_caching=prefix_caching,
                               avg_context=avg_context, seed=seed + 1)
            ls = [r.latency for r in done]
            rates.append(call_rate)
            lat["mean"].append(sum(ls) / len(ls))
            lat["p50"].append(_percentile(ls, 0.50))
            lat["p90"].append(_percentile(ls, 0.90))
            lat["p99"].append(_percentile(ls, 0.99))
        by_tp[tp] = TPProfile(tp=tp, rates=rates, latency=lat,
                              max_throughput=t_max)
    return LLMProfile(llm=llm, arch=cfg.name,
                      calls_per_group=calls_per_group, by_tp=by_tp)
