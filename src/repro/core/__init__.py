"""Scepsy's planning core — the paper's primary contribution.

The modules chain into the Fig. 2 flow (see ``docs/architecture.md``
for the guided tour):

* :mod:`repro.core.trace` — LLM-level execution traces (§4 step 1);
* :mod:`repro.core.aggregate` — per-LLM invocation counts, parallelism
  and execution-time shares (§4 step 2, the §2.4 stability
  observation);
* :mod:`repro.core.profiler` — per-LLM throughput/latency profiles by
  TP degree and chip fraction (§4 step 3);
* :mod:`repro.core.pipeline` — the Aggregate LLM Pipeline predictor and
  ``merge_pipelines`` for pooled multi-tenant fleets (§4 steps 4-5);
* :mod:`repro.core.scheduler` — allocation search for one workflow or a
  fleet, with welfare objectives and placement feedback (§5);
* :mod:`repro.core.placement` — hierarchical topology-aware placement,
  co-placement of partitioned fleets, feasibility probe, migration
  diffs (§6);
* :mod:`repro.core.drift` / :mod:`repro.core.replan` — online drift
  detection driving the three-rung re-plan ladder (post-paper, ROADMAP
  "Online re-scheduling on share drift");
* :mod:`repro.core.scepsy` — the ``deploy`` / ``deploy_multi`` facade.

Sibling subpackages supply the substrates: :mod:`repro.serving` (the
discrete-event runtime + cost model), :mod:`repro.workflows` (servable
agentic workloads), :mod:`repro.qos` (request-level SLO layer).
"""
