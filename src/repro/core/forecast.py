"""Short-horizon arrival forecasting for proactive replanning.

The drift monitor (:mod:`repro.core.drift`) is *reactive*: its rate
detector fires only after arrivals have already deviated, so the replan
ladder pays the ramp's queueing damage before capacity moves.  This
module closes the PR-3 carry-over ("react before the ramp"): per-workflow
arrival counts are binned into fixed windows, smoothed by a damped
Holt-Winters recursion (level + trend + optional multiplicative seasonal
indices for diurnal traffic), and extrapolated ``lead_s`` ahead.  A
:class:`ForecastTrigger` compares the extrapolation against deployed
capacity and emits a :class:`ForecastDrift` — a ``RateDrift`` subtype the
ladder's rung mapping already understands — *before* the crossing
happens.

Two layers of hysteresis keep false forecasts from thrashing the ladder:
the trigger itself requires ``confirm`` consecutive breached polls and
then latches (re-arming only once the forecast recedes below
``rearm × capacity``), and the :class:`~repro.core.replan.ReplanController`
rung cool-down applies on top unchanged.  Telemetry arrives through the
monitor: ``DriftMonitor.record_arrival`` forwards every arrival to an
attached forecaster, so the forecaster sees exactly the stream the
reactive detectors see.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional

from repro.core.drift import RateDrift


@dataclass(frozen=True)
class ForecastConfig:
    """Forecaster + trigger knobs.

    Defaults are sized for bench-scale days (minutes, not hours): a
    10 s bin at λ = 5/s holds ~50 arrivals, so bin-count noise is ~14%
    and the damped trend needs a sustained ramp — not one hot bin — to
    project a capacity crossing.
    """

    bin_s: float = 10.0  # arrival-count bin width
    alpha: float = 0.4  # level weight
    beta: float = 0.2  # trend weight
    gamma: float = 0.15  # seasonal-index weight
    phi: float = 0.9  # trend damping per step (1.0 = undamped)
    period_bins: int = 0  # seasonal cycle length in bins (0 = off)
    min_bins: int = 6  # bins observed before forecasts are served
    lead_s: float = 60.0  # forecast horizon = required reaction lead
    # horizon the emitted drift *provisions* for (0 = lead_s): when the
    # controller's cool-down means the next chance to act is a window
    # away, sizing only lead_s ahead under-provisions the ramp — set
    # this to lead_s + cooldown so one action covers the whole window
    plan_horizon_s: float = 0.0
    margin: float = 1.0  # fire when forecast > capacity * margin
    confirm: int = 2  # consecutive breached polls before firing
    rearm: float = 0.9  # latch releases below capacity*margin*rearm
    headroom: float = 1.2  # default capacity = planned rate * headroom
    # no-chase band: suppress firing once the *measured* level is already
    # past capacity*margin*chase — the ramp has arrived, the reactive
    # detectors own the episode.  > margin so a level marginally past
    # capacity (the normal pre-ramp firing point, where the level trails
    # the forecast by about one poll) does not suppress the early fire.
    chase: float = 1.5


class HoltWinters:
    """Damped-trend Holt-Winters with optional multiplicative season.

    ``update`` ingests one observation per fixed step; ``forecast(k)``
    extrapolates k steps ahead as ``level + Σ_{i=1..k} φ^i · trend``
    (times the seasonal index of the target step), clamped at 0 —
    negative arrival rates are not a thing.
    """

    def __init__(self, alpha: float, beta: float, gamma: float = 0.0,
                 period: int = 0, phi: float = 1.0):
        self.alpha = alpha
        self.beta = beta
        self.gamma = gamma
        self.period = period
        self.phi = phi
        self.level: Optional[float] = None
        self.trend = 0.0
        self.season: List[float] = [1.0] * period if period > 0 else []
        self.count = 0

    def _sidx(self, ahead: int = 0) -> int:
        return (self.count + ahead) % self.period

    def update(self, x: float) -> None:
        s = self.season[self._sidx()] if self.period > 0 else 1.0
        x_ds = x / max(s, 1e-9)  # deseasonalized observation
        if self.level is None:
            self.level = x_ds
        else:
            prev = self.level
            self.level = (self.alpha * x_ds
                          + (1.0 - self.alpha) * (prev + self.phi * self.trend))
            self.trend = (self.beta * (self.level - prev)
                          + (1.0 - self.beta) * self.phi * self.trend)
        if self.period > 0 and self.level is not None and self.level > 1e-9:
            idx = self._sidx()
            self.season[idx] += self.gamma * (x / max(self.level, 1e-9)
                                              - self.season[idx])
        self.count += 1

    def forecast(self, k: int) -> Optional[float]:
        if self.level is None:
            return None
        damp = sum(self.phi ** i for i in range(1, k + 1))
        f = self.level + damp * self.trend
        if self.period > 0:
            f *= self.season[self._sidx(k - 1)]
        return max(f, 0.0)


class ArrivalForecaster:
    """Bins per-workflow arrivals and serves short-horizon rate forecasts.

    Implements the one-method telemetry protocol
    (:meth:`observe`) that ``DriftMonitor.record_arrival`` forwards to
    when a forecaster is attached.  Bins close lazily: an arrival (or an
    explicit :meth:`advance`, which :class:`ForecastTrigger` issues every
    poll) flushes every completed bin — including empty ones, so a
    traffic *stop* decays the forecast instead of freezing it.
    """

    def __init__(self, workflows: Iterable[str],
                 config: ForecastConfig = ForecastConfig()):
        self.config = config
        c = config
        self._hw: Dict[str, HoltWinters] = {
            w: HoltWinters(c.alpha, c.beta, c.gamma, c.period_bins, c.phi)
            for w in workflows
        }
        self._count: Dict[str, int] = {w: 0 for w in self._hw}
        self._bin_end: Dict[str, Optional[float]] = {w: None for w in self._hw}

    def _flush_until(self, workflow: str, t: float) -> None:
        """Close every bin that ends at or before ``t``."""
        end = self._bin_end[workflow]
        if end is None:
            return
        while t >= end:
            self._hw[workflow].update(self._count[workflow] / self.config.bin_s)
            self._count[workflow] = 0
            end += self.config.bin_s
        self._bin_end[workflow] = end

    def observe(self, workflow: str, t: float) -> None:
        if workflow not in self._hw:
            return
        if self._bin_end[workflow] is None:
            # align the first bin to the global grid for seasonality
            self._bin_end[workflow] = (math.floor(t / self.config.bin_s) + 1) \
                * self.config.bin_s
        self._flush_until(workflow, t)
        self._count[workflow] += 1

    def advance(self, workflow: str, t: float) -> None:
        """Flush completed (possibly empty) bins up to ``t`` without
        recording an arrival."""
        if workflow in self._hw:
            self._flush_until(workflow, t)

    def rate(self, workflow: str) -> Optional[float]:
        """Current smoothed arrival-rate level (None before any bin)."""
        hw = self._hw.get(workflow)
        return hw.level if hw is not None else None

    def bins_seen(self, workflow: str) -> int:
        hw = self._hw.get(workflow)
        return hw.count if hw is not None else 0

    def forecast_rate(self, workflow: str, horizon_s: float) -> Optional[float]:
        """Forecast rate ``horizon_s`` ahead (None until ``min_bins``
        bins have closed — cold forecasters never trigger anything)."""
        hw = self._hw.get(workflow)
        if hw is None or hw.count < self.config.min_bins:
            return None
        k = max(int(math.ceil(horizon_s / self.config.bin_s)), 1)
        return hw.forecast(k)


@dataclass(frozen=True)
class ForecastDrift(RateDrift):
    """Proactive rate drift: the *forecast*, not the live estimate,
    crossed deployed capacity.  ``observed`` carries the forecast rate —
    the target the replan must provision for — and ``expected`` the
    planned rate, so ``recommend_rung`` and ``_drifted_targets`` treat it
    like any rate excursion, just ``lead_s`` early.

    ``horizon_s`` is the *provision* horizon the target was sized for;
    ``lead_s`` is the firing horizon, which is also the event's validity:
    a forecast about ``at + lead_s`` is stale once that moment has
    passed — the live detectors have seen the real thing by then."""

    horizon_s: float = 0.0
    lead_s: float = 0.0
    capacity: float = 0.0

    @property
    def stale_after(self) -> float:
        return self.at + (self.lead_s if self.lead_s > 0 else self.horizon_s)


class ForecastTrigger:
    """Turns forecasts into replan triggers, with hysteresis.

    ``planned_lams`` is what the incumbent plan provisions for;
    ``capacity_lams`` (default ``planned × headroom``) is the rate above
    which that plan is presumed saturated.  A breach must persist for
    ``confirm`` consecutive polls, then the trigger latches per workflow
    until the forecast recedes below the re-arm band — one event per
    ramp, however often the controller polls.
    """

    def __init__(self, forecaster: ArrivalForecaster,
                 planned_lams: Dict[str, float], *,
                 headroom: float = 1.2,
                 capacity_lams: Optional[Dict[str, float]] = None):
        self.forecaster = forecaster
        self.headroom = headroom
        self.planned_lams = dict(planned_lams)
        self.capacity_lams = (dict(capacity_lams) if capacity_lams is not None
                              else {w: lam * headroom
                                    for w, lam in planned_lams.items()})
        self._breach: Dict[str, int] = {w: 0 for w in self.planned_lams}
        self._latched: set = set()
        self.fired: List[ForecastDrift] = []  # full history, for benches

    def poll(self, now: float) -> List[ForecastDrift]:
        cfg = self.forecaster.config
        out: List[ForecastDrift] = []
        for w, cap in self.capacity_lams.items():
            self.forecaster.advance(w, now)
            f = self.forecaster.forecast_rate(w, cfg.lead_s)
            if f is None or cap <= 0:
                continue
            if w in self._latched:
                if f < cap * cfg.margin * cfg.rearm:
                    self._latched.discard(w)
                    self._breach[w] = 0
                continue
            # the trigger leads, it does not chase: once the *measured*
            # level is itself deep past capacity the ramp has arrived,
            # the lead time is spent, and the reactive detectors own the
            # episode — a forecast fired now would only inflate the
            # replan target mid-distress
            level = self.forecaster.rate(w)
            if level is not None and level > cap * cfg.margin * cfg.chase:
                self._breach[w] = 0
                continue
            if f > cap * cfg.margin:
                self._breach[w] = self._breach.get(w, 0) + 1
                if self._breach[w] >= cfg.confirm:
                    self._latched.add(w)
                    planned = self.planned_lams.get(w, cap)
                    # size the replan for the worst forecast over the
                    # plan horizon, not just the firing horizon — the
                    # cool-down means there is no second chance soon
                    ph = max(cfg.plan_horizon_s, cfg.lead_s)
                    fp = self.forecaster.forecast_rate(w, ph)
                    target = max(f, fp if fp is not None else 0.0)
                    mag = abs(target - planned) / max(planned, 1e-9)
                    out.append(ForecastDrift(
                        workflow=w, at=now, magnitude=mag,
                        observed=target, expected=planned,
                        horizon_s=ph, lead_s=cfg.lead_s, capacity=cap))
            else:
                self._breach[w] = 0
        self.fired.extend(out)
        return out

    def rebase(self, planned_lams: Dict[str, float],
               capacity_lams: Optional[Dict[str, float]] = None) -> None:
        """Adopt a new plan's targets (called by ``ReplanController.adopt``):
        capacity moves with the plan and the per-workflow latches clear,
        so the *next* ramp beyond the new capacity can fire again."""
        self.planned_lams = dict(planned_lams)
        self.capacity_lams = (dict(capacity_lams) if capacity_lams is not None
                              else {w: lam * self.headroom
                                    for w, lam in planned_lams.items()})
        self._breach = {w: 0 for w in self.planned_lams}
        self._latched.clear()
