"""Incremental re-scheduling on drift: the three-rung escalation ladder.

Drift events from :mod:`repro.core.drift` are mapped onto the cheapest
reaction that can absorb them:

1. **Routing rebalance** (pooled fleets only): re-derive the per-workflow
   routing tables from the *observed* rate mix via
   ``MergedPipeline.routing_weights`` — no scheduling search, no
   re-placement, the shared replica set is untouched.
2. **Warm incremental re-plan**: one :func:`schedule_multi` call threaded
   through the fleet's :class:`FleetWarmState` — unchanged workflows'
   (workflow, chips) schedules and option tables are reused verbatim,
   drifted workflows re-search from their previous unit split as a
   branch-and-bound incumbent, and a pooled re-plan is a single seeded
   merged-pipeline ``schedule()`` call.
3. **Full re-plan + re-placement**: a cold ``mode="auto"`` search (the
   same work the original deploy did) plus a fresh placement, emitted as
   a :class:`MigrationDiff` — chips to move, replicas to add/drop —
   rather than a from-scratch manifest.

The controller escalates automatically: a rebalance that leaves some
workflow infeasible falls through to rung 2; a warm re-plan that still
cannot serve every workflow falls through to rung 3.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro import hw
from repro.core.drift import (
    DriftEvent,
    DriftMonitor,
    Expectation,
    RateDrift,
    ShareDrift,
    SLOViolation,
    TokenDrift,
    expectation_from,
)
from repro.core.forecast import ForecastDrift, ForecastTrigger
from repro.core.pipeline import AggregateLLMPipeline, merge_pipelines
from repro.core.placement import (
    MigrationDiff,
    Placement,
    PlacementError,
    migration_diff,
    place,
    place_fleet,
    tenant_routing,
)
from repro.core.scheduler import (
    FleetWarmState,
    MultiScheduleResult,
    SchedulerConfig,
    schedule_multi,
)

RUNG_REBALANCE = 1
RUNG_WARM_REPLAN = 2
RUNG_FULL_REPLAN = 3


def partitioned_fleet_placement(result: MultiScheduleResult,
                                spec: hw.ClusterSpec) -> Optional[Placement]:
    """Global placement of a partitioned fleet schedule: all workflows'
    replicas co-placed in one pass over the real topology
    (:func:`~repro.core.placement.place_fleet`, instances keyed
    ``<workflow>/<llm>``), so partitioned re-plans produce a
    :class:`MigrationDiff` just like pooled ones — and rungs 2-3 deploy
    through the same packing the placement-aware split search probes."""
    if result.alloc_mode != "partitioned" or not result.chip_split:
        return None
    return place_fleet(
        {name: result.per_workflow[name].allocations
         for name in result.chip_split}, spec)


@dataclass
class ReplanAction:
    """One reaction taken (or proposed) by the controller."""

    rung: int
    reason: str
    latency_s: float  # wall-clock cost of computing the reaction
    lam_targets: Dict[str, float]  # targets the reaction plans for
    feasible: bool = True
    routing: Optional[dict] = None  # wf -> llm -> replica idx -> weight
    instance_routing: Optional[dict] = None  # wf -> llm -> instance -> w
    result: Optional[MultiScheduleResult] = None  # rungs 2-3
    placement: Optional[Placement] = None  # pooled re-placements
    migration: Optional[MigrationDiff] = None  # rung 3 (and rung 2 moves)
    welfare: Optional[float] = None
    events: List[DriftEvent] = field(default_factory=list)


def recommend_rung(events: List[DriftEvent], *, rebalance_band: float = 0.5) -> int:
    """Map a batch of drift events to the cheapest plausible rung.

    Rate drift within ``rebalance_band`` (relative deviation) is a mix
    shift the pooled replica set can absorb by re-weighting (rung 1);
    larger rate drift needs capacity to move (rung 2).  Share and token
    drift mean the *pipeline synthesis itself* is stale, which only a
    re-plan (over refreshed pipelines) can answer (rung 2).  An SLO
    violation (the fourth trigger: promised tiers are being missed) is
    a mild-overload signal a routing rebalance may absorb when the
    violation rate is inside the band; past it the tier needs capacity.
    """
    if not events:
        return 0
    rung = 0
    for ev in events:
        if isinstance(ev, (ShareDrift, TokenDrift)):
            rung = max(rung, RUNG_WARM_REPLAN)
        elif isinstance(ev, (RateDrift, SLOViolation)):
            if ev.magnitude <= rebalance_band:
                rung = max(rung, RUNG_REBALANCE)
            else:
                rung = max(rung, RUNG_WARM_REPLAN)
    return rung


class ReplanController:
    """Holds a fleet's planning state and reacts to drift events.

    Constructed by ``deploy_multi(..., online=True)`` (see
    :mod:`repro.core.scepsy`) or directly from a schedule result.  The
    controller owns the :class:`FleetWarmState`, the incumbent
    :class:`MultiScheduleResult` and (for pooled fleets) the incumbent
    :class:`Placement`, so every reaction is incremental with respect to
    what is actually deployed.
    """

    def __init__(
        self,
        pipelines: Dict[str, AggregateLLMPipeline],
        spec: hw.ClusterSpec,
        lam_targets: Dict[str, float],
        config: Optional[SchedulerConfig] = None,
        *,
        result: Optional[MultiScheduleResult] = None,
        placement: Optional[Placement] = None,
        monitor: Optional[DriftMonitor] = None,
        forecast: Optional[ForecastTrigger] = None,
        pipeline_refresh: Optional[Callable[[str], AggregateLLMPipeline]] = None,
        rebalance_band: float = 0.5,
        cooldown_s: float = 0.0,
    ):
        self.pipelines = dict(pipelines)
        self.spec = spec
        self.lam_targets = dict(lam_targets)
        self.config = config or SchedulerConfig(max_tp=spec.hb_domain_size)
        self.result = result
        self.placement = placement
        self.monitor = monitor
        # proactive trigger (repro.core.forecast.ForecastTrigger): polled
        # alongside the monitor in step(), rebased in adopt()
        self.forecast = forecast
        self.pipeline_refresh = pipeline_refresh
        self.rebalance_band = rebalance_band
        # rung hysteresis: after an adopted action, drift events inside
        # the cool-down window are ignored unless they recommend a
        # strictly HIGHER rung — flapping traffic cannot oscillate
        # rebalance -> replan -> rebalance, but genuine escalation is
        # never delayed
        self.cooldown_s = cooldown_s
        self._last_action_at = -math.inf
        self._last_rung = 0
        # events suppressed by the cool-down: the monitor is
        # edge-triggered (a fired detector stays latched until the
        # metric recovers), so a suppressed event would otherwise never
        # re-fire for a *persistent* condition — it is deferred and
        # re-considered on the next react()/step() instead
        self._deferred: List[DriftEvent] = []
        self.warm_state = (
            result.warm_state
            if result is not None and result.warm_state is not None
            else FleetWarmState()
        )
        self.history: List[ReplanAction] = []
        self._refreshed_since_adopt: set = set()
        # observability hook (repro.obs.spans.Tracer); None = untraced
        self.tracer = None

    # -- rungs -------------------------------------------------------------

    def rebalance(self, lam_targets: Dict[str, float]) -> ReplanAction:
        """Rung 1: new routing tables from the observed rate mix; the
        allocation and placement stay exactly as deployed."""
        t0 = time.perf_counter()
        if self.result is None or self.result.pooled is None:
            return ReplanAction(
                rung=RUNG_REBALANCE,
                reason="no pooled incumbent: rebalance unavailable",
                latency_s=time.perf_counter() - t0,
                lam_targets=dict(lam_targets),
                feasible=False,
            )
        pooled = self.result.pooled
        merged = merge_pipelines(self.pipelines, lam_targets)
        missing = [c for c in merged.tenants if c not in pooled.allocations]
        if missing:
            return ReplanAction(
                rung=RUNG_REBALANCE,
                reason=f"tenants {missing} not in deployed allocation",
                latency_s=time.perf_counter() - t0,
                lam_targets=dict(lam_targets),
                feasible=False,
            )
        routing = merged.routing_weights(
            pooled.allocations, policy=self.config.routing_policy
        )
        preds = merged.attribute(pooled.allocations, self.config.percentile)
        feasible = all(p.feasible for p in preds.values())
        inst_routing = None
        if self.placement is not None:
            members = {
                cid: [(t.workflow, t.llm) for t in mem]
                for cid, mem in merged.tenants.items()
            }
            inst_routing = tenant_routing(self.placement, members, routing)
        return ReplanAction(
            rung=RUNG_REBALANCE,
            reason="routing-weight rebalance (no re-placement)",
            latency_s=time.perf_counter() - t0,
            lam_targets=dict(lam_targets),
            feasible=feasible,
            routing=routing,
            instance_routing=inst_routing,
        )

    def replan(
        self, lam_targets: Dict[str, float], *, cold: bool = False
    ) -> ReplanAction:
        """Rung 2 (warm, incremental) or rung 3 (``cold=True``).

        A cold re-plan trusts *nothing* from the incumbent: when the
        deployment provided a ``pipeline_refresh`` it re-traces and
        re-profiles every workflow (the paper's steps 1-4, by far the
        dominant cost), then runs the same ``mode="auto"`` search the
        original deploy ran, from an empty warm state, and re-places.
        The warm rung instead reuses profiled pipelines (except any the
        caller refreshed), cached sub-schedules and incumbents.
        """
        t0 = time.perf_counter()
        if cold:
            state = FleetWarmState()
            mode = "auto"
            if self.pipeline_refresh is not None:
                for n in list(self.pipelines):
                    self.pipelines[n] = self.pipeline_refresh(n)
                    self._refreshed_since_adopt.add(n)
        else:
            state = self.warm_state
            mode = self.result.alloc_mode if self.result is not None else "auto"
        try:
            res = schedule_multi(
                self.pipelines,
                self.spec,
                lam_targets,
                self.config,
                mode=mode,
                warm_state=state,
            )
        except (ValueError, RuntimeError) as e:
            return ReplanAction(
                rung=RUNG_FULL_REPLAN if cold else RUNG_WARM_REPLAN,
                reason=f"re-plan failed: {e}",
                latency_s=time.perf_counter() - t0,
                lam_targets=dict(lam_targets),
                feasible=False,
            )
        placement = None
        migration = None
        routing = None
        placement_failed = False
        if res.alloc_mode == "pooled" and res.pooled is not None:
            routing = res.pooled.routing
            try:
                placement = place(res.pooled.allocations, self.spec)
            except PlacementError:
                placement_failed = True  # plan cannot deploy: escalate
        elif res.placement_ok is False:
            # placement-aware search found NO placeable split and fell
            # back to the blind winner: placing it is guaranteed to
            # fail, and the plan must not be reported deployable
            placement_failed = True
        else:
            try:
                placement = partitioned_fleet_placement(res, self.spec)
            except PlacementError:
                placement_failed = True
        if self.placement is not None and placement is not None:
            migration = migration_diff(self.placement, placement)
        feasible = (all(r.feasible for r in res.per_workflow.values())
                    and not placement_failed)
        reason = (
            "cold full re-plan + re-placement" if cold else "warm incremental re-plan"
        )
        return ReplanAction(
            rung=RUNG_FULL_REPLAN if cold else RUNG_WARM_REPLAN,
            reason=reason,
            latency_s=time.perf_counter() - t0,
            lam_targets=dict(lam_targets),
            feasible=feasible,
            routing=routing,
            result=res,
            placement=placement,
            migration=migration,
            welfare=res.welfare,
        )

    # -- the ladder --------------------------------------------------------

    def react(self, events: List[DriftEvent]) -> Optional[ReplanAction]:
        """Escalate through the ladder until a rung absorbs the drift,
        adopt the resulting action, and return it (None: no reaction
        needed, or suppressed — and deferred — by the cool-down
        hysteresis)."""
        events = self._merge_deferred(events)
        now = max((ev.at for ev in events), default=0.0)
        if self.monitor is not None:
            now = max(now, self.monitor.now)
        # a deferred forecast expires once its firing lead has passed:
        # by then the ramp it predicted is live traffic the reactive
        # detectors see directly, and provisioning for the stale
        # extrapolation would chase a peak that is already over
        events = [ev for ev in events
                  if not (isinstance(ev, ForecastDrift)
                          and now > ev.stale_after)]
        rung = recommend_rung(events, rebalance_band=self.rebalance_band)
        if rung == 0:
            return None
        if (self.cooldown_s > 0
                and now - self._last_action_at < self.cooldown_s
                and rung <= self._last_rung):
            self._deferred = events
            return None
        self._deferred = []
        lam_targets = self._drifted_targets(events)
        self._refresh_pipelines(events)
        action = None
        if rung <= RUNG_REBALANCE:
            action = self.rebalance(lam_targets)
            if not action.feasible:
                action = None
        if action is None and rung <= RUNG_WARM_REPLAN:
            action = self.replan(lam_targets, cold=False)
            if not action.feasible:
                # a forecast is speculative: when the cluster cannot
                # serve the extrapolated target, fall back to the
                # measured demand rather than escalating to a cold
                # re-plan the forecast alone cannot justify
                measured_evs = [ev for ev in events
                                if not isinstance(ev, ForecastDrift)]
                measured = self._drifted_targets(measured_evs)
                if len(measured_evs) < len(events) and measured != lam_targets:
                    retry = self.replan(measured, cold=False)
                    if retry.feasible:
                        action = retry
            if action is not None and not action.feasible:
                action = None
        if action is None:
            action = self.replan(lam_targets, cold=True)
        action.events = list(events)
        # an infeasible plan never deploys, so it must not become the
        # incumbent future reactions are incremental against — the
        # fleet keeps serving (and the monitor keeps measuring) the
        # last adopted plan
        if action.feasible:
            self.adopt(action)
        self._last_action_at = now
        self._last_rung = action.rung
        return action

    def step(self) -> Optional[ReplanAction]:
        """Poll the attached monitor and forecast trigger, and react to
        whatever they saw (or to drift deferred by an earlier cool-down
        suppression).  Forecast events ride the same ladder as reactive
        ones — they just arrive ``lead_s`` before the ramp does."""
        if self.monitor is None and self.forecast is None:
            return None
        events: List[DriftEvent] = []
        if self.monitor is not None:
            events.extend(self.monitor.poll())
        if self.forecast is not None:
            now = self.monitor.now if self.monitor is not None else 0.0
            events.extend(self.forecast.poll(now))
        if not events and not self._deferred:
            return None
        return self.react(events)

    def adopt(self, action: ReplanAction) -> None:
        """Commit an action: it becomes the incumbent the next reaction
        is incremental against, and the monitor is re-based onto the new
        targets so detectors re-arm."""
        self.lam_targets = dict(action.lam_targets)
        if action.result is not None:
            self.result = action.result
            self.warm_state = action.result.warm_state or self.warm_state
        if action.placement is not None:
            self.placement = action.placement
        if (
            action.routing is not None
            and self.result is not None
            and self.result.pooled is not None
        ):
            self.result.pooled.routing = action.routing
        if self.monitor is not None:
            rebased = {}
            for w, lam in self.lam_targets.items():
                old = self.monitor.expectations.get(w)
                if w in self._refreshed_since_adopt and w in self.pipelines:
                    # the re-traced pipeline is the new baseline: keeping
                    # the stale pre-drift shares would re-fire the
                    # detector (and re-trigger an expensive re-trace +
                    # re-plan) on every subsequent request; the token
                    # baseline re-arms on the monitor's live estimates so
                    # future token drift stays detectable
                    exp = expectation_from(self.pipelines[w], lam)
                    rebased[w] = Expectation(
                        lam=lam,
                        shares=exp.shares,
                        out_tokens=self.monitor.observed_tokens(w),
                        slo_target=old.slo_target if old else 0.0,
                        slo_class=old.slo_class if old else "",
                    )
                else:
                    # unchanged pipeline: keep the current (possibly
                    # runtime-calibrated) expectations, only the target
                    # rate moves
                    rebased[w] = Expectation(
                        lam=lam,
                        shares=dict(old.shares) if old else {},
                        out_tokens=dict(old.out_tokens) if old else {},
                        slo_target=old.slo_target if old else 0.0,
                        slo_class=old.slo_class if old else "",
                    )
            self.monitor.rebase(rebased)
        if self.forecast is not None:
            self.forecast.rebase(self.lam_targets)
        self._refreshed_since_adopt.clear()
        self.history.append(action)
        if self.tracer is not None:
            self.tracer.on_replan(action)

    # -- helpers -----------------------------------------------------------

    def _merge_deferred(self, events: List[DriftEvent]) -> List[DriftEvent]:
        """Carry cool-down-suppressed drift into this batch, deduplicated
        by detector identity (newest wins) so the buffer stays bounded."""
        if not self._deferred:
            return list(events)
        merged: Dict[tuple, DriftEvent] = {}
        for ev in self._deferred + list(events):
            merged[(type(ev), ev.workflow, getattr(ev, "llm", ""))] = ev
        return list(merged.values())

    def _drifted_targets(self, events: List[DriftEvent]) -> Dict[str, float]:
        """Planning targets under drift: observed rates for workflows
        that drifted, deployed targets elsewhere."""
        out = dict(self.lam_targets)
        if self.monitor is not None:
            observed = self.monitor.observed_lams()
        else:
            observed = {}
        for ev in events:
            if isinstance(ev, ForecastDrift):
                continue  # applied last: the forecast target must win
            if isinstance(ev, RateDrift):
                out[ev.workflow] = observed.get(ev.workflow, ev.observed)
            elif isinstance(ev, SLOViolation) and ev.workflow in observed:
                # a violated tier under an unchanged plan means the
                # observed load is what the fleet must actually absorb
                out[ev.workflow] = observed[ev.workflow]
        for ev in events:
            # proactive: plan for the FORECAST rate, not the current
            # estimate — the live stream has not ramped yet, which is
            # the entire point of firing early; a reactive event for the
            # same workflow in this batch must not talk the target back
            # down to the pre-ramp rate
            if isinstance(ev, ForecastDrift):
                out[ev.workflow] = max(ev.observed,
                                       observed.get(ev.workflow, 0.0),
                                       out.get(ev.workflow, 0.0))
        return out

    def _refresh_pipelines(self, events: List[DriftEvent]) -> None:
        """Share/token drift means the traced pipeline is stale; pull a
        fresh one when the deployment gave us a refresher."""
        if self.pipeline_refresh is None:
            return
        stale = {
            ev.workflow
            for ev in events
            if isinstance(ev, (ShareDrift, TokenDrift))
        }
        for w in stale:
            self.pipelines[w] = self.pipeline_refresh(w)
            self._refreshed_since_adopt.add(w)


# ``deploy_multi(..., online=True)`` hands callers this alias.
OnlineController = ReplanController
