"""Scepsy GPU scheduler (paper §5).

Searches over (fractional chip share, TP degree, replica count) per LLM
for the allocation that minimizes workflow latency subject to sustaining a
target arrival rate, using the Aggregate LLM Pipeline as the predictor.

Pruning (paper's three strategies + one exploited symmetry):
  (i)   latency-ratio ordering: LLMs are enumerated in descending latency
        share and unit assignments are non-increasing along that order
        (an LLM may still drop to its memory lower bound);
  (ii)  contiguous allocation: fractions pack contiguously onto chips, so
        only unit *counts* matter (allocation symmetry);
  (iii) TP degree capped by the high-bandwidth ICI domain size;
  (iv)  separability: eq. (1) is a sum and eq. (2) a min over per-LLM
        terms, so for a fixed unit split the best (TP, replicas) choice
        decomposes per LLM — no cross-product over parallelism configs.
"""
from __future__ import annotations

import itertools
import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro import hw
from repro.configs.base import ArchConfig
from repro.core.pipeline import AggregateLLMPipeline, Allocation, Prediction
from repro.serving import costmodel as cm


@dataclass
class SchedulerConfig:
    percentile: str = "mean"
    max_tp: Optional[int] = None  # default: hb domain size
    units_grid: int = 10  # candidate unit values per LLM per level
    max_assignments: int = 200_000  # hard cap on enumerated unit splits
    allow_fractional: bool = True  # ablation: co-location via GPU fractions
    allow_parallelism: bool = True  # ablation: TP > 1
    memoize: bool = True  # cache best_option_for(m, units) across splits


@dataclass
class ScheduleResult:
    allocations: Dict[str, Allocation]
    prediction: Prediction
    units: Dict[str, int]
    evaluated: int
    search_time_s: float
    feasible: bool


@dataclass
class _Option:
    alloc: Allocation
    units: int


def _parallelism_options(cfg: ArchConfig, units: int, spec: hw.ClusterSpec,
                         lo_units: int, max_tp: int,
                         allow_fractional: bool = True) -> List[_Option]:
    """Feasible (tp, replicas, fraction) configs for a unit budget."""
    F = spec.fractions_per_chip
    opts: List[_Option] = []
    # sub-chip / fractional replicas: tp=1, d replicas of u_r < F units
    # each (a replica never spans chips unless tensor-parallel).
    seen = set()
    if allow_fractional:
        for d in range(1, units + 1):
            u_r = min(units // d, F - 1)
            if u_r < lo_units:
                break
            if (d, u_r) in seen:
                continue
            seen.add((d, u_r))
            opts.append(_Option(Allocation(replicas=d, tp=1, fraction=u_r / F),
                                units=d * u_r))
    # whole-chip replicas (TP needs whole chips inside one hb domain)
    if units >= F:
        chips = units // F
        for tp in (t for t in (1, 2, 4, 8, 16) if t <= min(max_tp, chips)):
            if chips % tp:
                continue
            d = chips // tp
            if tp * F < lo_units:  # replica can't even hold the model
                continue
            opts.append(_Option(Allocation(replicas=d, tp=tp, fraction=1.0),
                                units=chips * F))
    return opts


def _candidate_units(lo: int, hi: int, grid: int, chip_units: int) -> List[int]:
    if hi <= lo:
        return [lo]
    step = max(1, (hi - lo) // grid)
    vals = set(range(hi, lo - 1, -step))
    vals.add(lo)
    # whole-chip-aligned values matter: TP/replica options exist only at
    # multiples of F, which a coarse grid can step over entirely
    c = (lo + chip_units - 1) // chip_units * chip_units
    while c <= hi:
        vals.add(c)
        c += chip_units
    return sorted(vals, reverse=True)


def schedule(pipeline: AggregateLLMPipeline, spec: hw.ClusterSpec,
             lam_target: float,
             config: SchedulerConfig = SchedulerConfig()) -> ScheduleResult:
    t0 = time.perf_counter()
    max_tp = config.max_tp or spec.hb_domain_size
    if not config.allow_parallelism:
        max_tp = 1
    F = spec.fractions_per_chip
    U = spec.total_units

    ratios = pipeline.latency_ratios(config.percentile)
    order = sorted(ratios, key=lambda m: -ratios[m])
    lo = {m: cm.min_fraction_units(pipeline.stages[m].cfg, spec)
          for m in order}
    if sum(lo.values()) > U:
        raise ValueError(
            f"cluster too small: need {sum(lo.values())} units, have {U}")

    # pre-compute per-LLM option tables for every candidate unit count
    tails = {m: sum(lo[x] for x in order[order.index(m) + 1:]) for m in order}

    evaluated = 0
    best: Optional[Tuple[float, Dict[str, Allocation], Prediction,
                         Dict[str, int]]] = None
    best_infeasible: Optional[Tuple[float, Dict[str, Allocation], Prediction,
                                    Dict[str, int]]] = None

    # best_option_for depends only on (m, units) — not on the rest of the
    # assignment being scored — so its result is shared across every
    # enumerated unit split (and the slack post-pass).  On large clusters
    # this collapses the search's hot path from O(splits × options) to
    # O(distinct (m, units) × options) option scans.
    option_cache: Dict[Tuple[str, int],
                       Optional[Tuple[Allocation, float, float]]] = {}

    def best_option_for(m: str, units: int) -> Optional[Tuple[Allocation, float, float]]:
        """(alloc, latency_contrib, llm_tput) minimizing latency s.t. tput."""
        if config.memoize:
            key = (m, units)
            if key not in option_cache:
                option_cache[key] = _best_option_uncached(m, units)
            return option_cache[key]
        return _best_option_uncached(m, units)

    def _best_option_uncached(m: str, units: int) -> Optional[Tuple[Allocation, float, float]]:
        st = pipeline.stages[m]
        opts = _parallelism_options(st.cfg, units, spec, lo[m], max_tp,
                                    config.allow_fractional)
        if not opts:
            return None
        lam_m = lam_target * st.n
        best_feas: Optional[Tuple[float, Allocation, float]] = None
        best_tput: Optional[Tuple[float, Allocation, float]] = None
        for o in opts:
            a = o.alloc
            tp = a.tp if a.tp in st.profile.by_tp else st.profile.tps()[0]
            if tp != a.tp:
                continue  # unprofiled TP degree
            tput = a.replicas * st.profile.max_throughput(
                a.tp, fraction=a.fraction)
            lmt = st.profile.latency(lam_m / a.replicas, a.tp,
                                     fraction=a.fraction,
                                     percentile=config.percentile)
            contrib = lmt * st.n / max(st.p, 1.0)
            if tput >= lam_m and math.isfinite(contrib):
                if best_feas is None or contrib < best_feas[0]:
                    best_feas = (contrib, a, tput)
            if best_tput is None or tput > best_tput[0]:
                best_tput = (tput, a, tput)
        if best_feas:
            return best_feas[1], best_feas[0], best_feas[2]
        if best_tput:
            a = best_tput[1]
            return a, math.inf, best_tput[2]
        return None

    def evaluate(units: Dict[str, int]):
        nonlocal evaluated, best, best_infeasible
        evaluated += 1
        allocs: Dict[str, Allocation] = {}
        total_lat = 0.0
        min_tput = math.inf
        for m in order:
            r = best_option_for(m, units[m])
            if r is None:
                return
            a, contrib, tput = r
            allocs[m] = a
            total_lat += contrib
            min_tput = min(min_tput, tput / pipeline.stages[m].n)
        pred = pipeline.predict(allocs, lam_target, config.percentile)
        key_units = dict(units)
        if pred.feasible:
            if best is None or pred.latency < best[0]:
                best = (pred.latency, allocs, pred, key_units)
        else:
            score = -pred.max_throughput
            if best_infeasible is None or score < best_infeasible[0]:
                best_infeasible = (score, allocs, pred, key_units)

    def recurse(i: int, remaining: int, prev_units: int,
                units: Dict[str, int]):
        if evaluated >= config.max_assignments:
            return
        if i == len(order):
            if remaining >= 0:
                evaluate(units)
            return
        m = order[i]
        # ratio-ordered prune (i), softened by the memory lower bound: an
        # LLM may exceed a higher-ratio LLM's share when its parameters
        # simply need more chips (e.g. 8B verifier vs 1B generator)
        cap = max(prev_units, 2 * lo[m])
        hi = min(remaining - tails[m], cap)
        if hi < lo[m]:
            return
        for u in _candidate_units(lo[m], hi, config.units_grid, F):
            units[m] = u
            recurse(i + 1, remaining - u, u, units)
        del units[m]

    recurse(0, U, U, {})

    def used_units(allocs: Dict[str, Allocation]) -> int:
        total = 0
        for a in allocs.values():
            if a.tp > 1 or a.fraction >= 1.0:
                total += a.replicas * a.tp * F
            else:
                total += a.replicas * int(round(a.fraction * F))
        return total

    def improve_with_slack(allocs: Dict[str, Allocation],
                           units: Dict[str, int]):
        """Greedy post-pass: hand leftover units to whichever LLM's
        re-optimized option lowers predicted latency most."""
        nonlocal evaluated
        allocs = dict(allocs)
        units = dict(units)
        best_pred = pipeline.predict(allocs, lam_target, config.percentile)
        for _ in range(8):
            leftover = U - used_units(allocs)
            if leftover <= 0:
                break
            improved = False
            for m in order:
                r = best_option_for(m, units[m] + leftover)
                if r is None:
                    continue
                cand = dict(allocs)
                cand[m] = r[0]
                pred = pipeline.predict(cand, lam_target, config.percentile)
                evaluated += 1
                if pred.feasible and pred.latency < best_pred.latency - 1e-12:
                    allocs, best_pred = cand, pred
                    units[m] = units[m] + leftover
                    improved = True
                    break
            if not improved:
                break
        return allocs, best_pred, units

    elapsed = time.perf_counter() - t0
    if best is not None:
        lat, allocs, pred, units = best
        allocs, pred, units = improve_with_slack(allocs, units)
        elapsed = time.perf_counter() - t0
        return ScheduleResult(allocs, pred, units, evaluated, elapsed, True)
    if best_infeasible is not None:
        _, allocs, pred, units = best_infeasible
        return ScheduleResult(allocs, pred, units, evaluated, elapsed, False)
    raise RuntimeError("scheduler found no viable allocation")


# ---------------------------------------------------------------------------
# Multi-workflow scheduling (egalitarian welfare, paper §5 end)
# ---------------------------------------------------------------------------


@dataclass
class MultiScheduleResult:
    per_workflow: Dict[str, ScheduleResult]
    chip_split: Dict[str, int]
    welfare: float
    search_time_s: float
    utilities: Dict[str, float] = field(default_factory=dict)
    evaluated_splits: int = 0
    schedule_calls: int = 0
    search_mode: str = "enumerate"


def schedule_multi(pipelines: Dict[str, AggregateLLMPipeline],
                   spec: hw.ClusterSpec, lam_targets: Dict[str, float],
                   config: SchedulerConfig = SchedulerConfig(),
                   split_step: int = 1, *,
                   search: str = "auto",
                   max_enumerated_splits: int = 4096) -> MultiScheduleResult:
    """Split the cluster between N >= 2 workflows; egalitarian welfare.

    Utility of a workflow = L_ref / L (reference = its latency given the
    whole cluster), so utilities are comparable across workflows; welfare
    is the minimum utility (max-min fairness).

    Small composition spaces are enumerated exhaustively — for two
    workflows this reproduces the paper's evaluated 2-way split exactly.
    Larger fleets/clusters fall back to greedy water-filling on welfare
    (seeded proportionally to per-workflow demand) with local-exchange
    refinement.  Either way, per-(workflow, chips) schedules are computed
    once and shared across every split candidate.
    """
    t0 = time.perf_counter()
    names = list(pipelines)
    if len(names) < 2:
        raise ValueError("schedule_multi needs >= 2 workflows")
    if search not in ("auto", "enumerate", "greedy"):
        raise ValueError(f"unknown search mode {search!r}")
    missing = [n for n in names if n not in lam_targets]
    if missing:
        raise ValueError(f"no arrival-rate target for workflows {missing}")
    G = spec.num_chips

    lo_chips = {
        n: _min_chips_for_units(
            sum(cm.min_fraction_units(pipelines[n].stages[m].cfg, spec)
                for m in pipelines[n].stages), spec)
        for n in names
    }
    if sum(lo_chips.values()) > G:
        raise ValueError(
            f"cluster too small for {len(names)} workflows: need "
            f">= {sum(lo_chips.values())} chips, have {G}")

    # reference schedules (whole cluster each) double as cache seeds
    stats = {"schedule_calls": 0, "evaluated_splits": 0}
    sched_cache: Dict[Tuple[str, int], Optional[ScheduleResult]] = {}

    def sched(n: str, chips: int) -> Optional[ScheduleResult]:
        if chips < lo_chips[n]:
            return None
        # key on the chip count _subcluster actually models: counts that
        # truncate to the same sub-cluster (9, 10, 11 -> 8 on a
        # 4-chip/host spec) share one search
        key = (n, _effective_chips(spec, chips))
        if key not in sched_cache:
            stats["schedule_calls"] += 1
            try:
                sched_cache[key] = schedule(
                    pipelines[n], _subcluster(spec, chips),
                    lam_targets[n], config)
            except (ValueError, RuntimeError):
                sched_cache[key] = None
        return sched_cache[key]

    refs = {}
    for n in names:
        r = sched(n, G)
        refs[n] = (r.prediction.latency
                   if r is not None and r.feasible else math.inf)

    def utility(n: str, r: Optional[ScheduleResult]) -> float:
        if (r is None or not r.feasible
                or not math.isfinite(r.prediction.latency)
                or r.prediction.latency <= 0):
            return 0.0
        if refs[n] <= 0:
            return 0.0
        return min(refs[n] / r.prediction.latency, 1.0)

    def score(split: Dict[str, int]):
        """(welfare, utils, per-workflow results) or None if any schedule
        call failed outright for this split."""
        stats["evaluated_splits"] += 1
        per: Dict[str, ScheduleResult] = {}
        for n in names:
            r = sched(n, split[n])
            if r is None:
                return None
            per[n] = r
        utils = {n: utility(n, per[n]) for n in names}
        return min(utils.values()), utils, per

    best: Optional[Tuple[float, Dict[str, float], Dict[str, ScheduleResult],
                         Dict[str, int]]] = None

    def consider(split: Dict[str, int]) -> None:
        nonlocal best
        s = score(split)
        if s is None:
            return
        welfare, utils, per = s
        if best is None or welfare > best[0]:
            best = (welfare, utils, per, dict(split))

    splits = (None if search == "greedy"
              else _enumerate_splits(names, lo_chips, G, split_step,
                                     max_enumerated_splits))
    if splits is None and search == "enumerate":
        raise ValueError(
            f"enumeration bound {max_enumerated_splits} exceeded; use "
            "search='auto'/'greedy' or raise max_enumerated_splits")
    mode = "enumerate" if splits is not None else "greedy"
    if splits is not None:
        for split in splits:
            consider(split)
    else:
        for split in _greedy_splits(names, lo_chips, G, split_step,
                                    lam_targets, refs, sched, utility):
            consider(split)
    if best is None:
        raise RuntimeError("no feasible multi-workflow split")
    welfare, utils, per_wf, split = best
    return MultiScheduleResult(per_wf, split, welfare,
                               time.perf_counter() - t0,
                               utilities=utils,
                               evaluated_splits=stats["evaluated_splits"],
                               schedule_calls=stats["schedule_calls"],
                               search_mode=mode)


def _effective_chips(spec: hw.ClusterSpec, chips: int) -> int:
    """Chip count :func:`_subcluster` actually provides (partial hosts
    beyond the first are truncated)."""
    cph = spec.chips_per_host
    return chips if chips <= cph else (chips // cph) * cph


def _min_chips_for_units(units_needed: int, spec: hw.ClusterSpec) -> int:
    """Smallest chip count whose :func:`_subcluster` actually provides
    ``units_needed`` fraction units.

    ``_subcluster`` truncates partial hosts beyond the first, so chip
    counts between host multiples provide no more units than the multiple
    below them — a lower bound that ignores this can strand the greedy
    split search on slices that can never become feasible.
    """
    chips = max(math.ceil(units_needed / spec.fractions_per_chip), 1)
    cph = spec.chips_per_host
    if chips <= cph:
        return chips
    return math.ceil(chips / cph) * cph


def _enumerate_splits(names: Sequence[str], lo: Dict[str, int], G: int,
                      step: int, cap: int) -> Optional[List[Dict[str, int]]]:
    """All N-way chip compositions (step granularity, remainder to the
    last workflow), or None if there are more than ``cap``."""
    out: List[Dict[str, int]] = []
    tails = {n: sum(lo[m] for m in names[i + 1:])
             for i, n in enumerate(names)}

    def rec(i: int, remaining: int, cur: Dict[str, int]) -> bool:
        n = names[i]
        if i == len(names) - 1:
            if remaining >= lo[n]:
                if len(out) >= cap:
                    return False
                out.append({**cur, n: remaining})
            return True
        for c in range(lo[n], remaining - tails[n] + 1, step):
            cur[n] = c
            if not rec(i + 1, remaining - c, cur):
                return False
        cur.pop(n, None)
        return True

    if not rec(0, G, {}):
        return None
    return out


def _greedy_splits(names: Sequence[str], lo: Dict[str, int], G: int,
                   step: int, lam_targets: Dict[str, float],
                   refs: Dict[str, float], sched, utility):
    """Candidate splits from greedy water-filling + local exchange.

    Yields complete splits (the caller keeps the best-scoring one):
      1. a proportional seed — lower bounds plus the leftover split by
         demand weight lam_n * L_ref,n (offered work per workflow);
      2. water-filling — chips granted ``step`` at a time to whichever
         workflow raises egalitarian welfare most (ties: largest own
         utility gain, then heaviest demand);
      3. local exchange — chip moves between workflow pairs kept while
         they strictly improve welfare.
    """
    weight = {}
    for n in names:
        ref = refs[n] if math.isfinite(refs[n]) and refs[n] > 0 else 1.0
        weight[n] = max(lam_targets[n], 1e-9) * ref
    total_w = sum(weight.values()) or 1.0

    split = dict(lo)
    pool = G - sum(split.values())
    # 1) proportional seed, floored to step multiples so water-filling
    # keeps granularity
    for n in names:
        give = int(pool * weight[n] / total_w) // step * step
        split[n] += give
    pool = G - sum(split.values())

    # 2) water-filling on welfare
    while pool > 0:
        g = min(step, pool)
        cur_util = {n: utility(n, sched(n, split[n])) for n in names}
        best_n, best_key = None, None
        for n in names:
            new_u = utility(n, sched(n, split[n] + g))
            new_welfare = min(new_u,
                              min(cur_util[m] for m in names if m != n))
            key = (new_welfare, new_u - cur_util[n], weight[n])
            if best_key is None or key > best_key:
                best_n, best_key = n, key
        split[best_n] += g
        pool -= g
    yield dict(split)

    # 3) local-exchange refinement
    def welfare_of(sp: Dict[str, int]) -> float:
        us = []
        for n in names:
            r = sched(n, sp[n])
            if r is None:
                return -math.inf
            us.append(utility(n, r))
        return min(us)

    cur = welfare_of(split)
    max_rounds = 2 * len(names) * len(names)
    for _ in range(max_rounds):
        improved = False
        for i in names:
            for j in names:
                if i == j or split[i] - step < lo[i]:
                    continue
                cand = dict(split)
                cand[i] -= step
                cand[j] += step
                w = welfare_of(cand)
                if w > cur + 1e-12:
                    split, cur = cand, w
                    yield dict(split)
                    improved = True
        if not improved:
            break


def _subcluster(spec: hw.ClusterSpec, chips: int) -> hw.ClusterSpec:
    """A contiguous sub-cluster of ``chips`` chips (contiguity prune ii)."""
    import dataclasses as dc

    full_hosts = chips // spec.chips_per_host
    if full_hosts >= 1 and chips % spec.chips_per_host == 0:
        return dc.replace(spec, num_hosts=full_hosts)
    # partial host: model as a single host with fewer chips
    return dc.replace(spec, num_hosts=max(chips // spec.chips_per_host, 0) or 1,
                      chips_per_host=min(chips, spec.chips_per_host))
