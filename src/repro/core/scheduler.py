"""Scepsy GPU scheduler (paper §5).

Searches over (fractional chip share, TP degree, replica count) per LLM
for the allocation that minimizes workflow latency subject to sustaining a
target arrival rate, using the Aggregate LLM Pipeline as the predictor.

Pruning (paper's three strategies + one exploited symmetry):
  (i)   latency-ratio ordering: LLMs are enumerated in descending latency
        share and unit assignments are non-increasing along that order
        (an LLM may still drop to its memory lower bound);
  (ii)  contiguous allocation: fractions pack contiguously onto chips, so
        only unit *counts* matter (allocation symmetry);
  (iii) TP degree capped by the high-bandwidth ICI domain size;
  (iv)  separability: eq. (1) is a sum and eq. (2) a min over per-LLM
        terms, so for a fixed unit split the best (TP, replicas) choice
        decomposes per LLM — no cross-product over parallelism configs.
"""
from __future__ import annotations

import itertools
import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro import hw
from repro.configs.base import ArchConfig
from repro.core.pipeline import AggregateLLMPipeline, Allocation, Prediction
from repro.serving import costmodel as cm


@dataclass
class SchedulerConfig:
    percentile: str = "mean"
    max_tp: Optional[int] = None  # default: hb domain size
    units_grid: int = 10  # candidate unit values per LLM per level
    max_assignments: int = 200_000  # hard cap on enumerated unit splits
    allow_fractional: bool = True  # ablation: co-location via GPU fractions
    allow_parallelism: bool = True  # ablation: TP > 1


@dataclass
class ScheduleResult:
    allocations: Dict[str, Allocation]
    prediction: Prediction
    units: Dict[str, int]
    evaluated: int
    search_time_s: float
    feasible: bool


@dataclass
class _Option:
    alloc: Allocation
    units: int


def _parallelism_options(cfg: ArchConfig, units: int, spec: hw.ClusterSpec,
                         lo_units: int, max_tp: int,
                         allow_fractional: bool = True) -> List[_Option]:
    """Feasible (tp, replicas, fraction) configs for a unit budget."""
    F = spec.fractions_per_chip
    opts: List[_Option] = []
    # sub-chip / fractional replicas: tp=1, d replicas of u_r < F units
    # each (a replica never spans chips unless tensor-parallel).
    seen = set()
    if allow_fractional:
        for d in range(1, units + 1):
            u_r = min(units // d, F - 1)
            if u_r < lo_units:
                break
            if (d, u_r) in seen:
                continue
            seen.add((d, u_r))
            opts.append(_Option(Allocation(replicas=d, tp=1, fraction=u_r / F),
                                units=d * u_r))
    # whole-chip replicas (TP needs whole chips inside one hb domain)
    if units >= F:
        chips = units // F
        for tp in (t for t in (1, 2, 4, 8, 16) if t <= min(max_tp, chips)):
            if chips % tp:
                continue
            d = chips // tp
            if tp * F < lo_units:  # replica can't even hold the model
                continue
            opts.append(_Option(Allocation(replicas=d, tp=tp, fraction=1.0),
                                units=chips * F))
    return opts


def _candidate_units(lo: int, hi: int, grid: int, chip_units: int) -> List[int]:
    if hi <= lo:
        return [lo]
    step = max(1, (hi - lo) // grid)
    vals = set(range(hi, lo - 1, -step))
    vals.add(lo)
    # whole-chip-aligned values matter: TP/replica options exist only at
    # multiples of F, which a coarse grid can step over entirely
    c = (lo + chip_units - 1) // chip_units * chip_units
    while c <= hi:
        vals.add(c)
        c += chip_units
    return sorted(vals, reverse=True)


def schedule(pipeline: AggregateLLMPipeline, spec: hw.ClusterSpec,
             lam_target: float,
             config: SchedulerConfig = SchedulerConfig()) -> ScheduleResult:
    t0 = time.perf_counter()
    max_tp = config.max_tp or spec.hb_domain_size
    if not config.allow_parallelism:
        max_tp = 1
    F = spec.fractions_per_chip
    U = spec.total_units

    ratios = pipeline.latency_ratios(config.percentile)
    order = sorted(ratios, key=lambda m: -ratios[m])
    lo = {m: cm.min_fraction_units(pipeline.stages[m].cfg, spec)
          for m in order}
    if sum(lo.values()) > U:
        raise ValueError(
            f"cluster too small: need {sum(lo.values())} units, have {U}")

    # pre-compute per-LLM option tables for every candidate unit count
    tails = {m: sum(lo[x] for x in order[order.index(m) + 1:]) for m in order}

    evaluated = 0
    best: Optional[Tuple[float, Dict[str, Allocation], Prediction,
                         Dict[str, int]]] = None
    best_infeasible: Optional[Tuple[float, Dict[str, Allocation], Prediction,
                                    Dict[str, int]]] = None

    def best_option_for(m: str, units: int) -> Optional[Tuple[Allocation, float, float]]:
        """(alloc, latency_contrib, llm_tput) minimizing latency s.t. tput."""
        st = pipeline.stages[m]
        opts = _parallelism_options(st.cfg, units, spec, lo[m], max_tp,
                                    config.allow_fractional)
        if not opts:
            return None
        lam_m = lam_target * st.n
        best_feas: Optional[Tuple[float, Allocation, float]] = None
        best_tput: Optional[Tuple[float, Allocation, float]] = None
        for o in opts:
            a = o.alloc
            tp = a.tp if a.tp in st.profile.by_tp else st.profile.tps()[0]
            if tp != a.tp:
                continue  # unprofiled TP degree
            tput = a.replicas * st.profile.max_throughput(
                a.tp, fraction=a.fraction)
            lmt = st.profile.latency(lam_m / a.replicas, a.tp,
                                     fraction=a.fraction,
                                     percentile=config.percentile)
            contrib = lmt * st.n / max(st.p, 1.0)
            if tput >= lam_m and math.isfinite(contrib):
                if best_feas is None or contrib < best_feas[0]:
                    best_feas = (contrib, a, tput)
            if best_tput is None or tput > best_tput[0]:
                best_tput = (tput, a, tput)
        if best_feas:
            return best_feas[1], best_feas[0], best_feas[2]
        if best_tput:
            a = best_tput[1]
            return a, math.inf, best_tput[2]
        return None

    def evaluate(units: Dict[str, int]):
        nonlocal evaluated, best, best_infeasible
        evaluated += 1
        allocs: Dict[str, Allocation] = {}
        total_lat = 0.0
        min_tput = math.inf
        for m in order:
            r = best_option_for(m, units[m])
            if r is None:
                return
            a, contrib, tput = r
            allocs[m] = a
            total_lat += contrib
            min_tput = min(min_tput, tput / pipeline.stages[m].n)
        pred = pipeline.predict(allocs, lam_target, config.percentile)
        key_units = dict(units)
        if pred.feasible:
            if best is None or pred.latency < best[0]:
                best = (pred.latency, allocs, pred, key_units)
        else:
            score = -pred.max_throughput
            if best_infeasible is None or score < best_infeasible[0]:
                best_infeasible = (score, allocs, pred, key_units)

    def recurse(i: int, remaining: int, prev_units: int,
                units: Dict[str, int]):
        if evaluated >= config.max_assignments:
            return
        if i == len(order):
            if remaining >= 0:
                evaluate(units)
            return
        m = order[i]
        # ratio-ordered prune (i), softened by the memory lower bound: an
        # LLM may exceed a higher-ratio LLM's share when its parameters
        # simply need more chips (e.g. 8B verifier vs 1B generator)
        cap = max(prev_units, 2 * lo[m])
        hi = min(remaining - tails[m], cap)
        if hi < lo[m]:
            return
        for u in _candidate_units(lo[m], hi, config.units_grid, F):
            units[m] = u
            recurse(i + 1, remaining - u, u, units)
        del units[m]

    recurse(0, U, U, {})

    def used_units(allocs: Dict[str, Allocation]) -> int:
        total = 0
        for a in allocs.values():
            if a.tp > 1 or a.fraction >= 1.0:
                total += a.replicas * a.tp * F
            else:
                total += a.replicas * int(round(a.fraction * F))
        return total

    def improve_with_slack(allocs: Dict[str, Allocation],
                           units: Dict[str, int]):
        """Greedy post-pass: hand leftover units to whichever LLM's
        re-optimized option lowers predicted latency most."""
        nonlocal evaluated
        allocs = dict(allocs)
        units = dict(units)
        best_pred = pipeline.predict(allocs, lam_target, config.percentile)
        for _ in range(8):
            leftover = U - used_units(allocs)
            if leftover <= 0:
                break
            improved = False
            for m in order:
                r = best_option_for(m, units[m] + leftover)
                if r is None:
                    continue
                cand = dict(allocs)
                cand[m] = r[0]
                pred = pipeline.predict(cand, lam_target, config.percentile)
                evaluated += 1
                if pred.feasible and pred.latency < best_pred.latency - 1e-12:
                    allocs, best_pred = cand, pred
                    units[m] = units[m] + leftover
                    improved = True
                    break
            if not improved:
                break
        return allocs, best_pred, units

    elapsed = time.perf_counter() - t0
    if best is not None:
        lat, allocs, pred, units = best
        allocs, pred, units = improve_with_slack(allocs, units)
        elapsed = time.perf_counter() - t0
        return ScheduleResult(allocs, pred, units, evaluated, elapsed, True)
    if best_infeasible is not None:
        _, allocs, pred, units = best_infeasible
        return ScheduleResult(allocs, pred, units, evaluated, elapsed, False)
    raise RuntimeError("scheduler found no viable allocation")


# ---------------------------------------------------------------------------
# Multi-workflow scheduling (egalitarian welfare, paper §5 end)
# ---------------------------------------------------------------------------


@dataclass
class MultiScheduleResult:
    per_workflow: Dict[str, ScheduleResult]
    chip_split: Dict[str, int]
    welfare: float
    search_time_s: float


def schedule_multi(pipelines: Dict[str, AggregateLLMPipeline],
                   spec: hw.ClusterSpec, lam_targets: Dict[str, float],
                   config: SchedulerConfig = SchedulerConfig(),
                   split_step: int = 1) -> MultiScheduleResult:
    """Split the cluster between workflows; egalitarian (max-min) welfare.

    Utility of a workflow = L_ref / L (reference = its latency given the
    whole cluster), so utilities are comparable across workflows.
    """
    t0 = time.perf_counter()
    names = list(pipelines)
    assert len(names) == 2, "enumerated split supports 2 workflows (paper's eval)"
    a, b = names
    refs = {}
    for n in names:
        r = schedule(pipelines[n], spec, lam_targets[n], config)
        refs[n] = r.prediction.latency if r.feasible else math.inf

    lo_chips = {
        n: math.ceil(sum(cm.min_fraction_units(pipelines[n].stages[m].cfg, spec)
                         for m in pipelines[n].stages)
                     / spec.fractions_per_chip)
        for n in names
    }
    G = spec.num_chips
    best = None
    for ca in range(lo_chips[a], G - lo_chips[b] + 1, split_step):
        cb = G - ca
        sub_a = _subcluster(spec, ca)
        sub_b = _subcluster(spec, cb)
        try:
            ra = schedule(pipelines[a], sub_a, lam_targets[a], config)
            rb = schedule(pipelines[b], sub_b, lam_targets[b], config)
        except (ValueError, RuntimeError):
            continue
        utils = {}
        for n, r in ((a, ra), (b, rb)):
            if not r.feasible or not math.isfinite(r.prediction.latency):
                utils[n] = 0.0
            else:
                utils[n] = min(refs[n] / r.prediction.latency, 1.0) if refs[n] > 0 else 0.0
        welfare = min(utils.values())  # egalitarian
        if best is None or welfare > best[0]:
            best = (welfare, {a: ra, b: rb}, {a: ca, b: cb})
    if best is None:
        raise RuntimeError("no feasible multi-workflow split")
    welfare, per_wf, split = best
    return MultiScheduleResult(per_wf, split, welfare,
                               time.perf_counter() - t0)


def _subcluster(spec: hw.ClusterSpec, chips: int) -> hw.ClusterSpec:
    """A contiguous sub-cluster of ``chips`` chips (contiguity prune ii)."""
    import dataclasses as dc

    full_hosts = chips // spec.chips_per_host
    if full_hosts >= 1 and chips % spec.chips_per_host == 0:
        return dc.replace(spec, num_hosts=full_hosts)
    # partial host: model as a single host with fewer chips
    return dc.replace(spec, num_hosts=max(chips // spec.chips_per_host, 0) or 1,
                      chips_per_host=min(chips, spec.chips_per_host))
