"""Scepsy GPU scheduler (paper §5).

Searches over (fractional chip share, TP degree, replica count) per LLM
for the allocation that minimizes workflow latency subject to sustaining a
target arrival rate, using the Aggregate LLM Pipeline as the predictor.

Pruning (paper's three strategies + one exploited symmetry):
  (i)   latency-ratio ordering: LLMs are enumerated in descending latency
        share and unit assignments are non-increasing along that order
        (an LLM may still drop to its memory lower bound);
  (ii)  contiguous allocation: fractions pack contiguously onto chips, so
        only unit *counts* matter (allocation symmetry);
  (iii) TP degree capped by the high-bandwidth ICI domain size;
  (iv)  separability: eq. (1) is a sum and eq. (2) a min over per-LLM
        terms, so for a fixed unit split the best (TP, replicas) choice
        decomposes per LLM — no cross-product over parallelism configs.

Fleet scheduling (:func:`schedule_multi`, post-paper): N workflows share
one cluster under partitioned / pooled / auto allocation modes with
egalitarian, weighted or proportional welfare; the partitioned split
search can close the loop with the placement layer
(``SchedulerConfig.placement_aware``): every candidate split is probed
through :func:`repro.core.placement.fleet_feasibility` — unplaceable
splits are rejected outright and placeable ones pay a
``fragmentation_weight``-scaled penalty, so the winning split is one
that actually deploys on the real host/ICI-domain topology (ROADMAP
"Placement-aware partitioned splits").

Inputs: :class:`AggregateLLMPipeline` predictors + a
:class:`repro.hw.ClusterSpec` + arrival-rate targets; outputs:
:class:`ScheduleResult` / :class:`MultiScheduleResult` allocation plans
consumed by :mod:`repro.core.placement` and :mod:`repro.core.scepsy`.
"""
from __future__ import annotations

import dataclasses
import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro import hw
from repro.configs.base import ArchConfig
from repro.core import placement as _pl
from repro.core.pipeline import (AggregateLLMPipeline,
                                 Allocation,
                                 Prediction,
                                 merge_pipelines)
from repro.serving import costmodel as cm

WELFARE_OBJECTIVES = ("egalitarian", "weighted", "proportional")


@dataclass
class SchedulerConfig:
    percentile: str = "mean"
    max_tp: Optional[int] = None  # default: hb domain size
    units_grid: int = 10  # candidate unit values per LLM per level
    max_assignments: int = 200_000  # hard cap on enumerated unit splits
    allow_fractional: bool = True  # ablation: co-location via GPU fractions
    allow_parallelism: bool = True  # ablation: TP > 1
    memoize: bool = True  # cache best_option_for(m, units) across splits
    # multi-workflow welfare: egalitarian (min utility), weighted
    # (weight-normalized mean utility), proportional (Nash: Σ w·log u)
    welfare: str = "egalitarian"
    welfare_weights: Optional[Dict[str, float]] = None  # default: all 1.0
    # pooled routing-table shape: "uniform" spreads every workflow over
    # all tenant replicas; "partition" hands each workflow a load-
    # proportional block (better KV-affinity, and re-balanceable on
    # drift without re-placement)
    routing_policy: str = "uniform"
    # share each workflow's best_option_for table across the split
    # search's sub-schedules (neighbouring chip counts re-use it)
    warm_start: bool = True
    # close the scheduler<->placement loop: probe every candidate
    # partitioned split through placement.fleet_feasibility — reject
    # splits that cannot be placed on the real topology, and break
    # welfare ties toward less fragmented packings
    placement_aware: bool = False
    # soft penalty: split score = welfare - weight * fragmentation
    # (fragmentation in [0, 1] = stranded fraction of free units); keep
    # small so it only breaks near-ties, never trades real welfare away
    fragmentation_weight: float = 0.05


@dataclass
class ScheduleResult:
    allocations: Dict[str, Allocation]
    prediction: Prediction
    units: Dict[str, int]
    evaluated: int
    search_time_s: float
    feasible: bool


@dataclass
class _Option:
    alloc: Allocation
    units: int


def _parallelism_options(cfg: ArchConfig, units: int, spec: hw.ClusterSpec,
                         lo_units: int, max_tp: int,
                         allow_fractional: bool = True) -> List[_Option]:
    """Feasible (tp, replicas, fraction) configs for a unit budget."""
    F = spec.fractions_per_chip
    opts: List[_Option] = []
    # sub-chip / fractional replicas: tp=1, d replicas of u_r < F units
    # each (a replica never spans chips unless tensor-parallel).
    seen = set()
    if allow_fractional:
        for d in range(1, units + 1):
            u_r = min(units // d, F - 1)
            if u_r < lo_units:
                break
            if (d, u_r) in seen:
                continue
            seen.add((d, u_r))
            opts.append(_Option(Allocation(replicas=d, tp=1, fraction=u_r / F),
                                units=d * u_r))
    # whole-chip replicas (TP needs whole chips inside one hb domain)
    if units >= F:
        chips = units // F
        for tp in (t for t in (1, 2, 4, 8, 16) if t <= min(max_tp, chips)):
            if chips % tp:
                continue
            d = chips // tp
            if tp * F < lo_units:  # replica can't even hold the model
                continue
            opts.append(_Option(Allocation(replicas=d, tp=tp, fraction=1.0),
                                units=chips * F))
    return opts


def _prof_table(prof, cname: Optional[str]) -> Dict:
    """The TP table of ``prof`` on chip class ``cname`` (None = default).

    Works for both :class:`~repro.core.profiler.LLMProfile` (per-class
    tables in ``by_class``) and
    :class:`~repro.core.pipeline.MergedLLMProfile` (one table, valid on
    the intersection of member classes).
    """
    if cname is None:
        return prof.by_tp
    by_class = getattr(prof, "by_class", None)
    if by_class is not None:
        return by_class.get(cname) or {}
    return prof.by_tp if cname in prof.classes() else {}


def _candidate_units(lo: int, hi: int, grid: int, chip_units: int) -> List[int]:
    if hi <= lo:
        return [lo]
    step = max(1, (hi - lo) // grid)
    vals = set(range(hi, lo - 1, -step))
    vals.add(lo)
    # whole-chip-aligned values matter: TP/replica options exist only at
    # multiples of F, which a coarse grid can step over entirely
    c = (lo + chip_units - 1) // chip_units * chip_units
    while c <= hi:
        vals.add(c)
        c += chip_units
    return sorted(vals, reverse=True)


def schedule(pipeline: AggregateLLMPipeline, spec: hw.ClusterSpec,
             lam_target: float,
             config: SchedulerConfig = SchedulerConfig(), *,
             option_cache: Optional[Dict] = None,
             warm_seed: Optional[Dict[str, int]] = None) -> ScheduleResult:
    """Search the allocation space for one pipeline.

    ``warm_seed`` (a unit assignment, e.g. the schedule chosen for a
    neighbouring chip count in the fleet split search) is evaluated
    first; together with the admissible unloaded-latency floor bound it
    turns the enumeration into branch-and-bound with an immediate
    incumbent, without changing the optimal latency found.

    Heterogeneous clusters (more than one chip class in the spec) are
    routed to the class-aware search, which additionally assigns each
    LLM to a chip class and draws units from per-class budgets.  A
    uniform non-default-class cluster runs this search with that class's
    cost constants and profile curves; the default class reproduces the
    legacy behavior exactly.
    """
    if not spec.is_uniform:
        return _schedule_hetero(pipeline, spec, lam_target, config,
                                option_cache=option_cache)
    t0 = time.perf_counter()
    max_tp = config.max_tp or spec.hb_domain_size
    if not config.allow_parallelism:
        max_tp = 1
    F = spec.fractions_per_chip
    U = spec.total_units

    cls_names = spec.classes()
    chip = hw.chip_class(cls_names[0]) if cls_names else hw.DEFAULT_CHIP_CLASS
    # None on the default class => allocations and profile lookups are
    # byte-identical to the pre-ChipClass scheduler
    cname = None if chip.name == hw.DEFAULT_CHIP_CLASS.name else chip.name

    ratios = pipeline.latency_ratios(config.percentile)
    order = sorted(ratios, key=lambda m: -ratios[m])
    lo = {m: cm.min_fraction_units(pipeline.stages[m].cfg, spec, chip=chip)
          for m in order}
    if sum(lo.values()) > U:
        raise ValueError(
            f"cluster too small: need {sum(lo.values())} units, have {U}")

    # pre-compute per-LLM option tables for every candidate unit count
    tails = {m: sum(lo[x] for x in order[order.index(m) + 1:]) for m in order}

    evaluated = 0
    best: Optional[Tuple[float, Dict[str, Allocation], Prediction,
                         Dict[str, int]]] = None
    best_infeasible: Optional[Tuple[float, Dict[str, Allocation], Prediction,
                                    Dict[str, int]]] = None

    # best_option_for depends only on (m, units) — not on the rest of the
    # assignment being scored, nor on the cluster's chip count — so its
    # result is shared across every enumerated unit split (and the slack
    # post-pass).  On large clusters this collapses the search's hot path
    # from O(splits × options) to O(distinct (m, units) × options) option
    # scans.  Callers scheduling the same pipeline on several sub-cluster
    # sizes (the fleet split search) pass ``option_cache`` to warm-start
    # each search from its neighbours' tables.
    if option_cache is None:
        option_cache = {}

    def best_option_for(m: str, units: int) -> Optional[Tuple[Allocation, float, float]]:
        """(alloc, latency_contrib, llm_tput) minimizing latency s.t. tput."""
        if config.memoize:
            key = (m, units)
            if key not in option_cache:
                option_cache[key] = _best_option_uncached(m, units)
            return option_cache[key]
        return _best_option_uncached(m, units)

    def _best_option_uncached(m: str, units: int) -> Optional[Tuple[Allocation, float, float]]:
        st = pipeline.stages[m]
        opts = _parallelism_options(st.cfg, units, spec, lo[m], max_tp,
                                    config.allow_fractional)
        if not opts:
            return None
        table = _prof_table(st.profile, cname)
        lam_m = lam_target * st.n
        best_feas: Optional[Tuple[float, Allocation, float]] = None
        best_tput: Optional[Tuple[float, Allocation, float]] = None
        for o in opts:
            a = o.alloc
            if a.tp not in table:
                continue  # unprofiled TP degree
            if cname is not None:
                a = dataclasses.replace(a, chip_class=cname)
            tput = a.replicas * st.profile.max_throughput(
                a.tp, fraction=a.fraction, chip_class=cname)
            lmt = st.profile.latency(lam_m / a.replicas, a.tp,
                                     fraction=a.fraction,
                                     percentile=config.percentile,
                                     chip_class=cname)
            contrib = lmt * st.n / max(st.p, 1.0)
            if tput >= lam_m and math.isfinite(contrib):
                if best_feas is None or contrib < best_feas[0]:
                    best_feas = (contrib, a, tput)
            if best_tput is None or tput > best_tput[0]:
                best_tput = (tput, a, tput)
        if best_feas:
            return best_feas[1], best_feas[0], best_feas[2]
        if best_tput:
            a = best_tput[1]
            return a, math.inf, best_tput[2]
        return None

    def evaluate(units: Dict[str, int]):
        nonlocal evaluated, best, best_infeasible
        evaluated += 1
        allocs: Dict[str, Allocation] = {}
        total_lat = 0.0
        min_tput = math.inf
        for m in order:
            r = best_option_for(m, units[m])
            if r is None:
                return
            a, contrib, tput = r
            allocs[m] = a
            total_lat += contrib
            min_tput = min(min_tput, tput / pipeline.stages[m].n)
        pred = pipeline.predict(allocs, lam_target, config.percentile)
        key_units = dict(units)
        if pred.feasible:
            if best is None or pred.latency < best[0]:
                best = (pred.latency, allocs, pred, key_units)
        else:
            score = -pred.max_throughput
            if best_infeasible is None or score < best_infeasible[0]:
                best_infeasible = (score, allocs, pred, key_units)

    # admissible latency floor per stage (unloaded latency at the best
    # profiled TP, whole chips): no allocation can serve below it, so
    # partial-assignment sums bound every completion of a branch.  The
    # 0.9 margin guards against mild non-monotonicity in simulated
    # profiles; it only weakens (never invalidates) the bound.
    floor = {}
    for m in order:
        st = pipeline.stages[m]
        f = min(st.profile.latency(0.0, tp, percentile=config.percentile,
                                   chip_class=cname)
                for tp in _prof_table(st.profile, cname))
        floor[m] = 0.9 * f * st.n / max(st.p, 1.0)
    tail_floor = {len(order): 0.0}
    for i in range(len(order) - 1, -1, -1):
        tail_floor[i] = tail_floor[i + 1] + floor[order[i]]

    def recurse(i: int, remaining: int, prev_units: int,
                units: Dict[str, int], partial: float):
        if evaluated >= config.max_assignments:
            return
        if i == len(order):
            if remaining >= 0:
                evaluate(units)
            return
        m = order[i]
        # ratio-ordered prune (i), softened by the memory lower bound: an
        # LLM may exceed a higher-ratio LLM's share when its parameters
        # simply need more chips (e.g. 8B verifier vs 1B generator)
        cap = max(prev_units, 2 * lo[m])
        hi = min(remaining - tails[m], cap)
        if hi < lo[m]:
            return
        for u in _candidate_units(lo[m], hi, config.units_grid, F):
            r = best_option_for(m, u)
            if r is None:
                continue  # no option fits this budget: branch is dead
            new_partial = partial + r[1]
            # branch-and-bound: prune completions that provably cannot
            # beat the feasible incumbent (seeded by warm_seed)
            if (best is not None
                    and new_partial + tail_floor[i + 1] >= best[0]):
                continue
            units[m] = u
            recurse(i + 1, remaining - u, u, units, new_partial)
        units.pop(m, None)

    if warm_seed is not None:
        seed = {m: warm_seed.get(m, 0) for m in order}
        if (all(seed[m] >= lo[m] for m in order)
                and sum(seed.values()) <= U):
            evaluate(seed)
    recurse(0, U, U, {}, 0.0)

    def used_units(allocs: Dict[str, Allocation]) -> int:
        total = 0
        for a in allocs.values():
            if a.tp > 1 or a.fraction >= 1.0:
                total += a.replicas * a.tp * F
            else:
                total += a.replicas * int(round(a.fraction * F))
        return total

    def improve_with_slack(allocs: Dict[str, Allocation],
                           units: Dict[str, int]):
        """Greedy post-pass: hand leftover units to whichever LLM's
        re-optimized option lowers predicted latency most."""
        nonlocal evaluated
        allocs = dict(allocs)
        units = dict(units)
        best_pred = pipeline.predict(allocs, lam_target, config.percentile)
        for _ in range(8):
            leftover = U - used_units(allocs)
            if leftover <= 0:
                break
            improved = False
            for m in order:
                r = best_option_for(m, units[m] + leftover)
                if r is None:
                    continue
                cand = dict(allocs)
                cand[m] = r[0]
                pred = pipeline.predict(cand, lam_target, config.percentile)
                evaluated += 1
                if pred.feasible and pred.latency < best_pred.latency - 1e-12:
                    allocs, best_pred = cand, pred
                    units[m] = units[m] + leftover
                    improved = True
                    break
            if not improved:
                break
        return allocs, best_pred, units

    elapsed = time.perf_counter() - t0
    if best is not None:
        lat, allocs, pred, units = best
        allocs, pred, units = improve_with_slack(allocs, units)
        elapsed = time.perf_counter() - t0
        return ScheduleResult(allocs, pred, units, evaluated, elapsed, True)
    if best_infeasible is not None:
        _, allocs, pred, units = best_infeasible
        return ScheduleResult(allocs, pred, units, evaluated, elapsed, False)
    raise RuntimeError("scheduler found no viable allocation")


def _schedule_hetero(pipeline: AggregateLLMPipeline, spec: hw.ClusterSpec,
                     lam_target: float,
                     config: SchedulerConfig = SchedulerConfig(), *,
                     option_cache: Optional[Dict] = None) -> ScheduleResult:
    """Class-aware allocation search for heterogeneous clusters.

    Extends the paper's search with one more decision per LLM: which
    chip class its replicas live on.  Units are drawn from per-class
    budgets (``spec.units_of_class``); an LLM is only assignable to
    classes it was profiled on AND whose HBM fits it
    (:func:`profile_llm` already drops unfittable classes), and every
    chosen :class:`Allocation` carries its ``chip_class`` so placement
    binds the instances to compatible host groups.  Latency and
    throughput come from the per-``(chip_class, tp)`` profile curves, so
    a 9B on big-HBM chips and the same 9B on mid-tier chips are scored
    as the different machines they are.
    """
    t0 = time.perf_counter()
    max_tp = config.max_tp or spec.hb_domain_size
    if not config.allow_parallelism:
        max_tp = 1
    F = spec.fractions_per_chip
    budgets = {c: spec.units_of_class(c) for c in spec.classes()}

    ratios = pipeline.latency_ratios(config.percentile)
    order = sorted(ratios, key=lambda m: -ratios[m])

    # feasible classes + per-class memory floors per LLM
    lo: Dict[str, Dict[str, int]] = {}
    for m in order:
        st = pipeline.stages[m]
        prof_classes = set(st.profile.classes())
        lo[m] = {}
        for c in spec.classes():
            if c not in prof_classes or not _prof_table(st.profile, c):
                continue
            u = cm.min_fraction_units(st.cfg, spec, chip=hw.chip_class(c))
            if u <= budgets[c]:
                lo[m][c] = u
        if not lo[m]:
            raise ValueError(
                f"{m}: no chip class on this cluster both fits the model "
                f"and has a profile (cluster classes: {spec.classes()})")
    lo_min = {m: min(lo[m].values()) for m in order}
    if sum(lo_min.values()) > spec.total_units:
        raise ValueError(
            f"cluster too small: need {sum(lo_min.values())} units, "
            f"have {spec.total_units}")

    if option_cache is None:
        option_cache = {}
    evaluated = 0
    best: Optional[Tuple[float, Dict[str, Allocation], Prediction,
                         Dict[str, int]]] = None
    best_infeasible: Optional[Tuple[float, Dict[str, Allocation], Prediction,
                                    Dict[str, int]]] = None

    def best_option_for(m: str, units: int, c: str
                        ) -> Optional[Tuple[Allocation, float, float]]:
        if not config.memoize:
            return _best_option_uncached(m, units, c)
        key = (m, units, c)
        if key not in option_cache:
            option_cache[key] = _best_option_uncached(m, units, c)
        return option_cache[key]

    def _best_option_uncached(m: str, units: int, c: str
                              ) -> Optional[Tuple[Allocation, float, float]]:
        if c not in lo[m]:
            return None
        st = pipeline.stages[m]
        table = _prof_table(st.profile, c)
        opts = _parallelism_options(st.cfg, units, spec, lo[m][c], max_tp,
                                    config.allow_fractional)
        lam_m = lam_target * st.n
        best_feas: Optional[Tuple[float, Allocation, float]] = None
        best_tput: Optional[Tuple[float, Allocation, float]] = None
        for o in opts:
            a = o.alloc
            if a.tp not in table:
                continue
            a = dataclasses.replace(a, chip_class=c)
            tput = a.replicas * st.profile.max_throughput(
                a.tp, fraction=a.fraction, chip_class=c)
            lmt = st.profile.latency(lam_m / a.replicas, a.tp,
                                     fraction=a.fraction,
                                     percentile=config.percentile,
                                     chip_class=c)
            contrib = lmt * st.n / max(st.p, 1.0)
            if tput >= lam_m and math.isfinite(contrib):
                if best_feas is None or contrib < best_feas[0]:
                    best_feas = (contrib, a, tput)
            if best_tput is None or tput > best_tput[0]:
                best_tput = (tput, a, tput)
        if best_feas:
            return best_feas[1], best_feas[0], best_feas[2]
        if best_tput:
            return best_tput[1], math.inf, best_tput[2]
        return None

    def evaluate(units: Dict[str, int], picks: Dict[str, str]) -> None:
        nonlocal evaluated, best, best_infeasible
        evaluated += 1
        allocs: Dict[str, Allocation] = {}
        for m in order:
            r = best_option_for(m, units[m], picks[m])
            if r is None:
                return
            allocs[m] = r[0]
        pred = pipeline.predict(allocs, lam_target, config.percentile)
        key_units = dict(units)
        if pred.feasible:
            if best is None or pred.latency < best[0]:
                best = (pred.latency, allocs, pred, key_units)
        else:
            score = -pred.max_throughput
            if best_infeasible is None or score < best_infeasible[0]:
                best_infeasible = (score, allocs, pred, key_units)

    # admissible unloaded-latency floor: min over (class, tp) points
    floor = {}
    for m in order:
        st = pipeline.stages[m]
        vals = [st.profile.latency(0.0, tp, percentile=config.percentile,
                                   chip_class=c)
                for c in lo[m] for tp in _prof_table(st.profile, c)]
        floor[m] = 0.9 * min(vals) * st.n / max(st.p, 1.0)
    tail_floor = {len(order): 0.0}
    for i in range(len(order) - 1, -1, -1):
        tail_floor[i] = tail_floor[i + 1] + floor[order[i]]

    def recurse(i: int, remaining: Dict[str, int], units: Dict[str, int],
                picks: Dict[str, str], partial: float) -> None:
        if evaluated >= config.max_assignments:
            return
        if i == len(order):
            evaluate(units, picks)
            return
        m = order[i]
        # roomiest feasible class first: a good incumbent early makes
        # the floor bound prune the rest
        for c in sorted(lo[m], key=lambda c: -remaining[c]):
            if remaining[c] < lo[m][c]:
                continue
            for u in _candidate_units(lo[m][c], remaining[c],
                                      config.units_grid, F):
                r = best_option_for(m, u, c)
                if r is None:
                    continue
                new_partial = partial + r[1]
                if (best is not None
                        and new_partial + tail_floor[i + 1] >= best[0]):
                    continue
                units[m], picks[m] = u, c
                remaining[c] -= u
                recurse(i + 1, remaining, units, picks, new_partial)
                remaining[c] += u
        units.pop(m, None)
        picks.pop(m, None)

    recurse(0, dict(budgets), {}, {}, 0.0)

    def used_units_in(allocs: Dict[str, Allocation], c: str) -> int:
        total = 0
        for a in allocs.values():
            if a.chip_class != c:
                continue
            if a.tp > 1 or a.fraction >= 1.0:
                total += a.replicas * a.tp * F
            else:
                total += a.replicas * int(round(a.fraction * F))
        return total

    def improve_with_slack(allocs: Dict[str, Allocation],
                           units: Dict[str, int]):
        nonlocal evaluated
        allocs, units = dict(allocs), dict(units)
        best_pred = pipeline.predict(allocs, lam_target, config.percentile)
        for _ in range(8):
            improved = False
            for c in budgets:
                leftover = budgets[c] - used_units_in(allocs, c)
                if leftover <= 0:
                    continue
                for m in order:
                    if allocs[m].chip_class != c:
                        continue
                    r = best_option_for(m, units[m] + leftover, c)
                    if r is None:
                        continue
                    cand = dict(allocs)
                    cand[m] = r[0]
                    pred = pipeline.predict(cand, lam_target,
                                            config.percentile)
                    evaluated += 1
                    if (pred.feasible
                            and pred.latency < best_pred.latency - 1e-12):
                        allocs, best_pred = cand, pred
                        units[m] = units[m] + leftover
                        improved = True
                        break
                if improved:
                    break
            if not improved:
                break
        return allocs, best_pred, units

    elapsed = time.perf_counter() - t0
    if best is not None:
        _, allocs, pred, units = best
        allocs, pred, units = improve_with_slack(allocs, units)
        elapsed = time.perf_counter() - t0
        return ScheduleResult(allocs, pred, units, evaluated, elapsed, True)
    if best_infeasible is not None:
        _, allocs, pred, units = best_infeasible
        return ScheduleResult(allocs, pred, units, evaluated, elapsed, False)
    raise RuntimeError("scheduler found no viable class-aware allocation")


# ---------------------------------------------------------------------------
# Multi-workflow scheduling (welfare objectives, paper §5 end)
# ---------------------------------------------------------------------------


@dataclass
class PooledScheduleResult:
    """Shared multi-tenant allocation: LLMs are tenants, workflows hold
    routing weights into the pooled replica set."""

    merged: ScheduleResult  # merged-pipeline schedule over the whole cluster
    allocations: Dict[str, Allocation]  # canonical llm id -> shared alloc
    cfgs: Dict[str, ArchConfig]  # canonical llm id -> architecture
    members: Dict[str, List[Tuple[str, str]]]  # id -> [(workflow, local llm)]
    routing: Dict[str, Dict[str, Dict[int, float]]]  # wf -> llm -> rep -> w
    predictions: Dict[str, Prediction]  # per-workflow attribution
    chip_share: Dict[str, float]  # traffic-weighted chip-equivalents
    lam_total: float


@dataclass
class FleetWarmState:
    """Carry-over state for incremental fleet re-planning.

    Everything the split search builds — the (workflow, chips) schedule
    cache, per-workflow ``best_option_for`` tables, the winning split and
    unit assignments — survives across :func:`schedule_multi` calls when
    threaded through ``warm_state``.  :meth:`sync` keeps it sound: a
    workflow whose pipeline object or arrival-rate target changed has its
    cached schedules and option tables dropped (both bake in the lam),
    while its last unit split is kept purely as a branch-and-bound
    incumbent seed, which can never change the optimum found.  A changed
    cluster spec drops everything.
    """

    sched_cache: Dict[Tuple[str, int], Optional[ScheduleResult]] = \
        field(default_factory=dict)
    option_tables: Dict[str, Dict] = field(default_factory=dict)
    lams: Dict[str, float] = field(default_factory=dict)
    pipelines: Dict[str, AggregateLLMPipeline] = field(default_factory=dict)
    last_split: Dict[str, int] = field(default_factory=dict)
    last_units: Dict[str, Dict[str, int]] = field(default_factory=dict)
    merged_units: Optional[Dict[str, int]] = None
    spec: Optional[hw.ClusterSpec] = None

    def invalidate(self, workflow: str) -> None:
        for key in [k for k in self.sched_cache if k[0] == workflow]:
            del self.sched_cache[key]
        self.option_tables.pop(workflow, None)

    def clear(self) -> None:
        self.sched_cache.clear()
        self.option_tables.clear()
        self.last_split = {}
        self.last_units = {}
        self.merged_units = None

    def sync(self, pipelines: Dict[str, AggregateLLMPipeline],
             lam_targets: Dict[str, float],
             spec: hw.ClusterSpec) -> List[str]:
        """Invalidate state made stale by drift; returns the changed
        workflow names."""
        if self.spec is not None and self.spec != spec:
            self.clear()
            self.pipelines.clear()
            self.lams.clear()
        self.spec = spec
        changed = []
        for n, pipe in pipelines.items():
            if n in self.pipelines and (self.pipelines[n] is not pipe
                                        or self.lams.get(n)
                                        != lam_targets[n]):
                self.invalidate(n)
                changed.append(n)
            self.pipelines[n] = pipe
            self.lams[n] = lam_targets[n]
        for n in [x for x in self.pipelines if x not in pipelines]:
            self.invalidate(n)
            del self.pipelines[n]
            self.lams.pop(n, None)
        return changed


@dataclass
class MultiScheduleResult:
    per_workflow: Dict[str, ScheduleResult]
    chip_split: Dict[str, int]  # empty when alloc_mode == "pooled"
    welfare: float
    search_time_s: float
    utilities: Dict[str, float] = field(default_factory=dict)
    evaluated_splits: int = 0
    schedule_calls: int = 0
    search_mode: str = "enumerate"
    alloc_mode: str = "partitioned"  # "partitioned" | "pooled"
    pooled: Optional[PooledScheduleResult] = None
    welfare_by_mode: Dict[str, float] = field(default_factory=dict)
    warm_state: Optional[FleetWarmState] = None
    # placement feedback (None unless config.placement_aware): did the
    # winning plan pass the placement probe, at what fragmentation, and
    # how many candidate splits the probe rejected as unplaceable
    placement_ok: Optional[bool] = None
    fragmentation: Optional[float] = None
    placement_rejected_splits: int = 0
    # True when a requested pooled plan existed but its shared replica
    # set failed the placement probe, so the result degraded to a
    # partitioned plan (distinct from "no shared LLMs")
    pooled_unplaceable: bool = False


def _welfare_fn(config: SchedulerConfig, names: Sequence[str]):
    """Welfare objective over per-workflow utilities in [0, 1]."""
    if config.welfare not in WELFARE_OBJECTIVES:
        raise ValueError(f"unknown welfare objective {config.welfare!r}; "
                         f"known: {WELFARE_OBJECTIVES}")
    wts = {n: (config.welfare_weights or {}).get(n, 1.0) for n in names}
    total_w = sum(wts.values()) or 1.0
    if config.welfare == "egalitarian":
        return lambda utils: min(utils.values())
    if config.welfare == "weighted":
        return lambda utils: sum(wts[n] * u for n, u in utils.items()) / total_w
    return lambda utils: sum(wts[n] * math.log(max(u, 1e-9))
                             for n, u in utils.items())


def schedule_multi(pipelines: Dict[str, AggregateLLMPipeline],
                   spec: hw.ClusterSpec, lam_targets: Dict[str, float],
                   config: SchedulerConfig = SchedulerConfig(),
                   split_step: int = 1, *,
                   search: str = "auto",
                   max_enumerated_splits: int = 4096,
                   mode: str = "partitioned",
                   warm_state: Optional[FleetWarmState] = None
                   ) -> MultiScheduleResult:
    """Allocate the cluster between N >= 2 workflows.

    Utility of a workflow = L_ref / L (reference = its latency given the
    whole cluster), so utilities are comparable across workflows; welfare
    combines them per ``config.welfare`` (egalitarian min by default).

    ``mode`` selects the allocation data model:
      * ``"partitioned"`` — every workflow owns a disjoint chip slice
        (the paper's evaluated split search): small composition spaces
        are enumerated exhaustively, larger fleets fall back to greedy
        water-filling with local-exchange refinement, and
        per-(workflow, chips) schedules are cached across candidates
        with option tables warm-started across neighbouring chip counts;
      * ``"pooled"`` — LLMs are tenants: the workflows' pipelines are
        merged (rate-weighted shares keyed by canonical model identity),
        the merged pipeline is scheduled over the *whole* cluster, and
        per-workflow latency/throughput is attributed back out of the
        shared allocation.  Degrades to the exact partitioned result
        when workflows share no LLM configs;
      * ``"auto"`` — both, keeping whichever yields higher welfare
        (ties prefer partitioned).

    ``warm_state`` (a :class:`FleetWarmState`, e.g. the one returned on a
    previous result's ``warm_state`` field) makes the call an
    *incremental re-plan*: schedules and option tables of workflows whose
    pipeline and target are unchanged are reused verbatim, drifted
    workflows are re-searched from their previous unit split as a
    branch-and-bound incumbent, and the pooled merged search is seeded
    from the previous merged units.  The state is invalidated
    conservatively (see :meth:`FleetWarmState.sync`), so warm results are
    identical to a cold search over the same inputs.

    With ``config.placement_aware`` the partitioned split search closes
    the loop with :mod:`repro.core.placement`: every candidate split's
    per-workflow allocations are probed through
    :func:`~repro.core.placement.fleet_feasibility` (the exact packing a
    deploy would run, without materializing a manifest) — unplaceable
    splits are filtered out, and placeable ones are scored
    ``welfare - fragmentation_weight * fragmentation``.  The pooled
    search probes its shared replica set over the whole cluster the same
    way.  The winner's ``placement_ok`` / ``fragmentation`` /
    ``placement_rejected_splits`` fields report what the probe saw; if
    NO split is placeable the placement-blind winner is returned with
    ``placement_ok=False``.  ``mode="pooled"`` with an unplaceable
    shared replica set degrades to the partitioned result flagged
    ``pooled_unplaceable=True``; ``mode="auto"`` prefers a placeable
    pooled plan over an unplaceable partitioned fallback.
    """
    t0 = time.perf_counter()
    names = list(pipelines)
    if len(names) < 2:
        raise ValueError("schedule_multi needs >= 2 workflows")
    if search not in ("auto", "enumerate", "greedy"):
        raise ValueError(f"unknown search mode {search!r}")
    if mode not in ("partitioned", "pooled", "auto"):
        raise ValueError(f"unknown allocation mode {mode!r}")
    missing = [n for n in names if n not in lam_targets]
    if missing:
        raise ValueError(f"no arrival-rate target for workflows {missing}")
    G = spec.num_chips
    welfare_of = _welfare_fn(config, names)

    chips_avail = [hw.chip_class(c) for c in spec.classes()] or \
        [hw.DEFAULT_CHIP_CLASS]
    lo_chips = {
        n: _min_chips_for_units(
            sum(min(cm.min_fraction_units(pipelines[n].stages[m].cfg, spec,
                                          chip=ch) for ch in chips_avail)
                for m in pipelines[n].stages), spec)
        for n in names
    }
    if sum(lo_chips.values()) > G:
        raise ValueError(
            f"cluster too small for {len(names)} workflows: need "
            f">= {sum(lo_chips.values())} chips, have {G}")

    # reference schedules (whole cluster each) double as cache seeds
    stats = {"schedule_calls": 0, "evaluated_splits": 0}
    # incremental re-plan: the schedule cache and per-workflow option
    # tables live in the (possibly caller-provided) FleetWarmState, so
    # they carry across re-plans; sync() drops whatever drift made stale
    ws = warm_state if warm_state is not None else FleetWarmState()
    ws.sync(pipelines, lam_targets, spec)
    sched_cache = ws.sched_cache
    # per-workflow best_option_for tables shared across every sub-cluster
    # size the split search visits (ROADMAP "warm-start each sub-schedule
    # from the neighbouring chip count's result"): the table depends only
    # on (stage, units), never on the cluster's chip count
    warm: Dict[str, Dict] = {n: ws.option_tables.setdefault(n, {})
                             for n in names}

    def sched(n: str, chips: int,
              offset: int = 0) -> Optional[ScheduleResult]:
        if chips < lo_chips[n]:
            return None
        # a k-chip slice of a uniform cluster is the same spec at any
        # offset, so the cache key (and slice) only carries the offset
        # on heterogeneous specs — uniform search behavior is unchanged
        key = (n, chips, offset) if spec.host_groups else (n, chips)
        if key not in sched_cache:
            stats["schedule_calls"] += 1
            cache = warm[n] if (config.warm_start and config.memoize) \
                else None
            seed = None
            if config.warm_start:
                # seed from the nearest chip count already scheduled:
                # its unit split is an immediate feasible incumbent for
                # the branch-and-bound at this size
                near = [(abs(k[1] - chips), k)
                        for k, r in sched_cache.items()
                        if k[0] == n
                        and (len(k) == 2 or k[2] == offset)
                        and r is not None and r.feasible]
                if near:
                    seed = sched_cache[min(near)[1]].units
                elif n in ws.last_units:
                    # drifted workflow on a warm re-plan: its cached
                    # schedules were invalidated, but the previous
                    # plan's unit split is still a valid incumbent
                    seed = ws.last_units[n]
            try:
                sched_cache[key] = schedule(
                    pipelines[n], _subcluster(spec, chips, offset),
                    lam_targets[n], config, option_cache=cache,
                    warm_seed=seed)
            except (ValueError, RuntimeError):
                sched_cache[key] = None
        return sched_cache[key]

    refs = {}
    for n in names:
        r = sched(n, G)
        refs[n] = (r.prediction.latency
                   if r is not None and r.feasible else math.inf)

    def utility_of(n: str, pred: Optional[Prediction]) -> float:
        if (pred is None or not pred.feasible
                or not math.isfinite(pred.latency) or pred.latency <= 0):
            return 0.0
        if refs[n] <= 0:
            return 0.0
        return min(refs[n] / pred.latency, 1.0)

    def utility(n: str, r: Optional[ScheduleResult]) -> float:
        return utility_of(n, r.prediction if r is not None else None)

    def partitioned_search() -> MultiScheduleResult:
        def score(split: Dict[str, int]):
            """(welfare, utils, per-workflow results) or None if any
            schedule call failed outright for this split."""
            stats["evaluated_splits"] += 1
            per: Dict[str, ScheduleResult] = {}
            # heterogeneous clusters: slices at cumulative offsets (in
            # canonical name order) are disjoint, so class-bound plans
            # of different workflows can never claim the same chips
            off = 0
            for n in names:
                r = sched(n, split[n], off if spec.host_groups else 0)
                if r is None:
                    return None
                per[n] = r
                off += split[n]
            utils = {n: utility(n, per[n]) for n in names}
            return welfare_of(utils), utils, per

        # best entries: (score_key, welfare, utils, per, split, frag);
        # score_key folds in the fragmentation penalty when the search
        # is placement-aware.  best_blind ignores the placement probe —
        # it is the fallback when NO candidate split is placeable, so a
        # pathological cluster still yields a plan (placement_ok=False)
        best: Optional[Tuple] = None
        best_blind: Optional[Tuple] = None
        rejected = {"n": 0}

        def consider(split: Dict[str, int]) -> None:
            nonlocal best, best_blind
            s = score(split)
            if s is None:
                return
            welfare, utils, per = s
            if config.placement_aware and (best_blind is None
                                           or welfare > best_blind[1]):
                best_blind = (welfare, welfare, utils, per, dict(split), None)
            frag = None
            key = welfare
            if config.placement_aware:
                # fragmentation >= 0 means key <= welfare: a split whose
                # raw welfare cannot beat the incumbent key can never
                # win, so skip its (full greedy packing) probe
                if best is not None and welfare <= best[0]:
                    return
                probe = _pl.fleet_feasibility(
                    {n: per[n].allocations for n in names}, spec)
                if not probe.ok:
                    rejected["n"] += 1
                    return
                frag = probe.fragmentation
                key = welfare - config.fragmentation_weight * frag
            if best is None or key > best[0]:
                best = (key, welfare, utils, per, dict(split), frag)

        # the previous plan's split is the incumbent: evaluated first so
        # greedy refinement and cache-driven re-plans start from it
        prev = ws.last_split
        if (prev and set(prev) == set(names) and sum(prev.values()) <= G
                and all(prev[n] >= lo_chips[n] for n in names)):
            consider(dict(prev))
        splits = (None if search == "greedy"
                  else _enumerate_splits(names, lo_chips, G, split_step,
                                         max_enumerated_splits))
        if splits is None and search == "enumerate":
            raise ValueError(
                f"enumeration bound {max_enumerated_splits} exceeded; use "
                "search='auto'/'greedy' or raise max_enumerated_splits")
        smode = "enumerate" if splits is not None else "greedy"
        if splits is not None:
            for split in splits:
                consider(split)
        else:
            for split in _greedy_splits(names, lo_chips, G, split_step,
                                        lam_targets, refs, sched, utility,
                                        welfare_of):
                consider(split)
        placement_ok: Optional[bool] = None
        if best is None and best_blind is not None and config.placement_aware:
            # every scoreable split failed the probe: fall back to the
            # placement-blind winner so the caller still gets a plan,
            # flagged unplaceable
            best = best_blind
            placement_ok = False
        elif best is not None and config.placement_aware:
            placement_ok = True
        if best is None:
            raise RuntimeError("no feasible multi-workflow split")
        _, welfare, utils, per_wf, split, frag = best
        ws.last_split = dict(split)
        ws.last_units = {n: dict(per_wf[n].units) for n in names}
        return MultiScheduleResult(per_wf, split, welfare,
                                   time.perf_counter() - t0,
                                   utilities=utils,
                                   evaluated_splits=stats["evaluated_splits"],
                                   schedule_calls=stats["schedule_calls"],
                                   search_mode=smode,
                                   alloc_mode="partitioned",
                                   warm_state=ws,
                                   placement_ok=placement_ok,
                                   fragmentation=frag,
                                   placement_rejected_splits=rejected["n"])

    # set by pooled_search when a pooled plan existed but its shared
    # replica set failed the placement probe (vs. "no shared LLMs")
    pooled_degraded = {"unplaceable": False}

    def pooled_search() -> Optional[MultiScheduleResult]:
        merged = merge_pipelines(pipelines, lam_targets)
        if not merged.shared_llms():
            return None  # degenerate: pooling cannot differ from a split
        try:
            # the previous merged unit split seeds branch-and-bound: a
            # pooled re-plan is ONE warm-started schedule() call
            res = schedule(merged, spec, merged.lam_total, config,
                           warm_seed=ws.merged_units)
        except (ValueError, RuntimeError):
            return None
        stats["schedule_calls"] += 1
        ws.merged_units = dict(res.units)
        pooled_ok: Optional[bool] = None
        pooled_frag: Optional[float] = None
        if config.placement_aware:
            # the shared replica set is placed once over the whole
            # cluster; probe it the same way the split search probes
            # per-split slices
            probe = _pl.feasibility(res.allocations, spec)
            if not probe.ok:
                # unplaceable pool: partitioned path decides, but the
                # degradation is flagged on the returned result
                pooled_degraded["unplaceable"] = True
                return None
            pooled_ok, pooled_frag = True, probe.fragmentation
        preds = merged.attribute(res.allocations, config.percentile)
        utils = {n: utility_of(n, preds[n]) for n in names}
        welfare = welfare_of(utils)
        routing = merged.routing_weights(res.allocations,
                                         policy=config.routing_policy)
        # traffic-weighted chip attribution (diagnostic: the pool has no
        # per-workflow chip ownership); Allocation.chip_units is already
        # in chips (replicas x tp x fraction)
        chip_share: Dict[str, float] = {n: 0.0 for n in names}
        for cid, mem in merged.tenants.items():
            total = sum(t.call_rate for t in mem) or 1.0
            for t in mem:
                chip_share[t.workflow] += (t.call_rate / total
                                           * res.allocations[cid].chip_units)
        per_wf: Dict[str, ScheduleResult] = {}
        for n in names:
            members = merged.members_of(n)
            per_wf[n] = ScheduleResult(
                allocations={t.llm: res.allocations[cid]
                             for cid, ts in members.items() for t in ts},
                prediction=preds[n],
                units={t.llm: res.units[cid]
                       for cid, ts in members.items() for t in ts},
                evaluated=res.evaluated,
                search_time_s=res.search_time_s,
                feasible=preds[n].feasible)
        pooled = PooledScheduleResult(
            merged=res,
            allocations=dict(res.allocations),
            cfgs={cid: merged.stages[cid].cfg for cid in merged.tenants},
            members={cid: [(t.workflow, t.llm) for t in mem]
                     for cid, mem in merged.tenants.items()},
            routing=routing, predictions=preds, chip_share=chip_share,
            lam_total=merged.lam_total)
        return MultiScheduleResult(
            per_wf, {}, welfare, time.perf_counter() - t0,
            utilities=utils,
            evaluated_splits=stats["evaluated_splits"],
            schedule_calls=stats["schedule_calls"],
            search_mode="pooled", alloc_mode="pooled", pooled=pooled,
            warm_state=ws, placement_ok=pooled_ok,
            fragmentation=pooled_frag)

    if mode == "partitioned":
        return partitioned_search()
    if mode == "pooled":
        pooled = pooled_search()
        if pooled is None:
            # no shared LLMs (exact partitioned parity) OR an
            # unplaceable shared replica set — the latter is flagged so
            # explicit pooled-mode callers can tell the difference
            part = partitioned_search()
            part.pooled_unplaceable = pooled_degraded["unplaceable"]
            return part
        return pooled
    # auto: evaluate both, keep the better welfare (ties -> partitioned).
    # A placement-aware partitioned result flagged placement_ok=False is
    # the blind fallback — it cannot deploy, so a placeable pooled plan
    # beats it regardless of welfare.
    part = partitioned_search()
    pooled = pooled_search()
    by_mode = {"partitioned": part.welfare}
    if pooled is not None:
        by_mode["pooled"] = pooled.welfare
    part_unplaceable = config.placement_aware and part.placement_ok is False
    winner = (pooled if pooled is not None
              and (pooled.welfare > part.welfare or part_unplaceable)
              else part)
    winner.pooled_unplaceable = (winner is part
                                 and pooled_degraded["unplaceable"])
    winner.welfare_by_mode = by_mode
    winner.search_time_s = time.perf_counter() - t0
    return winner


def _min_chips_for_units(units_needed: int, spec: hw.ClusterSpec) -> int:
    """Smallest chip count providing ``units_needed`` fraction units.

    :func:`_subcluster` models partial-host remainders explicitly (as
    ``tail_chips``), so no rounding to host multiples is needed — every
    chip a workflow is granted is usable.
    """
    return max(math.ceil(units_needed / spec.fractions_per_chip), 1)


def _enumerate_splits(names: Sequence[str], lo: Dict[str, int], G: int,
                      step: int, cap: int) -> Optional[List[Dict[str, int]]]:
    """All N-way chip compositions (step granularity, remainder to the
    last workflow), or None if there are more than ``cap``."""
    out: List[Dict[str, int]] = []
    tails = {n: sum(lo[m] for m in names[i + 1:])
             for i, n in enumerate(names)}

    def rec(i: int, remaining: int, cur: Dict[str, int]) -> bool:
        n = names[i]
        if i == len(names) - 1:
            if remaining >= lo[n]:
                if len(out) >= cap:
                    return False
                out.append({**cur, n: remaining})
            return True
        for c in range(lo[n], remaining - tails[n] + 1, step):
            cur[n] = c
            if not rec(i + 1, remaining - c, cur):
                return False
        cur.pop(n, None)
        return True

    if not rec(0, G, {}):
        return None
    return out


def _greedy_splits(names: Sequence[str], lo: Dict[str, int], G: int,
                   step: int, lam_targets: Dict[str, float],
                   refs: Dict[str, float], sched, utility, welfare_fn):
    """Candidate splits from greedy water-filling + local exchange.

    Yields complete splits (the caller keeps the best-scoring one):
      1. a proportional seed — lower bounds plus the leftover split by
         demand weight lam_n * L_ref,n (offered work per workflow);
      2. water-filling — chips granted ``step`` at a time to whichever
         workflow raises welfare most (ties: largest own utility gain,
         then heaviest demand);
      3. local exchange — chip moves between workflow pairs kept while
         they strictly improve welfare.
    """
    weight = {}
    for n in names:
        ref = refs[n] if math.isfinite(refs[n]) and refs[n] > 0 else 1.0
        weight[n] = max(lam_targets[n], 1e-9) * ref
    total_w = sum(weight.values()) or 1.0

    split = dict(lo)
    pool = G - sum(split.values())
    # 1) proportional seed, floored to step multiples so water-filling
    # keeps granularity
    for n in names:
        give = int(pool * weight[n] / total_w) // step * step
        split[n] += give
    pool = G - sum(split.values())

    # 2) water-filling on welfare
    while pool > 0:
        g = min(step, pool)
        cur_util = {n: utility(n, sched(n, split[n])) for n in names}
        best_n, best_key = None, None
        for n in names:
            new_u = utility(n, sched(n, split[n] + g))
            new_welfare = welfare_fn({**cur_util, n: new_u})
            key = (new_welfare, new_u - cur_util[n], weight[n])
            if best_key is None or key > best_key:
                best_n, best_key = n, key
        split[best_n] += g
        pool -= g
    yield dict(split)

    # 3) local-exchange refinement
    def welfare_of(sp: Dict[str, int]) -> float:
        us = {}
        for n in names:
            r = sched(n, sp[n])
            if r is None:
                return -math.inf
            us[n] = utility(n, r)
        return welfare_fn(us)

    cur = welfare_of(split)
    max_rounds = 2 * len(names) * len(names)
    for _ in range(max_rounds):
        improved = False
        for i in names:
            for j in names:
                if i == j or split[i] - step < lo[i]:
                    continue
                cand = dict(split)
                cand[i] -= step
                cand[j] += step
                w = welfare_of(cand)
                if w > cur + 1e-12:
                    split, cur = cand, w
                    yield dict(split)
                    improved = True
        if not improved:
            break


def _subcluster(spec: hw.ClusterSpec, chips: int,
                offset: int = 0) -> hw.ClusterSpec:
    """A contiguous sub-cluster of ``chips`` chips (contiguity prune ii).

    Partial-host remainders are modeled explicitly as ``tail_chips``
    rather than truncated, so a 9-chip slice of a 4-chip/host cluster
    really provides 9 chips — no chips are silently dropped from the
    split search's pool.

    On a heterogeneous spec the slice walks the host groups in order
    starting ``offset`` chips in, taking whole (or partial, via a
    reduced-host-count group) prefixes so each sliced chip keeps its
    chip class.  Offsets make the split search's per-workflow slices
    DISJOINT: on a mixed cluster a scarce class is granted to exactly
    one workflow's slice instead of every slice claiming it.  Uniform
    specs ignore ``offset`` — every k-chip slice is the same cluster.
    """
    import dataclasses as dc

    if spec.host_groups:
        left, skip = chips, offset
        taken = []
        for g in spec.groups():
            if left <= 0:
                break
            used = min(skip, g.num_chips)
            skip -= used
            avail = g.num_chips - used
            if avail <= 0:
                continue
            want = min(left, avail)
            left -= want
            # a mid-host start leaves a partial host at the slice head
            head = min(want, -used % g.chips_per_host)
            if head:
                taken.append(dc.replace(g, num_hosts=1,
                                        chips_per_host=head))
                want -= head
            take_hosts, rem = divmod(want, g.chips_per_host)
            if take_hosts:
                taken.append(dc.replace(g, num_hosts=take_hosts))
            if rem:
                taken.append(dc.replace(g, num_hosts=1, chips_per_host=rem))
        return dc.replace(spec, num_hosts=0, tail_chips=0,
                          host_groups=tuple(taken))

    full_hosts, tail = divmod(chips, spec.chips_per_host)
    if full_hosts >= 1:
        return dc.replace(spec, num_hosts=full_hosts, tail_chips=tail)
    # fewer chips than one host: a single smaller host
    return dc.replace(spec, num_hosts=1, chips_per_host=chips, tail_chips=0)
