"""Online drift detection over live serving telemetry.

The aggregate abstraction assumes per-LLM execution-time shares are stable
across executions (paper §2.4); this module watches that assumption at
runtime.  A :class:`DriftMonitor` ingests per-call telemetry from the
cluster executor (``ClusterDriver`` feeds it arrivals, call completions
and workflow-request completions), maintains sliding EWMA aggregates —
per-workflow arrival rate, per-(workflow, LLM) execution-time share, and
output-token summaries — and tests them against the profiled expectations
the deployment was planned for.  Sustained deviations emit *typed* drift
events, which the re-plan controller (:mod:`repro.core.replan`) maps onto
its escalation ladder.

Detector shape: EWMA + relative-deviation threshold with hysteresis for
shares and token lengths (the share signal is a bounded fraction, so the
EWMA of in-band samples provably stays in band — no false triggers on
share-stable traffic), plus a CUSUM-style accumulator on inter-arrival
times for small-but-sustained rate drift.  Events fire on the rising edge
and re-arm once the metric returns inside the hysteresis band or after
:meth:`DriftMonitor.rebase`.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass(frozen=True)
class DriftConfig:
    """Detector knobs (thresholds are *relative* deviations).

    Defaults are sized against Poisson arrival noise: an EWMA with
    weight α has an effective window of ~2/α samples, so the relative
    standard deviation of the rate estimate is roughly sqrt(α / 2) —
    at α = 0.05 that is ~16%, making the 0.5 default threshold a >3σ
    event on share-stable traffic while a 2x ramp (+100%) still clears
    it within a few tens of arrivals.
    """

    ewma_alpha: float = 0.05  # weight of the newest sample (shares)
    slow_alpha: float = 0.02  # inter-arrival/token EWMA weight (~50-sample window)
    share_threshold: float = 0.50  # |share - expected| / max(expected, floor)
    rate_threshold: float = 0.50  # |rate - target| / target
    token_threshold: float = 0.50  # |mean_out - expected| / expected
    min_samples: int = 20  # updates before a metric may fire
    hysteresis: float = 0.5  # re-arm band as a fraction of the threshold
    share_floor: float = 0.02  # relative-deviation denominator floor
    # two-sided CUSUM over raw normalized inter-arrivals (dt·λ is Exp(1)
    # under no drift, so samples are i.i.d. with unit mean/variance):
    # slack 0.4 / limit 18 gives a stable-traffic average run length of
    # ~25k arrivals while catching a sustained 2x ramp within ~200
    cusum_slack: float = 0.4
    cusum_limit: float = 18.0
    # share/token deviations must ALSO exceed z·std(EWMA): a relative
    # threshold alone misfires on high-variance streams (token lengths
    # with CV ≈ 1), while z alone misfires on near-constant ones
    zscore_gate: float = 4.0
    # SLO-violation detector: EWMA of the per-request violation
    # indicator (completed past target, or shed at the front door);
    # fires once the smoothed violation rate crosses the threshold
    slo_violation_threshold: float = 0.2
    slo_alpha: float = 0.05


@dataclass(frozen=True)
class Expectation:
    """What the deployed plan assumed for one workflow."""

    lam: float  # planned arrival rate (requests/s)
    shares: Dict[str, float]  # llm -> profiled mean execution-time share
    out_tokens: Dict[str, float] = field(default_factory=dict)
    # request-level SLO the plan promised (0 = detector disarmed)
    slo_target: float = 0.0
    slo_class: str = ""


def expectation_from(pipeline, lam: float, stats=None, slo=None) -> Expectation:
    """Build an :class:`Expectation` from a profiled pipeline.

    ``stats`` (a :class:`repro.core.aggregate.WorkflowStats`) adds the
    token-length expectations when available; without it the token
    detector stays disarmed for this workflow.  ``slo`` (a *resolved*
    :class:`repro.qos.slo.SLOClass`) arms the SLO-violation detector.
    """
    shares = {m: st.mean_share for m, st in pipeline.stages.items()}
    toks: Dict[str, float] = {}
    if stats is not None:
        toks = {
            m: st.mean_output_tokens
            for m, st in stats.per_llm.items()
            if st.mean_output_tokens > 0
        }
    target, cls = 0.0, ""
    if slo is not None and slo.latency_target_s is not None:
        target, cls = slo.latency_target_s, slo.name
    return Expectation(lam=lam, shares=shares, out_tokens=toks,
                       slo_target=target, slo_class=cls)


# ---------------------------------------------------------------------------
# Typed drift events
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DriftEvent:
    workflow: str
    at: float  # simulation/wall time of the emission
    magnitude: float  # relative deviation that tripped the detector


@dataclass(frozen=True)
class RateDrift(DriftEvent):
    """Arrival rate deviates from the planned target."""

    observed: float = 0.0
    expected: float = 0.0


@dataclass(frozen=True)
class ShareDrift(DriftEvent):
    """One LLM's aggregate execution-time share deviates from profile."""

    llm: str = ""
    observed: float = 0.0
    expected: float = 0.0


@dataclass(frozen=True)
class TokenDrift(DriftEvent):
    """One LLM's output-token distribution shifted."""

    llm: str = ""
    observed: float = 0.0
    expected: float = 0.0


@dataclass(frozen=True)
class SLOViolation(DriftEvent):
    """A workflow's smoothed SLO-violation rate (requests finishing past
    their latency target, plus front-door sheds) crossed the threshold —
    the re-plan controller's fourth trigger: the allocation no longer
    covers the promised service tier."""

    slo_class: str = ""
    violation_rate: float = 0.0
    target_s: float = 0.0


# ---------------------------------------------------------------------------
# Streaming estimators
# ---------------------------------------------------------------------------


class _Ewma:
    """Exponentially-weighted mean + variance; ``value`` is None until
    the first sample so cold starts never read as drift."""

    def __init__(self, alpha: float):
        self.alpha = alpha
        self.value: Optional[float] = None
        self._m2: Optional[float] = None
        self.count = 0

    def update(self, x: float) -> float:
        self.count += 1
        if self.value is None:
            self.value = x
            self._m2 = x * x
        else:
            self.value += self.alpha * (x - self.value)
            self._m2 += self.alpha * (x * x - self._m2)
        return self.value

    @property
    def std_of_mean(self) -> float:
        """Standard deviation of the EWMA itself (what excursions of
        ``value`` look like under a stationary input stream)."""
        if self.value is None or self._m2 is None:
            return 0.0
        var = max(self._m2 - self.value * self.value, 0.0)
        return (var * self.alpha / (2.0 - self.alpha)) ** 0.5


class _Cusum:
    """Two-sided CUSUM over normalized inter-arrival times.

    Fed ``x = dt · λ_expected``, which is i.i.d. Exp(1) under no drift:
    the ``hi`` side accumulates evidence of arrivals coming *faster*
    than planned (``1 - x``), the ``lo`` side of them coming slower.
    """

    def __init__(self, slack: float, limit: float):
        self.slack = slack
        self.limit = limit
        self.hi = 0.0
        self.lo = 0.0

    def update(self, x_norm: float) -> bool:
        self.hi = max(0.0, self.hi + (1.0 - x_norm) - self.slack)
        self.lo = max(0.0, self.lo + (x_norm - 1.0) - self.slack)
        return max(self.hi, self.lo) >= self.limit

    def reset(self) -> None:
        self.hi = self.lo = 0.0


# ---------------------------------------------------------------------------
# Monitor
# ---------------------------------------------------------------------------


class DriftMonitor:
    """Telemetry sink + detector bank for a fleet of workflows.

    Implements the executor-side telemetry protocol duck-typed by
    ``ClusterDriver``: :meth:`record_arrival`, :meth:`record_call`,
    :meth:`record_request_done`.  Emitted events accumulate until
    :meth:`poll` drains them.
    """

    def __init__(
        self,
        expectations: Dict[str, Expectation],
        config: DriftConfig = DriftConfig(),
        forecaster=None,
    ):
        self.config = config
        self.expectations = dict(expectations)
        # optional arrival forecaster (repro.core.forecast.
        # ArrivalForecaster, duck-typed on observe()): record_arrival
        # forwards every arrival so proactive and reactive detectors see
        # the same telemetry stream
        self.forecaster = forecaster
        a = config.ewma_alpha
        self._ia: Dict[str, _Ewma] = {
            w: _Ewma(config.slow_alpha) for w in expectations
        }
        self._rate_cusum: Dict[str, _Cusum] = {
            w: _Cusum(config.cusum_slack, config.cusum_limit) for w in expectations
        }
        self._last_arrival: Dict[str, Optional[float]] = {
            w: None for w in expectations
        }
        self._share: Dict[str, Dict[str, _Ewma]] = {
            w: {m: _Ewma(a) for m in e.shares} for w, e in expectations.items()
        }
        self._tokens: Dict[str, Dict[str, _Ewma]] = {
            w: {m: _Ewma(config.slow_alpha) for m in e.out_tokens}
            for w, e in expectations.items()
        }
        self._viol: Dict[str, _Ewma] = {
            w: _Ewma(config.slo_alpha) for w in expectations
        }
        # cumulative per-workflow SLO accounting (class from expectation)
        self.slo_counters: Dict[str, Dict[str, int]] = {
            w: {"completed": 0, "violations": 0, "rejected": 0, "degraded": 0}
            for w in expectations
        }
        self._open: Dict[tuple, Dict[str, float]] = {}  # (wf, rid) -> llm busy
        self._pending: List[DriftEvent] = []
        self._active: set = set()
        self.now = 0.0
        # last corroboration verdicts (see :meth:`corroborate`)
        self.corroboration: Dict[str, Dict[str, dict]] = {}

    # -- executor-side telemetry protocol --------------------------------

    def record_arrival(self, workflow: str, t: float) -> None:
        if workflow not in self.expectations:
            return
        self.now = max(self.now, t)
        if self.forecaster is not None:
            self.forecaster.observe(workflow, t)
        last = self._last_arrival[workflow]
        self._last_arrival[workflow] = t
        if last is None:
            return
        dt = max(t - last, 1e-9)
        ia = self._ia[workflow].update(dt)
        exp = self.expectations[workflow]
        if exp.lam <= 0 or ia is None:
            return
        observed = 1.0 / ia
        rel = (observed - exp.lam) / exp.lam
        tripped = self._rate_cusum[workflow].update(dt * exp.lam)
        # arm only after TWO full EWMA windows: at one window the
        # estimate is still half-converged from its cold start and reads
        # as phantom drift (slow workflows take proportionally longer to
        # become monitorable, which is inherent, not a knob)
        if self._ia[workflow].count < self._warmup():
            return
        sev = abs(rel)
        if tripped:  # sustained small drift: force past the threshold
            sev = max(sev, self.config.rate_threshold * 1.01)
        self._edge(
            ("rate", workflow),
            sev,
            self.config.rate_threshold,
            lambda: RateDrift(
                workflow=workflow,
                at=self.now,
                magnitude=abs(rel),
                observed=observed,
                expected=exp.lam,
            ),
        )

    def record_call(self, workflow: str, llm: str, req) -> None:
        if workflow not in self.expectations:
            return
        self.now = max(self.now, req.t_done)
        busy = max(req.t_done - req.t_start_service, 0.0)
        key = (workflow, req.workflow_request)
        self._open.setdefault(key, {})
        self._open[key][llm] = self._open[key].get(llm, 0.0) + busy
        # token EWMAs are tracked for every LLM seen; the detector arms
        # once an expectation exists (from traced stats, or learned by
        # calibrate() from the live baseline)
        toks = self._tokens[workflow].setdefault(
            llm, _Ewma(self.config.slow_alpha)
        )
        observed = toks.update(float(req.output_tokens))
        expected = self.expectations[workflow].out_tokens.get(llm)
        if expected is not None and expected > 0:
            rel = abs(observed - expected) / max(expected, 1.0)
            gate = self.config.zscore_gate * toks.std_of_mean
            if abs(observed - expected) <= gate:
                rel = 0.0
            if toks.count >= self._warmup():
                self._edge(
                    ("tokens", workflow, llm),
                    rel,
                    self.config.token_threshold,
                    lambda: TokenDrift(
                        workflow=workflow,
                        at=self.now,
                        magnitude=rel,
                        llm=llm,
                        observed=observed,
                        expected=expected,
                    ),
                )

    def record_shed(self, workflow: str, slo_class: str, kind: str,
                    t: float) -> None:
        """Front-door shed (reject/degrade) — counts as an SLO loss."""
        if workflow not in self.expectations:
            return
        self.now = max(self.now, t)
        key = "rejected" if kind == "reject" else "degraded"
        self.slo_counters[workflow][key] += 1
        self._update_violation(workflow, 1.0)

    def _update_violation(self, workflow: str, violated: float) -> None:
        exp = self.expectations[workflow]
        if exp.slo_target <= 0:
            return
        ew = self._viol[workflow]
        rate = ew.update(violated)
        if ew.count < self.config.min_samples:
            return
        self._edge(
            ("slo", workflow),
            rate,
            self.config.slo_violation_threshold,
            lambda rate=rate: SLOViolation(
                workflow=workflow,
                at=self.now,
                magnitude=rate,
                slo_class=exp.slo_class,
                violation_rate=rate,
                target_s=exp.slo_target,
            ),
        )

    def record_request_done(self, workflow: str, rec) -> None:
        if workflow not in self.expectations:
            return
        self.now = max(self.now, rec.done)
        exp = self.expectations[workflow]
        if exp.slo_target > 0 and not getattr(rec, "degraded", False):
            violated = rec.latency > exp.slo_target
            self.slo_counters[workflow]["completed"] += 1
            if violated:
                self.slo_counters[workflow]["violations"] += 1
            self._update_violation(workflow, 1.0 if violated else 0.0)
        busy = self._open.pop((workflow, rec.request_id), None)
        if not busy:
            return
        total = sum(busy.values())
        if total <= 0:
            return
        exp = self.expectations[workflow]
        for m, ew in self._share[workflow].items():
            observed = ew.update(busy.get(m, 0.0) / total)
            expected = exp.shares.get(m, 0.0)
            denom = max(expected, self.config.share_floor)
            rel = abs(observed - expected) / denom
            if abs(observed - expected) <= self.config.zscore_gate * ew.std_of_mean:
                rel = 0.0
            if ew.count < self.config.min_samples:
                continue
            self._edge(
                ("share", workflow, m),
                rel,
                self.config.share_threshold,
                lambda m=m, observed=observed, expected=expected, rel=rel: ShareDrift(
                    workflow=workflow,
                    at=self.now,
                    magnitude=rel,
                    llm=m,
                    observed=observed,
                    expected=expected,
                ),
            )

    # -- detector plumbing ------------------------------------------------

    def _warmup(self) -> int:
        return max(
            self.config.min_samples,
            int(round(2.0 / max(self.config.slow_alpha, 1e-6))),
        )

    def _edge(self, key: tuple, severity: float, threshold: float, make) -> None:
        if key in self._active:
            # re-arm only once safely back inside the hysteresis band
            if severity < threshold * self.config.hysteresis:
                self._active.discard(key)
                if key[0] == "rate":
                    self._rate_cusum[key[1]].reset()
        elif severity > threshold:
            self._active.add(key)
            self._pending.append(make())

    def poll(self) -> List[DriftEvent]:
        """Drain events emitted since the last poll."""
        out, self._pending = self._pending, []
        return out

    # -- state the controller reads ---------------------------------------

    def observed_lams(self) -> Dict[str, float]:
        """Current arrival-rate estimates (planned target until the EWMA
        has a sample)."""
        out = {}
        for w, exp in self.expectations.items():
            ia = self._ia[w].value
            out[w] = (1.0 / ia) if ia else exp.lam
        return out

    def observed_shares(self, workflow: str) -> Dict[str, float]:
        return {
            m: (ew.value if ew.value is not None else 0.0)
            for m, ew in self._share[workflow].items()
        }

    def corroborate(
        self, shares: Dict[str, Dict[str, float]], tol: float = 0.25
    ) -> Dict[str, Dict[str, dict]]:
        """Cross-check the monitor's share EWMAs against an independently
        reconstructed estimate (the span-derived shares from
        :meth:`repro.obs.spans.Tracer.observed_shares`).

        Both estimators consume the same busy-time normalization, so on
        a healthy pipeline they must agree; a gap beyond ``tol`` marks
        the EWMA (windowed) and the reconstruction (run-cumulative) as
        diverging — corroborating telemetry a replan decision can audit.
        Verdicts are returned and kept on :attr:`corroboration`.
        """
        floor = self.config.share_floor
        out: Dict[str, Dict[str, dict]] = {}
        for w, ext_row in shares.items():
            own_row = self.observed_shares(w) if w in self._share else {}
            row: Dict[str, dict] = {}
            for m in set(ext_row) | set(own_row):
                own = own_row.get(m, 0.0)
                ext = ext_row.get(m, 0.0)
                gap = abs(own - ext) / max(own, ext, floor)
                row[m] = {
                    "monitor": own,
                    "external": ext,
                    "gap": gap,
                    "agree": gap <= tol,
                }
            out[w] = row
        self.corroboration = out
        return out

    def observed_violation_rate(self, workflow: str) -> float:
        """Smoothed SLO-violation rate (0.0 until a sample arrives)."""
        ew = self._viol.get(workflow)
        return ew.value if ew is not None and ew.value is not None else 0.0

    def observed_tokens(self, workflow: str) -> Dict[str, float]:
        """Live mean-output-token estimates (only LLMs with samples)."""
        return {
            m: ew.value
            for m, ew in self._tokens.get(workflow, {}).items()
            if ew.value is not None
        }

    def calibrate(self) -> None:
        """Rebase expectations onto the *observed* steady state.

        Profiled expectations come from the unloaded tracing deployment;
        the live system adds queueing and cache effects that shift the
        measured shares systematically.  Calling this at the end of a
        known-stable warmup window re-centers the share and token
        detectors on what the deployment actually looks like, so
        subsequent events measure drift rather than trace-vs-runtime
        calibration error.  The *rate* baseline stays at the planned
        target — the plan is the correct reference for rate drift, and
        re-baselining it onto a noisy instantaneous estimate would bake
        sampling error into every later comparison.
        """
        for w, exp in list(self.expectations.items()):
            shares = {
                m: (ew.value if ew.value is not None else exp.shares.get(m, 0.0))
                for m, ew in self._share[w].items()
            }
            toks = {
                m: (ew.value if ew.value is not None else exp.out_tokens.get(m, 0.0))
                for m, ew in self._tokens[w].items()
            }
            self.expectations[w] = Expectation(
                lam=exp.lam, shares=shares, out_tokens=toks,
                slo_target=exp.slo_target, slo_class=exp.slo_class
            )
            self._rate_cusum[w].reset()
            self._viol[w] = _Ewma(self.config.slo_alpha)
        self._active.clear()
        self._pending.clear()

    def rebase(self, expectations: Dict[str, Expectation]) -> None:
        """Adopt new expectations after a re-plan: detectors re-arm and
        CUSUM accumulators reset, so the monitor measures drift relative
        to the *current* plan."""
        for w, exp in expectations.items():
            self.expectations[w] = exp
            if w in self._rate_cusum:
                self._rate_cusum[w].reset()
            if w in self._viol:
                self._viol[w] = _Ewma(self.config.slo_alpha)
        self._active = {k for k in self._active if k[1] not in expectations}
