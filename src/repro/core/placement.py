"""Topology-aware fractional placement (paper §6).

Maps the scheduler's allocation (replicas × TP × fraction per LLM) onto a
concrete cluster — hosts, high-bandwidth ICI domains (the NVLink-domain
analogue), chips, fraction units — with the paper's hierarchical
most-constrained-first heuristic:

  1. TP instances before non-TP; within each class, larger first;
  2. candidate hb domains scored by per-chip free-capacity *imbalance*
     (most balanced wins), ties broken by *least* remaining capacity
     (preserve large domains for future large placements);
  3. sub-chip fractions pack onto already-occupied chips first (best fit);
  4. the result is emitted as deployment manifests (the k8s-file
     analogue) consumed by ``repro.launch.serve``; fraction limits are
     enforced by the engine's slot scheduler (the MPS analogue).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro import hw
from repro.core.pipeline import Allocation


class PlacementError(RuntimeError):
    pass


@dataclass
class Chip:
    host: int
    domain: int  # global hb-domain id
    index: int  # global chip id
    free_units: int

    def used(self, total: int) -> int:
        return total - self.free_units


@dataclass
class PlacedInstance:
    llm: str
    replica: int
    tp: int
    chips: List[int]  # global chip ids
    units_per_chip: int
    host: int
    domain: int


@dataclass
class Placement:
    spec: hw.ClusterSpec
    instances: List[PlacedInstance] = field(default_factory=list)

    def chips_of(self, llm: str) -> List[int]:
        return sorted({c for i in self.instances if i.llm == llm
                       for c in i.chips})

    def validate(self) -> None:
        F = self.spec.fractions_per_chip
        used: Dict[int, int] = {}
        for inst in self.instances:
            if inst.tp > self.spec.hb_domain_size:
                raise PlacementError(
                    f"{inst.llm}: TP {inst.tp} exceeds hb domain "
                    f"{self.spec.hb_domain_size}")
            domains = set()
            for c in inst.chips:
                used[c] = used.get(c, 0) + inst.units_per_chip
                domains.add(c // self.spec.hb_domain_size)
            if inst.tp > 1 and len(domains) != 1:
                raise PlacementError(
                    f"{inst.llm}: TP instance spans domains {domains}")
        for c, u in used.items():
            if u > F:
                raise PlacementError(f"chip {c} oversubscribed: {u}/{F}")

    def fragmentation(self) -> float:
        """Fraction of free units stranded on partially-used chips."""
        F = self.spec.fractions_per_chip
        used: Dict[int, int] = {c: 0 for c in range(self.spec.num_chips)}
        for inst in self.instances:
            for c in inst.chips:
                used[c] += inst.units_per_chip
        stranded = sum(F - u for u in used.values() if 0 < u < F)
        total_free = sum(F - u for u in used.values())
        return stranded / total_free if total_free else 0.0

    def to_deployment(self, routing: Optional[dict] = None) -> dict:
        """k8s-style deployment manifest (consumed by repro.launch.serve).

        ``routing`` (from :func:`tenant_routing`) annotates a pooled
        multi-tenant placement with each workflow's routing table.
        """
        doc = {
            "apiVersion": "repro/v1",
            "kind": "WorkflowServingDeployment",
            "cluster": {
                "hosts": self.spec.num_hosts,
                "chips_per_host": self.spec.chips_per_host,
                "tail_chips": self.spec.tail_chips,
                "hb_domain_size": self.spec.hb_domain_size,
                "fractions_per_chip": self.spec.fractions_per_chip,
            },
            "instances": [
                {
                    "name": f"{i.llm}-r{i.replica}",
                    "llm": i.llm,
                    "tensor_parallel": i.tp,
                    "chips": i.chips,
                    "chip_fraction": i.units_per_chip
                    / self.spec.fractions_per_chip,
                    "host": i.host,
                    "hb_domain": i.domain,
                }
                for i in self.instances
            ],
        }
        if routing is not None:
            doc["routing"] = routing
        return doc


@dataclass
class _Cluster:
    spec: hw.ClusterSpec
    chips: List[Chip]

    @classmethod
    def fresh(cls, spec: hw.ClusterSpec) -> "_Cluster":
        chips = []
        for i in range(spec.num_chips):
            host = i // spec.chips_per_host
            domain = i // spec.hb_domain_size
            chips.append(Chip(host, domain, i, spec.fractions_per_chip))
        return cls(spec, chips)

    def domains(self) -> Dict[int, List[Chip]]:
        out: Dict[int, List[Chip]] = {}
        for c in self.chips:
            out.setdefault(c.domain, []).append(c)
        return out


def _instances_from_alloc(allocations: Dict[str, Allocation],
                          spec: hw.ClusterSpec):
    """Expand allocations into placeable instance descriptors."""
    F = spec.fractions_per_chip
    out = []
    for llm, a in allocations.items():
        for r in range(a.replicas):
            if a.tp > 1 or a.fraction >= 1.0:
                out.append((llm, r, a.tp, F))  # whole chips
            else:
                units = max(int(round(a.fraction * F)), 1)
                out.append((llm, r, 1, units))
    return out


def place(allocations: Dict[str, Allocation],
          spec: hw.ClusterSpec) -> Placement:
    cluster = _Cluster.fresh(spec)
    F = spec.fractions_per_chip
    placement = Placement(spec)

    insts = _instances_from_alloc(allocations, spec)
    # most-constrained-first: TP desc, then whole-chip, then fraction desc
    insts.sort(key=lambda t: (-(t[2] > 1), -t[2], -t[3]))

    for llm, replica, tp, units in insts:
        if tp >= 1 and units == F:
            chips = _place_whole(cluster, tp)
        else:
            chips = _place_fraction(cluster, units)
        if chips is None:
            raise PlacementError(
                f"cannot place {llm} replica {replica} (tp={tp}, "
                f"units={units}); fragmentation too high")
        placement.instances.append(PlacedInstance(
            llm=llm, replica=replica, tp=tp, chips=[c.index for c in chips],
            units_per_chip=units if tp == 1 and units < F else F,
            host=chips[0].host, domain=chips[0].domain))
        for c in chips:
            c.free_units -= units if (tp == 1 and units < F) else F

    placement.validate()
    return placement


def _place_whole(cluster: _Cluster, tp: int) -> Optional[List[Chip]]:
    """Place a tp-chip instance inside one hb domain (fully-free chips)."""
    F = cluster.spec.fractions_per_chip
    candidates = []
    for dom, chips in cluster.domains().items():
        free = [c for c in chips if c.free_units == F]
        if len(free) < tp:
            continue
        frees = [c.free_units for c in chips]
        imbalance = max(frees) - min(frees)
        capacity = sum(frees)
        candidates.append((imbalance, capacity, dom, free))
    if not candidates:
        return None
    candidates.sort(key=lambda t: (t[0], t[1]))
    _, _, _, free = candidates[0]
    return free[:tp]


def _place_fraction(cluster: _Cluster, units: int) -> Optional[List[Chip]]:
    """Best-fit a sub-chip fraction; prefer already-occupied chips."""
    F = cluster.spec.fractions_per_chip
    partial = [c for c in cluster.chips
               if 0 < c.free_units < F and c.free_units >= units]
    if partial:
        partial.sort(key=lambda c: c.free_units)  # tightest fit
        return [partial[0]]
    # open a fresh chip in the least-capacity domain that has one
    candidates = []
    for dom, chips in cluster.domains().items():
        free = [c for c in chips if c.free_units == F]
        if not free:
            continue
        capacity = sum(c.free_units for c in chips)
        candidates.append((capacity, dom, free[0]))
    if not candidates:
        return None
    candidates.sort(key=lambda t: t[0])
    return [candidates[0][2]]


def fleet_offsets(placements: Dict[str, Placement], order,
                  spec: hw.ClusterSpec) -> Dict[str, int]:
    """Disjoint physical slice starts for per-workflow slice-local
    placements (partitioned fleets).

    A slice start is hb-domain-aligned only when the slice contains TP
    groups (a TP instance must not cross a domain boundary after
    translation); TP=1 slices can start anywhere.  Raises
    :class:`PlacementError` when the slices do not fit the cluster.
    """
    dom = spec.hb_domain_size
    offsets: Dict[str, int] = {}
    cursor = 0
    for name in order:
        insts = placements[name].instances
        used = 1 + max((c for inst in insts for c in inst.chips), default=0)
        if any(inst.tp > 1 for inst in insts):
            cursor = (cursor + dom - 1) // dom * dom
        offsets[name] = cursor
        cursor += used
    if cursor > spec.num_chips:
        raise PlacementError(
            f"fleet needs {cursor} chips for disjoint slices, "
            f"cluster has {spec.num_chips}")
    return offsets


def merge_fleet(placements: Dict[str, Placement], offsets: Dict[str, int],
                spec: hw.ClusterSpec) -> Placement:
    """One global :class:`Placement` for a partitioned fleet.

    Slice-local instances are translated by their workflow's offset and
    renamed ``<workflow>/<llm>`` so instance keys — and therefore
    :func:`migration_diff` — are unambiguous fleet-wide.
    """
    import dataclasses as dc

    out = Placement(spec)
    for name, pl in placements.items():
        off = offsets[name]
        for inst in pl.instances:
            chips = [c + off for c in inst.chips]
            out.instances.append(dc.replace(
                inst, llm=f"{name}/{inst.llm}", chips=chips,
                host=chips[0] // spec.chips_per_host,
                domain=chips[0] // spec.hb_domain_size))
    return out


def tenant_routing(placement: Placement,
                   members: Dict[str, List[Tuple[str, str]]],
                   weights: Dict[str, Dict[str, Dict[int, float]]]
                   ) -> Dict[str, Dict[str, Dict[str, float]]]:
    """Routing tables into a pooled placement, keyed by instance name.

    A pooled fleet gets ONE physical placement (the tenants' shared
    replica sets); instead of a private chip offset, every workflow
    receives a table ``local llm name -> {placed instance -> weight}``.
    ``members`` maps canonical model id -> [(workflow, local name)] and
    ``weights`` is the scheduler's replica-indexed routing
    (:meth:`MergedPipeline.routing_weights`); weights per (workflow,
    llm) sum to 1.
    """
    by_tenant: Dict[str, List[PlacedInstance]] = {}
    for inst in placement.instances:
        by_tenant.setdefault(inst.llm, []).append(inst)
    out: Dict[str, Dict[str, Dict[str, float]]] = {}
    for cid, mem in members.items():
        insts = sorted(by_tenant.get(cid, []), key=lambda i: i.replica)
        for workflow, llm in mem:
            w = weights.get(workflow, {}).get(llm, {})
            table = {f"{i.llm}-r{i.replica}": w.get(i.replica, 0.0)
                     for i in insts}
            total = sum(table.values())
            if total > 0:
                table = {k: v / total for k, v in table.items()}
            out.setdefault(workflow, {})[llm] = table
    return out


@dataclass
class MigrationDiff:
    """What a re-placement actually changes, instance by instance.

    A full re-plan hands the operator this diff — chips to move, replicas
    to add or drop — rather than a from-scratch manifest, so a rung-3
    drift reaction is an *edit* to the running deployment.  An instance
    is keyed ``llm-r<replica>``; ``chip_loads`` counts the (instance,
    chip) assignments present in the new placement but not the old one —
    i.e. weight-loading events the migration must pay for.
    """

    added: List[str] = field(default_factory=list)
    dropped: List[str] = field(default_factory=list)
    moved: List[str] = field(default_factory=list)
    unchanged: List[str] = field(default_factory=list)
    chip_loads: int = 0

    @property
    def chips_moved(self) -> int:
        return self.chip_loads

    def summary(self) -> dict:
        return {
            "replicas_added": len(self.added),
            "replicas_dropped": len(self.dropped),
            "replicas_moved": len(self.moved),
            "replicas_unchanged": len(self.unchanged),
            "chips_moved": self.chip_loads,
        }


def migration_diff(old: Placement, new: Placement) -> MigrationDiff:
    """Instance-level diff between two placements of the same cluster."""
    def keyed(p: Placement) -> Dict[str, PlacedInstance]:
        return {f"{i.llm}-r{i.replica}": i for i in p.instances}

    a, b = keyed(old), keyed(new)
    diff = MigrationDiff()
    for name in sorted(set(a) | set(b)):
        if name not in a:
            diff.added.append(name)
            diff.chip_loads += len(b[name].chips)
        elif name not in b:
            diff.dropped.append(name)
        else:
            oi, ni = a[name], b[name]
            fresh = set(ni.chips) - set(oi.chips)
            if fresh or oi.units_per_chip != ni.units_per_chip \
                    or oi.tp != ni.tp:
                diff.moved.append(name)
                diff.chip_loads += len(fresh) if oi.tp == ni.tp else \
                    len(ni.chips)
            else:
                diff.unchanged.append(name)
    return diff


def save_deployment(placement: Placement, path: str,
                    routing: Optional[dict] = None) -> None:
    with open(path, "w") as f:
        json.dump(placement.to_deployment(routing), f, indent=2)
