"""Topology-aware fractional placement (paper §6; ROADMAP
"Placement-aware partitioned splits").

Maps the scheduler's allocation (replicas × TP × fraction per LLM) onto a
concrete cluster — hosts, high-bandwidth ICI domains (the NVLink-domain
analogue), chips, fraction units — with the paper's hierarchical
placement heuristic ("place the best allocation onto the GPU cluster,
minimizing fragmentation, while respecting network topology
constraints"):

  1. most-constrained shapes first: TP instances before non-TP; within
     each class, larger first;
  2. fill before spill: candidate hb domains on hosts that already run
     something beat domains on untouched hosts, so a fleet concentrates
     onto few hosts and whole hosts stay free for future large shapes;
  3. best-fit into domain-sized bins: among eligible domains the one
     whose free-chip count most tightly fits the instance wins (ties:
     least remaining capacity, then lowest domain id for determinism);
  4. sub-chip fractions pack onto already-occupied chips first (best
     fit), never onto chips owned by another workflow;
  5. the result is emitted as deployment manifests (the k8s-file
     analogue) consumed by ``repro.launch.serve``; fraction limits are
     enforced by the engine's slot scheduler (the MPS analogue).

Inputs are ``{llm: Allocation}`` maps from :mod:`repro.core.scheduler`
plus a :class:`repro.hw.ClusterSpec`; outputs are :class:`Placement`
objects (and :class:`MigrationDiff` edits between them).

Three entry points share one packing core:

* :func:`place` — one allocation map (a single workflow, or a pooled
  fleet's shared tenant replica set) over the whole cluster;
* :func:`place_fleet` — true co-placement of a partitioned fleet: every
  workflow's replicas packed in ONE pass over the real topology (tail
  chips included), chip ownership kept exclusive per workflow, instances
  keyed ``<workflow>/<llm>`` so :func:`migration_diff` works fleet-wide.
  This replaces the old contiguous-slice model (:func:`fleet_offsets` +
  :func:`merge_fleet`, kept for comparison) which padded every
  TP-carrying slice to an hb-domain boundary;
* :func:`feasibility` / :func:`fleet_feasibility` — the cheap probe the
  split search calls per candidate split: same packing, but no manifest
  is materialized; returns ``(ok, fragmentation_cost)``.

Failures raise a structured :class:`PlacementError` carrying the shape
that failed, the per-domain free contiguous capacity at the time of
failure, and a remediation hint.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro import hw
from repro.core.pipeline import Allocation


class PlacementError(RuntimeError):
    """A shape could not be placed (or a placement failed validation).

    Structured diagnostics (all optional — validation errors carry only
    a message):

    * ``shape`` — the instance that failed: ``{"llm", "replica", "tp",
      "units_per_chip"}``;
    * ``domain_capacity`` — per-hb-domain free capacity at failure time:
      ``{domain: {"host", "free_chips", "free_units",
      "largest_chip_free_units"}}`` (``free_chips`` counts fully-free
      chips — the contiguous capacity a TP group needs);
    * ``hint`` — what would make the shape placeable.
    """

    def __init__(self, message: str, *, shape: Optional[dict] = None,
                 domain_capacity: Optional[Dict[int, dict]] = None,
                 hint: Optional[str] = None):
        self.shape = shape
        self.domain_capacity = domain_capacity
        self.hint = hint
        parts = [message]
        if shape is not None:
            parts.append(f"shape: {shape}")
        if domain_capacity is not None:
            cap = ", ".join(
                f"d{d}(host {c['host']}): {c['free_chips']} free chips"
                f"/{c['free_units']}u"
                for d, c in sorted(domain_capacity.items()))
            parts.append(f"free contiguous capacity: {cap}")
        if hint is not None:
            parts.append(f"hint: {hint}")
        super().__init__("; ".join(parts))


@dataclass
class Chip:
    host: int
    domain: int  # global hb-domain id
    index: int  # global chip id
    free_units: int
    owner: Optional[str] = None  # workflow owning this chip (fleet packs)
    chip_class: str = ""  # hw.ChipClass name (heterogeneous clusters)

    def used(self, total: int) -> int:
        return total - self.free_units


@dataclass
class PlacedInstance:
    llm: str
    replica: int
    tp: int
    chips: List[int]  # global chip ids
    units_per_chip: int
    host: int
    domain: int
    # the Allocation's requested chip class; None = any (uniform cluster)
    chip_class: Optional[str] = None


@dataclass
class Placement:
    spec: hw.ClusterSpec
    instances: List[PlacedInstance] = field(default_factory=list)

    def chips_of(self, llm: str) -> List[int]:
        return sorted({c for i in self.instances if i.llm == llm
                       for c in i.chips})

    def validate(self) -> None:
        F = self.spec.fractions_per_chip
        table = self.spec.chip_table()
        used: Dict[int, int] = {}
        for inst in self.instances:
            if inst.tp > self.spec.hb_domain_size:
                raise PlacementError(
                    f"{inst.llm}: TP {inst.tp} exceeds hb domain "
                    f"{self.spec.hb_domain_size}")
            domains, classes = set(), set()
            for c in inst.chips:
                used[c] = used.get(c, 0) + inst.units_per_chip
                if c < len(table):
                    domains.add(table[c][1])
                    classes.add(table[c][2])
                else:  # chip id beyond spec (externally built placement)
                    domains.add(c // self.spec.hb_domain_size)
            if inst.tp > 1 and len(domains) != 1:
                raise PlacementError(
                    f"{inst.llm}: TP instance spans domains {domains}")
            if len(classes) > 1:
                raise PlacementError(
                    f"{inst.llm}: instance spans chip classes {classes}")
            if inst.chip_class is not None and classes and \
                    classes != {inst.chip_class}:
                raise PlacementError(
                    f"{inst.llm}: bound to class {inst.chip_class!r} but "
                    f"placed on {classes}")
        for c, u in used.items():
            if u > F:
                raise PlacementError(f"chip {c} oversubscribed: {u}/{F}")

    def fragmentation(self, scope: str = "cluster") -> float:
        """Fraction of free units stranded on partially-used chips.

        ``scope="cluster"`` (default) counts every chip in ``spec`` —
        the right metric for placements that own the whole cluster
        (:func:`place`, :func:`place_fleet`, the probe): untouched
        fully-free chips are usable capacity, not fragmentation.
        ``scope="touched"`` restricts to chips this placement's
        instances actually use — the right metric for the per-workflow
        views :func:`split_fleet` returns, whose ``spec`` is still the
        full cluster (chips owned by *other* workflows would otherwise
        dilute the number).
        """
        if scope not in ("cluster", "touched"):
            raise ValueError(f"unknown fragmentation scope {scope!r}")
        F = self.spec.fractions_per_chip
        used: Dict[int, int] = ({} if scope == "touched"
                                else {c: 0 for c in range(self.spec.num_chips)})
        for inst in self.instances:
            for c in inst.chips:
                used[c] = used.get(c, 0) + inst.units_per_chip
        stranded = sum(F - u for u in used.values() if 0 < u < F)
        total_free = sum(F - u for u in used.values())
        return stranded / total_free if total_free else 0.0

    def to_deployment(self, routing: Optional[dict] = None) -> dict:
        """k8s-style deployment manifest (consumed by repro.launch.serve).

        ``routing`` (from :func:`tenant_routing`) annotates a pooled
        multi-tenant placement with each workflow's routing table.
        """
        doc = {
            "apiVersion": "repro/v1",
            "kind": "WorkflowServingDeployment",
            "cluster": {
                "hosts": self.spec.num_hosts,
                "chips_per_host": self.spec.chips_per_host,
                "tail_chips": self.spec.tail_chips,
                "hb_domain_size": self.spec.hb_domain_size,
                "fractions_per_chip": self.spec.fractions_per_chip,
            },
            "instances": [
                {
                    "name": f"{i.llm}-r{i.replica}",
                    "llm": i.llm,
                    "tensor_parallel": i.tp,
                    "chips": i.chips,
                    "chip_fraction": i.units_per_chip
                    / self.spec.fractions_per_chip,
                    "host": i.host,
                    "hb_domain": i.domain,
                    **({"chip_class": i.chip_class}
                       if i.chip_class is not None else {}),
                }
                for i in self.instances
            ],
        }
        if self.spec.host_groups:
            doc["cluster"]["host_groups"] = [
                {"num_hosts": g.num_hosts,
                 "chips_per_host": g.chips_per_host,
                 "chip_class": g.chip_class}
                for g in self.spec.host_groups
            ]
        if routing is not None:
            doc["routing"] = routing
        return doc


@dataclass
class _Cluster:
    """Mutable packing state; the per-domain free counters and busy-host
    set are maintained incrementally by :meth:`claim` so the greedy
    placement loop never rescans the whole cluster per instance."""

    spec: hw.ClusterSpec
    chips: List[Chip]
    domain_map: Dict[int, List[Chip]]
    dom_free_chips: Dict[int, int]  # fully-free chips per domain
    dom_free_units: Dict[int, int]  # total free units per domain
    busy_hosts: set

    @classmethod
    def fresh(cls, spec: hw.ClusterSpec) -> "_Cluster":
        chips = []
        domain_map: Dict[int, List[Chip]] = {}
        # chip_table() materializes hosts, hb domains and chip classes for
        # every chip — including tail chips and heterogeneous host groups;
        # domains never span hosts, host groups or the tail boundary, so a
        # TP group can never be packed across either
        for i, (host, domain, cname) in enumerate(spec.chip_table()):
            chip = Chip(host, domain, i, spec.fractions_per_chip,
                        chip_class=cname)
            chips.append(chip)
            domain_map.setdefault(domain, []).append(chip)
        return cls(spec, chips, domain_map,
                   {d: len(cs) for d, cs in domain_map.items()},
                   {d: len(cs) * spec.fractions_per_chip
                    for d, cs in domain_map.items()},
                   set())

    def claim(self, chip: Chip, units: int, owner: Optional[str]) -> None:
        if chip.free_units == self.spec.fractions_per_chip:
            self.dom_free_chips[chip.domain] -= 1
        chip.free_units -= units
        chip.owner = owner
        self.dom_free_units[chip.domain] -= units
        self.busy_hosts.add(chip.host)

    def fragmentation(self) -> float:
        F = self.spec.fractions_per_chip
        stranded = sum(c.free_units for c in self.chips
                       if 0 < c.free_units < F)
        total_free = sum(c.free_units for c in self.chips)
        return stranded / total_free if total_free else 0.0

    def domain_capacity(self) -> Dict[int, dict]:
        out: Dict[int, dict] = {}
        for dom, chips in self.domain_map.items():
            out[dom] = {
                "host": chips[0].host,
                "free_chips": self.dom_free_chips[dom],
                "free_units": self.dom_free_units[dom],
                "largest_chip_free_units": max(c.free_units for c in chips),
            }
        return out


@dataclass
class FeasibilityResult:
    """Outcome of the placement probe (:func:`fleet_feasibility`).

    Iterable as ``(ok, fragmentation)`` so the split search can unpack
    it directly.  ``fragmentation`` is the stranded-free-unit fraction
    of the probed packing (0 = every touched chip exactly tiled);
    ``failed_shape`` names the first unplaceable instance when ``ok`` is
    False (fragmentation is then reported for the partial packing).
    """

    ok: bool
    fragmentation: float
    failed_shape: Optional[dict] = None

    def __bool__(self) -> bool:
        return self.ok

    def __iter__(self):
        yield self.ok
        yield self.fragmentation


def _instances_from_alloc(allocations: Dict[str, Allocation],
                          spec: hw.ClusterSpec, owner: Optional[str] = None):
    """Expand allocations into placeable (owner, llm, replica, tp, units,
    chip_class) instance descriptors; ``owner`` prefixes the instance key
    for fleet packs.  ``chip_class`` is the Allocation's binding (None =
    any chip)."""
    F = spec.fractions_per_chip
    key = (lambda m: f"{owner}/{m}") if owner is not None else (lambda m: m)
    out = []
    for llm, a in allocations.items():
        cc = getattr(a, "chip_class", None)
        for r in range(a.replicas):
            if a.tp > 1 or a.fraction >= 1.0:
                out.append((owner, key(llm), r, a.tp, F, cc))  # whole chips
            else:
                units = max(int(round(a.fraction * F)), 1)
                out.append((owner, key(llm), r, 1, units, cc))
    return out


def _pack(groups: Dict[Optional[str], Dict[str, Allocation]],
          spec: hw.ClusterSpec, *, record: bool
          ) -> Tuple[Optional[List[PlacedInstance]], Optional[dict], _Cluster]:
    """Shared packing core: hierarchical most-constrained-first greedy.

    ``groups`` maps owner (workflow name, or None for a single
    workflow / pooled tenant set) to its allocation map.  Chips are
    owned exclusively: a sub-chip fraction only co-locates with replicas
    of the same owner, which is what keeps a partitioned fleet's chip
    sets disjoint.  With ``record=False`` no :class:`PlacedInstance`
    objects are built — the probe path.

    Returns ``(instances_or_None, failed_shape, cluster)``; on failure
    ``instances`` is None and ``failed_shape`` describes the first
    unplaceable instance.
    """
    cluster = _Cluster.fresh(spec)
    F = spec.fractions_per_chip
    insts: list = []
    for owner, allocations in groups.items():
        insts.extend(_instances_from_alloc(allocations, spec, owner))
    # most-constrained-first across ALL owners: class-bound before
    # class-free (a bound shape has fewer candidate domains), TP desc,
    # then whole-chip, then fraction desc; owner/llm tail keys make the
    # order total
    insts.sort(key=lambda t: (-(t[5] is not None), -(t[3] > 1), -t[3],
                              -t[4], t[1], t[2]))

    placed: Optional[List[PlacedInstance]] = [] if record else None
    for owner, llm, replica, tp, units, cc in insts:
        if tp >= 1 and units == F:
            chips = _place_whole(cluster, tp, cc)
        else:
            chips = _place_fraction(cluster, units, owner, cc)
        if chips is None:
            return None, {"llm": llm, "replica": replica, "tp": tp,
                          "units_per_chip": units, "chip_class": cc}, cluster
        per_chip = units if (tp == 1 and units < F) else F
        for c in chips:
            cluster.claim(c, per_chip, owner)
        if record:
            placed.append(PlacedInstance(
                llm=llm, replica=replica, tp=tp,
                chips=[c.index for c in chips], units_per_chip=per_chip,
                host=chips[0].host, domain=chips[0].domain,
                chip_class=cc))
    return placed, None, cluster


def _fail(failed: dict, cluster: _Cluster) -> PlacementError:
    shape = failed
    tp, units = shape["tp"], shape["units_per_chip"]
    F = cluster.spec.fractions_per_chip
    if tp > 1 or units == F:
        hint = (f"needs {tp} fully-free chip(s) inside one hb domain "
                f"(domain size {cluster.spec.hb_domain_size}); free a "
                "domain, lower TP, or grant this workflow more chips")
    else:
        hint = (f"needs {units}/{F} free units on one chip owned by the "
                "same workflow; sub-chip replicas never span chips — "
                "use smaller fractions or more chips")
    if shape.get("chip_class"):
        hint += (f"; instance is bound to chip class "
                 f"{shape['chip_class']!r} — only hosts of that class "
                 "are candidates")
    return PlacementError("cannot place instance", shape=shape,
                          domain_capacity=cluster.domain_capacity(),
                          hint=hint)


def place(allocations: Dict[str, Allocation],
          spec: hw.ClusterSpec) -> Placement:
    """Place one allocation map (single workflow or pooled tenant set)
    over the whole cluster; raises :class:`PlacementError` on failure."""
    placed, failed, cluster = _pack({None: allocations}, spec, record=True)
    if placed is None:
        raise _fail(failed, cluster)
    placement = Placement(spec, placed)
    placement.validate()
    return placement


def place_fleet(allocs_by_workflow: Dict[str, Dict[str, Allocation]],
                spec: hw.ClusterSpec) -> Placement:
    """Co-place a partitioned fleet in ONE pass over the real topology.

    Every workflow's replicas compete for the same hosts/domains under
    the hierarchical heuristic (largest TP shapes first, fleet-wide);
    chip ownership stays exclusive per workflow, but slices are neither
    contiguous nor hb-domain-aligned — tail chips and odd-sized
    leftovers are all usable.  Instances are keyed ``<workflow>/<llm>``,
    matching what :func:`migration_diff` and the replan ladder expect.
    """
    placed, failed, cluster = _pack(dict(allocs_by_workflow), spec,
                                    record=True)
    if placed is None:
        raise _fail(failed, cluster)
    placement = Placement(spec, placed)
    placement.validate()
    return placement


def fleet_feasibility(allocs_by_workflow: Dict[str, Dict[str, Allocation]],
                      spec: hw.ClusterSpec) -> FeasibilityResult:
    """The split search's placement probe: ``(ok, fragmentation_cost)``.

    Runs the exact packing :func:`place_fleet` would run — so ``ok``
    really means the split deploys — but materializes no instances or
    manifest.  Cost is O(instances × domains) for whole-chip/TP shapes
    (per-domain free counters are maintained incrementally) plus a
    partial-chip scan per sub-chip fraction."""
    placed, failed, cluster = _pack(dict(allocs_by_workflow), spec,
                                    record=False)
    return FeasibilityResult(ok=failed is None,
                             fragmentation=cluster.fragmentation(),
                             failed_shape=failed)


def feasibility(allocations: Dict[str, Allocation],
                spec: hw.ClusterSpec) -> FeasibilityResult:
    """Single-group probe (one workflow, or a pooled tenant set)."""
    return fleet_feasibility({None: allocations}, spec)  # type: ignore[dict-item]


def _place_whole(cluster: _Cluster, tp: int,
                 chip_class: Optional[str] = None) -> Optional[List[Chip]]:
    """Place a tp-chip instance inside one hb domain (fully-free chips).

    Candidate domains are ranked fill-before-spill (hosts already in use
    first), then best-fit (tightest free-chip count), then least
    remaining capacity, then domain id.  Runs off the cluster's
    incrementally-maintained per-domain counters: O(domains) per call
    plus one scan of the winning domain.  ``chip_class`` restricts
    candidates to domains of that class (a domain never spans classes,
    so the first chip's class speaks for the domain)."""
    F = cluster.spec.fractions_per_chip
    best = None
    for dom, chips in cluster.domain_map.items():
        if chip_class is not None and chips[0].chip_class != chip_class:
            continue
        n_free = cluster.dom_free_chips[dom]
        if n_free < tp:
            continue
        spill = 0 if chips[0].host in cluster.busy_hosts else 1
        key = (spill, n_free - tp, cluster.dom_free_units[dom], dom)
        if best is None or key < best:
            best = key
    if best is None:
        return None
    free = [c for c in cluster.domain_map[best[3]] if c.free_units == F]
    return free[:tp]


def _place_fraction(cluster: _Cluster, units: int,
                    owner: Optional[str] = None,
                    chip_class: Optional[str] = None
                    ) -> Optional[List[Chip]]:
    """Best-fit a sub-chip fraction; prefer already-occupied chips of
    the same owner (exclusive chip ownership keeps partitioned fleets'
    chip sets disjoint).  ``chip_class`` restricts candidates to chips
    of that class."""
    F = cluster.spec.fractions_per_chip
    partial = [c for c in cluster.chips
               if 0 < c.free_units < F and c.free_units >= units
               and c.owner == owner
               and (chip_class is None or c.chip_class == chip_class)]
    if partial:
        partial.sort(key=lambda c: (c.free_units, c.index))  # tightest fit
        return [partial[0]]
    # open a fresh chip: fill-before-spill, then least-capacity domain
    best = None
    for dom, chips in cluster.domain_map.items():
        if chip_class is not None and chips[0].chip_class != chip_class:
            continue
        if cluster.dom_free_chips[dom] == 0:
            continue
        spill = 0 if chips[0].host in cluster.busy_hosts else 1
        key = (spill, cluster.dom_free_units[dom], dom)
        if best is None or key < best:
            best = key
    if best is None:
        return None
    return [next(c for c in cluster.domain_map[best[2]]
                 if c.free_units == F)]


def fleet_offsets(placements: Dict[str, Placement], order,
                  spec: hw.ClusterSpec) -> Dict[str, int]:
    """Disjoint physical slice starts for per-workflow slice-local
    placements — the LEGACY contiguous-slice fleet model.

    Superseded by :func:`place_fleet` (true co-placement, no alignment
    padding); kept as the placement-blind baseline the placement
    benchmark compares against.  A slice start is hb-domain-aligned only
    when the slice contains TP groups (a TP instance must not cross a
    domain boundary after translation); TP=1 slices can start anywhere.
    Raises :class:`PlacementError` when the slices do not fit the
    cluster.
    """
    dom = spec.hb_domain_size
    offsets: Dict[str, int] = {}
    cursor = 0
    for name in order:
        insts = placements[name].instances
        used = 1 + max((c for inst in insts for c in inst.chips), default=0)
        if any(inst.tp > 1 for inst in insts):
            cursor = (cursor + dom - 1) // dom * dom
        offsets[name] = cursor
        cursor += used
    if cursor > spec.num_chips:
        raise PlacementError(
            f"fleet needs {cursor} chips for disjoint contiguous slices, "
            f"cluster has {spec.num_chips}",
            hint="contiguous slices waste chips on hb-domain alignment; "
                 "co-place with place_fleet instead")
    return offsets


def merge_fleet(placements: Dict[str, Placement], offsets: Dict[str, int],
                spec: hw.ClusterSpec) -> Placement:
    """One global :class:`Placement` for a partitioned fleet (legacy
    contiguous-slice model; see :func:`place_fleet`).

    Slice-local instances are translated by their workflow's offset and
    renamed ``<workflow>/<llm>`` so instance keys — and therefore
    :func:`migration_diff` — are unambiguous fleet-wide.
    """
    import dataclasses as dc

    out = Placement(spec)
    for name, pl in placements.items():
        off = offsets[name]
        for inst in pl.instances:
            chips = [c + off for c in inst.chips]
            out.instances.append(dc.replace(
                inst, llm=f"{name}/{inst.llm}", chips=chips,
                host=chips[0] // spec.chips_per_host,
                domain=chips[0] // spec.hb_domain_size))
    return out


def split_fleet(placement: Placement
                ) -> Dict[str, Placement]:
    """Per-workflow views of a co-placed fleet (inverse of the
    ``<workflow>/<llm>`` keying).  Chip ids stay GLOBAL — a view is the
    workflow's slice of the real cluster, not a renumbered sub-cluster —
    and each view's ``spec`` is still the full cluster, so per-workflow
    fragmentation must be read with ``fragmentation(scope="touched")``
    (the cluster-scope default would count other workflows' chips as
    free capacity).
    """
    import dataclasses as dc

    out: Dict[str, Placement] = {}
    for inst in placement.instances:
        wf, _, llm = inst.llm.partition("/")
        out.setdefault(wf, Placement(placement.spec)).instances.append(
            dc.replace(inst, llm=llm))
    return out


def tenant_routing(placement: Placement,
                   members: Dict[str, List[Tuple[str, str]]],
                   weights: Dict[str, Dict[str, Dict[int, float]]]
                   ) -> Dict[str, Dict[str, Dict[str, float]]]:
    """Routing tables into a pooled placement, keyed by instance name.

    A pooled fleet gets ONE physical placement (the tenants' shared
    replica sets); instead of a private chip offset, every workflow
    receives a table ``local llm name -> {placed instance -> weight}``.
    ``members`` maps canonical model id -> [(workflow, local name)] and
    ``weights`` is the scheduler's replica-indexed routing
    (:meth:`MergedPipeline.routing_weights`); weights per (workflow,
    llm) sum to 1.
    """
    by_tenant: Dict[str, List[PlacedInstance]] = {}
    for inst in placement.instances:
        by_tenant.setdefault(inst.llm, []).append(inst)
    out: Dict[str, Dict[str, Dict[str, float]]] = {}
    for cid, mem in members.items():
        insts = sorted(by_tenant.get(cid, []), key=lambda i: i.replica)
        for workflow, llm in mem:
            w = weights.get(workflow, {}).get(llm, {})
            table = {f"{i.llm}-r{i.replica}": w.get(i.replica, 0.0)
                     for i in insts}
            total = sum(table.values())
            if total > 0:
                table = {k: v / total for k, v in table.items()}
            out.setdefault(workflow, {})[llm] = table
    return out


@dataclass
class MigrationDiff:
    """What a re-placement actually changes, instance by instance.

    A full re-plan hands the operator this diff — chips to move, replicas
    to add or drop — rather than a from-scratch manifest, so a rung-3
    drift reaction is an *edit* to the running deployment.  An instance
    is keyed ``llm-r<replica>``; ``chip_loads`` counts the (instance,
    chip) assignments present in the new placement but not the old one —
    i.e. weight-loading events the migration must pay for.
    """

    added: List[str] = field(default_factory=list)
    dropped: List[str] = field(default_factory=list)
    moved: List[str] = field(default_factory=list)
    unchanged: List[str] = field(default_factory=list)
    chip_loads: int = 0

    @property
    def chips_moved(self) -> int:
        return self.chip_loads

    def summary(self) -> dict:
        return {
            "replicas_added": len(self.added),
            "replicas_dropped": len(self.dropped),
            "replicas_moved": len(self.moved),
            "replicas_unchanged": len(self.unchanged),
            "chips_moved": self.chip_loads,
        }


def migration_diff(old: Placement, new: Placement) -> MigrationDiff:
    """Instance-level diff between two placements of the same cluster."""
    def keyed(p: Placement) -> Dict[str, PlacedInstance]:
        return {f"{i.llm}-r{i.replica}": i for i in p.instances}

    a, b = keyed(old), keyed(new)
    diff = MigrationDiff()
    for name in sorted(set(a) | set(b)):
        if name not in a:
            diff.added.append(name)
            diff.chip_loads += len(b[name].chips)
        elif name not in b:
            diff.dropped.append(name)
        else:
            oi, ni = a[name], b[name]
            fresh = set(ni.chips) - set(oi.chips)
            if fresh or oi.units_per_chip != ni.units_per_chip \
                    or oi.tp != ni.tp:
                diff.moved.append(name)
                diff.chip_loads += len(fresh) if oi.tp == ni.tp else \
                    len(ni.chips)
            else:
                diff.unchanged.append(name)
    return diff


def save_deployment(placement: Placement, path: str,
                    routing: Optional[dict] = None) -> None:
    with open(path, "w") as f:
        json.dump(placement.to_deployment(routing), f, indent=2)
