"""The Aggregate LLM Pipeline (paper §4 steps 4–5).

Synthesizes workflow statistics (n_m, p_m) and per-LLM throughput-latency
profiles into a pipeline of unique LLM stages, then predicts workflow-
level latency and throughput for a candidate GPU allocation:

    L_w(λ_w) = Σ_m L_m(λ_w · n_m / d_m ; TP_m, f_m) · n_m / p_m     (eq. 1)
    T_w      = min_m  d_m · T_m(TP_m, f_m) / n_m                    (eq. 2)

Prediction is profile lookups + arithmetic — negligible cost, which is
what lets the GPU scheduler explore large allocation spaces (§5).

Aggregate pipelines also compose *across* workflows: rate-weighted
aggregate shares over a shared LLM are still aggregate shares, so N
workflows' pipelines fuse into one tenant-tagged pipeline
(:func:`merge_pipelines`) whose stages are keyed by canonical model
identity rather than workflow-local stage names.  The merged pipeline
drives the pooled multi-tenant scheduling path, and per-workflow
latency/throughput is attributed back out of the shared allocation
(:meth:`MergedPipeline.attribute`).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.configs.base import ArchConfig
from repro.core.aggregate import WorkflowStats
from repro.core.profiler import LLMProfile


def canonical_llm_id(cfg: ArchConfig) -> str:
    """Pooling identity of an LLM: the architecture name.

    Workflow-local stage names ("map", "debater") are routing labels;
    two stages are servable by the same replicas iff they load the same
    weights, which ``ArchConfig.name`` identifies.
    """
    return cfg.name


@dataclass(frozen=True)
class Allocation:
    """Scheduler decision for one LLM.

    ``chip_class`` binds the replicas to one chip class on a
    heterogeneous cluster (``None`` = any / the uniform default class);
    placement refuses to put a class-bound instance on another class's
    chips.
    """

    replicas: int = 1
    tp: int = 1
    fraction: float = 1.0  # per-replica chip share (tp chips x fraction)
    chip_class: Optional[str] = None

    @property
    def chip_units(self) -> float:
        return self.replicas * self.tp * self.fraction


@dataclass
class PipelineStage:
    llm: str
    cfg: ArchConfig
    n: float  # invocations per workflow request
    p: float  # request-level parallelism
    profile: LLMProfile
    mean_share: float


@dataclass
class Prediction:
    latency: float  # workflow-level latency at λ_w (s)
    max_throughput: float  # workflow requests/s
    feasible: bool  # max_throughput >= λ_w and finite latency
    bottleneck_llm: str
    latency_dominant_llm: str
    per_llm_latency: Dict[str, float] = field(default_factory=dict)


class AggregateLLMPipeline:
    def __init__(self, workflow: str, stages: List[PipelineStage]):
        self.workflow = workflow
        self.stages = {s.llm: s for s in stages}

    @classmethod
    def synthesize(cls, stats: WorkflowStats,
                   profiles: Dict[str, LLMProfile],
                   cfgs: Dict[str, ArchConfig]) -> "AggregateLLMPipeline":
        stages = []
        for m, st in stats.per_llm.items():
            if st.n <= 0:
                continue
            stages.append(PipelineStage(
                llm=m, cfg=cfgs[m], n=st.n, p=st.p, profile=profiles[m],
                mean_share=st.mean_share))
        return cls(stats.workflow, stages)

    # ------------------------------------------------------------------
    # Predictions
    # ------------------------------------------------------------------

    def predict(self, alloc: Dict[str, Allocation], lam_w: float,
                percentile: str = "mean") -> Prediction:
        total_latency = 0.0
        per_llm: Dict[str, float] = {}
        t_w = math.inf
        bottleneck = ""
        dominant = ""
        dom_lat = -1.0
        for m, st in self.stages.items():
            a = alloc[m]
            per_replica_rate = lam_w * st.n / max(a.replicas, 1)
            lm = st.profile.latency(per_replica_rate, a.tp,
                                    fraction=a.fraction,
                                    percentile=percentile,
                                    chip_class=a.chip_class)
            contrib = lm * st.n / max(st.p, 1.0)
            per_llm[m] = contrib
            total_latency += contrib
            tm = (a.replicas * st.profile.max_throughput(
                      a.tp, fraction=a.fraction, chip_class=a.chip_class)
                  / st.n)
            if tm < t_w:
                t_w, bottleneck = tm, m
            if contrib > dom_lat:
                dom_lat, dominant = contrib, m
        feasible = t_w >= lam_w and math.isfinite(total_latency)
        return Prediction(latency=total_latency, max_throughput=t_w,
                          feasible=feasible, bottleneck_llm=bottleneck,
                          latency_dominant_llm=dominant,
                          per_llm_latency=per_llm)

    def latency_ratios(self, percentile: str = "mean") -> Dict[str, float]:
        """Low-load latency contribution shares (scheduler's pruning order)."""
        shares = {}
        for m, st in self.stages.items():
            tp0 = st.profile.tps()[0]
            rate = 0.05 * st.profile.max_throughput(tp0)
            lm = st.profile.latency(rate, tp0, percentile=percentile)
            shares[m] = lm * st.n / max(st.p, 1.0)
        total = sum(shares.values()) or 1.0
        return {m: v / total for m, v in shares.items()}

    def llms(self) -> List[str]:
        return list(self.stages)


# ---------------------------------------------------------------------------
# Cross-workflow pipeline merging (pooled multi-tenant allocation)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TenantMember:
    """One workflow's view of a shared LLM tenant."""

    workflow: str
    llm: str  # workflow-local stage name
    n: float
    p: float
    profile: LLMProfile
    lam: float  # workflow arrival-rate target (requests/s)

    @property
    def call_rate(self) -> float:
        """Offered call rate this member contributes to the tenant."""
        return self.lam * self.n


class MergedLLMProfile:
    """Rate-weighted mixture of per-workflow profiles of one model.

    Each member workflow profiled the same architecture on its own token
    distribution, so the mixture's capacity is the weighted *harmonic*
    mean of member capacities (a member call consumes 1/T_w replica-
    seconds on average), and latency maps across members at equal
    *utilization*: a replica at mixed load ρ serves member w's calls as
    if that member alone ran it at rate ρ·T_w.  With a single member
    both formulas reduce exactly to the member profile.
    """

    def __init__(self, llm: str, members: List[TenantMember]):
        if not members:
            raise ValueError("merged profile needs >= 1 member")
        self.llm = llm
        self.members = sorted(members, key=lambda m: (m.workflow, m.llm))
        total = sum(m.call_rate for m in self.members)
        if total <= 0:
            # no offered load: weight members equally
            self.phi = [1.0 / len(self.members)] * len(self.members)
        else:
            self.phi = [m.call_rate / total for m in self.members]
        common = set(self.members[0].profile.by_tp)
        for m in self.members[1:]:
            common &= set(m.profile.by_tp)
        if not common:
            raise ValueError(
                f"{llm}: member profiles share no TP degree")
        self.by_tp = {tp: tp for tp in sorted(common)}

    def tps(self, chip_class: Optional[str] = None) -> List[int]:
        return sorted(self.by_tp)

    def classes(self) -> List[str]:
        """Chip classes every member profiled (intersection)."""
        common = set(self.members[0].profile.classes())
        for m in self.members[1:]:
            common &= set(m.profile.classes())
        return sorted(common)

    def max_throughput(self, tp: int, *, fraction: float = 1.0,
                       chip_class: Optional[str] = None) -> float:
        inv = 0.0
        for phi, m in zip(self.phi, self.members):
            t = m.profile.max_throughput(tp, chip_class=chip_class)
            if t <= 0:
                return 0.0
            inv += phi / t
        return fraction / inv if inv > 0 else math.inf

    def member_latency(self, idx: int, rate: float, tp: int, *,
                       fraction: float = 1.0,
                       percentile: str = "mean",
                       chip_class: Optional[str] = None) -> float:
        """Latency of member ``idx``'s calls on a shared replica serving
        the whole mix at per-replica call rate ``rate``."""
        if fraction <= 0:
            return math.inf
        t_mix = self.max_throughput(tp, chip_class=chip_class)
        if not math.isfinite(t_mix) or t_mix <= 0:
            return math.inf
        rho = (rate / fraction) / t_mix
        m = self.members[idx]
        equiv = rho * m.profile.max_throughput(tp, chip_class=chip_class)
        return m.profile.latency(equiv * fraction, tp, fraction=fraction,
                                 percentile=percentile,
                                 chip_class=chip_class)

    def latency(self, rate: float, tp: int, *, fraction: float = 1.0,
                percentile: str = "mean",
                chip_class: Optional[str] = None) -> float:
        return sum(phi * self.member_latency(i, rate, tp, fraction=fraction,
                                             percentile=percentile,
                                             chip_class=chip_class)
                   for i, phi in enumerate(self.phi))


class MergedPipeline(AggregateLLMPipeline):
    """N workflows' pipelines fused into one tenant-tagged pipeline.

    Stages are keyed by canonical model identity; the stage-level
    (n, p) are rate-weighted so that, driven at the fleet arrival rate
    ``lam_total``, every stage sees exactly the sum of its members'
    offered call rates.  ``attribute`` maps a shared allocation back to
    per-workflow predictions.
    """

    def __init__(self, stages: List[PipelineStage],
                 tenants: Dict[str, List[TenantMember]],
                 lam_targets: Dict[str, float]):
        super().__init__("pooled", stages)
        self.tenants = tenants
        self.lam_targets = dict(lam_targets)
        self.lam_total = sum(lam_targets.values())

    def workflows(self) -> List[str]:
        return sorted(self.lam_targets)

    def shared_llms(self) -> Dict[str, List[TenantMember]]:
        """Tenants referenced by more than one workflow."""
        return {m: mem for m, mem in self.tenants.items()
                if len({t.workflow for t in mem}) > 1}

    def members_of(self, workflow: str) -> Dict[str, List[TenantMember]]:
        """Canonical id -> this workflow's member entries (a workflow may
        point several of its stages at the same model)."""
        out: Dict[str, List[TenantMember]] = {}
        for cid, mem in self.tenants.items():
            for t in mem:
                if t.workflow == workflow:
                    out.setdefault(cid, []).append(t)
        return out

    # -- per-workflow attribution ------------------------------------

    def attribute(self, alloc: Dict[str, Allocation],
                  percentile: str = "mean") -> Dict[str, Prediction]:
        """Per-workflow predicted latency/throughput under a shared
        allocation.

        Latency: each member's calls run on replicas loaded by the whole
        mix (utilization-mapped member latency), summed over the
        workflow's stages per eq. (1).  Throughput: the largest factor κ
        by which this workflow alone could scale before some tenant it
        uses saturates — spare tenant capacity is attributed to whoever
        asks for it, not split a priori.
        """
        out: Dict[str, Prediction] = {}
        # per-tenant utilization under the current mix
        rho: Dict[str, float] = {}
        rate: Dict[str, float] = {}
        for cid, mem in self.tenants.items():
            a = alloc[cid]
            prof: MergedLLMProfile = self.stages[cid].profile
            r = sum(t.call_rate for t in mem) / max(a.replicas, 1)
            rate[cid] = r
            cap = prof.max_throughput(a.tp, fraction=a.fraction,
                                      chip_class=a.chip_class)
            rho[cid] = math.inf if cap <= 0 else r / cap
        for w in self.workflows():
            lam_w = self.lam_targets[w]
            members = self.members_of(w)
            total_lat, per_llm = 0.0, {}
            dominant, dom_lat = "", -1.0
            t_w, bottleneck = math.inf, ""
            for cid, ts in members.items():
                a = alloc[cid]
                prof = self.stages[cid].profile
                for t in ts:
                    idx = prof.members.index(t)
                    lm = prof.member_latency(idx, rate[cid], a.tp,
                                             fraction=a.fraction,
                                             percentile=percentile,
                                             chip_class=a.chip_class)
                    contrib = lm * t.n / max(t.p, 1.0)
                    per_llm[t.llm] = contrib
                    total_lat += contrib
                    if contrib > dom_lat:
                        dom_lat, dominant = contrib, t.llm
                    # scaling headroom: κ = 1 + spare / own share of load
                    own = t.call_rate / max(a.replicas, 1)
                    cap = prof.max_throughput(a.tp, fraction=a.fraction,
                                              chip_class=a.chip_class)
                    spare = cap - rate[cid]
                    if own <= 0:
                        cap_w = math.inf
                    else:
                        cap_w = lam_w * (1.0 + spare / own)
                    if cap_w < t_w:
                        t_w, bottleneck = cap_w, t.llm
            feasible = (t_w >= lam_w and math.isfinite(total_lat)
                        and all(rho[cid] <= 1.0 + 1e-9 for cid in members))
            out[w] = Prediction(latency=total_lat, max_throughput=t_w,
                                feasible=feasible, bottleneck_llm=bottleneck,
                                latency_dominant_llm=dominant,
                                per_llm_latency=per_llm)
        return out

    # -- substitution feedback ---------------------------------------

    def with_substitution(self, rates: Dict[str, float]) -> "MergedPipeline":
        """Re-merge with observed just-in-time substitution rates.

        ``rates`` maps a canonical model id to the observed fraction of
        its calls the admission layer rerouted to the model's
        ``ArchConfig.substitute``.  Each affected tenant member's call
        volume is split: ``(1 - r)`` stays on the original tenant and
        ``r`` moves to the substitute tenant (labelled ``<stage>~sub``),
        so share attribution, pooled re-planning and routing-weight
        rebalances all see the real serving mix rather than the planned
        one.  Substitution only targets models already served in the
        fleet; rates for tenants whose substitute has no replicas (no
        stage in this pipeline) are ignored, mirroring the admission
        controller, which never substitutes toward a model with no
        routable replicas.
        """
        tenants: Dict[str, List[TenantMember]] = {
            cid: list(mem) for cid, mem in self.tenants.items()}
        cfgs = {cid: self.stages[cid].cfg for cid in self.stages}
        shares = {cid: self.stages[cid].mean_share for cid in self.stages}
        for cid in sorted(rates):
            r = min(max(rates[cid], 0.0), 1.0)
            if r <= 0 or cid not in self.tenants:
                continue
            sub = cfgs[cid].substitute
            if not sub or sub not in self.stages:
                continue
            sub_prof = self.stages[sub].profile
            moved: List[TenantMember] = []
            kept: List[TenantMember] = []
            for t in self.tenants[cid]:
                kept.append(dataclasses.replace(t, n=t.n * (1.0 - r)))
                moved.append(TenantMember(
                    workflow=t.workflow, llm=t.llm + "~sub",
                    n=t.n * r, p=t.p, profile=sub_prof, lam=t.lam))
            tenants[cid] = kept
            tenants[sub] = tenants.get(sub, []) + moved
        stages: List[PipelineStage] = []
        out_tenants: Dict[str, List[TenantMember]] = {}
        for cid in sorted(tenants):
            mem = sorted([t for t in tenants[cid] if t.n > 0],
                         key=lambda t: (t.workflow, t.llm))
            if not mem:
                continue
            prof = MergedLLMProfile(cid, mem)
            total_rate = sum(t.call_rate for t in mem)
            n_eff = (total_rate / self.lam_total if self.lam_total > 0
                     else sum(t.n for t in mem))
            np_eff = sum((t.lam / self.lam_total if self.lam_total > 0
                          else 1.0 / len(mem))
                         * t.n / max(t.p, 1.0) for t in mem)
            p_eff = n_eff / np_eff if np_eff > 0 else 1.0
            stages.append(PipelineStage(
                llm=cid, cfg=cfgs[cid], n=n_eff, p=p_eff, profile=prof,
                mean_share=shares[cid]))
            out_tenants[cid] = mem
        return MergedPipeline(stages, out_tenants, self.lam_targets)

    def routing_weights(self, alloc: Dict[str, Allocation], *,
                        policy: str = "uniform"
                        ) -> Dict[str, Dict[str, Dict[int, float]]]:
        """workflow -> local llm name -> replica index -> weight.

        Weights per (workflow, llm) sum to 1.  This is the routing table
        deploy_multi hands each workflow instead of a private chip
        offset.

        ``policy="uniform"``: pooled replicas of a tenant are identical,
        so every workflow spreads its calls evenly over all of them.

        ``policy="partition"``: each member owns a contiguous,
        load-proportional *block* of the replica set (member i with
        call-rate share φ_i covers the interval [Σ_{j<i} φ_j·d,
        Σ_{j<=i} φ_j·d) of the d replicas; a replica straddling a block
        boundary is shared pro rata).  Concentrating a workflow on few
        replicas improves KV/prefix affinity and isolates tenants — and
        because the blocks are a pure function of the current rate mix,
        re-deriving them IS the rung-1 drift reaction: re-balance with
        no re-placement.
        """
        if policy not in ("uniform", "partition"):
            raise ValueError(f"unknown routing policy {policy!r}")
        out: Dict[str, Dict[str, Dict[int, float]]] = {}
        for cid, mem in self.tenants.items():
            d = max(alloc[cid].replicas, 1)
            if policy == "uniform":
                for t in mem:
                    out.setdefault(t.workflow, {})[t.llm] = {
                        r: 1.0 / d for r in range(d)}
                continue
            prof: MergedLLMProfile = self.stages[cid].profile
            cursor = 0.0
            for phi, t in zip(prof.phi, prof.members):
                span = phi * d
                lo, hi = cursor, cursor + span
                cursor = hi
                w: Dict[int, float] = {}
                for r in range(d):
                    overlap = min(hi, r + 1) - max(lo, r)
                    if overlap > 1e-12:
                        w[r] = overlap / max(span, 1e-12)
                if not w:  # zero-rate member: park it on its block start
                    w = {min(int(lo), d - 1): 1.0}
                out.setdefault(t.workflow, {})[t.llm] = w
        return out


def merge_pipelines(pipelines: Dict[str, AggregateLLMPipeline],
                    lam_targets: Dict[str, float]) -> MergedPipeline:
    """Fuse N workflows' aggregate pipelines into one tenant-tagged
    pipeline, rate-weighting the shares of LLMs that appear in several
    workflows (keyed by canonical model identity).

    The result is order-invariant in ``pipelines``: tenants are keyed by
    canonical id and members sorted by (workflow, stage name).
    """
    missing = [w for w in pipelines if w not in lam_targets]
    if missing:
        raise ValueError(f"no arrival-rate target for workflows {missing}")
    tenants: Dict[str, List[TenantMember]] = {}
    cfgs: Dict[str, ArchConfig] = {}
    shares: Dict[str, float] = {}
    for w in sorted(pipelines):
        pipe = pipelines[w]
        for llm, st in pipe.stages.items():
            cid = canonical_llm_id(st.cfg)
            tenants.setdefault(cid, []).append(TenantMember(
                workflow=w, llm=llm, n=st.n, p=st.p, profile=st.profile,
                lam=lam_targets[w]))
            cfgs[cid] = st.cfg
            shares[cid] = shares.get(cid, 0.0) + st.mean_share * lam_targets[w]
    lam_total = sum(lam_targets[w] for w in pipelines)
    stages: List[PipelineStage] = []
    for cid in sorted(tenants):
        mem = sorted(tenants[cid], key=lambda t: (t.workflow, t.llm))
        tenants[cid] = mem
        prof = MergedLLMProfile(cid, mem)
        total_rate = sum(t.call_rate for t in mem)
        # n such that lam_total * n == the tenant's total offered call
        # rate; p such that n/p matches the rate-weighted mean latency
        # multiplier of the members (predict()'s contribution weight)
        n_eff = total_rate / lam_total if lam_total > 0 else \
            sum(t.n for t in mem)
        np_eff = sum((t.lam / lam_total if lam_total > 0 else 1.0 / len(mem))
                     * t.n / max(t.p, 1.0) for t in mem)
        p_eff = n_eff / np_eff if np_eff > 0 else 1.0
        stages.append(PipelineStage(
            llm=cid, cfg=cfgs[cid], n=n_eff, p=p_eff, profile=prof,
            mean_share=shares[cid] / (lam_total or 1.0)))
    return MergedPipeline(stages, tenants, lam_targets)
