"""The Aggregate LLM Pipeline (paper §4 steps 4–5).

Synthesizes workflow statistics (n_m, p_m) and per-LLM throughput-latency
profiles into a pipeline of unique LLM stages, then predicts workflow-
level latency and throughput for a candidate GPU allocation:

    L_w(λ_w) = Σ_m L_m(λ_w · n_m / d_m ; TP_m, f_m) · n_m / p_m     (eq. 1)
    T_w      = min_m  d_m · T_m(TP_m, f_m) / n_m                    (eq. 2)

Prediction is profile lookups + arithmetic — negligible cost, which is
what lets the GPU scheduler explore large allocation spaces (§5).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.configs.base import ArchConfig
from repro.core.aggregate import WorkflowStats
from repro.core.profiler import LLMProfile


@dataclass(frozen=True)
class Allocation:
    """Scheduler decision for one LLM."""

    replicas: int = 1
    tp: int = 1
    fraction: float = 1.0  # per-replica chip share (tp chips x fraction)

    @property
    def chip_units(self) -> float:
        return self.replicas * self.tp * self.fraction


@dataclass
class PipelineStage:
    llm: str
    cfg: ArchConfig
    n: float  # invocations per workflow request
    p: float  # request-level parallelism
    profile: LLMProfile
    mean_share: float


@dataclass
class Prediction:
    latency: float  # workflow-level latency at λ_w (s)
    max_throughput: float  # workflow requests/s
    feasible: bool  # max_throughput >= λ_w and finite latency
    bottleneck_llm: str
    latency_dominant_llm: str
    per_llm_latency: Dict[str, float] = field(default_factory=dict)


class AggregateLLMPipeline:
    def __init__(self, workflow: str, stages: List[PipelineStage]):
        self.workflow = workflow
        self.stages = {s.llm: s for s in stages}

    @classmethod
    def synthesize(cls, stats: WorkflowStats,
                   profiles: Dict[str, LLMProfile],
                   cfgs: Dict[str, ArchConfig]) -> "AggregateLLMPipeline":
        stages = []
        for m, st in stats.per_llm.items():
            if st.n <= 0:
                continue
            stages.append(PipelineStage(
                llm=m, cfg=cfgs[m], n=st.n, p=st.p, profile=profiles[m],
                mean_share=st.mean_share))
        return cls(stats.workflow, stages)

    # ------------------------------------------------------------------
    # Predictions
    # ------------------------------------------------------------------

    def predict(self, alloc: Dict[str, Allocation], lam_w: float,
                percentile: str = "mean") -> Prediction:
        total_latency = 0.0
        per_llm: Dict[str, float] = {}
        t_w = math.inf
        bottleneck = ""
        dominant = ""
        dom_lat = -1.0
        for m, st in self.stages.items():
            a = alloc[m]
            per_replica_rate = lam_w * st.n / max(a.replicas, 1)
            lm = st.profile.latency(per_replica_rate, a.tp,
                                    fraction=a.fraction,
                                    percentile=percentile)
            contrib = lm * st.n / max(st.p, 1.0)
            per_llm[m] = contrib
            total_latency += contrib
            tm = (a.replicas * st.profile.max_throughput(a.tp,
                                                         fraction=a.fraction)
                  / st.n)
            if tm < t_w:
                t_w, bottleneck = tm, m
            if contrib > dom_lat:
                dom_lat, dominant = contrib, m
        feasible = t_w >= lam_w and math.isfinite(total_latency)
        return Prediction(latency=total_latency, max_throughput=t_w,
                          feasible=feasible, bottleneck_llm=bottleneck,
                          latency_dominant_llm=dominant,
                          per_llm_latency=per_llm)

    def latency_ratios(self, percentile: str = "mean") -> Dict[str, float]:
        """Low-load latency contribution shares (scheduler's pruning order)."""
        shares = {}
        for m, st in self.stages.items():
            tp0 = st.profile.tps()[0]
            rate = 0.05 * st.profile.max_throughput(tp0)
            lm = st.profile.latency(rate, tp0, percentile=percentile)
            shares[m] = lm * st.n / max(st.p, 1.0)
        total = sum(shares.values()) or 1.0
        return {m: v / total for m, v in shares.items()}

    def llms(self) -> List[str]:
        return list(self.stages)
