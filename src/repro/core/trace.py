"""Execution traces of agentic workflows (paper §4 step 1).

Scepsy is framework-agnostic: it never sees the workflow program, only the
LLM-level requests captured by a proxy in front of each engine's
completions API.  Here the proxy is :class:`TracingProxy`, which the
workflow runtime routes every LLM call through; each call records request
content sizes, start/end timestamps and the workflow-request id — exactly
the telemetry the paper's HTTP proxy captures.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Dict, List


@dataclass
class LLMCall:
    workflow_request: int
    llm: str
    t_start: float
    t_end: float
    prompt_tokens: int
    output_tokens: int
    cached_prefix_tokens: int = 0

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start


@dataclass
class WorkflowTrace:
    """All LLM-level calls of one workflow-level request."""

    request_id: int
    workflow: str
    t_start: float
    t_end: float
    calls: List[LLMCall] = field(default_factory=list)

    @property
    def latency(self) -> float:
        return self.t_end - self.t_start

    def calls_for(self, llm: str) -> List[LLMCall]:
        return [c for c in self.calls if c.llm == llm]

    def llms(self) -> List[str]:
        seen: Dict[str, None] = {}
        for c in self.calls:
            seen.setdefault(c.llm, None)
        return list(seen)


@dataclass
class TraceStore:
    workflow: str
    traces: List[WorkflowTrace] = field(default_factory=list)

    def llms(self) -> List[str]:
        seen: Dict[str, None] = {}
        for t in self.traces:
            for name in t.llms():
                seen.setdefault(name, None)
        return list(seen)

    def all_calls(self, llm: str) -> List[LLMCall]:
        return [c for t in self.traces for c in t.calls if c.llm == llm]

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"workflow": self.workflow,
                       "traces": [asdict(t) for t in self.traces]}, f)

    @classmethod
    def load(cls, path: str) -> "TraceStore":
        with open(path) as f:
            raw = json.load(f)
        traces = []
        for t in raw["traces"]:
            calls = [LLMCall(**c) for c in t.pop("calls")]
            traces.append(WorkflowTrace(calls=calls, **t))
        return cls(workflow=raw["workflow"], traces=traces)


class TracingProxy:
    """Engine-front proxy: records every LLM-level request.

    The workflow runtime calls :meth:`record` with simulated-clock
    timestamps; the proxy neither sees nor needs the workflow definition
    (unrestricted programming model, Tab. 1).
    """

    def __init__(self, workflow: str):
        self.store = TraceStore(workflow=workflow)
        self._open: Dict[int, WorkflowTrace] = {}

    def begin_request(self, request_id: int, t: float) -> None:
        self._open[request_id] = WorkflowTrace(
            request_id=request_id, workflow=self.store.workflow,
            t_start=t, t_end=t)

    def record(self, call: LLMCall) -> None:
        tr = self._open[call.workflow_request]
        tr.calls.append(call)
        tr.t_end = max(tr.t_end, call.t_end)

    def end_request(self, request_id: int, t: float) -> None:
        tr = self._open.pop(request_id)
        tr.t_end = max(tr.t_end, t)
        self.store.traces.append(tr)
