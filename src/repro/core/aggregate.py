"""Statistical workflow aggregation (paper §4 step 2).

For every LLM *m* in a trace set, extract:
  * ``n_m`` — average number of invocations per workflow request;
  * ``p_m`` — average request-level parallelism: busy time divided by the
    union (sweep-line merged) time of m's call intervals within a request;
  * relative execution-time shares — the stability observation (§2.4,
    Fig. 3) that motivates the whole system.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

from repro.core.trace import LLMCall, TraceStore


def merged_busy_time(intervals: Sequence[Tuple[float, float]]) -> float:
    """Total length of the union of intervals (sweep-line)."""
    if not intervals:
        return 0.0
    out = 0.0
    cur_s, cur_e = None, None
    for s, e in sorted(intervals):
        if cur_s is None:
            cur_s, cur_e = s, e
        elif s <= cur_e:
            cur_e = max(cur_e, e)
        else:
            out += cur_e - cur_s
            cur_s, cur_e = s, e
    out += cur_e - cur_s
    return out


def request_parallelism(calls: Sequence[LLMCall]) -> float:
    """Average number of concurrently-running calls (busy / union)."""
    busy = sum(c.duration for c in calls)
    union = merged_busy_time([(c.t_start, c.t_end) for c in calls])
    if union <= 0:
        return 1.0
    return max(busy / union, 1.0)


@dataclass
class LLMStats:
    llm: str
    n: float  # avg invocations per workflow request
    p: float  # avg request-level parallelism
    mean_prompt_tokens: float
    mean_output_tokens: float
    mean_share: float  # fraction of per-request total LLM time
    share_cov: float  # coefficient of variation of the share (stability)
    abs_cov: float  # coefficient of variation of absolute time


@dataclass
class WorkflowStats:
    workflow: str
    num_traces: int
    per_llm: Dict[str, LLMStats]
    mean_latency: float
    latency_cov: float

    def latency_ratio_order(self) -> List[str]:
        """LLMs ordered by descending latency contribution (scheduler prune)."""
        return sorted(self.per_llm,
                      key=lambda m: -self.per_llm[m].mean_share)


def _cov(xs: List[float]) -> float:
    if len(xs) < 2:
        return 0.0
    mu = sum(xs) / len(xs)
    if mu == 0:
        return 0.0
    var = sum((x - mu) ** 2 for x in xs) / (len(xs) - 1)
    return math.sqrt(var) / mu


def aggregate(store: TraceStore) -> WorkflowStats:
    llms = store.llms()
    per_llm: Dict[str, LLMStats] = {}
    latencies = [t.latency for t in store.traces]
    ntr = len(store.traces)

    for m in llms:
        counts, paras, shares, abs_times = [], [], [], []
        prompts, outs = [], []
        for tr in store.traces:
            calls = tr.calls_for(m)
            counts.append(len(calls))
            if calls:
                paras.append(request_parallelism(calls))
                total_m = sum(c.duration for c in calls)
                total_all = sum(c.duration for c in tr.calls)
                abs_times.append(total_m)
                if total_all > 0:
                    shares.append(total_m / total_all)
                prompts.extend(c.prompt_tokens for c in calls)
                outs.extend(c.output_tokens for c in calls)
        per_llm[m] = LLMStats(
            llm=m,
            n=sum(counts) / max(ntr, 1),
            p=sum(paras) / max(len(paras), 1) if paras else 1.0,
            mean_prompt_tokens=sum(prompts) / max(len(prompts), 1),
            mean_output_tokens=sum(outs) / max(len(outs), 1),
            mean_share=sum(shares) / max(len(shares), 1) if shares else 0.0,
            share_cov=_cov(shares),
            abs_cov=_cov(abs_times),
        )
    return WorkflowStats(
        workflow=store.workflow,
        num_traces=ntr,
        per_llm=per_llm,
        mean_latency=sum(latencies) / max(ntr, 1),
        latency_cov=_cov(latencies),
    )
