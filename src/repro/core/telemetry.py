"""O(1)-memory telemetry for million-request runs.

Three pieces (see ``docs/scale.md``):

* :class:`GKQuantile` — a Greenwald–Khanna streaming quantile sketch:
  ``query(q)`` returns a value whose rank in the stream is within
  ``eps * n`` of ``q * n``, holding O((1/eps) log(eps n)) tuples
  instead of the stream.  The hard rank-error bound (unlike p²'s
  heuristic estimate) is what the hypothesis property test checks.
* :class:`StatsSink` — the driver-facing aggregate: per-workflow
  counters, an in-flight gauge with a high-water mark, latency
  sketches, and a bounded ring of recent samples.  It replaces the
  unbounded ``ClusterDriver.records`` list when a driver is built with
  ``sink=``; exact-record mode stays the default for tests/benches.
* :class:`BatchedTelemetry` — wraps a telemetry consumer (e.g. a
  :class:`repro.core.drift.DriftMonitor`) and replays buffered events
  once per flush interval of *simulated* time rather than per call.
  Events are replayed in arrival order and every ``poll`` flushes
  first, so poll-time results are identical to unbatched ingestion.
"""
from __future__ import annotations

import math
from bisect import insort
from collections import deque
from typing import Deque, Dict, List, Tuple


class GKQuantile:
    """Greenwald–Khanna ε-approximate streaming quantiles.

    Invariant: for every tracked tuple ``(v, g, Δ)``, the rank of ``v``
    lies in ``[rmin, rmin + Δ]`` where ``rmin`` is the running sum of
    ``g``; compression merges neighbours while ``g_i + g_{i+1} + Δ_{i+1}
    <= 2 ε n``, which caps both memory and the answer's rank error at
    ``ε n``.
    """

    def __init__(self, eps: float = 0.005):
        if not 0 < eps < 0.5:
            raise ValueError(f"eps must be in (0, 0.5), got {eps}")
        self.eps = eps
        self.n = 0
        # sorted by value; each entry is (v, g, delta)
        self._entries: List[Tuple[float, int, int]] = []
        self._compress_every = max(int(1.0 / (2.0 * eps)), 1)
        self._since_compress = 0

    def add(self, v: float) -> None:
        self.n += 1
        entries = self._entries
        if not entries:
            entries.append((v, 1, 0))
            return
        # min/max observations must be exact (delta = 0 at the ends)
        if v < entries[0][0]:
            entries.insert(0, (v, 1, 0))
        elif v >= entries[-1][0]:
            entries.append((v, 1, 0))
        else:
            delta = max(int(2 * self.eps * self.n) - 1, 0)
            insort(entries, (v, 1, delta))
        self._since_compress += 1
        if self._since_compress >= self._compress_every:
            self._since_compress = 0
            self._compress()

    def _compress(self) -> None:
        entries = self._entries
        if len(entries) < 3:
            return
        cap = int(2 * self.eps * self.n)
        out = [entries[0]]
        for v, g, d in entries[1:-1]:
            pv, pg, pd = out[-1]
            # merge the previous tuple into this one when safe (never
            # the first entry: the stream minimum stays exact)
            if len(out) > 1 and pg + g + d <= cap:
                out[-1] = (v, pg + g, d)
            else:
                out.append((v, g, d))
        out.append(entries[-1])
        self._entries = out

    def query(self, q: float) -> float:
        """A value whose stream rank is within ``eps*n`` of ``q*n``."""
        if not self._entries:
            return math.nan
        q = min(max(q, 0.0), 1.0)
        target = q * self.n
        margin = self.eps * self.n
        cum = 0
        prev_v = self._entries[0][0]
        for v, g, d in self._entries:
            if cum + g + d > target + margin:
                return prev_v
            cum += g
            prev_v = v
        return self._entries[-1][0]

    def __len__(self) -> int:
        """Tuples held (the memory footprint), not stream length."""
        return len(self._entries)


class _WorkflowStats:
    __slots__ = ("arrived", "completed", "rejected", "degraded", "slo_met",
                 "inflight", "peak_inflight", "lat_sum", "lat_min",
                 "lat_max", "sketch", "recent")

    def __init__(self, eps: float, ring: int):
        self.arrived = 0
        self.completed = 0
        self.rejected = 0
        self.degraded = 0
        self.slo_met = 0
        self.inflight = 0
        self.peak_inflight = 0
        self.lat_sum = 0.0
        self.lat_min = math.inf
        self.lat_max = 0.0
        self.sketch = GKQuantile(eps)
        self.recent: Deque[Tuple[float, float]] = deque(maxlen=ring)


class StatsSink:
    """Aggregate request telemetry with O(in-flight) memory.

    The driver calls ``observe_arrival`` / ``observe_reject`` /
    ``observe_degrade`` / ``observe`` (completion); readers use
    ``latency_quantile``, ``peak_inflight`` and ``summary()``.
    """

    def __init__(self, *, eps: float = 0.005, ring: int = 1024):
        self.eps = eps
        self.ring = ring
        self.stats: Dict[str, _WorkflowStats] = {}
        self.inflight = 0
        self.peak_inflight = 0

    def _wf(self, name: str) -> _WorkflowStats:
        s = self.stats.get(name)
        if s is None:
            s = self.stats[name] = _WorkflowStats(self.eps, self.ring)
        return s

    def observe_arrival(self, name: str, t: float) -> None:
        s = self._wf(name)
        s.arrived += 1
        s.inflight += 1
        if s.inflight > s.peak_inflight:
            s.peak_inflight = s.inflight
        self.inflight += 1
        if self.inflight > self.peak_inflight:
            self.peak_inflight = self.inflight

    def observe_reject(self, name: str) -> None:
        s = self._wf(name)
        s.rejected += 1
        s.inflight -= 1
        self.inflight -= 1

    def observe_degrade(self, name: str) -> None:
        self._wf(name).degraded += 1

    def observe(self, name: str, rec) -> None:
        """A completed workflow request (rec: RequestRecord-like)."""
        s = self._wf(name)
        s.completed += 1
        s.inflight -= 1
        self.inflight -= 1
        if getattr(rec, "slo_met", True):
            s.slo_met += 1
        lat = rec.done - rec.arrival
        s.lat_sum += lat
        if lat < s.lat_min:
            s.lat_min = lat
        if lat > s.lat_max:
            s.lat_max = lat
        s.sketch.add(lat)
        s.recent.append((rec.done, lat))

    # -- readers -----------------------------------------------------------

    def latency_quantile(self, name: str, q: float) -> float:
        s = self.stats.get(name)
        return s.sketch.query(q) if s else math.nan

    def summary(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        for name, s in self.stats.items():
            out[name] = {
                "arrived": s.arrived,
                "completed": s.completed,
                "rejected": s.rejected,
                "degraded": s.degraded,
                "slo_met": s.slo_met,
                "peak_inflight": s.peak_inflight,
                "latency_mean": (s.lat_sum / s.completed
                                 if s.completed else math.nan),
                "latency_min": s.lat_min if s.completed else math.nan,
                "latency_max": s.lat_max if s.completed else math.nan,
                "latency_p50": (s.sketch.query(0.50)
                                if s.completed else math.nan),
                "latency_p99": (s.sketch.query(0.99)
                                if s.completed else math.nan),
            }
        return out


class BatchedTelemetry:
    """Buffer driver telemetry and replay it into ``monitor`` once per
    ``flush_s`` of simulated time (``loop.now``), in arrival order.

    The driver's per-call overhead drops to one list append; because
    :meth:`poll` flushes first, anything the monitor computes at poll
    time (drift detection, rate estimates) sees exactly the events an
    unbatched monitor would have seen.
    """

    def __init__(self, monitor, loop, *, flush_s: float = 1.0):
        self.monitor = monitor
        self.loop = loop
        self.flush_s = flush_s
        self._buf: List[Tuple[str, tuple]] = []
        self._next_flush = flush_s
        self.flushes = 0

    # -- telemetry protocol (duck-typed, same as DriftMonitor) -------------

    def record_arrival(self, name: str, t: float) -> None:
        self._record("record_arrival", (name, t))

    def record_call(self, name: str, llm: str, req) -> None:
        self._record("record_call", (name, llm, req))

    def record_request_done(self, name: str, rec) -> None:
        self._record("record_request_done", (name, rec))

    def record_shed(self, name: str, slo: str, action: str, t: float) -> None:
        if hasattr(self.monitor, "record_shed"):
            self._record("record_shed", (name, slo, action, t))

    def _record(self, kind: str, args: tuple) -> None:
        self._buf.append((kind, args))
        if self.loop.now >= self._next_flush:
            self.flush()

    def flush(self) -> None:
        if self._buf:
            mon = self.monitor
            for kind, args in self._buf:
                getattr(mon, kind)(*args)
            self._buf.clear()
            self.flushes += 1
        self._next_flush = self.loop.now + self.flush_s

    # -- pass-through ------------------------------------------------------

    def poll(self, *args, **kwargs):
        self.flush()
        return self.monitor.poll(*args, **kwargs)

    def __getattr__(self, item):
        return getattr(self.monitor, item)
