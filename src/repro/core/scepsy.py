"""Scepsy facade: trace -> aggregate -> profile -> pipeline -> schedule ->
place (paper Fig. 2 end-to-end flow)."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

from repro import hw
from repro.core.aggregate import WorkflowStats, aggregate
from repro.core.pipeline import AggregateLLMPipeline
from repro.core.placement import Placement, place
from repro.core.profiler import LLMProfile, profile_llm
from repro.core.scheduler import (ScheduleResult, SchedulerConfig,
                                  MultiScheduleResult, schedule,
                                  schedule_multi)
from repro.core.trace import TraceStore
from repro.workflows.runtime import Workflow, trace_workflow


@dataclass
class ScepsyDeployment:
    workflow: str
    stats: WorkflowStats
    pipeline: AggregateLLMPipeline
    schedule: ScheduleResult
    placement: Placement


def build_pipeline(wf: Workflow, *, n_trace_requests: int = 60,
                   tp_degrees: Sequence[int] = (1, 2, 4), seed: int = 0,
                   max_profile_groups: int = 60,
                   store: Optional[TraceStore] = None
                   ) -> Tuple[AggregateLLMPipeline, WorkflowStats, TraceStore]:
    """Steps 1-4: trace the workflow, aggregate, profile, synthesize."""
    if store is None:
        store = trace_workflow(wf, n_trace_requests, seed=seed)
    stats = aggregate(store)
    profiles: Dict[str, LLMProfile] = {}
    for m in stats.per_llm:
        cfg = wf.llms[m]
        tps = [t for t in tp_degrees]
        profiles[m] = profile_llm(cfg, store, m, tp_degrees=tps,
                                  max_groups=max_profile_groups, seed=seed)
    pipeline = AggregateLLMPipeline.synthesize(stats, profiles, wf.llms)
    return pipeline, stats, store


def _default_tp_degrees(spec: hw.ClusterSpec) -> list:
    """TP degrees to profile: 1/2/4 capped by the hb domain, plus the
    domain size itself (one grid for single-workflow and fleet deploys)."""
    return sorted({1, 2, min(4, spec.hb_domain_size), spec.hb_domain_size})


def deploy(wf: Workflow, spec: hw.ClusterSpec, lam_target: float, *,
           n_trace_requests: int = 60, seed: int = 0,
           scheduler_config: Optional[SchedulerConfig] = None,
           pipeline: Optional[AggregateLLMPipeline] = None
           ) -> ScepsyDeployment:
    """Full flow: returns the chosen allocation + concrete placement."""
    cfg = scheduler_config or SchedulerConfig(max_tp=spec.hb_domain_size)
    if pipeline is None:
        pipeline, stats, _ = build_pipeline(
            wf, n_trace_requests=n_trace_requests,
            tp_degrees=_default_tp_degrees(spec), seed=seed)
    else:
        stats = None
    result = schedule(pipeline, spec, lam_target, cfg)
    placement = place(result.allocations, spec)
    return ScepsyDeployment(wf.name, stats, pipeline, result, placement)


@dataclass
class ScepsyFleetDeployment:
    """N workflows sharing one cluster via an egalitarian chip split.

    Each per-workflow placement is *slice-local*: chip ids are numbered
    from 0 within that workflow's sub-cluster.  ``chip_offsets`` maps a
    workflow to the start of its (hb-domain-aligned, disjoint) slice of
    the physical cluster; :meth:`global_instances` applies them.
    """

    deployments: Dict[str, ScepsyDeployment]
    chip_split: Dict[str, int]
    welfare: float
    schedule: MultiScheduleResult
    spec: Optional[hw.ClusterSpec] = None
    chip_offsets: Dict[str, int] = None

    def global_instances(self):
        """Every placed instance with slice-local chip/host/domain ids
        translated to physical cluster coordinates."""
        import dataclasses as dc

        out = []
        for name, dep in self.deployments.items():
            off = self.chip_offsets[name]
            for inst in dep.placement.instances:
                chips = [c + off for c in inst.chips]
                out.append(dc.replace(
                    inst, chips=chips,
                    host=chips[0] // self.spec.chips_per_host,
                    domain=chips[0] // self.spec.hb_domain_size))
        return out


def deploy_multi(wfs: Sequence[Workflow], spec: hw.ClusterSpec,
                 lam_targets: Dict[str, float], *,
                 n_trace_requests: int = 60, seed: int = 0,
                 scheduler_config: Optional[SchedulerConfig] = None,
                 pipelines: Optional[Dict[str, AggregateLLMPipeline]] = None,
                 split_step: int = 1, search: str = "auto"
                 ) -> ScepsyFleetDeployment:
    """Fleet flow: trace/profile each workflow, split the cluster with
    :func:`schedule_multi`, and place every workflow on its sub-cluster.

    Placements are slice-local (see :class:`ScepsyFleetDeployment`);
    the returned ``chip_offsets`` give each workflow a disjoint,
    hb-domain-aligned range of physical chips so TP groups never span
    a domain boundary after translation.
    """
    from repro.core.placement import PlacementError
    from repro.core.scheduler import _subcluster

    cfg = scheduler_config or SchedulerConfig(max_tp=spec.hb_domain_size)
    stats_by_name: Dict[str, Optional[WorkflowStats]] = {}
    if pipelines is None:
        pipelines = {}
        for wf in wfs:
            pipeline, stats, _ = build_pipeline(
                wf, n_trace_requests=n_trace_requests,
                tp_degrees=_default_tp_degrees(spec), seed=seed)
            pipelines[wf.name] = pipeline
            stats_by_name[wf.name] = stats
    else:
        stats_by_name = {n: None for n in pipelines}
    multi = schedule_multi(pipelines, spec, lam_targets, cfg,
                           split_step=split_step, search=search)
    deployments: Dict[str, ScepsyDeployment] = {}
    for name, result in multi.per_workflow.items():
        sub = _subcluster(spec, multi.chip_split[name])
        placement = place(result.allocations, sub)
        deployments[name] = ScepsyDeployment(
            name, stats_by_name.get(name), pipelines[name], result,
            placement)
    # disjoint hb-domain-aligned slice starts (the split sums to the
    # cluster, and _subcluster truncation leaves slack, so the aligned
    # layout fits except in pathological many-tiny-workflow cases)
    dom = spec.hb_domain_size
    offsets: Dict[str, int] = {}
    cursor = 0
    for name in multi.chip_split:
        used = 1 + max((c for inst in deployments[name].placement.instances
                        for c in inst.chips), default=0)
        offsets[name] = cursor
        cursor += (used + dom - 1) // dom * dom
    if cursor > spec.num_chips:
        raise PlacementError(
            f"fleet needs {cursor} chips for disjoint hb-aligned slices, "
            f"cluster has {spec.num_chips}")
    return ScepsyFleetDeployment(deployments, multi.chip_split,
                                 multi.welfare, multi, spec=spec,
                                 chip_offsets=offsets)
