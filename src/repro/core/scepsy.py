"""Scepsy facade: trace -> aggregate -> profile -> pipeline -> schedule ->
place (paper Fig. 2 end-to-end flow).

Inputs are :class:`~repro.workflows.runtime.Workflow` programs plus a
:class:`repro.hw.ClusterSpec` and arrival-rate targets; outputs are
deployment objects bundling the chosen allocation, its concrete
placement and (optionally) QoS contexts and an online re-plan
controller.  :func:`deploy` serves one workflow; :func:`deploy_multi`
serves a fleet — partitioned (disjoint chips, co-placed in one pass
over the real topology via :func:`~repro.core.placement.place_fleet`),
pooled (LLMs are tenants, workflows hold routing tables into a shared
replica set — ROADMAP "Cross-workflow LLM sharing"), or auto
(whichever wins on welfare).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

from repro import hw
from repro.core.aggregate import WorkflowStats, aggregate
from repro.core.pipeline import AggregateLLMPipeline
from repro.core.placement import Placement, place
from repro.core.profiler import LLMProfile, profile_llm
from repro.core.scheduler import (ScheduleResult, SchedulerConfig,
                                  MultiScheduleResult, schedule,
                                  schedule_multi)
from repro.core.trace import TraceStore
from repro.workflows.runtime import Workflow, trace_workflow


@dataclass
class ScepsyDeployment:
    workflow: str
    stats: WorkflowStats
    pipeline: AggregateLLMPipeline
    schedule: ScheduleResult
    placement: Placement
    # request-level QoS context (repro.qos.slo.WorkflowQoS): the
    # resolved SLO class + the pipeline-derived work model; None when
    # the workflow is unclassified
    qos: Optional[object] = None


def _resolve_qos(wf: Workflow, pipeline: AggregateLLMPipeline,
                 stats: Optional[WorkflowStats], slo=None):
    """Build the runtime QoS context for one workflow: resolve the SLO
    class's relative target against the traced unloaded latency (or the
    work model's critical-path estimate when stats are unavailable)."""
    slo = slo if slo is not None else wf.slo
    if slo is None:
        return None
    from repro.qos.slo import WorkflowQoS, WorkModel

    work = WorkModel.from_pipeline(pipeline, stats)
    base = stats.mean_latency if stats is not None else work.serial_s
    return WorkflowQoS(slo=slo.resolve(base), work=work)


def build_pipeline(wf: Workflow, *, n_trace_requests: int = 60,
                   tp_degrees: Sequence[int] = (1, 2, 4), seed: int = 0,
                   max_profile_groups: int = 60,
                   store: Optional[TraceStore] = None,
                   chip_classes: Sequence[hw.ChipClass] = ()
                   ) -> Tuple[AggregateLLMPipeline, WorkflowStats, TraceStore]:
    """Steps 1-4: trace the workflow, aggregate, profile, synthesize.

    ``chip_classes`` lists every chip class the serving cluster exposes;
    each LLM is profiled per ``(chip_class, tp)`` so the scheduler can
    price allocations on each class.  Empty = default class only.
    """
    if store is None:
        store = trace_workflow(wf, n_trace_requests, seed=seed)
    stats = aggregate(store)
    profiles: Dict[str, LLMProfile] = {}
    for m in stats.per_llm:
        cfg = wf.llms[m]
        tps = [t for t in tp_degrees]
        profiles[m] = profile_llm(cfg, store, m, tp_degrees=tps,
                                  max_groups=max_profile_groups, seed=seed,
                                  chip_classes=chip_classes)
    pipeline = AggregateLLMPipeline.synthesize(stats, profiles, wf.llms)
    return pipeline, stats, store


def _default_tp_degrees(spec: hw.ClusterSpec) -> list:
    """TP degrees to profile: 1/2/4 capped by the hb domain, plus the
    domain size itself (one grid for single-workflow and fleet deploys)."""
    return sorted({1, 2, min(4, spec.hb_domain_size), spec.hb_domain_size})


def _spec_chip_classes(spec: hw.ClusterSpec) -> Tuple[hw.ChipClass, ...]:
    """Chip classes to profile for ``spec`` (empty = default only)."""
    if spec.is_uniform and (
            not spec.classes()
            or spec.classes()[0] == hw.DEFAULT_CHIP_CLASS.name):
        return ()
    return tuple(hw.chip_class(c) for c in spec.classes())


def deploy(wf: Workflow, spec: hw.ClusterSpec, lam_target: float, *,
           n_trace_requests: int = 60, seed: int = 0,
           scheduler_config: Optional[SchedulerConfig] = None,
           pipeline: Optional[AggregateLLMPipeline] = None,
           slo=None) -> ScepsyDeployment:
    """Full flow: returns the chosen allocation + concrete placement.

    ``slo`` (a :class:`repro.qos.slo.SLOClass`) overrides the
    workflow's own tier; relative targets are resolved against the
    traced unloaded latency.
    """
    cfg = scheduler_config or SchedulerConfig(max_tp=spec.hb_domain_size)
    if pipeline is None:
        pipeline, stats, _ = build_pipeline(
            wf, n_trace_requests=n_trace_requests,
            tp_degrees=_default_tp_degrees(spec), seed=seed,
            chip_classes=_spec_chip_classes(spec))
    else:
        stats = None
    result = schedule(pipeline, spec, lam_target, cfg)
    placement = place(result.allocations, spec)
    return ScepsyDeployment(wf.name, stats, pipeline, result, placement,
                            qos=_resolve_qos(wf, pipeline, stats, slo))


@dataclass
class ScepsyFleetDeployment:
    """N workflows sharing one cluster.

    Partitioned mode: the fleet is co-placed in ONE pass over the real
    topology (:func:`~repro.core.placement.place_fleet`) —
    ``fleet_placement`` holds the global ``workflow/llm``-keyed
    placement (the replan ladder's migration-diff incumbent) and each
    per-workflow ``deployments[name].placement`` is its view of that
    placement with chip ids already GLOBAL.  Chip ownership is
    exclusive per workflow but slices are neither contiguous nor
    hb-domain-aligned; ``chip_offsets`` is kept for API compatibility
    and is all zeros.

    Pooled mode: LLMs are tenants — the shared replica set gets ONE
    physical placement (``tenant_placement``, chip ids already global)
    and every workflow receives a routing table (``routing``: local llm
    name -> placed instance -> weight) instead of a private chip offset.
    """

    deployments: Dict[str, ScepsyDeployment]
    chip_split: Dict[str, int]
    welfare: float
    schedule: MultiScheduleResult
    spec: Optional[hw.ClusterSpec] = None
    chip_offsets: Dict[str, int] = None
    mode: str = "partitioned"
    # partitioned mode: the global workflow/llm-keyed co-placement
    fleet_placement: Optional[Placement] = None
    tenant_placement: Optional[Placement] = None
    routing: Optional[Dict[str, Dict[str, Dict[str, float]]]] = None
    # online drift handling (deploy_multi(..., online=True)): a
    # ReplanController wired to a DriftMonitor over this deployment
    controller: Optional[object] = None
    # per-workflow request-level QoS contexts (workflow name ->
    # repro.qos.slo.WorkflowQoS); empty when no workflow carries a tier
    qos: Dict[str, object] = None

    def global_instances(self):
        """Every placed instance in physical cluster coordinates."""
        import dataclasses as dc

        if self.mode == "pooled":
            return list(self.tenant_placement.instances)
        out = []
        for name, dep in self.deployments.items():
            # co-placed views already hold global chip ids (offset 0);
            # the translation is kept for placements built externally
            off = self.chip_offsets[name]
            table = self.spec.chip_table()
            for inst in dep.placement.instances:
                chips = [c + off for c in inst.chips]
                host, domain = (table[chips[0]][:2] if chips[0] < len(table)
                                else (chips[0] // self.spec.chips_per_host,
                                      chips[0] // self.spec.hb_domain_size))
                out.append(dc.replace(inst, chips=chips, host=host,
                                      domain=domain))
        return out

    def to_deployment(self) -> dict:
        """One manifest for the whole fleet (pooled mode only)."""
        if self.mode != "pooled":
            raise ValueError("fleet manifest only exists in pooled mode; "
                             "use per-workflow placements instead")
        return self.tenant_placement.to_deployment(self.routing)


def deploy_multi(wfs: Sequence[Workflow], spec: hw.ClusterSpec,
                 lam_targets: Dict[str, float], *,
                 n_trace_requests: int = 60, seed: int = 0,
                 scheduler_config: Optional[SchedulerConfig] = None,
                 pipelines: Optional[Dict[str, AggregateLLMPipeline]] = None,
                 split_step: int = 1, search: str = "auto",
                 mode: str = "partitioned",
                 welfare: Optional[str] = None,
                 online: bool = False,
                 drift_config=None,
                 forecast=None,
                 replan_cooldown_s: float = 0.0,
                 slos: Optional[Dict[str, object]] = None,
                 max_profile_groups: int = 60) -> ScepsyFleetDeployment:
    """Fleet flow: trace/profile each workflow, allocate the cluster with
    :func:`schedule_multi` (``mode`` selects partitioned slices vs the
    pooled multi-tenant allocation vs auto), and emit placements.

    A partitioned fleet is co-placed in one pass over the real topology
    (:func:`~repro.core.placement.place_fleet`): chips stay exclusive
    per workflow but slices are neither contiguous nor hb-domain-
    aligned, so tail chips and odd-sized leftovers are usable; pass a
    ``scheduler_config`` with ``placement_aware=True`` to also feed
    placement feasibility and fragmentation back into the split search.
    In pooled mode the tenants' shared replica set is placed once over
    the whole cluster and each workflow gets a routing table into it.

    ``welfare`` overrides ``scheduler_config.welfare`` (egalitarian /
    weighted / proportional).

    ``online=True`` attaches an online re-scheduling controller
    (``.controller``): a :class:`repro.core.drift.DriftMonitor` primed
    with this deployment's profiled expectations (feed it to the cluster
    executor as ``telemetry=``) plus a
    :class:`repro.core.replan.ReplanController` whose escalation ladder
    re-plans incrementally against this deployment's warm state.
    ``drift_config`` is an optional :class:`repro.core.drift.DriftConfig`;
    ``replan_cooldown_s`` sets the controller's rung hysteresis (drift
    events inside the window only act if they escalate the rung).
    ``forecast`` (a :class:`repro.core.forecast.ForecastConfig`, or
    ``True`` for defaults) additionally arms the proactive trigger: an
    :class:`~repro.core.forecast.ArrivalForecaster` fed by the monitor's
    arrival telemetry plus a
    :class:`~repro.core.forecast.ForecastTrigger` the controller polls —
    the ladder then reacts ``lead_s`` *before* a forecast capacity
    crossing instead of after it.

    ``slos`` overrides per-workflow SLO classes (default: each
    workflow's own ``Workflow.slo``); resolved classes + pipeline work
    models land in the returned deployment's ``qos`` dict, and each
    class's latency target arms the monitor's SLO-violation detector.
    """
    import dataclasses as dc

    from repro.core.placement import place_fleet, split_fleet, tenant_routing

    cfg = scheduler_config or SchedulerConfig(max_tp=spec.hb_domain_size)
    if welfare is not None:
        cfg = dc.replace(cfg, welfare=welfare)
    stats_by_name: Dict[str, Optional[WorkflowStats]] = {}
    if pipelines is None:
        pipelines = {}
        for wf in wfs:
            pipeline, stats, _ = build_pipeline(
                wf, n_trace_requests=n_trace_requests,
                tp_degrees=_default_tp_degrees(spec), seed=seed,
                max_profile_groups=max_profile_groups,
                chip_classes=_spec_chip_classes(spec))
            pipelines[wf.name] = pipeline
            stats_by_name[wf.name] = stats
    else:
        stats_by_name = {n: None for n in pipelines}
    multi = schedule_multi(pipelines, spec, lam_targets, cfg,
                           split_step=split_step, search=search, mode=mode)

    wf_by_name = {wf.name: wf for wf in wfs}
    qos_by_name = {}
    for name, pipe in pipelines.items():
        q = _resolve_qos(wf_by_name[name], pipe, stats_by_name.get(name),
                         (slos or {}).get(name))
        if q is not None:
            qos_by_name[name] = q

    def _controller(placement=None):
        if not online:
            return None
        from repro.core.drift import DriftConfig, DriftMonitor, \
            expectation_from
        from repro.core.replan import ReplanController

        forecaster = trigger = None
        if forecast:
            from repro.core.forecast import (ArrivalForecaster,
                                             ForecastConfig, ForecastTrigger)
            fc = forecast if isinstance(forecast, ForecastConfig) \
                else ForecastConfig()
            forecaster = ArrivalForecaster(list(pipelines), fc)
            trigger = ForecastTrigger(forecaster, dict(lam_targets),
                                      headroom=fc.headroom)
        monitor = DriftMonitor(
            {n: expectation_from(
                pipelines[n], lam_targets[n], stats_by_name.get(n),
                slo=(qos_by_name[n].slo if n in qos_by_name else None))
             for n in pipelines},
            drift_config or DriftConfig(),
            forecaster=forecaster)

        def refresh(name: str) -> AggregateLLMPipeline:
            # a cold (rung-3) re-plan re-runs trace -> profile ->
            # synthesize at the same fidelity the deployment was built at;
            # warm rungs reuse the deployed pipelines
            pipe, _, _ = build_pipeline(
                wf_by_name[name], n_trace_requests=n_trace_requests,
                tp_degrees=_default_tp_degrees(spec), seed=seed,
                max_profile_groups=max_profile_groups)
            return pipe

        return ReplanController(pipelines, spec, lam_targets, cfg,
                                result=multi, placement=placement,
                                monitor=monitor, forecast=trigger,
                                pipeline_refresh=refresh,
                                cooldown_s=replan_cooldown_s)

    if multi.alloc_mode == "pooled":
        pooled = multi.pooled
        placement = place(pooled.allocations, spec)
        routing = tenant_routing(placement, pooled.members, pooled.routing)
        deployments = {
            name: ScepsyDeployment(
                name, stats_by_name.get(name), pipelines[name], result,
                placement, qos=qos_by_name.get(name))
            for name, result in multi.per_workflow.items()
        }
        return ScepsyFleetDeployment(deployments, {}, multi.welfare, multi,
                                     spec=spec, chip_offsets=None,
                                     mode="pooled",
                                     tenant_placement=placement,
                                     routing=routing,
                                     controller=_controller(placement),
                                     qos=qos_by_name)

    # a placement-aware search that found NO placeable split returns
    # the blind winner flagged placement_ok=False: placing it below is
    # guaranteed to fail, so surface the scheduler's diagnosis instead
    # of a low-level per-instance packing error
    if multi.placement_ok is False:
        from repro.core.placement import PlacementError

        raise PlacementError(
            f"placement-aware search found no placeable split: all "
            f"{multi.placement_rejected_splits} probed candidate(s) were "
            f"rejected (search mode {multi.search_mode!r}; a greedy "
            f"search only probes its welfare-driven trajectory, so an "
            f"off-trajectory placeable split may still exist)",
            hint="try search='enumerate' for exhaustive coverage, grant "
                 "the fleet more chips, relax TP (max_tp), or use "
                 "mode='auto' so a placeable pooled plan can win")
    # true co-placement: every workflow's replicas packed in one pass
    # over the real topology (tail chips included), chip ownership
    # exclusive per workflow but with no contiguity or hb-domain
    # alignment waste; the global workflow/llm-keyed placement is the
    # controller's migration-diff incumbent
    incumbent = place_fleet(
        {n: r.allocations for n, r in multi.per_workflow.items()}, spec)
    views = split_fleet(incumbent)
    deployments = {
        name: ScepsyDeployment(
            name, stats_by_name.get(name), pipelines[name], result,
            views[name], qos=qos_by_name.get(name))
        for name, result in multi.per_workflow.items()
    }
    return ScepsyFleetDeployment(deployments, multi.chip_split,
                                 multi.welfare, multi, spec=spec,
                                 chip_offsets={n: 0 for n in deployments},
                                 fleet_placement=incumbent,
                                 controller=_controller(incumbent),
                                 qos=qos_by_name)
