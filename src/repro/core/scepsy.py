"""Scepsy facade: trace -> aggregate -> profile -> pipeline -> schedule ->
place (paper Fig. 2 end-to-end flow)."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

from repro import hw
from repro.core.aggregate import WorkflowStats, aggregate
from repro.core.pipeline import AggregateLLMPipeline
from repro.core.placement import Placement, place
from repro.core.profiler import LLMProfile, profile_llm
from repro.core.scheduler import (ScheduleResult, SchedulerConfig,
                                  MultiScheduleResult, schedule,
                                  schedule_multi)
from repro.core.trace import TraceStore
from repro.workflows.runtime import Workflow, trace_workflow


@dataclass
class ScepsyDeployment:
    workflow: str
    stats: WorkflowStats
    pipeline: AggregateLLMPipeline
    schedule: ScheduleResult
    placement: Placement


def build_pipeline(wf: Workflow, *, n_trace_requests: int = 60,
                   tp_degrees: Sequence[int] = (1, 2, 4), seed: int = 0,
                   max_profile_groups: int = 60,
                   store: Optional[TraceStore] = None
                   ) -> Tuple[AggregateLLMPipeline, WorkflowStats, TraceStore]:
    """Steps 1-4: trace the workflow, aggregate, profile, synthesize."""
    if store is None:
        store = trace_workflow(wf, n_trace_requests, seed=seed)
    stats = aggregate(store)
    profiles: Dict[str, LLMProfile] = {}
    for m in stats.per_llm:
        cfg = wf.llms[m]
        tps = [t for t in tp_degrees]
        profiles[m] = profile_llm(cfg, store, m, tp_degrees=tps,
                                  max_groups=max_profile_groups, seed=seed)
    pipeline = AggregateLLMPipeline.synthesize(stats, profiles, wf.llms)
    return pipeline, stats, store


def deploy(wf: Workflow, spec: hw.ClusterSpec, lam_target: float, *,
           n_trace_requests: int = 60, seed: int = 0,
           scheduler_config: Optional[SchedulerConfig] = None,
           pipeline: Optional[AggregateLLMPipeline] = None
           ) -> ScepsyDeployment:
    """Full flow: returns the chosen allocation + concrete placement."""
    cfg = scheduler_config or SchedulerConfig(max_tp=spec.hb_domain_size)
    if pipeline is None:
        tps = sorted({1, 2, min(4, spec.hb_domain_size),
                      spec.hb_domain_size})
        pipeline, stats, _ = build_pipeline(
            wf, n_trace_requests=n_trace_requests,
            tp_degrees=[t for t in tps if t >= 1], seed=seed)
    else:
        stats = None
    result = schedule(pipeline, spec, lam_target, cfg)
    placement = place(result.allocations, spec)
    return ScepsyDeployment(wf.name, stats, pipeline, result, placement)
