"""Baseline serving systems (paper §7.2 comparisons), on the same
discrete-event simulator so comparisons are apples-to-apples.

* :class:`KubernetesHPA` — reactive per-LLM autoscaling on observed
  utilization; whole-chip tp=1 replicas (the HPA knows nothing about
  tensor parallelism), cold-start weight loads, and the oscillation
  behavior the paper describes emerges from the control loop.
* :class:`AegaeonLike` — token-level GPU pooling with static
  prefill/decode instance splits, model swapping between requests of
  different LLMs, KV transfer at the P->D handoff, and NO prefix caching
  (its two weaknesses in §7.2).
* :class:`AyoLike` — workflow-aware request scheduling over a
  user-specified *static* allocation (equal chips per LLM, tp=1).
  Request-level optimizations are modeled by prefix caching + batched
  parallel stages; the throughput ceiling of a demand-blind allocation
  emerges naturally.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

from repro import hw
from repro.configs.base import ArchConfig
from repro.serving import costmodel as cm
from repro.serving.simulator import EngineRequest, EngineSim, EventLoop, Router
from repro.workflows.runtime import Workflow


# ---------------------------------------------------------------------------
# Kubernetes HPA autoscaler
# ---------------------------------------------------------------------------


class KubernetesHPA:
    def __init__(self, wf: Workflow, spec: hw.ClusterSpec, loop: EventLoop, *,
                 sync_period: float = 15.0, target_util: float = 0.75,
                 scale_down_util: float = 0.30, prefix_caching: bool = True):
        self.wf = wf
        self.spec = spec
        self.loop = loop
        self.sync_period = sync_period
        self.target_util = target_util
        self.scale_down_util = scale_down_util
        self.prefix_caching = prefix_caching
        self.free_chips = spec.num_chips - len(wf.llms)
        assert self.free_chips >= 0, "cluster smaller than one chip per LLM"
        self.replicas: Dict[str, List[EngineSim]] = {}
        self.routers: Dict[str, Router] = {}
        self._last_busy: Dict[str, float] = {}
        for llm, cfg in wf.llms.items():
            eng = self._new_engine(llm, cfg, cold=False)
            self.replicas[llm] = [eng]
            # plain least-loaded balancing: KV-aware affinity routing is
            # part of Scepsy's stack (SGLang gateway), not a stock HPA
            self.routers[llm] = Router(self.replicas[llm], affinity=False)
            self._last_busy[llm] = 0.0
        loop.schedule(sync_period, self._sync)

    def _new_engine(self, llm: str, cfg: ArchConfig, cold: bool = True) -> EngineSim:
        eng = EngineSim(cfg, self.loop, tp=1, fraction=1.0,
                        name=f"{llm}/hpa", prefix_caching=self.prefix_caching)
        if cold:
            eng.request_swap(cm.swap_cost(cfg))  # cold-start weight load
        return eng

    def _sync(self) -> None:
        for llm, engines in self.replicas.items():
            busy = sum(e.busy_time for e in engines)
            util = ((busy - self._last_busy[llm])
                    / (self.sync_period * max(len(engines), 1)))
            self._last_busy[llm] = busy
            n = len(engines)
            desired = max(1, math.ceil(n * util / self.target_util))
            if util < self.scale_down_util and n > 1:
                desired = max(1, n - 1)
            if desired > n:
                add = min(desired - n, self.free_chips)
                for _ in range(add):
                    engines.append(self._new_engine(llm, self.wf.llms[llm]))
                    self.free_chips -= 1
            elif desired < n:
                # drain the least-loaded replica; chip returns to the pool
                engines.sort(key=lambda e: e.load)
                victim = engines.pop(0)
                victim.prefix_caching = False  # drained; won't get new work
                self.free_chips += 1
        self.loop.schedule(self.loop.now + self.sync_period, self._sync)


# ---------------------------------------------------------------------------
# Aegaeon-like P/D pooled multiplexing
# ---------------------------------------------------------------------------


class SwapPoolEngine:
    """A pooled instance serving any model, with swap overhead on model
    change.  FIFO, batches consecutive same-model requests."""

    def __init__(self, loop: EventLoop, phase: str, name: str = ""):
        self.loop = loop
        self.phase = phase  # "prefill" | "decode"
        self.name = name
        self.queue: List[tuple] = []  # (req, cfg, callback)
        self.busy = False
        self.busy_time = 0.0
        self.current_model: Optional[str] = None
        self.max_batch = 64

    @property
    def load(self) -> float:
        return sum(r.prompt_tokens + r.remaining for r, _, _ in self.queue)

    def submit(self, req: EngineRequest, cfg: ArchConfig, callback) -> None:
        req.remaining = req.output_tokens
        self.queue.append((req, cfg, callback))
        if not self.busy:
            self.busy = True
            self.loop.schedule(self.loop.now, self._iterate)

    def _iterate(self) -> None:
        if not self.queue:
            self.busy = False
            return
        t0 = self.loop.now
        req0, cfg, _ = self.queue[0]
        batch = []
        while (self.queue and len(batch) < self.max_batch
               and self.queue[0][1].name == cfg.name):
            batch.append(self.queue.pop(0))
        duration = 0.0
        if self.current_model != cfg.name:
            duration += cm.swap_cost(cfg)
            self.current_model = cfg.name
        if self.phase == "prefill":
            for r, c, _ in batch:
                duration += cm.prefill_cost(c, r.prompt_tokens).total
        else:
            # decode the batch in quanta until all finish
            remaining = [r.output_tokens for r, _, _ in batch]
            while any(x > 0 for x in remaining):
                q = max(min(8, min(x for x in remaining if x > 0)), 1)
                live = sum(1 for x in remaining if x > 0)
                ctx = sum(r.prompt_tokens for r, _, _ in batch) / len(batch)
                step = cm.decode_step_cost(cfg, live, int(ctx))
                duration += q * step.total
                remaining = [max(x - q, 0) if x > 0 else 0 for x in remaining]
        t1 = t0 + max(duration, 1e-6)
        self.busy_time += t1 - t0

        def finish():
            for r, c, cb in batch:
                cb(r, t1)
            self._iterate()

        self.loop.schedule(t1, finish)


class AegaeonLike:
    """Static P/D split pools; encoders served by prefill instances."""

    def __init__(self, wf: Workflow, spec: hw.ClusterSpec, loop: EventLoop, *,
                 prefill_per_node: int = 2, decode_per_node: int = 2):
        self.wf = wf
        self.loop = loop
        per_node = spec.chips_per_host
        assert prefill_per_node + decode_per_node == per_node or True
        self.prefill_pool: List[SwapPoolEngine] = []
        self.decode_pool: List[SwapPoolEngine] = []
        for h in range(spec.num_hosts):
            for i in range(prefill_per_node):
                self.prefill_pool.append(
                    SwapPoolEngine(loop, "prefill", f"P{h}.{i}"))
            for i in range(decode_per_node):
                self.decode_pool.append(
                    SwapPoolEngine(loop, "decode", f"D{h}.{i}"))
        self.routers = {llm: _AegaeonRouter(self, cfg)
                        for llm, cfg in wf.llms.items()}


class _AegaeonRouter:
    def __init__(self, system: AegaeonLike, cfg: ArchConfig):
        self.system = system
        self.cfg = cfg

    def submit(self, req: EngineRequest) -> None:
        sysm = self.system
        pe = min(sysm.prefill_pool, key=lambda e: e.load)
        encoder_like = req.output_tokens <= 2

        def after_prefill(r: EngineRequest, t: float):
            if encoder_like:
                r.t_done = t
                r.t_start_service = max(r.t_start_service, r.arrival)
                if r.on_complete:
                    r.on_complete(r)
                return
            # KV transfer P -> D over ICI
            kv = cm.kv_bytes_per_seq(self.cfg, r.prompt_tokens)
            xfer = kv / hw.ICI_LINK_BW
            de = min(sysm.decode_pool, key=lambda e: e.load)

            def after_decode(r2: EngineRequest, t2: float):
                r2.t_done = t2
                if r2.on_complete:
                    r2.on_complete(r2)

            sysm.loop.schedule(t + xfer,
                               lambda: de.submit(r, self.cfg, after_decode))

        req.t_start_service = self.system.loop.now
        pe.submit(req, self.cfg, after_prefill)


# ---------------------------------------------------------------------------
# Ayo-like static workflow-aware serving
# ---------------------------------------------------------------------------


class AyoLike:
    def __init__(self, wf: Workflow, spec: hw.ClusterSpec, loop: EventLoop, *,
                 engine_efficiency: float = 0.9):
        """Equal static chip split per LLM (user-specified allocation),
        tp=1 replicas; ``engine_efficiency`` models the older engine
        generation the paper had to use for comparability."""
        self.routers: Dict[str, Router] = {}
        llms = list(wf.llms)
        chips_each = max(spec.num_chips // len(llms), 1)
        for llm in llms:
            cfg = wf.llms[llm]
            engines = [EngineSim(cfg, loop, tp=1,
                                 fraction=engine_efficiency,
                                 name=f"{llm}/ayo{i}", prefix_caching=True)
                       for i in range(chips_each)]
            self.routers[llm] = Router(engines)
