"""Workflow registry: every servable workload by name.

The fleet scheduler (:func:`repro.core.scheduler.schedule_multi`) and the
benchmarks look workloads up here, so adding a scenario is one module +
one entry.

Each registered workflow carries a default SLO class
(:mod:`repro.qos.slo`): interactive agent loops are gold (tight latency
target, never shed), throughput-oriented pipelines are silver (degrade
under overload), batch-style search/debate workloads are bronze (reject
under overload).  Targets are relative (a multiple of the workflow's
unloaded latency) and get resolved against traced stats at deploy time;
callers that want different tiers pass ``slos=`` to ``deploy_multi`` or
re-wrap with :func:`repro.workflows.runtime.with_slo`.
"""
from __future__ import annotations

from typing import Dict, List

from repro.qos.slo import BRONZE, GOLD, SILVER, SLOClass
from repro.workflows.beam_search import BEAM_SEARCH
from repro.workflows.debate import DEBATE
from repro.workflows.map_reduce import MAP_REDUCE
from repro.workflows.rag_reranker import RAG_RERANKER
from repro.workflows.react_agent import REACT_AGENT
from repro.workflows.runtime import Workflow, with_slo
from repro.workflows.session import RECURSIVE_AGENT, SESSION_CHAT

DEFAULT_SLOS: Dict[str, SLOClass] = {
    "react_agent": GOLD,  # interactive tool agent: a user is waiting
    "rag_reranker": GOLD,  # interactive retrieval front-end
    "session_chat": GOLD,  # live conversation: a user is typing back
    "map_reduce": SILVER,  # throughput pipeline: degrade before reject
    "beam_search": SILVER,
    "recursive_agent": SILVER,  # background task decomposition
    "debate": BRONZE,  # batch-style deliberation: sheddable
}

# a workflow without a DEFAULT_SLOS entry registers unclassified
# (best-effort, no admission control) rather than failing at import
WORKFLOWS: Dict[str, Workflow] = {
    wf.name: (with_slo(wf, DEFAULT_SLOS[wf.name])
              if wf.name in DEFAULT_SLOS else wf)
    for wf in (BEAM_SEARCH, RAG_RERANKER, REACT_AGENT, MAP_REDUCE, DEBATE,
               SESSION_CHAT, RECURSIVE_AGENT)
}


def get_workflow(name: str) -> Workflow:
    if name not in WORKFLOWS:
        raise KeyError(f"unknown workflow {name!r}; known: {sorted(WORKFLOWS)}")
    return WORKFLOWS[name]


def workflow_names() -> List[str]:
    return sorted(WORKFLOWS)
