"""Workflow registry: every servable workload by name.

The fleet scheduler (:func:`repro.core.scheduler.schedule_multi`) and the
benchmarks look workloads up here, so adding a scenario is one module +
one entry.
"""
from __future__ import annotations

from typing import Dict, List

from repro.workflows.beam_search import BEAM_SEARCH
from repro.workflows.debate import DEBATE
from repro.workflows.map_reduce import MAP_REDUCE
from repro.workflows.rag_reranker import RAG_RERANKER
from repro.workflows.react_agent import REACT_AGENT
from repro.workflows.runtime import Workflow

WORKFLOWS: Dict[str, Workflow] = {
    wf.name: wf
    for wf in (BEAM_SEARCH, RAG_RERANKER, REACT_AGENT, MAP_REDUCE, DEBATE)
}


def get_workflow(name: str) -> Workflow:
    if name not in WORKFLOWS:
        raise KeyError(f"unknown workflow {name!r}; known: {sorted(WORKFLOWS)}")
    return WORKFLOWS[name]


def workflow_names() -> List[str]:
    return sorted(WORKFLOWS)
