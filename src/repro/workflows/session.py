"""Stateful multi-turn session workloads.

The registry's original fleet is memoryless: every workflow request is
one shot and its calls share prefixes only *within* the request.  Real
agentic traffic is dominated by *sessions* — a user holds a conversation
whose context grows turn over turn, which is exactly the access pattern
the radix/affinity serving path (PR 6) is built for.  Both workloads
here model a session as ONE workflow-level request spanning several
turns: each assistant call extends the previous turn's transcript via
``parent=`` handles (process-unique, so pooled replicas key prefix reuse
correctly), turns are separated by :class:`Tool` think-time gaps, and
the driver's ``Router.forget()`` prunes sticky state when the session
generator finally returns.

``session_chat`` is a plain chat session (linear transcript growth);
``recursive_agent`` is a recursive tool-use agent that decomposes tasks
into subtasks with data-dependent depth and branching — its prefix tree
branches where the plan does, the DAG shape the aggregate abstraction
claims to absorb without inspecting.
"""
from __future__ import annotations

import random

from repro.configs.paper_workloads import LLAMA_3_2_1B, QWEN_2_5_3B_AGENT
from repro.workflows.runtime import Call, Tool, Workflow

MAX_TURNS = 10  # chat session length cap
MAX_DEPTH = 3  # recursion cap for the task-decomposition agent
SUMMARIZE_THRESHOLD = 200  # leaf tool outputs longer than this compress


def session_chat_program(rng: random.Random):
    # system prompt + user profile, resent (cached) on every turn
    context = 80 + int(rng.lognormvariate(4.5, 0.4))
    turns = min(2 + int(rng.expovariate(1 / 3.0)), MAX_TURNS)
    last = None

    for turn in range(turns):
        user = 15 + int(rng.expovariate(1 / 35.0))
        context += user
        reply = 40 + int(rng.expovariate(1 / 90.0))
        (res,) = yield [Call("chat", context, reply, parent=last)]
        last = res.handle
        context += reply
        if turn + 1 < turns:
            # user reads the reply and types the next message
            yield Tool(0.2 + rng.expovariate(1 / 1.0))


def _solve(rng: random.Random, context: int, parent, depth: int):
    """One task node, driven via ``yield from``: plan, recurse or
    execute, then synthesize — every call continuing the node's own
    transcript.  Returns ``(handle, context)`` for the caller to chain."""
    plan_tokens = 25 + int(rng.expovariate(1 / 30.0))
    (plan,) = yield [Call("agent", context, plan_tokens, parent=parent)]
    context += plan_tokens
    last = plan.handle

    branch_p = 0.5 if depth == 0 else 0.25
    if depth < MAX_DEPTH and rng.random() < branch_p:
        subtasks = 1 + (rng.random() < 0.4)
        for _ in range(subtasks):
            last, context = yield from _solve(rng, context, last, depth + 1)
    else:
        # leaf: run the tool and fold its (possibly summarized) output in
        yield Tool(0.01 + rng.expovariate(1 / 0.04))
        obs = 20 + int(rng.expovariate(1 / 150.0))
        if obs > SUMMARIZE_THRESHOLD:
            summary = 30 + int(rng.expovariate(1 / 30.0))
            yield [Call("summ", obs, summary)]
            obs = summary
        context += obs

    synth_tokens = 30 + int(rng.expovariate(1 / 50.0))
    (res,) = yield [Call("agent", context, synth_tokens, parent=last)]
    return res.handle, context + synth_tokens


def recursive_agent_program(rng: random.Random):
    context = 100 + int(rng.lognormvariate(4.8, 0.5))  # task statement
    yield from _solve(rng, context, None, 0)


SESSION_CHAT = Workflow(
    name="session_chat",
    program=session_chat_program,
    llms={"chat": QWEN_2_5_3B_AGENT},
)

RECURSIVE_AGENT = Workflow(
    name="recursive_agent",
    program=recursive_agent_program,
    llms={"agent": QWEN_2_5_3B_AGENT, "summ": LLAMA_3_2_1B},
)
