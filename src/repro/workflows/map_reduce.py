"""Map-reduce summarization fan-out workload.

A long document is chunked; a small mapper LLM summarizes every chunk in
parallel (the fan-out — high request-level parallelism p_m), then an 8B
reducer folds the partial summaries hierarchically (fan-in trees of
``REDUCE_FANOUT``) down to one final summary.  The fan-out width is
data-dependent (drawn from the document length), so n_map varies per
request while the map/reduce execution-share stays stable — the paper's
§2.4 observation in a workload whose structure is width- rather than
depth-dependent.
"""
from __future__ import annotations

import math
import random

from repro.configs.paper_workloads import LLAMA_3_1_8B, LLAMA_3_2_1B
from repro.workflows.runtime import Call, Tool, Workflow

CHUNK_TOKENS = 800
MAX_CHUNKS = 24
REDUCE_FANOUT = 4
PARTIAL_TOKENS = 90  # per-chunk summary length scale


def map_reduce_program(rng: random.Random):
    doc = int(rng.lognormvariate(8.3, 0.6))  # ~4k-token documents
    chunks = min(max(math.ceil(doc / CHUNK_TOKENS), 2), MAX_CHUNKS)

    # chunking / dispatch (non-LLM)
    yield Tool(0.002)

    # map: summarize all chunks in parallel
    map_calls = [Call("map", CHUNK_TOKENS + int(rng.expovariate(1 / 60.0)),
                      PARTIAL_TOKENS + int(rng.expovariate(1 / 30.0)))
                 for _ in range(chunks)]
    partials = yield map_calls

    # reduce: fold partial summaries in trees of REDUCE_FANOUT
    width = len(partials)
    while width > 1:
        groups = math.ceil(width / REDUCE_FANOUT)
        out_tokens = (PARTIAL_TOKENS if groups > 1
                      else 160 + int(rng.expovariate(1 / 60.0)))
        reduce_calls = [
            Call("reduce",
                 min(width - g * REDUCE_FANOUT, REDUCE_FANOUT)
                 * PARTIAL_TOKENS + 40,
                 out_tokens)
            for g in range(groups)
        ]
        yield reduce_calls
        width = groups


MAP_REDUCE = Workflow(
    name="map_reduce",
    program=map_reduce_program,
    llms={"map": LLAMA_3_2_1B, "reduce": LLAMA_3_1_8B},
)
