"""Beam-search workflow (paper Fig. 1 / §7 workload).

A generator LLM (GEN) expands candidate reasoning steps; a verifier LLM
(VER) scores them; the top beams survive.  Execution is data-dependent:
the number of rounds and the per-step token counts are drawn per request
(the paper's trace spans 24–844 GEN invocations and 9–264 s latency).
Beam expansions share their parent's prefix — the prefix-cache hit that
the Aegaeon baseline lacks.
"""
from __future__ import annotations

import random

from repro.configs.paper_workloads import LLAMA_3_1_8B_PRM, LLAMA_3_2_1B
from repro.workflows.runtime import Call, Tool, Workflow

BEAM_WIDTH = 4
EXPANSIONS_PER_BEAM = 2


def beam_search_program(rng: random.Random):
    prompt = int(rng.lognormvariate(5.5, 0.4))  # ~250 token question
    rounds = min(3 + int(rng.expovariate(1 / 10.0)), 50)
    context = prompt
    beam_handles = [None] * BEAM_WIDTH  # gen-side prefix lineage
    ver_handles = [None] * BEAM_WIDTH  # ver-side prefix lineage (the
    # verifier's KV prefix is its *own* previous scoring of this beam)

    for _ in range(rounds):
        # expand: GEN continues each beam (children share the beam prefix)
        expansions = []
        parents = []
        for b in range(BEAM_WIDTH):
            for _ in range(EXPANSIONS_PER_BEAM):
                step = 20 + int(rng.expovariate(1 / 40.0))
                expansions.append(Call("gen", context, step,
                                       parent=beam_handles[b]))
                parents.append(b)
        gen_results = yield expansions

        # verify: VER scores each expansion, extending its own prior
        # scoring context for that beam (radix-cache hit)
        step_ctx = context + 40
        ver_calls = [Call("ver", step_ctx, 2, parent=ver_handles[parents[i]])
                     for i, _ in enumerate(gen_results)]
        ver_results = yield ver_calls

        # non-LLM: select top beams
        yield Tool(0.002)
        order = list(range(len(gen_results)))
        rng.shuffle(order)
        keep = order[:BEAM_WIDTH]
        beam_handles = [gen_results[i].handle for i in keep]
        ver_handles = [ver_results[i].handle for i in keep]
        context += 40

    # final answer from the best beam
    yield [Call("gen", context, 100 + int(rng.expovariate(1 / 80.0)),
                parent=beam_handles[0])]


BEAM_SEARCH = Workflow(
    name="beam_search",
    program=beam_search_program,
    llms={"gen": LLAMA_3_2_1B, "ver": LLAMA_3_1_8B_PRM},
)
