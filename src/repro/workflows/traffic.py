"""Realistic arrival traffic: diurnal cycles, bursts, flash crowds, replay.

Every bench so far drove the fleet with constant-rate Poisson arrivals
(or one synthetic 2x ramp).  Real agentic traffic is none of that: it
follows a daily cycle, arrives in correlated bursts, and occasionally
spikes when something goes viral.  This module models all three as
*piecewise-constant intensities* — a :class:`TrafficModel` composes a
diurnal sinusoid, an MMPP-style on/off burst modulator and a flash-crowd
spike into one ``[(rate, duration), ...]`` segment list, the exact shape
:meth:`ClusterDriver.schedule_arrivals` already turns into a lazy
:class:`~repro.workflows.runtime.ArrivalSource` (one pending loop event,
O(1) heap space).  Piecewise-constant segments keep the process exactly
analyzable: the integrated intensity of every segment is ``rate ×
duration``, which the rate-conservation property test checks empirical
counts against, and seeded draws are bit-identical run to run.

The second half is trace replay: :func:`record_trace` samples a fleet of
models into an :class:`ArrivalTrace` of ``(t, workflow, session)`` rows
(JSONL on disk, checked-in golden fixtures under ``tests/data/``), and
:func:`replay_trace` replays one through a dict of drivers sharing an
event loop — lazily by default, again with a single pending event.
"""
from __future__ import annotations

import json
import math
import random
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from repro.workflows.runtime import ClusterDriver

RateSegment = Tuple[float, float]  # (rate requests/s, duration s)


# ---------------------------------------------------------------------------
# Intensity components (each emits piecewise-constant multiplier pieces)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DiurnalCycle:
    """Sinusoidal day/night modulation, sampled piecewise-constant.

    The multiplier is ``1 + amplitude * sin(2π(t/period - phase))``
    evaluated at each bin midpoint, so the mean multiplier over a full
    period is 1 and the peak sits at ``t = period * (phase + 1/4)``.
    """

    period_s: float
    amplitude: float = 0.5  # 0..1: peak-to-mean modulation depth
    phase: float = 0.0  # fraction of a period
    bins: int = 48  # piecewise resolution per period

    def pieces(self, duration_s: float) -> List[RateSegment]:
        if not 0.0 <= self.amplitude <= 1.0:
            raise ValueError(f"amplitude must be in [0,1], got {self.amplitude}")
        dt = self.period_s / self.bins
        out: List[RateSegment] = []
        t = 0.0
        while t < duration_s - 1e-12:
            d = min(dt, duration_s - t)
            mid = t + d / 2.0
            x = 2.0 * math.pi * (mid / self.period_s - self.phase)
            out.append((1.0 + self.amplitude * math.sin(x), d))
            t += d
        return out


@dataclass(frozen=True)
class BurstModulator:
    """MMPP-style on/off burst process: exponentially-distributed quiet
    periods (multiplier 1) alternating with exponentially-distributed
    bursts (multiplier ``factor``) — arrivals inside a burst are still
    Poisson, but counts across bursts are over-dispersed relative to a
    homogeneous process, the correlation signature of real traffic."""

    factor: float = 2.5
    mean_on_s: float = 30.0
    mean_off_s: float = 150.0

    def pieces(self, duration_s: float, rng: random.Random) -> List[RateSegment]:
        out: List[RateSegment] = []
        t, on = 0.0, False  # always start quiet: bursts are drawn, not given
        while t < duration_s - 1e-12:
            mean = self.mean_on_s if on else self.mean_off_s
            d = min(rng.expovariate(1.0 / mean), duration_s - t)
            out.append((self.factor if on else 1.0, d))
            t += d
            on = not on
        return out


@dataclass(frozen=True)
class FlashCrowd:
    """One deterministic viral spike: linear ramp to ``peak``, hold,
    linear decay back to 1 — piecewise-constant in ``steps`` stairs per
    ramp so the integrated intensity stays exact."""

    at_s: float
    peak: float = 3.0
    ramp_s: float = 30.0
    hold_s: float = 60.0
    decay_s: float = 120.0
    steps: int = 8

    def pieces(self, duration_s: float) -> List[RateSegment]:
        out: List[RateSegment] = [(1.0, self.at_s)]

        def stair(f0: float, f1: float, span: float) -> None:
            d = span / self.steps
            for i in range(self.steps):
                frac = (i + 0.5) / self.steps
                out.append((f0 + (f1 - f0) * frac, d))

        stair(1.0, self.peak, self.ramp_s)
        out.append((self.peak, self.hold_s))
        stair(self.peak, 1.0, self.decay_s)
        # clip/extend to the requested window
        total, clipped = 0.0, []
        for f, d in out:
            if total >= duration_s:
                break
            d = min(d, duration_s - total)
            clipped.append((f, d))
            total += d
        if total < duration_s:
            clipped.append((1.0, duration_s - total))
        return clipped


def _merge(pieces_list: Sequence[Sequence[RateSegment]],
           duration_s: float) -> List[RateSegment]:
    """Product of piecewise-constant factors over a common breakpoint
    grid: the output changes value wherever ANY input does."""
    cuts = {0.0, duration_s}
    starts: List[List[Tuple[float, float]]] = []
    for pieces in pieces_list:
        t, row = 0.0, []
        for value, d in pieces:
            row.append((t, value))
            t += d
            cuts.add(min(t, duration_s))
        starts.append(row)
    grid = sorted(c for c in cuts if c < duration_s)
    out: List[RateSegment] = []
    idx = [0] * len(starts)
    for j, t0 in enumerate(grid):
        t1 = grid[j + 1] if j + 1 < len(grid) else duration_s
        prod = 1.0
        for k, row in enumerate(starts):
            while idx[k] + 1 < len(row) and row[idx[k] + 1][0] <= t0 + 1e-12:
                idx[k] += 1
            prod *= row[idx[k]][1]
        if t1 - t0 > 1e-12:
            out.append((prod, t1 - t0))
    return out


@dataclass(frozen=True)
class TrafficModel:
    """One workflow's arrival intensity over a day: ``base_rate``
    modulated multiplicatively by whichever components are present.
    ``segments()`` is deterministic in ``seed`` (only the burst
    modulator draws randomness) and feeds straight into
    :meth:`ClusterDriver.schedule_arrivals`."""

    base_rate: float
    diurnal: Optional[DiurnalCycle] = None
    bursts: Optional[BurstModulator] = None
    flash: Optional[FlashCrowd] = None

    def segments(self, duration_s: float, *, seed: int = 0) -> List[RateSegment]:
        pieces: List[List[RateSegment]] = [[(self.base_rate, duration_s)]]
        if self.diurnal is not None:
            pieces.append(self.diurnal.pieces(duration_s))
        if self.bursts is not None:
            pieces.append(self.bursts.pieces(duration_s, random.Random(seed)))
        if self.flash is not None:
            pieces.append(self.flash.pieces(duration_s))
        return _merge(pieces, duration_s)

    def mean_rate(self, duration_s: float, *, seed: int = 0) -> float:
        segs = self.segments(duration_s, seed=seed)
        total = sum(r * d for r, d in segs)
        return total / max(duration_s, 1e-12)

    def peak_rate(self, duration_s: float, *, seed: int = 0) -> float:
        return max(r for r, _ in self.segments(duration_s, seed=seed))


def poisson_arrivals(segments: Sequence[RateSegment], *, seed: int = 0,
                     start: float = 0.0, rid_start: int = 0
                     ) -> Iterator[Tuple[float, int]]:
    """Seeded inhomogeneous-Poisson arrival times over piecewise-constant
    segments, as ``(t, rid)`` pairs.  Draw order matches
    :meth:`ClusterDriver.schedule_arrivals` exactly, so a trace recorded
    here replays bit-identically through the driver."""
    rng = random.Random(seed)
    rid = rid_start
    t_seg = start
    for rate, duration in segments:
        t_end = t_seg + duration
        t = t_seg
        while rate > 0:
            t += rng.expovariate(rate)
            if t >= t_end:
                break
            yield t, rid
            rid += 1
        t_seg = t_end


# ---------------------------------------------------------------------------
# Recorded traces: (t, workflow, session) rows, JSONL on disk
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TraceEvent:
    t: float
    workflow: str
    session: int  # per-workflow session id (the driver's request id)


@dataclass
class ArrivalTrace:
    """A recorded arrival trace, sorted by time (ties broken by workflow
    then session so replay order is total and deterministic)."""

    events: List[TraceEvent]

    def __post_init__(self) -> None:
        self.events = sorted(self.events,
                             key=lambda e: (e.t, e.workflow, e.session))

    def __len__(self) -> int:
        return len(self.events)

    @property
    def duration(self) -> float:
        return self.events[-1].t if self.events else 0.0

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for ev in self.events:
            out[ev.workflow] = out.get(ev.workflow, 0) + 1
        return out

    def save(self, path) -> None:
        with open(path, "w") as f:
            for ev in self.events:
                f.write(json.dumps({"t": ev.t, "workflow": ev.workflow,
                                    "session": ev.session}) + "\n")

    @staticmethod
    def load(path) -> "ArrivalTrace":
        events = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                row = json.loads(line)
                events.append(TraceEvent(float(row["t"]), row["workflow"],
                                         int(row["session"])))
        return ArrivalTrace(events)


def record_trace(models: Dict[str, TrafficModel], duration_s: float, *,
                 seed: int = 0) -> ArrivalTrace:
    """Sample every model over one window into a single merged trace.
    Per-workflow arrival seeds follow the fleet convention
    (``seed * 1000 + k`` over sorted names, matching
    ``benchmarks.common.drive_fleet``)."""
    events: List[TraceEvent] = []
    for k, name in enumerate(sorted(models)):
        segs = models[name].segments(duration_s, seed=seed * 1000 + k)
        for t, rid in poisson_arrivals(segs, seed=seed * 1000 + k):
            events.append(TraceEvent(t, name, rid))
    return ArrivalTrace(events)


class TraceReplaySource:
    """Lazy multi-driver trace replay: ONE pending loop event (the next
    trace row) regardless of trace length, dispatching each row to its
    workflow's driver — the replay twin of
    :class:`~repro.workflows.runtime.ArrivalSource`."""

    def __init__(self, drivers: Dict[str, ClusterDriver],
                 trace: ArrivalTrace, *, seed: int = 0):
        missing = sorted({e.workflow for e in trace.events} - set(drivers))
        if missing:
            raise KeyError(f"trace names workflows with no driver: {missing}")
        loops = {id(d.loop) for d in drivers.values()}
        if len(loops) > 1:
            raise ValueError("replay drivers must share one event loop")
        self._drivers = drivers
        self._loop = next(iter(drivers.values())).loop
        self._it = iter(trace.events)
        self._seed = seed
        self.scheduled = 0
        self.exhausted = False
        self._arm()

    def _arm(self) -> None:
        try:
            ev = next(self._it)
        except StopIteration:
            self.exhausted = True
            return
        self._loop.schedule(ev.t, self._fire, ev)

    def _fire(self, ev: TraceEvent) -> None:
        self.scheduled += 1
        self._arm()  # keep the stream primed before running the program
        self._drivers[ev.workflow].start_request(ev.session, seed=self._seed)


def replay_trace(drivers: Dict[str, ClusterDriver], trace: ArrivalTrace, *,
                 seed: int = 0, eager: bool = False):
    """Schedule a recorded trace onto the drivers' shared loop.  Lazy by
    default (returns the :class:`TraceReplaySource`); ``eager=True``
    pre-schedules every row and returns the count — both paths start
    each session with the same ``(rid, seed)``, so completions match
    bit-for-bit (gated by the parity test)."""
    if eager:
        missing = sorted({e.workflow for e in trace.events} - set(drivers))
        if missing:
            raise KeyError(f"trace names workflows with no driver: {missing}")
        for ev in trace.events:
            drv = drivers[ev.workflow]
            drv.loop.schedule(ev.t, drv.start_request, ev.session, seed)
        return len(trace.events)
    return TraceReplaySource(drivers, trace, seed=seed)
