"""Multi-agent debate workload (K agents x R rounds).

K debater instances of one LLM argue for R rounds: each round every
agent speaks once, in parallel, conditioned on *all* agents' prior
statements.  An agent's prompt extends its own transcript (parent =
its previous statement, cross-round prefix reuse) while the other
agents' latest statements are newly appended — the cross-agent prefix
structure that distinguishes debate from independent sampling.  A final
judge LLM reads the whole debate and issues the verdict.  The round
count is data-dependent (hard questions debate longer).
"""
from __future__ import annotations

import random

from repro.configs.paper_workloads import LLAMA_3_1_8B, LLAMA_3_2_1B
from repro.workflows.runtime import Call, Tool, Workflow

NUM_AGENTS = 3
MAX_ROUNDS = 5
STATEMENT_TOKENS = 80  # statement length scale


def debate_program(rng: random.Random):
    question = 60 + int(rng.lognormvariate(5.0, 0.4))
    rounds = min(2 + int(rng.expovariate(1 / 1.5)), MAX_ROUNDS)
    handles = [None] * NUM_AGENTS  # per-agent own-transcript lineage
    context = question  # tokens visible to every agent this round
    statements = []  # per-round statement lengths (for the judge)

    for _ in range(rounds):
        lens = [STATEMENT_TOKENS // 2 + int(rng.expovariate(1 / 40.0))
                for _ in range(NUM_AGENTS)]
        results = yield [Call("debater", context, lens[a], parent=handles[a])
                         for a in range(NUM_AGENTS)]
        handles = [r.handle for r in results]
        statements.extend(lens)
        context += sum(lens)  # everyone sees everyone's new statements

    # non-LLM: collate transcripts for the judge
    yield Tool(0.002)
    yield [Call("judge", question + sum(statements),
                40 + int(rng.expovariate(1 / 40.0)))]


DEBATE = Workflow(
    name="debate",
    program=debate_program,
    llms={"debater": LLAMA_3_2_1B, "judge": LLAMA_3_1_8B},
)
