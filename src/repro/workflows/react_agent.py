"""ReAct-style tool-agent loop workload.

An agent LLM alternates thought/action steps with external tool calls
(search, code execution, ...), feeding each observation back into its
growing context; a small summarizer LLM compresses long tool outputs
before they enter the context.  Execution is data-dependent: the number
of loop iterations, the tool latencies, and the observation lengths are
all drawn per request.  Every agent step extends the agent's own prior
context — the dominant prefix-cache pattern of tool agents.
"""
from __future__ import annotations

import random

from repro.configs.paper_workloads import LLAMA_3_2_1B, QWEN_2_5_3B_AGENT
from repro.workflows.runtime import Call, Tool, Workflow

MAX_STEPS = 12
SUMMARIZE_THRESHOLD = 300  # tool outputs longer than this get compressed


def react_agent_program(rng: random.Random):
    context = 120 + int(rng.lognormvariate(5.0, 0.5))  # task + tool schemas
    steps = min(2 + int(rng.expovariate(1 / 3.0)), MAX_STEPS)
    agent_handle = None

    for _ in range(steps):
        # think + act: the agent emits a thought and a tool invocation,
        # continuing its own transcript (prefix hit on all prior turns)
        action_tokens = 30 + int(rng.expovariate(1 / 40.0))
        (act,) = yield [Call("agent", context, action_tokens,
                             parent=agent_handle)]
        agent_handle = act.handle
        context += action_tokens

        # external tool execution (search / code / API round-trip)
        yield Tool(0.01 + rng.expovariate(1 / 0.05))

        # observation: long tool outputs are compressed by the summarizer
        obs = int(rng.expovariate(1 / 250.0)) + 20
        if obs > SUMMARIZE_THRESHOLD:
            summary_tokens = 40 + int(rng.expovariate(1 / 40.0))
            yield [Call("summ", obs, summary_tokens)]
            obs = summary_tokens
        context += obs

    # final answer over the full trajectory
    yield [Call("agent", context, 80 + int(rng.expovariate(1 / 80.0)),
                parent=agent_handle)]


REACT_AGENT = Workflow(
    name="react_agent",
    program=react_agent_program,
    llms={"agent": QWEN_2_5_3B_AGENT, "summ": LLAMA_3_2_1B},
)
