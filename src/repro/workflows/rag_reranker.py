"""RAG + reranker workflow (paper §7 workload).

embed query -> retrieve (tool) -> rerank k docs in parallel (cross-encoder)
-> generate with top docs.  Heterogeneous LLMs: a tiny embedder, a tiny
reranker and an 8B generator — the case where fractional GPU allocation
matters most (§5's 1/13-GPU example).
"""
from __future__ import annotations

import random

from repro.configs.paper_workloads import (E5_BASE_V2, LLAMA_3_1_8B,
                                           RERANKER_MINILM)
from repro.workflows.runtime import Call, Tool, Workflow

RERANK_K = 8


def rag_reranker_program(rng: random.Random):
    query = 16 + int(rng.expovariate(1 / 30.0))
    # 1) embed the query
    yield [Call("emb", query, 1)]
    # 2) vector-store retrieval (non-LLM tool)
    yield Tool(0.004)
    # 3) rerank candidates in parallel
    doc_len = lambda: 120 + int(rng.expovariate(1 / 120.0))
    yield [Call("rer", query + doc_len(), 1) for _ in range(RERANK_K)]
    # 4) generate from the top documents
    ctx = query + 3 * 250
    out = 80 + int(rng.expovariate(1 / 120.0))
    yield [Call("gen", ctx, out)]


RAG_RERANKER = Workflow(
    name="rag_reranker",
    program=rag_reranker_program,
    llms={"emb": E5_BASE_V2, "rer": RERANKER_MINILM, "gen": LLAMA_3_1_8B},
)
