"""Framework-agnostic workflow runtime.

A workflow is *any* Python generator: it yields groups of LLM calls (or
tool calls) and receives their results — Scepsy never inspects the
program, mirroring the paper's "unrestricted programming model" property.
Two executors drive the same programs:

  * :func:`trace_workflow` — the tracing deployment (paper §4 step 1):
    each workflow-level request runs against an *unloaded* engine (nominal
    cost-model durations, no queueing), and the TracingProxy captures the
    LLM-level telemetry;
  * :class:`ClusterDriver` — the full discrete-event cluster execution
    used by the end-to-end benchmarks: Poisson arrivals, routing,
    continuous batching, prefix caching.
"""
from __future__ import annotations

import itertools
import math
import random
from dataclasses import dataclass
from functools import partial
from typing import Callable, Dict, Generator, List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig
from repro.core.trace import LLMCall, TracingProxy, TraceStore
from repro.serving import costmodel as cm
from repro.serving.radix import Segment
from repro.serving.simulator import (EngineRequest, EventLoop, Router,
                                     output_segment)


@dataclass
class Call:
    llm: str
    prompt_tokens: int
    output_tokens: int
    parent: Optional[int] = None  # handle of a prior call (prefix sharing)


@dataclass
class Tool:
    seconds: float = 0.005  # non-LLM step; negligible per the paper


@dataclass
class CallResult:
    handle: int
    t_start: float
    t_end: float


WorkflowProgram = Callable[[random.Random], Generator]


class _GroupJoin:
    """Fan-in for one yielded call group: collects each call's result
    and resumes the workflow generator when the last one lands.  One
    small object per group (plus a two-arg partial per call) instead of
    one full closure per call — the driver's hot-path allocation."""

    __slots__ = ("driver", "gen", "rec", "results", "pending")

    def __init__(self, driver: "ClusterDriver", gen: Generator,
                 rec: "RequestRecord", n: int):
        self.driver = driver
        self.gen = gen
        self.rec = rec
        self.results: List[Optional[CallResult]] = [None] * n
        self.pending = n

    def done(self, i: int, llm: str, req: "EngineRequest") -> None:
        d = self.driver
        self.results[i] = CallResult(req.req_id, req.t_start_service,
                                     req.t_done)
        if d.telemetry is not None:
            d.telemetry.record_call(d.wf.name, llm, req)
        if d.tracer is not None:
            d.tracer.on_call_done(d.wf.name, self.rec.request_id, llm, req)
        self.pending -= 1
        if self.pending == 0:
            d._advance(self.gen, self.rec, self.results)


@dataclass
class Workflow:
    name: str
    program: WorkflowProgram
    llms: Dict[str, ArchConfig]  # logical name -> architecture
    # service tier (repro.qos.slo.SLOClass); None = unclassified, which
    # every layer treats as best-effort with no admission control
    slo: Optional[object] = None


def with_slo(wf: Workflow, slo) -> Workflow:
    """The same workflow under a service tier (program shared, not copied)."""
    return Workflow(wf.name, wf.program, dict(wf.llms), slo=slo)


# ---------------------------------------------------------------------------
# Tracing executor (unloaded deployment, nominal durations)
# ---------------------------------------------------------------------------


def nominal_call_seconds(cfg: ArchConfig, prompt: int, out: int,
                         cached: int = 0) -> float:
    pf = cm.prefill_cost(cfg, prompt, cached_tokens=cached).total
    dc = cm.decode_step_cost(cfg, 1, prompt + out // 2).total
    return pf + out * dc


def trace_workflow(wf: Workflow, n_requests: int, *, seed: int = 0,
                   cache_aware: bool = True) -> TraceStore:
    proxy = TracingProxy(wf.name)
    handle_counter = [0]
    for rid in range(n_requests):
        rng = random.Random((seed << 20) + rid)
        gen = wf.program(rng)
        proxy.begin_request(rid, 0.0)
        t = 0.0
        handles: Dict[int, CallResult] = {}
        totals: Dict[int, int] = {}  # handle -> prompt+output tokens
        try:
            group = next(gen)
            while True:
                if isinstance(group, Tool):
                    t += group.seconds
                    group = gen.send([])
                    continue
                calls: Sequence[Call] = group
                results = []
                t_end_group = t
                for c in calls:
                    cfg = wf.llms[c.llm]
                    cached = 0
                    if cache_aware and c.parent is not None and c.parent in handles:
                        # exact: the child re-sends its parent's full
                        # sequence (prompt + output) as its prefix
                        cached = max(min(totals[c.parent],
                                         c.prompt_tokens - 1), 0)
                    dur = nominal_call_seconds(cfg, c.prompt_tokens,
                                               c.output_tokens, cached)
                    handle_counter[0] += 1
                    h = handle_counter[0]
                    res = CallResult(h, t, t + dur)
                    handles[h] = res
                    totals[h] = c.prompt_tokens + c.output_tokens
                    results.append(res)
                    proxy.record(LLMCall(
                        workflow_request=rid, llm=c.llm, t_start=t,
                        t_end=t + dur, prompt_tokens=c.prompt_tokens,
                        output_tokens=c.output_tokens,
                        cached_prefix_tokens=cached))
                    t_end_group = max(t_end_group, t + dur)
                t = t_end_group
                group = gen.send(results)
        except StopIteration:
            pass
        proxy.end_request(rid, t)
    return proxy.store


# ---------------------------------------------------------------------------
# Cluster executor (end-to-end benchmark driver)
# ---------------------------------------------------------------------------


def _truncate_seq(seq: Sequence[Segment], n: int) -> Tuple[Segment, ...]:
    """Leading ``n`` tokens of a segment sequence (last span partial)."""
    out: List[Segment] = []
    left = n
    for seg_id, length in seq:
        if left <= 0:
            break
        take = min(length, left)
        out.append((seg_id, take))
        left -= take
    return tuple(out)


@dataclass
class RequestRecord:
    request_id: int
    arrival: float
    done: float = -1.0
    # request-level QoS bookkeeping (populated when the driver has a
    # WorkflowQoS context):
    slo_class: str = ""
    deadline: float = math.inf  # absolute; inf = best-effort
    rejected: bool = False  # shed at the front door, never dispatched
    degraded: bool = False  # admitted, but demoted to best-effort
    # admitted via JIT model substitution: calls to substitutable stages
    # route to the substitute tier's replicas; SLO class/deadline kept
    substituted: bool = False
    issued_s: float = 0.0  # expected work already dispatched (WorkModel)
    # True when an installed Tracer holds this request in its trace
    # reservoir: unsampled requests skip the per-group/per-tool hooks
    obs_sampled: bool = False

    @property
    def latency(self) -> float:
        return self.done - self.arrival

    @property
    def slo_met(self) -> bool:
        """Completed within its latency target (best-effort is always
        met; rejected or unfinished requests never are)."""
        return self.done >= 0 and not self.rejected \
            and self.done <= self.deadline


class ArrivalSource:
    """Generator-driven lazy arrival stream: exactly ONE pending loop
    event (the next arrival) at any time, so a million-request ramp
    costs O(1) heap space instead of O(N) pre-materialized events.

    The underlying generator yields ``(t, rid)`` pairs drawn from the
    *same* RNG in the *same* order as the legacy eager schedulers, so
    lazy and eager runs see identical arrival processes (gated by an
    equivalence test).  ``scheduled`` counts arrivals fired so far.
    """

    def __init__(self, driver: "ClusterDriver", gen, seed: int):
        self._driver = driver
        self._gen = gen
        self._seed = seed
        self.scheduled = 0
        self.exhausted = False
        self._arm()

    def _arm(self) -> None:
        try:
            t, rid = next(self._gen)
        except StopIteration:
            self.exhausted = True
            return
        self._driver.loop.schedule(t, self._fire, rid)

    def _fire(self, rid: int) -> None:
        self.scheduled += 1
        self._arm()  # keep the stream primed before running the program
        self._driver._start(rid, self._seed)


class ClusterDriver:
    """Drives workflow requests through routed engine replicas.

    ``routers`` is keyed by whatever serving tier the deployment exposes:
    workflow-local stage names in a partitioned deployment, or shared
    tenant ids in a pooled one — in the latter case ``route_map``
    translates each call's workflow-local LLM name to its tenant, so the
    same workflow program runs unchanged against pooled replicas.

    ``telemetry`` (optional, duck-typed — e.g. a
    :class:`repro.core.drift.DriftMonitor`) receives ``record_arrival``,
    ``record_call`` and ``record_request_done`` callbacks, the live
    signal the online drift detector runs on; ``record_shed`` is called
    (when the sink defines it) for front-door rejections/degradations.

    ``qos`` (a :class:`repro.qos.slo.WorkflowQoS`) turns on request-
    level QoS: each arrival passes admission control (when the context
    carries a controller), every engine request is tagged with
    :class:`repro.qos.slo.RequestQoS` metadata — deadline, class weight
    and the work model's remaining-work estimate — which the engines'
    queue disciplines order by.

    ``sink`` (a :class:`repro.core.telemetry.StatsSink`, duck-typed)
    switches the driver to aggregate-only accounting: ``records`` stays
    empty and every completion feeds the sink's counters/sketches
    instead, so memory is O(in-flight) regardless of run length.  The
    default (no sink) keeps the exact per-request record list.
    """

    # handles are unique process-wide: drivers can share pooled engine
    # replicas, and engine-side prefix/parent registries key on them
    _uid = itertools.count(1)

    def __init__(self, wf: Workflow, routers: Dict[str, Router],
                 loop: EventLoop,
                 route_map: Optional[Dict[str, str]] = None,
                 telemetry=None, qos=None, sink=None,
                 substitute_map: Optional[Dict[str, str]] = None):
        self.wf = wf
        self.routers = routers
        self.loop = loop
        self.route_map = route_map or {}
        # JIT substitution routes: workflow-local llm name -> router key
        # of the substitute tier's replicas (used only for requests the
        # admission controller decided to substitute)
        self.substitute_map = substitute_map or {}
        self.telemetry = telemetry
        self.qos = qos
        self.sink = sink
        # observability hook (repro.obs.spans.Tracer); None = untraced
        # fast path — every site below guards on it
        self.tracer = None
        self.records: List[RequestRecord] = []
        self.n_started = 0
        self.n_completed = 0
        # call handle -> full segment sequence (prompt + output) of the
        # call, kept while its workflow request is in flight so children
        # can extend it; pruned at request completion
        self._seqs: Dict[int, Tuple[Segment, ...]] = {}
        self._rec_handles: Dict[int, List[int]] = {}
        # distinct router objects, for sticky-state pruning on completion
        # (baseline systems may pass duck-typed routers without forget)
        self._router_objs = [r for r in
                             {id(r): r for r in routers.values()}.values()
                             if hasattr(r, "forget")]

    def set_routers(self, routers: Dict[str, Router],
                    route_map: Optional[Dict[str, str]] = None) -> None:
        """Swap the serving tier live (replan adoption mid-run): future
        calls route through the new views, and the sticky-prune list is
        recomputed so ``Router.forget`` keeps reaching the routers that
        are actually accumulating sticky state."""
        self.routers = routers
        if route_map is not None:
            self.route_map = route_map
        self._router_objs = [r for r in
                             {id(r): r for r in routers.values()}.values()
                             if hasattr(r, "forget")]

    def router_for(self, llm: str, rec: Optional["RequestRecord"] = None
                   ) -> Router:
        """The router serving a workflow-local LLM name (tenancy-aware).

        When ``rec`` was admitted via substitution, stages with a
        substitute route go to the substitute tier's replicas instead.
        """
        if rec is not None and rec.substituted \
                and llm in self.substitute_map:
            return self.routers[self.substitute_map[llm]]
        return self.routers[self.route_map.get(llm, llm)]

    def schedule_open_loop(self, arrival_rate: float, n_requests: int, *,
                           seed: int = 0, start: float = 0.0,
                           rid_start: int = 0,
                           arrival_seed: Optional[int] = None,
                           eager: bool = False):
        """Constant-rate Poisson arrivals, lazily generated by default
        (one pending event; see :class:`ArrivalSource`).  ``eager=True``
        pre-schedules every arrival (legacy behavior, O(N) pending) and
        returns the count; otherwise returns the source.  The arrival
        process draws from ``arrival_seed`` (default: ``seed``); request
        programs always seed from ``seed``.
        """
        aseed = seed if arrival_seed is None else arrival_seed
        if eager:
            rng = random.Random(aseed)
            t = start
            for rid in range(rid_start, rid_start + n_requests):
                self.loop.schedule(t, self._start, rid, seed)
                t += rng.expovariate(arrival_rate)
            return n_requests

        def gen():
            rng = random.Random(aseed)
            t = start
            for rid in range(rid_start, rid_start + n_requests):
                yield t, rid
                t += rng.expovariate(arrival_rate)

        return ArrivalSource(self, gen(), seed)

    def run_open_loop(self, arrival_rate: float, n_requests: int, *,
                      seed: int = 0, until: float = math.inf,
                      eager: bool = False) -> List[RequestRecord]:
        self.schedule_open_loop(arrival_rate, n_requests, seed=seed,
                                eager=eager)
        self.loop.run(until)
        return [r for r in self.records if r.done >= 0]

    def schedule_arrivals(self, segments: Sequence[tuple], *,
                          seed: int = 0, start: float = 0.0,
                          rid_start: int = 0, eager: bool = False):
        """Schedule piecewise-constant Poisson arrivals.

        ``segments`` is a sequence of ``(rate, duration_s)`` pairs — the
        arrival-rate *ramp* used to reproduce rate drift without
        hardware.  Request ids continue from ``rid_start``.  Lazy by
        default: returns an :class:`ArrivalSource` whose ``scheduled``
        counter is live; ``eager=True`` pre-schedules everything and
        returns the request count (legacy behavior).
        """
        if eager:
            rng = random.Random(seed)
            rid = rid_start
            t_seg = start
            for rate, duration in segments:
                t_end = t_seg + duration
                t = t_seg
                while rate > 0:
                    t += rng.expovariate(rate)
                    if t >= t_end:
                        break
                    self.loop.schedule(t, self._start, rid, seed)
                    rid += 1
                t_seg = t_end
            return rid - rid_start

        def gen():
            rng = random.Random(seed)
            rid = rid_start
            t_seg = start
            for rate, duration in segments:
                t_end = t_seg + duration
                t = t_seg
                while rate > 0:
                    t += rng.expovariate(rate)
                    if t >= t_end:
                        break
                    yield t, rid
                    rid += 1
                t_seg = t_end

        return ArrivalSource(self, gen(), seed)

    def run_ramped(self, segments: Sequence[tuple], *, seed: int = 0,
                   until: float = math.inf) -> List[RequestRecord]:
        """Open-loop run under an arrival-rate ramp (see
        :meth:`schedule_arrivals`)."""
        self.schedule_arrivals(segments, seed=seed)
        self.loop.run(until)
        return [r for r in self.records if r.done >= 0]

    def start_request(self, rid: int, seed: int = 0) -> None:
        """Begin one workflow-level request now (external arrival
        control — e.g. several drivers interleaved on one loop)."""
        self._start(rid, seed)

    def _start(self, rid: int, seed: int) -> None:
        rec = RequestRecord(rid, self.loop.now)
        self.n_started += 1
        if self.sink is None:
            self.records.append(rec)
        else:
            self.sink.observe_arrival(self.wf.name, self.loop.now)
        if self.telemetry is not None:
            self.telemetry.record_arrival(self.wf.name, self.loop.now)
        if self.tracer is not None:
            rec.obs_sampled = self.tracer.on_request_start(
                self.wf.name, rid, self.loop.now)
        if self.qos is not None:
            slo = self.qos.slo
            rec.slo_class = slo.name
            rec.deadline = self.loop.now + slo.deadline_s
            if self.qos.admission is not None:
                decision = self.qos.admission.admit(
                    self.wf.name, self.loop.now)
                if self.tracer is not None:
                    self.tracer.on_request_admission(
                        self.wf.name, rid, decision, self.loop.now)
                if decision == "reject":
                    rec.rejected = True
                    if self.sink is not None:
                        self.sink.observe_reject(self.wf.name)
                    if self.telemetry is not None and \
                            hasattr(self.telemetry, "record_shed"):
                        self.telemetry.record_shed(
                            self.wf.name, slo.name, "reject", self.loop.now)
                    return
                if decision == "substitute":
                    # rerouted to the substitute tier; class and
                    # deadline are KEPT (substitution never upgrades)
                    rec.substituted = True
                    if self.sink is not None and \
                            hasattr(self.sink, "observe_substitute"):
                        self.sink.observe_substitute(self.wf.name)
                    if self.telemetry is not None and \
                            hasattr(self.telemetry, "record_shed"):
                        self.telemetry.record_shed(
                            self.wf.name, slo.name, "substitute",
                            self.loop.now)
                if decision == "degrade":
                    rec.degraded = True
                    rec.deadline = math.inf
                    if self.sink is not None:
                        self.sink.observe_degrade(self.wf.name)
                    if self.telemetry is not None and \
                            hasattr(self.telemetry, "record_shed"):
                        self.telemetry.record_shed(
                            self.wf.name, slo.name, "degrade", self.loop.now)
        rng = random.Random((seed << 20) + rid)
        gen = self.wf.program(rng)
        self._advance(gen, rec, None)

    def _advance(self, gen: Generator, rec: RequestRecord, send_val) -> None:
        try:
            group = next(gen) if send_val is None else gen.send(send_val)
        except StopIteration:
            rec.done = self.loop.now
            self.n_completed += 1
            for h in self._rec_handles.pop(rec.request_id, []):
                self._seqs.pop(h, None)
            for router in self._router_objs:
                router.forget(rec.request_id)
            if self.sink is not None:
                self.sink.observe(self.wf.name, rec)
            if self.telemetry is not None:
                self.telemetry.record_request_done(self.wf.name, rec)
            if self.tracer is not None:
                self.tracer.on_request_done(self.wf.name, rec)
            return
        if isinstance(group, Tool):
            if rec.obs_sampled and self.tracer is not None:
                self.tracer.on_tool(self.wf.name, rec.request_id,
                                    self.loop.now, group.seconds)
            self.loop.schedule(self.loop.now + group.seconds,
                               self._advance, gen, rec, [])
            return
        calls: Sequence[Call] = group
        join = _GroupJoin(self, gen, rec, len(calls))
        tr = self.tracer
        sampled = (rec.obs_sampled and tr is not None
                   and tr.on_group_start(self.wf.name, rec.request_id,
                                         self.loop.now, len(calls)))
        for i, c in enumerate(calls):
            h = next(ClusterDriver._uid)
            out_tokens = max(c.output_tokens, 1)
            prefix, truth = self._prefix_for(h, c)
            self._seqs[h] = prefix + (output_segment(h, out_tokens),)
            self._rec_handles.setdefault(rec.request_id, []).append(h)
            if sampled:
                tr.on_call_submit(self.wf.name, rec.request_id, h, c.llm,
                                  self.loop.now)
            req = EngineRequest(
                req_id=h, prompt_tokens=c.prompt_tokens,
                output_tokens=out_tokens, arrival=self.loop.now,
                on_complete=partial(join.done, i, c.llm),
                parent_id=c.parent,
                workflow_request=rec.request_id,
                prefix=prefix, true_prefix=truth,
                qos=self._request_qos(rec, c.llm))
            self.router_for(c.llm, rec).submit(req)

    def _prefix_for(self, h: int, c: Call
                    ) -> Tuple[Tuple[Segment, ...], int]:
        """The call's prompt as a segment sequence, plus the ground-truth
        shared-prefix tokens (vs its parent) for the exactness gate.

        A child's prompt is modeled as its parent's full sequence
        (prompt + generated output) followed by a fresh delta segment;
        when the child's prompt is *shorter* than the parent's sequence
        (beam-search style truncation) it is the truncated parent
        sequence instead.  Parentless prompts are one fresh segment.
        """
        p = c.parent
        if p is None or p not in self._seqs:
            return ((("s", h), max(c.prompt_tokens, 1)),), 0
        parent_seq = self._seqs[p]
        ptotal = sum(length for _, length in parent_seq)
        if c.prompt_tokens > ptotal:
            prefix = parent_seq + ((("d", h), c.prompt_tokens - ptotal),)
        else:
            prefix = _truncate_seq(parent_seq, max(c.prompt_tokens, 1))
        truth = max(min(ptotal, c.prompt_tokens - 1), 0)
        return prefix, truth

    def _request_qos(self, rec: RequestRecord, llm: str):
        """Tag one engine request with this workflow request's urgency
        state: deadline, class weight, and the work model's estimate of
        the work still ahead once this call finishes."""
        if self.qos is None:
            return None
        from repro.qos.slo import RequestQoS

        work = self.qos.work
        rec.issued_s += work.per_call_s.get(llm, 0.0)
        slo = self.qos.slo
        return RequestQoS(
            tenant=self.wf.name, slo=slo.name, weight=slo.weight,
            deadline=rec.deadline,
            remaining_s=work.remaining_after(rec.issued_s),
            degraded=rec.degraded)


# ---------------------------------------------------------------------------
# Drift injection (reproducible share-shifting request mixes)
# ---------------------------------------------------------------------------


def drift_workflow(wf: Workflow, *,
                   output_scale: Optional[Dict[str, float]] = None,
                   call_repeat: Optional[Dict[str, int]] = None,
                   name: Optional[str] = None) -> Workflow:
    """A share-shifted variant of ``wf`` for drift experiments.

    ``output_scale`` multiplies the output length of calls to the named
    LLMs (shifting that LLM's aggregate execution-time share and token
    distribution); ``call_repeat`` issues each call to the named LLMs
    ``k`` times in parallel (shifting n_m).  Extra repeated calls are
    invisible to the wrapped program — it receives exactly the results
    it asked for — so any workflow program can be drifted unmodified.
    """
    scales = dict(output_scale or {})
    repeats = dict(call_repeat or {})

    def program(rng: random.Random) -> Generator:
        gen = wf.program(rng)
        try:
            group = next(gen)
        except StopIteration:
            return
        while True:
            if isinstance(group, Tool):
                sent = yield group
            else:
                out_calls: List[Call] = []
                keep: List[int] = []
                for c in group:
                    out = max(int(round(
                        c.output_tokens * scales.get(c.llm, 1.0))), 1)
                    keep.append(len(out_calls))
                    out_calls.append(Call(c.llm, c.prompt_tokens, out,
                                          parent=c.parent))
                    for _ in range(max(repeats.get(c.llm, 1), 1) - 1):
                        out_calls.append(Call(c.llm, c.prompt_tokens, out))
                results = yield out_calls
                sent = ([results[i] for i in keep]
                        if results else results)
            try:
                group = gen.send(sent)
            except StopIteration:
                return

    return Workflow(name or f"{wf.name}", program, dict(wf.llms),
                    slo=wf.slo)
