"""Observed-vs-predicted reconciliation — the paper-closing analyzer.

Scepsy's aggregate abstraction stands on two empirical claims: per-LLM
*execution-time shares* are stable across executions (so a workflow can
be summarized by its aggregate pipeline), and the pipeline's
:class:`~repro.core.pipeline.Prediction` prices latency well enough to
drive allocation.  This module audits both against a live run:

* :func:`expected_shares` — what the deployed plan *assumed*: profiled
  ``mean_share`` from a :class:`~repro.core.aggregate.WorkflowStats`,
  an :class:`~repro.core.pipeline.AggregateLLMPipeline`'s stages, or a
  :class:`~repro.core.pipeline.MergedPipeline`'s per-workflow members;
* :func:`share_report` — observed shares (the tracer's busy-seconds
  totals over every completed call — the same weighting
  :func:`expected_shares` applies to the planned pipeline) against
  expected, with per-LLM and max relative error;
* :func:`critical_path_report` — which stage dominates each workflow's
  end-to-end time, from sampled span phases (each group phase is
  attributed to its last-finishing call's LLM; tool phases to
  ``tool``), with the exact-tiling residual check;
* :func:`predictor_report` — measured request latency against the
  deployed allocation's ``Prediction`` (total and per-LLM serial
  contributions);
* :func:`accuracy_report` — all of the above in one JSON-safe dict,
  optionally feeding :meth:`DriftMonitor.corroborate
  <repro.core.drift.DriftMonitor.corroborate>`.
"""
from __future__ import annotations

from typing import Dict, Optional

SHARE_FLOOR = 0.02  # relative-error denominator floor (matches DriftConfig)


def _normalize(row: Dict[str, float]) -> Dict[str, float]:
    total = sum(row.values())
    if total <= 0:
        return dict(row)
    return {m: v / total for m, v in row.items()}


def expected_shares(source, workflow: Optional[str] = None
                    ) -> Dict[str, float]:
    """Planned per-LLM execution-time shares, duck-dispatched:

    * ``WorkflowStats`` — profiled ``per_llm[m].mean_share``;
    * ``MergedPipeline`` + ``workflow`` — that workflow's members, each
      weighted by calls/request times the member profile's low-load
      latency (the tenant decomposition of the profiled shares);
    * any other ``AggregateLLMPipeline`` — its stages' ``mean_share``.

    Always normalized to sum to 1 over the LLMs present.
    """
    per_llm = getattr(source, "per_llm", None)
    if per_llm is not None:  # WorkflowStats
        return _normalize({m: st.mean_share for m, st in per_llm.items()})
    members_of = getattr(source, "members_of", None)
    if members_of is not None and workflow is not None:  # MergedPipeline
        # keyed by the member's workflow-local stage name (``t.llm``) —
        # the name the driver dispatches (and the tracer observes) under
        row: Dict[str, float] = {}
        for _cid, members in members_of(workflow).items():
            for t in members:
                tp0 = 1
                cap = t.profile.max_throughput(tp0)
                lat = t.profile.latency(0.05 * cap if cap > 0 else 0.0, tp0)
                row[t.llm] = row.get(t.llm, 0.0) + t.n * lat
        return _normalize(row)
    stages = getattr(source, "stages", None)
    if stages is not None:  # AggregateLLMPipeline
        return _normalize({m: st.mean_share for m, st in stages.items()})
    raise TypeError(f"cannot derive expected shares from {type(source)!r}")


def share_report(observed: Dict[str, Dict[str, float]],
                 expected: Dict[str, Dict[str, float]]) -> dict:
    """Per-(workflow, LLM) observed-vs-expected share errors.

    ``rel_err`` divides by ``max(expected, SHARE_FLOOR)`` so a tiny
    planned share cannot manufacture a huge relative error; ``max_rel_
    err`` over all pairs is the value ``bench_obs`` gates at 15%.
    """
    per_workflow: Dict[str, dict] = {}
    worst = 0.0
    for w in sorted(set(observed) & set(expected)):
        obs_row, exp_row = observed[w], expected[w]
        rows: Dict[str, dict] = {}
        w_worst = 0.0
        for m in sorted(set(obs_row) | set(exp_row)):
            o = obs_row.get(m, 0.0)
            e = exp_row.get(m, 0.0)
            rel = abs(o - e) / max(e, SHARE_FLOOR)
            rows[m] = {"observed": o, "expected": e, "rel_err": rel}
            w_worst = max(w_worst, rel)
        per_workflow[w] = {"per_llm": rows, "max_rel_err": w_worst}
        worst = max(worst, w_worst)
    return {"per_workflow": per_workflow, "max_rel_err": worst}


def critical_path_report(tracer) -> dict:
    """Where each workflow's end-to-end time goes, from sampled spans.

    Each finished sampled request's phases are attributed: a group phase
    to the LLM of its last-finishing call, a tool phase to ``tool``.
    Phases tile ``[arrival, done]`` by construction, so per workflow the
    attributed seconds sum to the sampled total latency — ``residual``
    (relative) reports how exactly, and ``dominant`` names the stage
    with the largest attributed fraction.
    """
    out: Dict[str, dict] = {}
    acc: Dict[str, Dict[str, float]] = {}
    lat: Dict[str, float] = {}
    cnt: Dict[str, int] = {}
    for tr in tracer.traces(finished_only=True):
        if tr["outcome"] == "rejected":
            continue
        w = tr["workflow"]
        row = acc.setdefault(w, {})
        for ph in tr["phases"]:
            dur = ph["t1"] - ph["t0"]
            key = (ph.get("critical_llm") or "unattributed"
                   if ph["kind"] == "group" else "tool")
            row[key] = row.get(key, 0.0) + dur
        lat[w] = lat.get(w, 0.0) + (tr["done"] - tr["arrival"])
        cnt[w] = cnt.get(w, 0) + 1
    for w, row in acc.items():
        total = lat[w]
        attributed = sum(row.values())
        residual = abs(total - attributed) / total if total > 0 else 0.0
        breakdown = {k: {"seconds": v,
                         "fraction": v / total if total > 0 else 0.0}
                     for k, v in sorted(row.items(), key=lambda kv: -kv[1])}
        dominant = max(row, key=row.get) if row else ""
        out[w] = {"sampled_requests": cnt[w],
                  "mean_latency": total / cnt[w] if cnt[w] else 0.0,
                  "breakdown": breakdown,
                  "dominant": dominant,
                  "residual_rel": residual}
    return out


def predictor_report(tracer, predictions: Dict[str, object]) -> dict:
    """Measured request latency vs the deployed ``Prediction``.

    ``predictions`` maps workflow -> :class:`repro.core.pipeline.
    Prediction` (e.g. from ``MergedPipeline.attribute`` or a plain
    pipeline's ``predict``).  Reports mean/p50/p99 measured latency,
    the predicted latency, their ratio, and the prediction's per-LLM
    serial contributions for side-by-side reading with the critical-
    path breakdown.
    """
    out: Dict[str, dict] = {}
    for w, pred in sorted(predictions.items()):
        meas = tracer.request_latency(w)
        if not meas.get("count"):
            continue
        predicted = pred.latency
        out[w] = {
            "measured": meas,
            "predicted_latency": predicted,
            "ratio_mean_over_predicted": (
                meas["mean"] / predicted if predicted > 0 else float("inf")),
            "feasible": pred.feasible,
            "bottleneck_llm": pred.bottleneck_llm,
            "per_llm_predicted": dict(pred.per_llm_latency),
        }
    return out


def accuracy_report(tracer, expected: Dict[str, Dict[str, float]], *,
                    predictions: Optional[Dict[str, object]] = None,
                    monitor=None, tol: float = 0.25) -> dict:
    """One JSON-safe reconciliation document for a finished run.

    ``expected`` maps workflow -> planned shares (see
    :func:`expected_shares`); ``predictions`` optionally adds the
    predictor-error section; ``monitor`` (a :class:`repro.core.drift.
    DriftMonitor`) additionally cross-checks the tracer's shares
    against the monitor's EWMAs (:meth:`DriftMonitor.corroborate
    <repro.core.drift.DriftMonitor.corroborate>`).
    """
    observed = tracer.observed_shares()
    report = {
        "shares": share_report(observed, expected),
        "critical_path": critical_path_report(tracer),
    }
    if predictions is not None:
        report["predictor"] = predictor_report(tracer, predictions)
    if monitor is not None:
        report["corroboration"] = monitor.corroborate(observed, tol=tol)
    return report
