"""Span records + the Tracer hook threaded through the serving stack.

Span model (one sampled workflow request):

* the **request span** runs from arrival to completion;
* its **phase spans** tile it exactly: at any moment the driver is
  either waiting on one yielded call group (``group`` phase, closed
  when the last call lands) or on a tool timer (``tool`` phase), so
  the phase durations sum to the end-to-end latency — an invariant the
  property tests (and ``bench_obs``'s critical-path gate) check;
* each **call span** inside a group records queued time (router submit
  to engine admission), service time (admission to completion), exact
  prefill seconds (accumulated per admitted chunk from the engine's
  cost model), and point events (preemption, substitution).

Sampling: per-workflow reservoir (algorithm R) over *arrivals*, so a
10^6-request run holds at most ``sample_per_workflow`` request traces
per workflow — O(sample) memory — while every request still feeds the
O(1) aggregate accounting (per-(workflow, LLM) execution shares,
latency sketches, metric counters).  The reservoir draws from its own
RNG, never the simulation's, so installing a tracer cannot perturb a
seeded run (``bench_obs`` gates bit-identical completion traces).

Every hook site in the driver / engine / router / admission / replan
layers is guarded by ``if tracer is None`` on a plain attribute that
defaults to ``None`` — the disabled path allocates nothing and runs no
observability code at all.
"""
from __future__ import annotations

import random
from bisect import bisect_left
from typing import Dict, List, Optional, Tuple

from repro.core.telemetry import GKQuantile
from repro.obs.metrics import MetricsRegistry

# phase / event kinds
GROUP = "group"
TOOL = "tool"
QUEUED = "queued"
SERVICE = "service"
PREFILL = "prefill"
DECODE = "decode"
PREEMPTED = "preempted"
SUBSTITUTED = "substituted"
MIGRATED = "migrated"

_BATCH_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256)

# engine-iteration hooks are stride-sampled (1-in-ITER_STRIDE events
# take the full accounting path); must be a power of two
ITER_STRIDE = 8
_ITER_MASK = ITER_STRIDE - 1


class _ReqTrace:
    """One sampled workflow request: phases tile [arrival, done]."""

    __slots__ = ("workflow", "rid", "arrival", "done", "outcome",
                 "slo_class", "phases", "calls", "events", "_open",
                 "_open_calls", "live_handles")

    def __init__(self, workflow: str, rid: int, arrival: float):
        self.workflow = workflow
        self.rid = rid
        self.arrival = arrival
        self.done = -1.0
        self.outcome = ""
        self.slo_class = ""
        self.phases: List[dict] = []
        self.calls: List[dict] = []
        self.events: List[dict] = []
        self._open: Optional[dict] = None  # phase awaiting its end time
        self._open_calls: List[int] = []  # call indices of the open group
        self.live_handles: set = set()

    def close_phase(self, t: float) -> None:
        ph = self._open
        if ph is None:
            return
        ph["t1"] = t
        if ph["kind"] == GROUP and self._open_calls:
            crit = max(self._open_calls,
                       key=lambda i: self.calls[i]["done"])
            ph["critical_llm"] = self.calls[crit]["llm"]
        self.phases.append(ph)
        self._open = None
        self._open_calls = []

    def as_dict(self) -> dict:
        return {"workflow": self.workflow, "rid": self.rid,
                "arrival": self.arrival, "done": self.done,
                "outcome": self.outcome, "slo_class": self.slo_class,
                "phases": list(self.phases), "calls": list(self.calls),
                "events": list(self.events)}


class _EngineStats:
    """Per-engine aggregate counters (hot path: plain field adds)."""

    __slots__ = ("engine", "label", "iterations", "batch_sum",
                 "queue_sum", "queue_max", "batch_hist")

    def __init__(self, engine, label: str):
        self.engine = engine
        self.label = label
        self.iterations = 0
        self.batch_sum = 0
        self.queue_sum = 0
        self.queue_max = 0
        self.batch_hist: Dict[int, int] = {}


class Tracer:
    """The hook object installed on driver/engine/router/admission/
    replan components (see :func:`install_tracer`).

    ``sample_per_workflow`` bounds retained request traces per workflow
    (reservoir over arrivals); ``enabled=False`` builds a tracer that
    :func:`install_tracer` refuses to wire — the stack stays on its
    ``tracer is None`` fast path.
    """

    def __init__(self, *, sample_per_workflow: int = 64, seed: int = 0,
                 enabled: bool = True,
                 metrics: Optional[MetricsRegistry] = None):
        self.enabled = enabled
        self.k = max(int(sample_per_workflow), 1)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._rng = random.Random(seed)
        # reservoir state
        self._seen: Dict[str, int] = {}  # workflow -> arrivals observed
        self._slots: Dict[str, List[Tuple[str, int]]] = {}
        self._traces: Dict[Tuple[str, int], _ReqTrace] = {}
        # sampled in-flight engine calls: handle -> [trace, call dict]
        self._live_calls: Dict[int, list] = {}
        self._lat: Dict[str, GKQuantile] = {}
        self._lat_sum: Dict[str, float] = {}
        self._lat_n: Dict[str, int] = {}
        # engine aggregates, keyed id(engine)
        self._eng: Dict[int, _EngineStats] = {}
        self._eng_labels: set = set()
        # hot-path accumulators: per-event hooks touch ONLY these plain
        # dicts; :meth:`collect` materializes them into the metric
        # families (a labels() lookup per event is measurable at 10^5+
        # events/s of simulated traffic — deferring it keeps enabled-
        # tracer overhead low)
        self._call_acc: Dict[Tuple[str, str], list] = {}  # n, busy, queued
        self.iter_mask = _ITER_MASK  # read by EngineSim's hook guard
        self._route_counts: Dict[str, int] = {}
        self._adm_counts: Dict[Tuple[str, str], int] = {}
        self._req_counts: Dict[Tuple[str, str], int] = {}
        self._shed_counts: Dict[Tuple[str, str], int] = {}
        # pre-bound metric families
        m = self.metrics
        self._m_requests = m.counter(
            "scepsy_requests_total",
            "workflow requests by outcome", ("workflow", "outcome"))
        self._m_calls = m.counter(
            "scepsy_calls_total", "LLM calls completed",
            ("workflow", "llm"))
        self._m_busy = m.counter(
            "scepsy_call_busy_seconds_total",
            "engine service seconds by call", ("workflow", "llm"))
        self._m_queued = m.counter(
            "scepsy_call_queued_seconds_total",
            "queueing seconds before admission", ("workflow", "llm"))
        self._m_admission = m.counter(
            "scepsy_admission_total",
            "front-door admission decisions", ("workflow", "decision"))
        self._m_shed = m.counter(
            "scepsy_shed_total", "rejected/degraded/substituted arrivals",
            ("workflow", "kind"))
        self._m_preempt = m.counter(
            "scepsy_preemptions_total", "QoS preemptions", ("engine",))
        self._m_replan = m.counter(
            "scepsy_replan_total", "replan actions by rung", ("rung",))
        self._m_route = m.counter(
            "scepsy_routing_total", "router target-selection tier",
            ("tier",))
        self._m_batch = m.histogram(
            "scepsy_engine_batch_occupancy",
            "running batch size at each engine iteration", (),
            buckets=_BATCH_BUCKETS)
        self._m_queue_depth = m.gauge(
            "scepsy_engine_queue_depth",
            "waiting requests at last iteration", ("engine",))
        self._m_kv_util = m.gauge(
            "scepsy_engine_kv_utilization",
            "radix-cache resident tokens / KV budget", ("engine",))
        self._m_batch_mean = m.gauge(
            "scepsy_engine_batch_mean",
            "mean running batch size over all iterations", ("engine",))
        self._m_iters = m.gauge(
            "scepsy_engine_iterations_total",
            "engine scheduling iterations", ("engine",))
        self._batch_child = self._m_batch.labels()

    # ------------------------------------------------------------------
    # driver hooks
    # ------------------------------------------------------------------

    def on_request_start(self, workflow: str, rid: int, t: float) -> bool:
        """Returns True when the request enters the trace reservoir; the
        driver stamps the flag on the request record so unsampled
        requests skip the per-group / per-tool hooks entirely."""
        n = self._seen.get(workflow, 0) + 1
        self._seen[workflow] = n
        slots = self._slots.setdefault(workflow, [])
        key = (workflow, rid)
        if len(slots) < self.k:
            slots.append(key)
            self._traces[key] = _ReqTrace(workflow, rid, t)
            return True
        j = self._rng.randrange(n)
        if j < self.k:
            self._evict(slots[j])
            slots[j] = key
            self._traces[key] = _ReqTrace(workflow, rid, t)
            return True
        return False

    def _evict(self, key: Tuple[str, int]) -> None:
        tr = self._traces.pop(key, None)
        if tr is not None:
            for h in tr.live_handles:
                self._live_calls.pop(h, None)

    def on_request_admission(self, workflow: str, rid: int,
                             decision: str, t: float) -> None:
        if decision != "admit":
            k = (workflow, decision)
            self._shed_counts[k] = self._shed_counts.get(k, 0) + 1
            if decision == "reject":
                k = (workflow, "rejected")
                self._req_counts[k] = self._req_counts.get(k, 0) + 1
        tr = self._traces.get((workflow, rid))
        if tr is None:
            return
        if decision == "reject":
            tr.outcome = "rejected"
            tr.done = t
        elif decision != "admit":
            tr.events.append({"type": SUBSTITUTED if decision ==
                              "substitute" else decision, "t": t})

    def on_group_start(self, workflow: str, rid: int, t: float,
                       n_calls: int) -> bool:
        """Returns True when this request is sampled (the driver then
        reports per-call submissions)."""
        tr = self._traces.get((workflow, rid))
        if tr is None:
            return False
        tr.close_phase(t)
        tr._open = {"kind": GROUP, "t0": t, "t1": -1.0, "n_calls": n_calls,
                    "critical_llm": ""}
        return True

    def on_call_submit(self, workflow: str, rid: int, handle: int,
                       llm: str, t: float) -> None:
        tr = self._traces.get((workflow, rid))
        if tr is None:
            return
        call = {"llm": llm, "handle": handle, "submit": t, "start": -1.0,
                "done": -1.0, "queued_s": 0.0, "service_s": 0.0,
                "prefill_s": 0.0, "preemptions": 0}
        tr.calls.append(call)
        tr._open_calls.append(len(tr.calls) - 1)
        tr.live_handles.add(handle)
        self._live_calls[handle] = [tr, call]

    def on_call_done(self, workflow: str, rid: int, llm: str, req) -> None:
        busy = req.t_done - req.t_start_service
        if busy < 0.0:
            busy = 0.0
        queued = req.t_start_service - req.arrival
        if queued < 0.0:
            queued = 0.0
        try:
            acc = self._call_acc[(workflow, llm)]
        except KeyError:
            acc = self._call_acc[(workflow, llm)] = [0, 0.0, 0.0]
        acc[0] += 1
        acc[1] += busy
        acc[2] += queued
        rec = self._live_calls.pop(req.req_id, None)
        if rec is None:
            return
        tr, call = rec
        tr.live_handles.discard(req.req_id)
        call["start"] = req.t_start_service
        call["done"] = req.t_done
        call["queued_s"] = queued
        call["service_s"] = busy
        call["preemptions"] = req.preemptions

    def on_tool(self, workflow: str, rid: int, t: float,
                seconds: float) -> None:
        tr = self._traces.get((workflow, rid))
        if tr is None:
            return
        tr.close_phase(t)
        tr._open = {"kind": TOOL, "t0": t, "t1": -1.0}

    def on_request_done(self, workflow: str, rec) -> None:
        rid = rec.request_id
        outcome = ("degraded" if getattr(rec, "degraded", False)
                   else "substituted" if getattr(rec, "substituted", False)
                   else "completed")
        k = (workflow, outcome)
        self._req_counts[k] = self._req_counts.get(k, 0) + 1
        lat = rec.done - rec.arrival
        sk = self._lat.get(workflow)
        if sk is None:
            sk = self._lat[workflow] = GKQuantile(0.005)
            self._lat_sum[workflow] = 0.0
            self._lat_n[workflow] = 0
        sk.add(lat)
        self._lat_sum[workflow] += lat
        self._lat_n[workflow] += 1
        tr = self._traces.get((workflow, rid))
        if tr is not None:
            tr.close_phase(rec.done)
            tr.done = rec.done
            tr.outcome = outcome
            tr.slo_class = getattr(rec, "slo_class", "")

    # ------------------------------------------------------------------
    # engine hooks
    # ------------------------------------------------------------------

    def _register_engine(self, engine) -> _EngineStats:
        label = getattr(engine, "name", "engine") or "engine"
        k = 1
        while label in self._eng_labels:
            k += 1
            label = f"{getattr(engine, 'name', 'engine')}#{k}"
        self._eng_labels.add(label)
        st = _EngineStats(engine, label)
        self._eng[id(engine)] = st
        return st

    def on_engine_iteration(self, engine, t0: float, duration: float,
                            batch: int, waiting: int) -> None:
        # stride-sampled at the CALL SITE: the engine invokes this for
        # one in ITER_STRIDE scheduling iterations (it reads the
        # tracer's ``iter_mask``), so each received event counts for
        # ITER_STRIDE iterations — collect() scales the derived totals;
        # occupancy / queue statistics are systematic samples of the
        # iteration stream.
        st = self._eng.get(id(engine))
        if st is None:
            st = self._register_engine(engine)
        st.iterations += 1
        st.batch_sum += batch
        st.queue_sum += waiting
        if waiting > st.queue_max:
            st.queue_max = waiting
        h = st.batch_hist
        h[batch] = h.get(batch, 0) + 1

    def on_engine_admit(self, req, t0: float, new_tokens: int,
                        prefill_s: float) -> None:
        rec = self._live_calls.get(req.req_id)
        if rec is None:
            return
        call = rec[1]
        call["prefill_s"] += prefill_s
        if call["start"] < 0:
            call["start"] = t0

    def on_engine_preempt(self, engine, victim, t0: float) -> None:
        st = self._eng.get(id(engine))
        if st is None:
            st = self._register_engine(engine)
        self._m_preempt.labels(st.label).inc()
        rec = self._live_calls.get(victim.req_id)
        if rec is not None:
            rec[0].events.append({"type": PREEMPTED, "t": t0,
                                  "handle": victim.req_id,
                                  "engine": st.label})

    # ------------------------------------------------------------------
    # router / control-plane hooks
    # ------------------------------------------------------------------

    def on_route(self, tier: str) -> None:
        rc = self._route_counts
        rc[tier] = rc.get(tier, 0) + 1

    def on_admission_decision(self, workflow: str, decision: str,
                              t: float) -> None:
        k = (workflow, decision)
        self._adm_counts[k] = self._adm_counts.get(k, 0) + 1

    def on_replan(self, action) -> None:
        self._m_replan.labels(str(getattr(action, "rung", 0))).inc()

    # ------------------------------------------------------------------
    # readers
    # ------------------------------------------------------------------

    def collect(self) -> None:
        """Materialize the hot-path accumulators into the metric
        families and refresh pull-style gauges (queue depth, KV
        utilization, batch occupancy) from the registered engines.
        Call before reading ``metrics`` mid-run; :meth:`export` calls
        it for you.  Idempotent — accumulators are monotone and the
        children are overwritten, not incremented."""
        for (w, llm), acc in self._call_acc.items():
            self._m_calls.labels(w, llm).set(acc[0])
            self._m_busy.labels(w, llm).set(acc[1])
            self._m_queued.labels(w, llm).set(acc[2])
        for (w, outcome), n in self._req_counts.items():
            self._m_requests.labels(w, outcome).set(n)
        for (w, kind), n in self._shed_counts.items():
            self._m_shed.labels(w, kind).set(n)
        for (w, decision), n in self._adm_counts.items():
            self._m_admission.labels(w, decision).set(n)
        for tier, n in self._route_counts.items():
            self._m_route.labels(tier).set(n)
        ch = self._batch_child
        counts = [0] * (len(ch.bounds) + 1)
        total, sm = 0, 0.0
        for st in self._eng.values():
            for b, n in st.batch_hist.items():
                counts[bisect_left(ch.bounds, b)] += n * ITER_STRIDE
                total += n * ITER_STRIDE
                sm += b * n * ITER_STRIDE
        ch.counts, ch.count, ch.sum = counts, total, sm
        for st in self._eng.values():
            eng = st.engine
            self._m_iters.labels(st.label).set(st.iterations * ITER_STRIDE)
            if st.iterations:
                self._m_batch_mean.labels(st.label).set(
                    st.batch_sum / st.iterations)
            self._m_queue_depth.labels(st.label).set(
                len(getattr(eng, "waiting", ())))
            radix = getattr(eng, "radix", None)
            cap = getattr(eng, "kv_capacity_tokens", 0)
            if radix is not None and cap:
                self._m_kv_util.labels(st.label).set(radix.tokens / cap)

    def traces(self, workflow: Optional[str] = None,
               finished_only: bool = True) -> List[dict]:
        """Sampled request traces (reservoir members), arrival-ordered."""
        out = []
        for tr in self._traces.values():
            if workflow is not None and tr.workflow != workflow:
                continue
            if finished_only and tr.done < 0:
                continue
            out.append(tr.as_dict())
        out.sort(key=lambda d: (d["workflow"], d["arrival"], d["rid"]))
        return out

    def observed_shares(self) -> Dict[str, Dict[str, float]]:
        """Per-(workflow, LLM) execution-time shares: each LLM's total
        engine-busy seconds over the workflow's total, accumulated from
        EVERY completed call (not just sampled ones).  Busy-seconds
        weighting is exactly how :func:`repro.obs.accuracy.
        expected_shares` weights a pipeline's stages (calls x service
        latency), so observed and expected compare like for like.
        :class:`repro.core.drift.DriftMonitor` uses a mean of
        per-request shares instead — close at steady state, which is
        what :meth:`DriftMonitor.corroborate`'s tolerance absorbs."""
        out: Dict[str, Dict[str, float]] = {}
        for (wf, llm), acc in self._call_acc.items():
            out.setdefault(wf, {})[llm] = acc[1]
        for wf, row in out.items():
            total = sum(row.values())
            if total > 0:
                out[wf] = {llm: b / total for llm, b in row.items()}
        return out

    def request_latency(self, workflow: str) -> dict:
        n = self._lat_n.get(workflow, 0)
        if not n:
            return {"count": 0}
        sk = self._lat[workflow]
        return {"count": n, "mean": self._lat_sum[workflow] / n,
                "p50": sk.query(0.50), "p99": sk.query(0.99)}

    def sampled_counts(self) -> Dict[str, dict]:
        return {wf: {"seen": n, "sampled": len(self._slots.get(wf, []))}
                for wf, n in sorted(self._seen.items())}

    def export(self) -> dict:
        """JSON-safe dump: sampled traces + metrics snapshot + text
        exposition (what ``tools/scepsy_report.py`` renders)."""
        self.collect()
        return {
            "traces": self.traces(finished_only=False),
            "sampling": {"per_workflow": self.k,
                         "counts": self.sampled_counts()},
            "shares": self.observed_shares(),
            "latency": {wf: self.request_latency(wf)
                        for wf in sorted(self._lat_n)},
            "metrics": self.metrics.snapshot(),
            "exposition": self.metrics.expose(),
        }

    def to_chrome_trace(self) -> dict:
        """Chrome/Perfetto ``trace_event`` JSON: one pid per workflow,
        one tid per sampled request; phases and calls are complete
        ("ph": "X") events, preemptions instant ("ph": "i") events.
        Load the dict (written as JSON) in https://ui.perfetto.dev."""
        return chrome_trace(self.traces(finished_only=False))


def chrome_trace(traces: List[dict]) -> dict:
    """Convert trace dicts (:meth:`Tracer.traces` / an export dump's
    ``traces`` list) into Chrome trace_event JSON — also reachable
    offline via ``tools/scepsy_report.py --perfetto``."""
    events: List[dict] = []
    pids: Dict[str, int] = {}
    us = 1e6
    for tr in traces:
        pid = pids.setdefault(tr["workflow"], len(pids) + 1)
        tid = tr["rid"] + 1
        if not pids.get(("named", pid)):
            events.append({"name": "process_name", "ph": "M",
                           "pid": pid, "tid": 0,
                           "args": {"name": tr["workflow"]}})
            pids[("named", pid)] = 1
        end = tr["done"] if tr["done"] >= 0 else tr["arrival"]
        events.append({
            "name": f"request {tr['rid']}", "cat": "request",
            "ph": "X", "pid": pid, "tid": tid,
            "ts": tr["arrival"] * us,
            "dur": max(end - tr["arrival"], 0.0) * us,
            "args": {"outcome": tr["outcome"],
                     "slo_class": tr["slo_class"]}})
        for ph in tr["phases"]:
            name = ph["kind"]
            if ph["kind"] == GROUP and ph.get("critical_llm"):
                name = f"group[{ph['critical_llm']}]"
            events.append({
                "name": name, "cat": "phase", "ph": "X",
                "pid": pid, "tid": tid, "ts": ph["t0"] * us,
                "dur": max(ph["t1"] - ph["t0"], 0.0) * us,
                "args": {k: v for k, v in ph.items()
                         if k not in ("t0", "t1")}})
        for call in tr["calls"]:
            if call["done"] < 0:
                continue
            events.append({
                "name": f"call {call['llm']}", "cat": "call",
                "ph": "X", "pid": pid, "tid": tid,
                "ts": call["submit"] * us,
                "dur": max(call["done"] - call["submit"], 0.0) * us,
                "args": {"queued_s": call["queued_s"],
                         "service_s": call["service_s"],
                         "prefill_s": call["prefill_s"],
                         "preemptions": call["preemptions"]}})
        for ev in tr["events"]:
            events.append({"name": ev["type"], "cat": "event",
                           "ph": "i", "s": "t", "pid": pid,
                           "tid": tid, "ts": ev["t"] * us})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def install_tracer(tracer: Optional[Tracer], *, drivers=(), routers=(),
                   admission=None, replan=None) -> Optional[Tracer]:
    """Wire one tracer through a deployed stack.

    ``drivers`` are ClusterDrivers (their router dicts — including
    pooled tenant views — and every reachable engine replica are walked
    automatically); extra ``routers`` cover replicas no driver routes
    to.  ``admission`` is an AdmissionController, ``replan`` a
    ReplanController.  A ``None`` or disabled tracer installs nothing:
    every component keeps its ``tracer is None`` fast path, so the run
    is indistinguishable from an un-instrumented one.
    """
    if tracer is None or not tracer.enabled:
        return tracer
    router_objs = {}
    for drv in drivers:
        drv.tracer = tracer
        for r in getattr(drv, "routers", {}).values():
            router_objs[id(r)] = r
    for r in routers:
        router_objs[id(r)] = r
    for r in router_objs.values():
        if hasattr(r, "submit"):
            r.tracer = tracer
        for eng in getattr(r, "replicas", ()):
            eng.tracer = tracer
            # eager registration: engines with few iterations would
            # otherwise be invisible to stride-sampled hooks
            if id(eng) not in tracer._eng:
                tracer._register_engine(eng)
    if admission is not None:
        admission.tracer = tracer
    if replan is not None:
        replan.tracer = tracer
    return tracer
