"""Observability: tracing, metrics exposition, predictor accuracy.

Three layers over the serving stack (see ``docs/observability.md``):

* :mod:`repro.obs.spans` — a :class:`Tracer` hook installed on the
  driver / engine / router / admission / replan layers; per-workflow
  reservoir-sampled span records exportable as Chrome ``trace_event``
  JSON.  Every hook site is guarded by ``tracer is None``, so the
  un-instrumented hot path is untouched (zero cost when disabled).
* :mod:`repro.obs.metrics` — counter/gauge/histogram registry with
  Prometheus-style text exposition, fed by the same hooks.
* :mod:`repro.obs.accuracy` — observed-vs-predicted reconciliation:
  per-(workflow, LLM) execution shares against the deployed pipeline's
  ``mean_share``, per-stage serial latency against ``Prediction``
  contributions, and a critical-path breakdown per workflow.
"""
from repro.obs.metrics import MetricsRegistry, parse_exposition
from repro.obs.spans import Tracer, chrome_trace, install_tracer
from repro.obs.accuracy import (accuracy_report, critical_path_report,
                                expected_shares, predictor_report,
                                share_report)

__all__ = [
    "MetricsRegistry",
    "parse_exposition",
    "Tracer",
    "chrome_trace",
    "install_tracer",
    "accuracy_report",
    "critical_path_report",
    "expected_shares",
    "predictor_report",
    "share_report",
]
