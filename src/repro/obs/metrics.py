"""Counter/gauge/histogram registry with Prometheus-style exposition.

The registry is deliberately small and allocation-light: a *family* is
one metric name + label schema; ``family.labels(...)`` returns a child
whose ``inc`` / ``set`` / ``observe`` is a couple of attribute writes,
so hot-path hooks can pre-bind children and pay no per-event lookup.
``expose()`` renders the whole registry in the Prometheus text format
and ``snapshot()`` returns a JSON-safe dict (histogram ``+Inf`` bucket
bounds become the string ``"+Inf"``); both can be taken mid-run.
"""
from __future__ import annotations

import re
from bisect import bisect_left
from typing import Dict, List, Optional, Sequence, Tuple

COUNTER = "counter"
GAUGE = "gauge"
HISTOGRAM = "histogram"

DEFAULT_BUCKETS = (0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                   1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


class _Child:
    """One labeled time series of a counter or gauge family."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def set(self, value: float) -> None:
        self.value = value


class _HistChild:
    """One labeled histogram series: bucket counts + sum + count."""

    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: Sequence[float]):
        self.bounds = tuple(bounds)
        self.counts = [0] * (len(self.bounds) + 1)  # last = +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1


class _Family:
    def __init__(self, name: str, help_text: str, kind: str,
                 labelnames: Sequence[str],
                 buckets: Optional[Sequence[float]] = None):
        self.name = name
        self.help = help_text
        self.kind = kind
        self.labelnames = tuple(labelnames)
        self.buckets = tuple(buckets) if buckets is not None else None
        self._children: Dict[Tuple[str, ...], object] = {}

    def labels(self, *labelvalues: str):
        key = tuple(str(v) for v in labelvalues)
        if len(key) != len(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, got {key}")
        child = self._children.get(key)
        if child is None:
            child = (_HistChild(self.buckets) if self.kind == HISTOGRAM
                     else _Child())
            self._children[key] = child
        return child

    # -- rendering -----------------------------------------------------

    @staticmethod
    def _fmt_labels(names: Tuple[str, ...], values: Tuple[str, ...],
                    extra: str = "") -> str:
        parts = [f'{n}="{v}"' for n, v in zip(names, values)]
        if extra:
            parts.append(extra)
        return "{" + ",".join(parts) + "}" if parts else ""

    def expose(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.kind}"]
        for key in sorted(self._children):
            child = self._children[key]
            if self.kind == HISTOGRAM:
                cum = 0
                for bound, n in zip(list(child.bounds) + ["+Inf"],
                                    child.counts):
                    cum += n
                    le = bound if bound == "+Inf" else repr(float(bound))
                    lab = self._fmt_labels(self.labelnames, key,
                                           f'le="{le}"')
                    lines.append(f"{self.name}_bucket{lab} {cum}")
                lab = self._fmt_labels(self.labelnames, key)
                lines.append(f"{self.name}_sum{lab} {child.sum!r}")
                lines.append(f"{self.name}_count{lab} {child.count}")
            else:
                lab = self._fmt_labels(self.labelnames, key)
                v = child.value
                out = repr(float(v)) if isinstance(v, float) else str(v)
                lines.append(f"{self.name}{lab} {out}")
        return lines

    def snapshot(self) -> dict:
        series = []
        for key in sorted(self._children):
            child = self._children[key]
            labels = dict(zip(self.labelnames, key))
            if self.kind == HISTOGRAM:
                buckets = {}
                for bound, n in zip(list(child.bounds) + ["+Inf"],
                                    child.counts):
                    buckets[str(bound)] = n
                series.append({"labels": labels, "buckets": buckets,
                               "sum": child.sum, "count": child.count})
            else:
                series.append({"labels": labels, "value": child.value})
        return {"type": self.kind, "help": self.help, "series": series}


class MetricsRegistry:
    """Named metric families, snapshotable mid-run."""

    def __init__(self) -> None:
        self._families: Dict[str, _Family] = {}

    def _family(self, name: str, help_text: str, kind: str,
                labelnames: Sequence[str],
                buckets: Optional[Sequence[float]] = None) -> _Family:
        fam = self._families.get(name)
        if fam is None:
            fam = _Family(name, help_text, kind, labelnames, buckets)
            self._families[name] = fam
        elif fam.kind != kind or fam.labelnames != tuple(labelnames):
            raise ValueError(f"metric {name!r} re-registered with a "
                             f"different type or label schema")
        return fam

    def counter(self, name: str, help_text: str = "",
                labelnames: Sequence[str] = ()) -> _Family:
        return self._family(name, help_text, COUNTER, labelnames)

    def gauge(self, name: str, help_text: str = "",
              labelnames: Sequence[str] = ()) -> _Family:
        return self._family(name, help_text, GAUGE, labelnames)

    def histogram(self, name: str, help_text: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> _Family:
        return self._family(name, help_text, HISTOGRAM, labelnames, buckets)

    def expose(self) -> str:
        """The whole registry in Prometheus text exposition format."""
        lines: List[str] = []
        for name in sorted(self._families):
            lines.extend(self._families[name].expose())
        return "\n".join(lines) + ("\n" if lines else "")

    def snapshot(self) -> dict:
        """JSON-safe dump of every family (taken mid-run is fine)."""
        return {name: fam.snapshot()
                for name, fam in sorted(self._families.items())}


_SAMPLE_RE = re.compile(
    r"^(?P<name>[A-Za-z_:][A-Za-z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)$")
_LABEL_RE = re.compile(r'([A-Za-z_][A-Za-z0-9_]*)="([^"]*)"')


def parse_exposition(text: str) -> Dict[str, Dict[Tuple[Tuple[str, str], ...],
                                                  float]]:
    """Parse Prometheus text exposition back into
    ``{metric name: {sorted (label, value) pairs: sample value}}`` —
    the round-trip counterpart of :meth:`MetricsRegistry.expose`,
    used by the exposition round-trip test."""
    out: Dict[str, Dict[Tuple[Tuple[str, str], ...], float]] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"unparseable exposition line: {line!r}")
        labels = tuple(sorted(_LABEL_RE.findall(m.group("labels") or "")))
        out.setdefault(m.group("name"), {})[labels] = float(m.group("value"))
    return out
