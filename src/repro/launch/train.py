"""End-to-end training driver.

Runs real steps on this host (reduced configs for CPU; full configs on a
TPU slice with the same code path) with the production substrate:
sharded init, AdamW, synthetic data pipeline, checkpoint/restart,
optional int8 gradient compression, and fault-tolerance hooks.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
      --reduced --steps 30 --ckpt-dir /tmp/ckpt [--resume] [--compress]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, reduced_config
from repro.models import build_model
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, SyntheticCorpus
from repro.training.optimizer import AdamWConfig, apply_updates, init_state
from repro.distributed.compression import compress_tree, init_error


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient compression")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(args.seed))
    opt_state = init_state(params)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=10)
    err = init_error(params) if args.compress else None

    data = SyntheticCorpus(DataConfig(cfg.vocab_size, args.seq, args.batch,
                                      seed=args.seed))
    start_step = 0
    if args.resume and args.ckpt_dir:
        try:
            (params, opt_state), start_step, extra = ckpt.restore_checkpoint(
                args.ckpt_dir, (params, opt_state))
            params = jax.tree.map(jnp.asarray, params)
            opt_state = jax.tree.map(jnp.asarray, opt_state)
            print(f"resumed from step {start_step}")
        except FileNotFoundError:
            print("no checkpoint found; starting fresh")

    grad_fn = jax.jit(jax.value_and_grad(bundle.loss_fn))
    update_fn = jax.jit(lambda p, g, s: apply_updates(p, g, s, opt_cfg))

    losses = []
    for step in range(start_step, start_step + args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        for name, fn in (bundle.extra_inputs or {}).items():
            s = fn(args.batch)
            batch[name] = jnp.zeros(s.shape, s.dtype)
        t0 = time.time()
        loss, grads = grad_fn(params, batch)
        if args.compress:
            grads, err = compress_tree(grads, err)
        params, opt_state = update_fn(params, grads, opt_state)
        losses.append(float(loss))
        print(f"step {step}: loss={float(loss):.4f} "
              f"({time.time()-t0:.2f}s)", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            path = ckpt.save_checkpoint(args.ckpt_dir, step + 1,
                                        (params, opt_state),
                                        extra={"arch": cfg.name})
            ckpt.prune_old(args.ckpt_dir)
            print(f"  checkpointed -> {path}")
    if len(losses) >= 10:
        first, last = np.mean(losses[:5]), np.mean(losses[-5:])
        print(f"loss {first:.4f} -> {last:.4f} "
              f"({'DECREASED' if last < first else 'no decrease'})")
    return losses


if __name__ == "__main__":
    main()
