"""Workflow-serving launcher: realizes a Scepsy deployment.

Given a workflow + cluster + target rate it runs the full Scepsy flow
(trace -> profile -> schedule -> place), writes the deployment manifest
(the Kubernetes-file analogue; placement decisions are locked ahead of
time per §6), then serves an open-loop request stream through the
simulated cluster and reports the achieved throughput-latency point.

  PYTHONPATH=src python -m repro.launch.serve --workflow beam_search \
      --chips 8 --rate 0.4 --requests 60
"""
from __future__ import annotations

import argparse

from repro import hw
from repro.core.scepsy import deploy
from repro.core.placement import save_deployment
from repro.serving.deploy import routers_from_allocations
from repro.serving.simulator import EventLoop
from repro.workflows.beam_search import BEAM_SEARCH
from repro.workflows.rag_reranker import RAG_RERANKER
from repro.workflows.runtime import ClusterDriver

WORKFLOWS = {w.name: w for w in (BEAM_SEARCH, RAG_RERANKER)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workflow", default="beam_search",
                    choices=sorted(WORKFLOWS))
    ap.add_argument("--chips", type=int, default=8)
    ap.add_argument("--rate", type=float, default=0.4)
    ap.add_argument("--requests", type=int, default=60)
    ap.add_argument("--manifest", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    wf = WORKFLOWS[args.workflow]
    spec = hw.ClusterSpec(num_hosts=max(args.chips // 4, 1),
                          chips_per_host=min(args.chips, 4))
    print(f"== Scepsy deploy: {wf.name} on {spec.num_chips} chips, "
          f"target {args.rate} req/s")
    dep = deploy(wf, spec, args.rate, n_trace_requests=30, seed=args.seed)
    for m, a in dep.schedule.allocations.items():
        print(f"  {m}: replicas={a.replicas} tp={a.tp} "
              f"fraction={a.fraction:.2f}")
    print(f"  predicted: latency={dep.schedule.prediction.latency:.2f}s "
          f"max_tput={dep.schedule.prediction.max_throughput:.3f} req/s")
    if args.manifest:
        save_deployment(dep.placement, args.manifest)
        print(f"  manifest -> {args.manifest}")

    loop = EventLoop()
    routers = routers_from_allocations(wf, dep.schedule.allocations, loop)
    driver = ClusterDriver(wf, routers, loop)
    recs = driver.run_open_loop(args.rate, args.requests, seed=args.seed)
    lats = sorted(r.latency for r in recs)
    span = max(r.done for r in recs) - min(r.arrival for r in recs)
    print(f"== served {len(recs)} requests: "
          f"tput={len(recs)/span:.3f} req/s "
          f"mean={sum(lats)/len(lats):.2f}s p50={lats[len(lats)//2]:.2f}s "
          f"p99={lats[int(0.99*(len(lats)-1))]:.2f}s")


if __name__ == "__main__":
    main()
