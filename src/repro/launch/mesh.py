"""Production mesh construction.

Importing this module never touches jax device state; meshes are built
only inside the functions.  The dry-run (and only the dry-run) forces 512
host platform devices via XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod (16, 16) = 256 chips, or 2-pod (2, 16, 16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def single_device_mesh():
    """1x1 mesh for smoke tests / CPU engine runs."""
    return jax.make_mesh((1, 1), ("data", "model"))
