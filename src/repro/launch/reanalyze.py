"""Re-derive hlo_stats for every dry-run cell from the saved optimized
HLO (no recompilation) — used after analyzer improvements.

    PYTHONPATH=src python -m repro.launch.reanalyze
"""
import gzip
import json
from pathlib import Path

from repro.analysis.hlo_stats import analyze

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def main():
    hlo_dir = RESULTS_DIR / "hlo"
    n = 0
    for gz in sorted(hlo_dir.glob("*.hlo.gz")):
        cell = gz.name.replace(".hlo.gz", "")
        jpath = RESULTS_DIR / f"{cell}.json"
        if not jpath.exists():
            continue
        rec = json.loads(jpath.read_text())
        stats = analyze(gzip.decompress(gz.read_bytes()).decode())
        rec["hlo_stats"] = {
            "flops": stats.flops,
            "hbm_bytes": stats.hbm_bytes,
            "collective_bytes": stats.collective_bytes,
            "collective_counts": stats.collective_counts,
            "total_collective_bytes": stats.total_collective_bytes,
            "while_trip_counts": stats.while_trip_counts,
        }
        jpath.write_text(json.dumps(rec, indent=2))
        n += 1
        print(f"reanalyzed {cell}", flush=True)
    print(f"done: {n} cells")


if __name__ == "__main__":
    main()
