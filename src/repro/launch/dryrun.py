import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# TPU-faithful bf16 dots in the compiled HLO (never executed here):
os.environ["REPRO_EXEC_SAFE"] = "0"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell, lower + compile the
real step function — train_step for train shapes, prefill/serve_step for
inference shapes — against ShapeDtypeStruct inputs on the production
meshes, and record:

  * ``compiled.memory_analysis()``  (bytes per device — proves it fits),
  * ``compiled.cost_analysis()``    (FLOPs / bytes for §Roofline),
  * per-collective bytes parsed from the optimized HLO.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import contextlib
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape, SHAPES_BY_NAME, shapes_for
from repro.distributed.sharding import fit_spec, tree_shardings
from repro.configs.registry import ASSIGNED, get_config
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.training.optimizer import init_state, state_specs
from repro.training.train_loop import make_train_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
               "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: InputShape, bundle) -> dict:
    B = shape.global_batch
    out = {}
    if shape.kind == "train":
        S = shape.seq_len
        out["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        out["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    elif shape.kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((B, shape.seq_len), jnp.int32)
    else:  # decode: one new token against a seq_len cache
        out["tokens"] = jax.ShapeDtypeStruct((B,), jnp.int32)
    for name, fn in (bundle.extra_inputs or {}).items():
        out[name] = fn(B)
    return out


def pick_microbatches(cfg: ArchConfig, shape: InputShape, mesh) -> int:
    """Split the global batch so per-chip live activations stay bounded."""
    data_ways = 1
    for ax in ("pod", "data"):
        if ax in mesh.shape:
            data_ways *= mesh.shape[ax]
    per_shard = max(shape.global_batch // data_ways, 1)
    # target <= 2 sequences per data shard per microbatch; hybrid/SSM
    # archs carry extra f32 scan state (mamba/WKV chunk buffers), so give
    # them 1 sequence per shard per microbatch
    per_mb = 1 if cfg.ssm_state else 2
    mb = max(per_shard // per_mb, 1)
    while shape.global_batch % mb:
        mb -= 1
    return mb


# ---------------------------------------------------------------------------
# lowering per shape kind
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def _mesh_context(mesh):
    """Enter the mesh — and, where the installed JAX has it, the
    abstract-mesh context that newer shard_hint paths read.  Older JAX
    (no ``use_abstract_mesh``) exposes the physical mesh to tracing via
    the pxla thread-resources env, which shard_hint falls back to."""
    with contextlib.ExitStack() as stack:
        stack.enter_context(mesh)
        use_am = getattr(jax.sharding, "use_abstract_mesh", None)
        if use_am is not None:
            stack.enter_context(use_am(mesh.abstract_mesh))
        yield


def lower_cell(cfg: ArchConfig, shape: InputShape, mesh, mesh_name: str):
    t0 = time.time()
    with _mesh_context(mesh):
        if shape.kind == "train":
            nmb = pick_microbatches(cfg, shape, mesh)
            bundle = build_model(cfg, num_microbatches=nmb)
            params = bundle.shapes()
            params_sh = tree_shardings(bundle.specs(), mesh, params)
            opt_state = jax.eval_shape(init_state, params)
            opt_sh = tree_shardings(state_specs(bundle.specs()), mesh,
                                    opt_state)
            batch = input_specs(cfg, shape, bundle)
            batch_sh = {k: NamedSharding(mesh, fit_spec(
                P(("pod", "data")), v.shape, mesh))
                for k, v in batch.items()}
            step = make_train_step(bundle)
            jitted = jax.jit(step,
                             in_shardings=(params_sh, opt_sh, batch_sh),
                             out_shardings=(params_sh, opt_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params, opt_state, batch)
        elif shape.kind == "prefill":
            bundle = build_model(cfg)
            params = bundle.shapes()
            params_sh = tree_shardings(bundle.specs(), mesh, params)
            batch = input_specs(cfg, shape, bundle)
            batch_sh = {k: NamedSharding(mesh, fit_spec(
                P(("pod", "data")), v.shape, mesh))
                for k, v in batch.items()}
            cache_sh = None
            if bundle.cache_spec_fn and bundle.cache_shape_fn:
                cache_shapes = bundle.cache_shape_fn(shape.global_batch,
                                                     shape.seq_len)
                cache_sh = tree_shardings(bundle.cache_spec_fn(), mesh,
                                          cache_shapes)
            jitted = jax.jit(bundle.prefill,
                             in_shardings=(params_sh, batch_sh),
                             out_shardings=(None, cache_sh))
            lowered = jitted.lower(params, batch)
        else:  # decode
            bundle = build_model(cfg)
            params = bundle.shapes()
            params_sh = tree_shardings(bundle.specs(), mesh, params)
            cache = bundle.cache_shape_fn(shape.global_batch, shape.seq_len)
            # §Perf: head-sharded decode cache when kv heads fill the
            # model axis (local attention, no seq-dim DUS resharding)
            model_ways = mesh.shape.get("model", 1)
            kv_layout = ("heads" if cfg.num_kv_heads % model_ways == 0
                         and not cfg.attn_free and not cfg.sliding_window
                         and os.environ.get("REPRO_KV_LAYOUT", "auto") != "seq"
                         else "seq")
            try:
                cache_specs = bundle.cache_spec_fn(kv_layout)
            except TypeError:
                cache_specs = bundle.cache_spec_fn()
            cache_sh = tree_shardings(cache_specs, mesh, cache)
            tokens = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
            tok_sh = NamedSharding(mesh, fit_spec(P(("pod", "data")),
                                                  tokens.shape, mesh))
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(bundle.decode_step,
                             in_shardings=(params_sh, cache_sh, tok_sh, None),
                             out_shardings=(None, cache_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params, cache, tokens, pos)

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # older JAX returns one dict per device program in a list
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    coll = collective_bytes(compiled)
    from repro.analysis.hlo_stats import analyze_compiled
    hlo = analyze_compiled(compiled)
    # persist the optimized HLO so analyzer improvements can re-derive
    # stats without recompiling (repro.launch.reanalyze)
    import gzip
    hlo_dir = RESULTS_DIR / "hlo"
    hlo_dir.mkdir(parents=True, exist_ok=True)
    (hlo_dir / f"{cfg.name}__{shape.name}__{mesh_name}.hlo.gz").write_bytes(
        gzip.compress(compiled.as_text().encode()))
    return {
        "hlo_stats": {
            "flops": hlo.flops,
            "hbm_bytes": hlo.hbm_bytes,
            "collective_bytes": hlo.collective_bytes,
            "collective_counts": hlo.collective_counts,
            "total_collective_bytes": hlo.total_collective_bytes,
            "while_trip_counts": hlo.while_trip_counts,
        },
        "arch": cfg.name,
        "shape": shape.name,
        "kind": shape.kind,
        "mesh": mesh_name,
        "num_devices": mesh.devices.size,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0) or 0),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            "code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "cost": {k: float(v) for k, v in dict(cost).items()
                 if isinstance(v, (int, float))},
        "collectives": coll,
    }


def collective_bytes(compiled) -> dict:
    """Sum result-shape bytes of every collective op in the optimized HLO.

    (The result shape is the ring-traffic proxy: all-reduce result ==
    operand; all-gather result == total gathered bytes.)"""
    txt = compiled.as_text()
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVE_OPS}
    shape_re = re.compile(
        r"=\s*(?:\([^)]*\)|((?:f|bf|s|u|pred)[0-9a-z]*)\[([0-9,]*)\][^ ]*)\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
    tuple_re = re.compile(r"((?:f|bf|s|u|pred)[0-9a-z]*)\[([0-9,]*)\]")
    for line in txt.splitlines():
        m = shape_re.search(line)
        if not m:
            continue
        op = m.group(3)
        if m.group(1):  # single result
            entries = [(m.group(1), m.group(2))]
        else:  # tuple result: parse all shapes in the tuple
            head = line.split("=")[1].split(op)[0]
            entries = tuple_re.findall(head)
        nbytes = 0
        for dt, dims in entries:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES.get(dt, 4)
        out[op]["count"] += 1
        out[op]["bytes"] += nbytes
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             force: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out_path = RESULTS_DIR / f"{arch}__{shape_name}__{mesh_name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    if shape not in shapes_for(cfg):
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                  "status": "skipped",
                  "reason": "long_500k requires sub-quadratic attention "
                            "(DESIGN.md §4 skip list)"}
        out_path.write_text(json.dumps(result, indent=2))
        return result
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        result = lower_cell(cfg, shape, mesh, mesh_name)
        result["status"] = "ok"
    except Exception as e:  # record failures as bugs to fix
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                  "status": "error", "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-3000:]}
    out_path.write_text(json.dumps(result, indent=2))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.multi_pod or not args.single_pod:
        meshes.append(True)
    if args.single_pod or not args.multi_pod:
        meshes.append(False)
    meshes = sorted(set(meshes))  # [False, True] order: single first

    cells = []
    if args.all:
        for name, cfg in ASSIGNED.items():
            for sh in shapes_for(cfg):
                cells.append((name, sh.name))
    else:
        assert args.arch and args.shape, "--arch and --shape or --all"
        cells.append((args.arch, args.shape))

    for arch, shape in cells:
        for mp in meshes:
            r = run_cell(arch, shape, mp, force=args.force)
            status = r.get("status")
            extra = ""
            if status == "ok":
                flops = r["cost"].get("flops", 0)
                extra = (f"compile={r['compile_s']}s flops={flops:.3e} "
                         f"coll={r['collectives']['total_bytes']:.3e}B "
                         f"temp={r['memory']['temp_bytes']/2**30:.2f}GiB")
            else:
                extra = r.get("error", "")[:160]
            print(f"[{time.strftime('%H:%M:%S')}] {arch} {shape} "
                  f"{'2pod' if mp else '1pod'}: {status} {extra}",
                  flush=True)


if __name__ == "__main__":
    main()
