"""Combined workflows (paper §7.3): schedule RAG+reranker and beam search
together under an egalitarian-welfare split of one cluster.

    PYTHONPATH=src python examples/multi_workflow.py
"""
from repro import hw
from repro.core.scepsy import build_pipeline
from repro.core.scheduler import SchedulerConfig, schedule_multi
from repro.workflows.beam_search import BEAM_SEARCH
from repro.workflows.rag_reranker import RAG_RERANKER

pipes, lams = {}, {}
for wf, lam in ((BEAM_SEARCH, 0.3), (RAG_RERANKER, 4.0)):
    pipeline, _, _ = build_pipeline(wf, n_trace_requests=15,
                                    tp_degrees=(1, 2), max_profile_groups=12)
    pipes[wf.name] = pipeline
    lams[wf.name] = lam

res = schedule_multi(pipes, hw.PAPER_CLUSTER_16, lams,
                     SchedulerConfig(max_tp=2), split_step=2)
print(f"chip split: {res.chip_split}  (egalitarian welfare {res.welfare:.3f}, "
      f"search {res.search_time_s:.1f}s)")
for name, r in res.per_workflow.items():
    print(f"\n{name}: predicted latency {r.prediction.latency:.2f}s, "
          f"max tput {r.prediction.max_throughput:.2f} req/s")
    for m, a in r.allocations.items():
        print(f"  {m}: replicas={a.replicas} tp={a.tp} "
              f"fraction={a.fraction:.2f}")
