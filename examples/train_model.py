"""End-to-end driver: train a reduced-config model for a few hundred
steps with the full substrate (AdamW, synthetic data, checkpoints) and
show the loss decreasing + checkpoint/restart working.

    PYTHONPATH=src python examples/train_model.py --arch olmoe-1b-7b
"""
import argparse
import shutil
import tempfile

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        print(f"== phase 1: {args.steps} steps with checkpoints -> {ckpt}")
        train_main(["--arch", args.arch, "--steps", str(args.steps),
                    "--ckpt-dir", ckpt, "--ckpt-every", "10"])
        print("\n== phase 2: simulated crash + restart from checkpoint")
        train_main(["--arch", args.arch, "--steps", "10",
                    "--ckpt-dir", ckpt, "--resume"])
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
