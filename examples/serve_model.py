"""End-to-end driver: serve a real (reduced-config) model with batched
requests through the JAX serving engine — continuous batching, slot KV
cache, greedy decode.

    PYTHONPATH=src python examples/serve_model.py --arch qwen2.5-3b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.registry import get_config, reduced_config
from repro.models import build_model
from repro.serving.engine import ServeRequest, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0))
    engine = ServingEngine(bundle, params, slots=args.slots, max_len=128)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(8, 24))).astype(np.int32)
        engine.submit(ServeRequest(i, prompt, max_new_tokens=args.max_new))
    done = engine.run_to_completion()
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in done)
    print(f"arch={cfg.name} (reduced) served {len(done)} requests, "
          f"{total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s, "
          f"{engine.stats['decode_steps']} engine iterations)")
    for r in done[:3]:
        print(f"  req {r.req_id}: prompt[{len(r.prompt)}] -> "
              f"{r.generated[:8]}...")


if __name__ == "__main__":
    main()
