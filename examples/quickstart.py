"""Quickstart: the Scepsy flow end to end in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro import hw
from repro.core.aggregate import aggregate
from repro.core.scepsy import build_pipeline, deploy
from repro.workflows.beam_search import BEAM_SEARCH
from repro.workflows.runtime import trace_workflow

# 1) trace the workflow (framework-agnostic proxy capture)
store = trace_workflow(BEAM_SEARCH, 20, seed=0)
stats = aggregate(store)
print("per-LLM aggregate statistics (the paper's key observation):")
for m, st in stats.per_llm.items():
    print(f"  {m}: n={st.n:.1f} calls/req, parallelism p={st.p:.2f}, "
          f"share={st.mean_share:.2f} "
          f"(share CoV {st.share_cov:.3f} vs absolute CoV {st.abs_cov:.3f})")

# 2-3) profile + synthesize the Aggregate LLM Pipeline
pipeline, _, _ = build_pipeline(BEAM_SEARCH, n_trace_requests=20,
                                tp_degrees=(1, 2), store=store)
print("\nlatency ratios (scheduler pruning order):",
      {m: round(v, 3) for m, v in pipeline.latency_ratios().items()})

# 4-5) schedule + place on a 16-chip cluster at 0.5 req/s
dep = deploy(BEAM_SEARCH, hw.PAPER_CLUSTER_16, lam_target=0.5,
             pipeline=pipeline)
print("\nchosen allocation:")
for m, a in dep.schedule.allocations.items():
    print(f"  {m}: replicas={a.replicas} tp={a.tp} fraction={a.fraction:.2f}")
print(f"predicted latency {dep.schedule.prediction.latency:.2f}s, "
      f"max throughput {dep.schedule.prediction.max_throughput:.2f} req/s")
print(f"placement: {len(dep.placement.instances)} instances, "
      f"fragmentation {dep.placement.fragmentation():.3f}")
