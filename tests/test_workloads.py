"""New agentic workloads (ReAct tool agent, map-reduce summarization,
multi-agent debate): deterministic tracing, non-empty aggregate shares,
and end-to-end execution through schedule -> place -> ClusterDriver,
plus the fleet deploy facade."""
import math

import pytest

from repro import hw
from repro.core.aggregate import aggregate
from repro.core.scepsy import build_pipeline, deploy_multi
from repro.core.scheduler import SchedulerConfig, schedule
from repro.serving.deploy import routers_from_allocations
from repro.serving.simulator import EventLoop
from repro.workflows.registry import WORKFLOWS, get_workflow
from repro.workflows.runtime import ClusterDriver, trace_workflow

NEW_WORKFLOWS = ("react_agent", "map_reduce", "debate")


def test_registry_contains_all_workloads():
    assert set(WORKFLOWS) >= {"beam_search", "rag_reranker", *NEW_WORKFLOWS}
    for name in NEW_WORKFLOWS:
        wf = get_workflow(name)
        assert wf.name == name and wf.llms
    with pytest.raises(KeyError, match="unknown workflow"):
        get_workflow("nope")


def _store_fingerprint(store):
    return [
        (tr.request_id, tr.t_end,
         [(c.llm, c.t_start, c.t_end, c.prompt_tokens, c.output_tokens,
           c.cached_prefix_tokens) for c in tr.calls])
        for tr in store.traces
    ]


@pytest.mark.parametrize("name", NEW_WORKFLOWS)
def test_trace_deterministic_under_fixed_seed(name):
    wf = get_workflow(name)
    a = trace_workflow(wf, 8, seed=5)
    b = trace_workflow(wf, 8, seed=5)
    assert _store_fingerprint(a) == _store_fingerprint(b)
    c = trace_workflow(wf, 8, seed=6)
    assert _store_fingerprint(a) != _store_fingerprint(c)


@pytest.mark.parametrize("name", NEW_WORKFLOWS)
def test_aggregate_shares_nonempty(name):
    wf = get_workflow(name)
    stats = aggregate(trace_workflow(wf, 12, seed=2))
    assert set(stats.per_llm) == set(wf.llms)
    for m, st in stats.per_llm.items():
        assert st.n > 0, f"{m} never invoked"
        assert st.mean_share > 0, f"{m} has empty execution share"
        assert st.p >= 1.0
    # per-trace shares sum to 1, but each LLM's mean is taken only over
    # the traces it appears in, so the sum of means is only near 1
    assert 0.9 <= sum(st.mean_share for st in stats.per_llm.values()) <= 1.2
    assert stats.mean_latency > 0


@pytest.fixture(scope="module")
def pipelines():
    out = {}
    for name, lam in (("react_agent", 0.5), ("map_reduce", 0.4),
                      ("debate", 0.8)):
        wf = get_workflow(name)
        pipe, stats, _ = build_pipeline(wf, n_trace_requests=10,
                                        tp_degrees=(1, 2),
                                        max_profile_groups=8)
        out[name] = (wf, pipe, lam)
    return out


@pytest.mark.parametrize("name", NEW_WORKFLOWS)
def test_end_to_end_all_requests_finish(pipelines, name):
    wf, pipe, lam = pipelines[name]
    res = schedule(pipe, hw.PAPER_CLUSTER_8, lam, SchedulerConfig(max_tp=2))
    assert res.feasible
    loop = EventLoop()
    routers = routers_from_allocations(wf, res.allocations, loop)
    driver = ClusterDriver(wf, routers, loop)
    n = 12
    recs = driver.run_open_loop(lam, n, seed=9, until=1e5)
    done = [r for r in recs if r.done >= 0]
    assert len(done) == n, f"{len(done)}/{n} completed"
    assert all(math.isfinite(r.latency) and r.latency > 0 for r in done)


def test_fleet_deploy_multi(pipelines):
    spec = hw.PAPER_CLUSTER_16
    wfs = [pipelines[n][0] for n in NEW_WORKFLOWS]
    lams = {n: pipelines[n][2] for n in NEW_WORKFLOWS}
    fleet = deploy_multi(wfs, spec, lams,
                         scheduler_config=SchedulerConfig(max_tp=2),
                         pipelines={n: pipelines[n][1]
                                    for n in NEW_WORKFLOWS})
    assert sum(fleet.chip_split.values()) == spec.num_chips
    assert 0.0 <= fleet.welfare <= 1.0
    for name, dep in fleet.deployments.items():
        dep.placement.validate()
        assert dep.schedule.feasible
        # placement fits inside this workflow's slice of the cluster
        used_chips = {c for inst in dep.placement.instances
                      for c in inst.chips}
        assert len(used_chips) <= fleet.chip_split[name]
    # slice-local placements translate to disjoint physical chips, with
    # every TP group still inside one hb domain
    seen = {}
    for inst in fleet.global_instances():
        assert all(0 <= c < spec.num_chips for c in inst.chips)
        if inst.tp > 1:
            assert len({c // spec.hb_domain_size for c in inst.chips}) == 1
    for name, dep in fleet.deployments.items():
        off = fleet.chip_offsets[name]
        for inst in dep.placement.instances:
            for c in inst.chips:
                owner = seen.setdefault(c + off, name)
                assert owner == name, (
                    f"chip {c + off} shared by {owner} and {name}")
