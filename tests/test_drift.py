"""Online drift detection + incremental re-scheduling.

Property tests (hypothesis, skipped when unavailable): the share detector
never fires on share-stable traffic and always fires on a sustained step
change past the threshold; warm-started fleet re-plans are exactly equal
to cold searches over the same inputs.  Deterministic versions of both
properties run everywhere, plus unit coverage of the escalation ladder,
partition routing, migration diffs and the telemetry plumbing.
"""
import math
import random
from types import SimpleNamespace

import pytest

from repro import hw
from repro.configs.base import ArchConfig
from repro.core.drift import (DriftConfig, DriftMonitor, Expectation,
                              RateDrift, ShareDrift, TokenDrift,
                              expectation_from)
from repro.core.forecast import (ArrivalForecaster, ForecastConfig,
                                 ForecastDrift, ForecastTrigger, HoltWinters)
from repro.core.pipeline import (AggregateLLMPipeline, Allocation,
                                 PipelineStage, merge_pipelines)
from repro.core.placement import migration_diff, place
from repro.core.profiler import LLMProfile, TPProfile
from repro.core.replan import (RUNG_FULL_REPLAN, RUNG_REBALANCE,
                               RUNG_WARM_REPLAN, ReplanController,
                               recommend_rung)
from repro.core.scheduler import SchedulerConfig, schedule_multi
from repro.serving.simulator import EventLoop
from repro.workflows.registry import get_workflow
from repro.workflows.runtime import (ClusterDriver, Workflow,
                                     drift_workflow, trace_workflow)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# synthetic telemetry helpers
# ---------------------------------------------------------------------------


def _share_monitor(expected_share: float, config: DriftConfig) -> DriftMonitor:
    exp = Expectation(lam=1.0, shares={"a": expected_share,
                                       "b": 1.0 - expected_share})
    return DriftMonitor({"wf": exp}, config)


def _feed_share(monitor: DriftMonitor, values, t0: float = 0.0) -> None:
    """One workflow request per value: llm 'a' busy for `v`, 'b' for 1-v,
    so the observed share of 'a' is exactly `v`."""
    for i, v in enumerate(values):
        t = t0 + float(i)
        # feed both calls of the request, then close it
        for llm, busy in (("a", v), ("b", 1.0 - v)):
            req = SimpleNamespace(workflow_request=i, t_start_service=t,
                                  t_done=t + max(busy, 1e-9),
                                  output_tokens=100)
            monitor.record_call("wf", llm, req)
        monitor.record_request_done(
            "wf", SimpleNamespace(request_id=i, done=t + 1.0))


def _share_events(monitor: DriftMonitor):
    return [e for e in monitor.poll() if isinstance(e, ShareDrift)]


CFG = DriftConfig(min_samples=10, share_threshold=0.4)


def test_share_detector_stable_no_false_trigger_deterministic():
    rng = random.Random(0)
    expected = 0.5
    band = CFG.share_threshold * max(expected, CFG.share_floor)
    values = [expected + rng.uniform(-0.9, 0.9) * band for _ in range(400)]
    mon = _share_monitor(expected, CFG)
    _feed_share(mon, values)
    assert _share_events(mon) == []


def test_share_detector_step_change_guaranteed_trigger_deterministic():
    expected = 0.4
    step = expected * (1.0 + 2.0 * CFG.share_threshold)  # far past threshold
    mon = _share_monitor(expected, CFG)
    _feed_share(mon, [expected] * 50)
    assert _share_events(mon) == []
    _feed_share(mon, [step] * 300, t0=50.0)
    events = _share_events(mon)
    assert events and events[0].workflow == "wf" and events[0].llm == "a"
    assert events[0].magnitude > CFG.share_threshold


def test_rate_detector_step_and_stability():
    exp = Expectation(lam=2.0, shares={})
    mon = DriftMonitor({"wf": exp}, DriftConfig())
    t = 0.0
    for _ in range(150):  # exactly the planned rate: silent
        mon.record_arrival("wf", t)
        t += 0.5
    assert [e for e in mon.poll() if isinstance(e, RateDrift)] == []
    for _ in range(400):  # rate doubles
        mon.record_arrival("wf", t)
        t += 0.25
    events = [e for e in mon.poll() if isinstance(e, RateDrift)]
    assert events and events[0].observed > exp.lam


def test_token_detector_after_calibration():
    exp = Expectation(lam=1.0, shares={"a": 1.0})
    mon = DriftMonitor({"wf": exp}, DriftConfig())
    rng = random.Random(1)

    def call(i, toks, t):
        req = SimpleNamespace(workflow_request=i, t_start_service=t,
                              t_done=t + 1.0, output_tokens=toks)
        mon.record_call("wf", "a", req)
        mon.record_request_done("wf", SimpleNamespace(request_id=i, done=t))

    for i in range(150):
        call(i, rng.randint(90, 110), float(i))
    mon.calibrate()  # learn the ~100-token baseline
    for i in range(150, 500):
        call(i, rng.randint(190, 210), float(i))
    events = [e for e in mon.poll() if isinstance(e, TokenDrift)]
    assert events and events[0].llm == "a"
    assert events[0].observed > events[0].expected


if HAVE_HYPOTHESIS:

    @settings(deadline=None, max_examples=30)
    @given(expected=st.floats(0.1, 0.9), seed=st.integers(0, 10_000),
           amp=st.floats(0.0, 0.9))
    def test_share_detector_no_false_trigger_property(expected, seed, amp):
        """Traffic whose per-request shares stay inside the threshold
        band never fires: the EWMA of in-band samples stays in-band."""
        rng = random.Random(seed)
        band = CFG.share_threshold * max(expected, CFG.share_floor)
        values = [min(max(expected + rng.uniform(-amp, amp) * band, 0.0), 1.0)
                  for _ in range(200)]
        mon = _share_monitor(expected, CFG)
        _feed_share(mon, values)
        assert _share_events(mon) == []

    @settings(deadline=None, max_examples=30)
    @given(expected=st.floats(0.1, 0.6), factor=st.floats(1.8, 3.0))
    def test_share_detector_step_triggers_property(expected, factor):
        """A sustained step to a share past the threshold always fires."""
        step = min(expected * (1.0 + factor * CFG.share_threshold), 0.99)
        mon = _share_monitor(expected, CFG)
        _feed_share(mon, [expected] * 40)
        _feed_share(mon, [step] * 400, t0=40.0)
        events = _share_events(mon)
        assert events and events[0].llm == "a"


# ---------------------------------------------------------------------------
# synthetic two-workflow fleet (analytic profiles, shared config)
# ---------------------------------------------------------------------------


def _cfg(name: str) -> ArchConfig:
    return ArchConfig(name=name, family="dense", num_layers=16,
                      d_model=2048, num_heads=16, num_kv_heads=8,
                      d_ff=8192, vocab_size=32_000)


def _stage(llm: str, cfg: ArchConfig, size_gb: float, n: float,
           p: float = 2.0) -> PipelineStage:
    base_lat = 0.05 * size_gb
    t_max = 40.0 / size_gb
    by_tp = {}
    for tp in (1, 2):
        tmax = t_max * (tp ** 0.85)
        rates = [f * tmax for f in (0.1, 0.3, 0.5, 0.7, 0.9)]
        lat = [base_lat / tp / max(1 - r / tmax, 0.05) for r in rates]
        by_tp[tp] = TPProfile(tp=tp, rates=rates,
                              latency={"mean": lat, "p50": lat,
                                       "p90": [2 * x for x in lat],
                                       "p99": [4 * x for x in lat]},
                              max_throughput=tmax)
    prof = LLMProfile(llm=llm, arch=cfg.name, calls_per_group=n, by_tp=by_tp)
    return PipelineStage(llm=llm, cfg=cfg, n=n, p=p, profile=prof,
                         mean_share=1.0)


SHARED = _cfg("shared-small")


@pytest.fixture
def sharing_fleet():
    return {
        "wf_a": AggregateLLMPipeline("wf_a", [_stage("gen", SHARED, 2.0, 2.0)]),
        "wf_b": AggregateLLMPipeline("wf_b", [_stage("draft", SHARED, 2.0, 1.0)]),
    }


LAMS = {"wf_a": 0.4, "wf_b": 0.6}
SPEC = hw.PAPER_CLUSTER_16
SCFG = SchedulerConfig(max_tp=2)


# ---------------------------------------------------------------------------
# warm-started re-plan parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["pooled", "partitioned"])
def test_warm_replan_parity_with_cold(sharing_fleet, mode):
    first = schedule_multi(sharing_fleet, SPEC, LAMS, SCFG, mode=mode)
    assert first.warm_state is not None
    drifted = {"wf_a": 0.9, "wf_b": 0.6}
    warm = schedule_multi(sharing_fleet, SPEC, drifted, SCFG, mode=mode,
                          warm_state=first.warm_state)
    cold = schedule_multi(sharing_fleet, SPEC, drifted, SCFG, mode=mode)
    assert warm.welfare == pytest.approx(cold.welfare, rel=1e-9)
    assert warm.alloc_mode == cold.alloc_mode
    for n in sharing_fleet:
        assert (warm.per_workflow[n].allocations
                == cold.per_workflow[n].allocations)
    # the warm re-plan reuses the unchanged workflow's cached schedules
    assert warm.schedule_calls < cold.schedule_calls


def test_warm_state_invalidates_on_lam_change(sharing_fleet):
    first = schedule_multi(sharing_fleet, SPEC, LAMS, SCFG, mode="partitioned")
    ws = first.warm_state
    cached_a = [k for k in ws.sched_cache if k[0] == "wf_a"]
    assert cached_a
    changed = ws.sync(sharing_fleet, {"wf_a": 0.8, "wf_b": 0.6}, SPEC)
    assert changed == ["wf_a"]
    assert not [k for k in ws.sched_cache if k[0] == "wf_a"]
    assert [k for k in ws.sched_cache if k[0] == "wf_b"]


# ---------------------------------------------------------------------------
# escalation ladder
# ---------------------------------------------------------------------------


def _rate_event(wf, magnitude, observed, expected):
    return RateDrift(workflow=wf, at=1.0, magnitude=magnitude,
                     observed=observed, expected=expected)


def test_recommend_rung_mapping():
    assert recommend_rung([]) == 0
    small = _rate_event("wf_a", 0.3, 0.52, 0.4)
    big = _rate_event("wf_a", 1.0, 0.8, 0.4)
    share = ShareDrift(workflow="wf_a", at=1.0, magnitude=0.6, llm="gen",
                       observed=0.9, expected=0.5)
    assert recommend_rung([small]) == RUNG_REBALANCE
    assert recommend_rung([big]) == RUNG_WARM_REPLAN
    assert recommend_rung([share]) == RUNG_WARM_REPLAN
    assert recommend_rung([small, share]) == RUNG_WARM_REPLAN


def test_rung1_rebalance_on_pooled_incumbent(sharing_fleet):
    res = schedule_multi(sharing_fleet, SPEC, LAMS, SCFG, mode="pooled")
    assert res.alloc_mode == "pooled"
    ctrl = ReplanController(sharing_fleet, SPEC, LAMS, SCFG, result=res)
    act = ctrl.react([_rate_event("wf_a", 0.3, 0.52, 0.4)])
    assert act is not None and act.rung == RUNG_REBALANCE
    assert act.feasible and act.routing is not None
    for tables in act.routing.values():
        for table in tables.values():
            assert sum(table.values()) == pytest.approx(1.0)
    assert ctrl.lam_targets["wf_a"] == pytest.approx(0.52)
    assert ctrl.history[-1] is act


def test_large_drift_escalates_to_warm_replan(sharing_fleet):
    res = schedule_multi(sharing_fleet, SPEC, LAMS, SCFG, mode="pooled")
    ctrl = ReplanController(sharing_fleet, SPEC, LAMS, SCFG, result=res)
    act = ctrl.react([_rate_event("wf_a", 1.5, 1.0, 0.4)])
    assert act is not None and act.rung == RUNG_WARM_REPLAN
    assert act.result is not None and act.feasible
    assert ctrl.lam_targets["wf_a"] == pytest.approx(1.0)


def test_rebalance_without_pooled_incumbent_escalates(sharing_fleet):
    ctrl = ReplanController(sharing_fleet, SPEC, LAMS, SCFG)
    act = ctrl.react([_rate_event("wf_a", 0.3, 0.52, 0.4)])
    # rung 1 is unavailable (nothing pooled deployed) -> warm re-plan
    assert act is not None and act.rung == RUNG_WARM_REPLAN


def test_cold_replan_emits_migration_diff(sharing_fleet):
    res = schedule_multi(sharing_fleet, SPEC, LAMS, SCFG, mode="pooled")
    placement = place(res.pooled.allocations, SPEC)
    ctrl = ReplanController(sharing_fleet, SPEC, LAMS, SCFG, result=res,
                            placement=placement)
    act = ctrl.replan({"wf_a": 0.4, "wf_b": 0.6}, cold=True)
    assert act.rung == RUNG_FULL_REPLAN
    if act.result.alloc_mode == "pooled":
        assert act.migration is not None
        s = act.migration.summary()
        total = (s["replicas_added"] + s["replicas_moved"]
                 + s["replicas_unchanged"])
        assert total == len(act.placement.instances)


# ---------------------------------------------------------------------------
# routing policies + migration diff
# ---------------------------------------------------------------------------


def test_partition_routing_blocks_are_load_proportional(sharing_fleet):
    merged = merge_pipelines(sharing_fleet, LAMS)
    cid = merged.llms()[0]
    alloc = {cid: Allocation(replicas=4, tp=1, fraction=1.0)}
    uniform = merged.routing_weights(alloc, policy="uniform")
    part = merged.routing_weights(alloc, policy="partition")
    for routing in (uniform, part):
        for wf, tables in routing.items():
            for table in tables.values():
                assert sum(table.values()) == pytest.approx(1.0)
    assert uniform["wf_a"]["gen"] == {r: 0.25 for r in range(4)}
    # wf_a offers 0.4*2.0=0.8 calls/s, wf_b 0.6: blocks [0, 2.29) / [2.29, 4)
    a, b = part["wf_a"]["gen"], part["wf_b"]["draft"]
    assert 0 in a and 3 not in a
    assert 3 in b and 0 not in b
    assert len(set(a) & set(b)) <= 1  # at most the boundary replica shared
    with pytest.raises(ValueError):
        merged.routing_weights(alloc, policy="nope")


def test_migration_diff_identity_and_growth():
    spec = hw.PAPER_CLUSTER_8
    p1 = place({"m": Allocation(replicas=2, tp=1, fraction=1.0)}, spec)
    same = migration_diff(p1, p1)
    assert same.summary() == {"replicas_added": 0, "replicas_dropped": 0,
                              "replicas_moved": 0, "replicas_unchanged": 2,
                              "chips_moved": 0}
    p2 = place({"m": Allocation(replicas=3, tp=1, fraction=1.0)}, spec)
    grow = migration_diff(p1, p2)
    assert grow.added == ["m-r2"]
    assert not grow.dropped
    assert grow.chip_loads >= 1
    shrink = migration_diff(p2, p1)
    assert shrink.dropped == ["m-r2"]


# ---------------------------------------------------------------------------
# drift injection + telemetry plumbing
# ---------------------------------------------------------------------------


def test_drift_workflow_scales_tokens_and_repeats_calls():
    wf = get_workflow("map_reduce")
    base = trace_workflow(wf, 6, seed=3)
    from repro.core.aggregate import aggregate

    base_stats = aggregate(base)
    target = next(iter(base_stats.per_llm))
    scaled = drift_workflow(wf, output_scale={target: 2.0})
    assert scaled.name == wf.name  # routing/telemetry stay keyed correctly
    shifted_stats = aggregate(trace_workflow(scaled, 6, seed=3))
    assert shifted_stats.per_llm[target].mean_output_tokens == pytest.approx(
        2.0 * base_stats.per_llm[target].mean_output_tokens, rel=0.05)
    repeated = drift_workflow(wf, call_repeat={target: 2})
    rep_stats = aggregate(trace_workflow(repeated, 6, seed=3))
    assert rep_stats.per_llm[target].n == pytest.approx(
        2.0 * base_stats.per_llm[target].n, rel=1e-6)
    # untouched LLMs keep their statistics
    for m in base_stats.per_llm:
        if m != target:
            assert shifted_stats.per_llm[m].n == pytest.approx(
                base_stats.per_llm[m].n)


def test_schedule_arrivals_segments_and_ramp():
    def prog(rng):
        return
        yield  # a workflow with no LLM calls

    wf = Workflow("noop", prog, {})
    loop = EventLoop()
    drv = ClusterDriver(wf, {}, loop)
    src = drv.schedule_arrivals([(5.0, 10.0), (10.0, 10.0)], seed=1)
    assert loop.pending == 1  # lazy: only the next arrival is queued
    loop.run(math.inf)
    n = src.scheduled
    assert src.exhausted
    assert n == len(drv.records) and n > 0
    arrivals = sorted(r.arrival for r in drv.records)
    assert arrivals[-1] < 20.0
    seg1 = sum(1 for a in arrivals if a < 10.0)
    seg2 = n - seg1
    assert 20 <= seg1 <= 90
    assert seg2 > seg1  # the ramped segment is denser
    # the eager path schedules the same process upfront
    loop2 = EventLoop()
    drv2 = ClusterDriver(wf, {}, loop2)
    n2 = drv2.schedule_arrivals([(5.0, 10.0), (10.0, 10.0)], seed=1,
                                eager=True)
    assert n2 == n and loop2.pending == n
    loop2.run(math.inf)
    assert [r.arrival for r in drv2.records] \
        == [r.arrival for r in drv.records]


def test_cluster_driver_feeds_telemetry():
    wf = get_workflow("map_reduce")
    pipe, stats, _ = build_pipeline_small(wf)
    monitor = DriftMonitor(
        {wf.name: expectation_from(pipe, 2.0, stats)}, DriftConfig())
    from repro.serving.deploy import routers_from_allocations

    loop = EventLoop()
    allocs = {m: Allocation(replicas=1, tp=1, fraction=1.0)
              for m in wf.llms}
    routers = routers_from_allocations(wf, allocs, loop)
    drv = ClusterDriver(wf, routers, loop, telemetry=monitor)
    drv.run_ramped([(2.0, 15.0)], seed=2)
    assert monitor.observed_lams()[wf.name] > 0
    shares = monitor.observed_shares(wf.name)
    assert shares and sum(shares.values()) == pytest.approx(1.0, abs=0.05)


def build_pipeline_small(wf):
    from repro.core.scepsy import build_pipeline

    return build_pipeline(wf, n_trace_requests=6, tp_degrees=(1,),
                          max_profile_groups=4)


def test_rebalance_pooled_drivers_swaps_live_views(sharing_fleet):
    from repro.serving.deploy import (pooled_fleet_routers,
                                      rebalance_pooled_drivers,
                                      tenant_routers)

    res = schedule_multi(sharing_fleet, SPEC, LAMS, SCFG, mode="pooled")
    pooled = res.pooled
    loop = EventLoop()
    tenants = tenant_routers(pooled.allocations, pooled.cfgs, loop)
    per_wf = pooled_fleet_routers(tenants, pooled.members, pooled.routing)
    wfa = Workflow("wf_a", lambda rng: iter(()), {"gen": SHARED})
    wfb = Workflow("wf_b", lambda rng: iter(()), {"draft": SHARED})
    drivers = {"wf_a": ClusterDriver(wfa, per_wf["wf_a"], loop),
               "wf_b": ClusterDriver(wfb, per_wf["wf_b"], loop)}
    old_engines = {n: drv.routers[llm].replicas
                   for n, drv in drivers.items()
                   for llm in drv.routers}
    merged = merge_pipelines(sharing_fleet, {"wf_a": 0.9, "wf_b": 0.6})
    new_routing = merged.routing_weights(pooled.allocations,
                                         policy="partition")
    rebalance_pooled_drivers(drivers, tenants, pooled.members, new_routing)
    for n, drv in drivers.items():
        for llm, router in drv.routers.items():
            # same physical replicas (queues/KV preserved), new weights
            assert router.replicas is old_engines[n]
            assert router.weights == new_routing[n][llm]


def test_online_controller_share_drift_refreshes_and_adopts():
    """End-to-end rung-2 path on a real deployment: a ShareDrift event
    must re-trace the drifted workflow, warm re-plan, and re-base the
    monitor onto the refreshed pipeline (not the stale shares)."""
    from repro.core.scepsy import deploy_multi

    wfs = [get_workflow("map_reduce"), get_workflow("react_agent")]
    lams = {"map_reduce": 0.3, "react_agent": 0.3}
    dep = deploy_multi(wfs, hw.PAPER_CLUSTER_16, lams, mode="pooled",
                       online=True, n_trace_requests=6,
                       max_profile_groups=4)
    ctrl = dep.controller
    assert ctrl.pipeline_refresh is not None
    wf, llm = "map_reduce", next(iter(ctrl.pipelines["map_reduce"].stages))
    ev = ShareDrift(workflow=wf, at=1.0, magnitude=0.8, llm=llm,
                    observed=0.9, expected=0.5)
    act = ctrl.react([ev])
    assert act is not None and act.rung == RUNG_WARM_REPLAN and act.feasible
    exp = ctrl.monitor.expectations[wf]
    refreshed = ctrl.pipelines[wf]
    assert exp.shares == {m: s.mean_share
                          for m, s in refreshed.stages.items()}
    # the other workflow keeps its expectations untouched
    other = ctrl.monitor.expectations["react_agent"]
    assert set(other.shares) == set(ctrl.pipelines["react_agent"].stages)


def test_deploy_multi_online_attaches_controller(sharing_fleet):
    wfa = Workflow("wf_a", lambda rng: iter(()), {"gen": SHARED})
    wfb = Workflow("wf_b", lambda rng: iter(()), {"draft": SHARED})
    from repro.core.scepsy import deploy_multi

    dep = deploy_multi([wfa, wfb], SPEC, LAMS, pipelines=sharing_fleet,
                       scheduler_config=SCFG, mode="pooled", online=True)
    ctrl = dep.controller
    assert ctrl is not None and ctrl.monitor is not None
    assert set(ctrl.monitor.expectations) == {"wf_a", "wf_b"}
    assert ctrl.result is dep.schedule
    assert ctrl.step() is None  # no telemetry yet -> no reaction
    offline = deploy_multi([wfa, wfb], SPEC, LAMS, pipelines=sharing_fleet,
                           scheduler_config=SCFG, mode="pooled")
    assert offline.controller is None


# ---------------------------------------------------------------------------
# arrival forecasting: Holt-Winters, the trigger ladder, controller wiring
# ---------------------------------------------------------------------------


def _feed(fc, rate_fn, until, trig=None, poll_s=1.0, w="w"):
    """Deterministic arrivals with exact local rate ``rate_fn(t)``
    (uniform spacing, no Poisson noise), interleaved with per-second
    trigger polls.  The first arrival sits at half a spacing so steady
    segments put an *exact* count in every bin — otherwise the short
    first bin fakes an upward trend during warm-up.  Returns
    [(poll_time, event, measured_level_at_fire), ...]."""
    arrivals = []
    t = 0.5 / max(rate_fn(0.0), 1e-9)
    while t < until:
        arrivals.append(t)
        t += 1.0 / max(rate_fn(t), 1e-9)
    fired = []
    i = 0
    p = poll_s
    while p <= until:
        while i < len(arrivals) and arrivals[i] <= p:
            fc.observe(w, arrivals[i])
            i += 1
        if trig is not None:
            for ev in trig.poll(p):
                fired.append((p, ev, fc.rate(w)))
        else:
            fc.advance(w, p)
        p += poll_s
    return fired


def test_holtwinters_damped_trend_forecast():
    hw_ = HoltWinters(alpha=1.0, beta=1.0, phi=1.0)
    assert hw_.forecast(1) is None  # no observations yet
    for x in (1.0, 2.0, 3.0):
        hw_.update(x)
    # alpha=beta=1, phi=1: level tracks the last point, trend its slope
    assert hw_.level == pytest.approx(3.0)
    assert hw_.trend == pytest.approx(1.0)
    assert hw_.forecast(2) == pytest.approx(5.0)
    damped = HoltWinters(alpha=1.0, beta=1.0, phi=0.5)
    for x in (1.0, 2.0, 3.0):
        damped.update(x)
    assert damped.forecast(2) == pytest.approx(3.0 + (0.5 + 0.25) * 1.0)
    # extrapolation clamps at zero: negative rates are not a thing
    down = HoltWinters(alpha=1.0, beta=1.0, phi=1.0)
    for x in (5.0, 1.0):
        down.update(x)
    assert down.forecast(10) == 0.0


def test_forecaster_warmup_gate_and_steady_rate():
    cfg = ForecastConfig(bin_s=1.0, min_bins=5, lead_s=5.0)
    fc = ArrivalForecaster(["w"], cfg)
    _feed(fc, lambda t: 2.0, until=4.0)
    assert fc.forecast_rate("w", cfg.lead_s) is None  # still warming up
    _feed(fc, lambda t: 2.0, until=20.0)
    assert fc.bins_seen("w") >= cfg.min_bins
    assert fc.rate("w") == pytest.approx(2.0, abs=0.25)
    assert fc.forecast_rate("w", cfg.lead_s) == pytest.approx(2.0, abs=0.3)


def test_trigger_stationary_traffic_never_fires():
    cfg = ForecastConfig(bin_s=1.0, min_bins=4, lead_s=10.0, confirm=2)
    fc = ArrivalForecaster(["w"], cfg)
    trig = ForecastTrigger(fc, {"w": 1.0}, headroom=1.2)
    fired = _feed(fc, lambda t: 1.0, until=120.0, trig=trig)
    assert fired == [] and trig.fired == []


def test_trigger_fires_before_measured_crossing():
    # rate ramps 4.0 -> past the 4.8 capacity at t=40; the undamped
    # trend forecast must fire ahead of the crossing, while the measured
    # level is still inside the no-chase band
    cfg = ForecastConfig(bin_s=1.0, min_bins=4, lead_s=10.0, phi=1.0,
                         confirm=2, plan_horizon_s=25.0)
    fc = ArrivalForecaster(["w"], cfg)
    trig = ForecastTrigger(fc, {"w": 4.0}, headroom=1.2)
    rate = lambda t: 4.0 if t < 20.0 else 4.0 + 0.04 * (t - 20.0)
    fired = _feed(fc, rate, until=60.0, trig=trig)
    assert len(fired) == 1
    t_fire, ev, level = fired[0]
    assert t_fire < 40.0  # before the measured crossing
    assert level < ev.capacity * cfg.chase  # fired leading, not chasing
    assert ev.capacity == pytest.approx(4.8)
    assert ev.observed > ev.capacity
    assert ev.lead_s == pytest.approx(10.0)
    assert ev.horizon_s == pytest.approx(25.0)  # plan horizon > lead wins
    assert ev.stale_after == pytest.approx(ev.at + ev.lead_s)
    assert isinstance(ev, RateDrift)  # rides the existing drift ladder


def test_trigger_latch_rearm_fires_once_per_ramp():
    cfg = ForecastConfig(bin_s=1.0, min_bins=4, lead_s=8.0, phi=1.0,
                         confirm=2)
    fc = ArrivalForecaster(["w"], cfg)
    trig = ForecastTrigger(fc, {"w": 1.0}, headroom=1.2)

    def rate(t):
        if t < 40.0:
            return 1.0 + 0.05 * t  # first ramp
        if t < 80.0:
            return 0.4  # recede below the re-arm band
        return 0.5 + 0.08 * (t - 80.0)  # second ramp

    fired = _feed(fc, rate, until=120.0, trig=trig)
    ts = [t for t, _, _ in fired]
    # the latch holds for the rest of the first ramp, the quiet valley
    # cannot fire, and the re-armed trigger catches the second ramp
    assert len([t for t in ts if t <= 40.0]) == 1
    assert [t for t in ts if 40.0 < t <= 80.0] == []
    assert len([t for t in ts if t > 80.0]) >= 1


def test_trigger_no_chase_band_suppresses_mid_episode():
    # traffic starts already deep past capacity: the lead time is spent,
    # the reactive detectors own the episode, the trigger must stay mute
    cfg = ForecastConfig(bin_s=1.0, min_bins=4, lead_s=10.0, confirm=2)
    fc = ArrivalForecaster(["w"], cfg)
    trig = ForecastTrigger(fc, {"w": 1.0}, headroom=1.2)  # chase band 1.8
    fired = _feed(fc, lambda t: 4.0, until=60.0, trig=trig)
    assert fired == []
    assert fc.forecast_rate("w", cfg.lead_s) > 1.2  # it *would* have fired


def test_trigger_rebase_moves_capacity_and_clears_latch():
    cfg = ForecastConfig(bin_s=1.0, min_bins=4, lead_s=8.0, phi=1.0,
                         confirm=2)
    fc = ArrivalForecaster(["w"], cfg)
    trig = ForecastTrigger(fc, {"w": 1.0}, headroom=1.2)
    fired = _feed(fc, lambda t: 1.0 + 0.05 * t, until=40.0, trig=trig)
    assert len(fired) == 1 and trig._latched == {"w"}
    trig.rebase({"w": 4.0})
    assert trig._latched == set() and trig._breach == {"w": 0}
    assert trig.capacity_lams["w"] == pytest.approx(4.8)
    # the forecast that latched the old plan is legal under the new one
    assert trig.poll(41.0) == []


def test_controller_drops_stale_deferred_forecast(sharing_fleet):
    res = schedule_multi(sharing_fleet, SPEC, LAMS, SCFG, mode="pooled")
    ctrl = ReplanController(sharing_fleet, SPEC, LAMS, SCFG, result=res,
                            cooldown_s=100.0)
    first = ctrl.react([RateDrift(workflow="wf_a", at=1.0, magnitude=1.0,
                                  observed=0.8, expected=0.4)])
    assert first is not None and first.feasible
    fd = ForecastDrift(workflow="wf_a", at=20.0, magnitude=1.5,
                       observed=2.0, expected=0.8,
                       horizon_s=60.0, lead_s=30.0)
    assert ctrl.react([fd]) is None  # cool-down defers it
    assert ctrl._deferred == [fd]
    # next batch lands past the forecast's firing lead (stale_after=50):
    # the extrapolated 2.0 target must not survive into this plan
    late = RateDrift(workflow="wf_b", at=120.0, magnitude=1.0,
                     observed=1.2, expected=0.6)
    act = ctrl.react([late])
    assert act is not None
    assert not any(isinstance(ev, ForecastDrift) for ev in act.events)
    assert act.lam_targets["wf_a"] < 2.0


def test_controller_honours_still_valid_deferred_forecast(sharing_fleet):
    res = schedule_multi(sharing_fleet, SPEC, LAMS, SCFG, mode="pooled")
    ctrl = ReplanController(sharing_fleet, SPEC, LAMS, SCFG, result=res,
                            cooldown_s=100.0)
    ctrl.react([RateDrift(workflow="wf_a", at=1.0, magnitude=1.0,
                          observed=0.8, expected=0.4)])
    fd = ForecastDrift(workflow="wf_a", at=20.0, magnitude=1.5,
                       observed=2.0, expected=0.8,
                       horizon_s=200.0, lead_s=200.0)
    assert ctrl.react([fd]) is None  # deferred, but stays valid to t=220
    late = RateDrift(workflow="wf_b", at=120.0, magnitude=1.0,
                     observed=1.2, expected=0.6)
    act = ctrl.react([late])
    assert act is not None and fd in act.events
    assert act.lam_targets["wf_a"] == pytest.approx(2.0)


def test_controller_never_adopts_infeasible_plan(sharing_fleet):
    res = schedule_multi(sharing_fleet, SPEC, LAMS, SCFG, mode="pooled")
    ctrl = ReplanController(sharing_fleet, SPEC, LAMS, SCFG, result=res)
    before_targets = dict(ctrl.lam_targets)
    before_result = ctrl.result
    act = ctrl.react([RateDrift(workflow="wf_a", at=1.0, magnitude=1000.0,
                                observed=500.0, expected=0.4)])
    assert act is not None and not act.feasible
    # the incumbent plan (and the targets the monitor measures against)
    # must survive: the fleet keeps serving what it can actually serve
    assert ctrl.lam_targets == before_targets
    assert ctrl.result is before_result
    assert ctrl.history == []


def test_controller_infeasible_forecast_falls_back_to_measured(sharing_fleet):
    res = schedule_multi(sharing_fleet, SPEC, LAMS, SCFG, mode="pooled")
    ctrl = ReplanController(sharing_fleet, SPEC, LAMS, SCFG, result=res)
    rd = RateDrift(workflow="wf_a", at=1.0, magnitude=1.0,
                   observed=0.8, expected=0.4)
    fd = ForecastDrift(workflow="wf_a", at=1.0, magnitude=1000.0,
                       observed=500.0, expected=0.4,
                       horizon_s=60.0, lead_s=60.0)
    act = ctrl.react([rd, fd])
    # the speculative 500/s target cannot be served; the ladder retries
    # at the measured 0.8/s instead of escalating to a cold re-plan
    assert act is not None and act.feasible
    assert act.rung == RUNG_WARM_REPLAN
    assert act.lam_targets["wf_a"] == pytest.approx(0.8)
    assert ctrl.lam_targets["wf_a"] == pytest.approx(0.8)


def test_deploy_multi_forecast_attaches_trigger(sharing_fleet):
    from repro.core.scepsy import deploy_multi

    wfa = Workflow("wf_a", lambda rng: iter(()), {"gen": SHARED})
    wfb = Workflow("wf_b", lambda rng: iter(()), {"draft": SHARED})
    fcfg = ForecastConfig(bin_s=1.0, min_bins=4, lead_s=10.0)
    dep = deploy_multi([wfa, wfb], SPEC, LAMS, pipelines=sharing_fleet,
                       scheduler_config=SCFG, mode="pooled", online=True,
                       forecast=fcfg)
    ctrl = dep.controller
    assert ctrl is not None and ctrl.forecast is not None
    assert set(ctrl.forecast.planned_lams) == {"wf_a", "wf_b"}
    for w, lam in LAMS.items():
        assert ctrl.forecast.capacity_lams[w] == pytest.approx(
            lam * ctrl.forecast.headroom)
    # the monitor's arrival hook feeds the forecaster
    ctrl.monitor.record_arrival("wf_a", 0.5)
    assert ctrl.monitor.forecaster is ctrl.forecast.forecaster
    assert ctrl.forecast.forecaster._count["wf_a"] == 1
    # offline deployments carry no trigger
    off = deploy_multi([wfa, wfb], SPEC, LAMS, pipelines=sharing_fleet,
                       scheduler_config=SCFG, mode="pooled")
    assert off.controller is None


if HAVE_HYPOTHESIS:

    @given(rate=st.floats(min_value=0.3, max_value=4.0),
           headroom=st.floats(min_value=1.05, max_value=2.0))
    @settings(max_examples=30, deadline=None)
    def test_property_trigger_silent_on_stationary_traffic(rate, headroom):
        """No stationary rate at the planned level ever trips the
        forecast trigger, whatever the capacity headroom."""
        cfg = ForecastConfig(bin_s=1.0, min_bins=4, lead_s=10.0, confirm=2)
        fc = ArrivalForecaster(["w"], cfg)
        trig = ForecastTrigger(fc, {"w": rate}, headroom=headroom)
        fired = _feed(fc, lambda t: rate, until=80.0, trig=trig)
        assert fired == [] and trig.fired == []

    @given(xs=st.lists(st.floats(min_value=0.0, max_value=50.0),
                       min_size=2, max_size=30),
           k=st.integers(min_value=1, max_value=20))
    @settings(max_examples=50, deadline=None)
    def test_property_holtwinters_forecast_nonnegative(xs, k):
        """Extrapolation never goes negative, and with a non-negative
        trend it is monotone in the horizon."""
        hw_ = HoltWinters(alpha=0.4, beta=0.2, phi=0.9)
        for x in xs:
            hw_.update(x)
        f1, fk = hw_.forecast(1), hw_.forecast(k)
        assert f1 >= 0.0 and fk >= 0.0
        if hw_.trend >= 0.0 and k >= 1:
            assert fk >= f1
