"""Traffic generation + trace replay: the property-test hardening pass.

Property tests (hypothesis, skipped when unavailable): seeded segment
lists and arrival draws are bit-identical run to run; every composed
model conserves integrated intensity through ``_merge``; empirical
per-segment Poisson counts stay within statistical bounds of ``rate ×
duration``.  Deterministic versions of each property run everywhere,
plus unit coverage of the intensity components, trace record/replay
round-trips, lazy-vs-eager replay parity, and the checked-in golden
trace fingerprint.
"""
import json
import math
import random

import pytest

from repro.core.pipeline import Allocation
from repro.serving.simulator import EventLoop
from repro.workflows.registry import get_workflow
from repro.workflows.runtime import ClusterDriver
from repro.workflows.traffic import (ArrivalTrace, BurstModulator,
                                     DiurnalCycle, FlashCrowd, TraceEvent,
                                     TrafficModel, _merge, poisson_arrivals,
                                     record_trace, replay_trace)
from repro.serving.deploy import routers_from_allocations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _integral(segments) -> float:
    return sum(r * d for r, d in segments)


def _duration(segments) -> float:
    return sum(d for _, d in segments)


DAY = TrafficModel(
    2.0,
    diurnal=DiurnalCycle(period_s=200.0, amplitude=0.6, phase=0.25),
    bursts=BurstModulator(factor=2.0, mean_on_s=8.0, mean_off_s=40.0),
    flash=FlashCrowd(at_s=60.0, peak=3.0, ramp_s=10.0, hold_s=15.0,
                     decay_s=20.0),
)


# ---------------------------------------------------------------------------
# intensity components
# ---------------------------------------------------------------------------


def test_diurnal_mean_multiplier_is_one_over_full_period():
    cyc = DiurnalCycle(period_s=100.0, amplitude=0.7, phase=0.3)
    pieces = cyc.pieces(100.0)
    assert _duration(pieces) == pytest.approx(100.0)
    assert _integral(pieces) / 100.0 == pytest.approx(1.0, abs=1e-9)
    # peak lands at period * (phase + 1/4)
    peak_t, t = None, 0.0
    best = -math.inf
    for v, d in pieces:
        if v > best:
            best, peak_t = v, t + d / 2.0
        t += d
    assert peak_t == pytest.approx(100.0 * (0.3 + 0.25), abs=100.0 / 48)


def test_diurnal_amplitude_validated():
    with pytest.raises(ValueError):
        DiurnalCycle(period_s=10.0, amplitude=1.5).pieces(10.0)


def test_burst_modulator_starts_quiet_and_alternates():
    pieces = BurstModulator(factor=3.0, mean_on_s=5.0, mean_off_s=20.0) \
        .pieces(500.0, random.Random(7))
    assert pieces[0][0] == 1.0  # bursts are drawn, never given
    for (a, _), (b, _) in zip(pieces, pieces[1:]):
        assert {a, b} == {1.0, 3.0}  # strict on/off alternation
    assert _duration(pieces) == pytest.approx(500.0)


def test_flash_crowd_integrated_intensity_exact():
    fc = FlashCrowd(at_s=30.0, peak=4.0, ramp_s=12.0, hold_s=6.0,
                    decay_s=18.0, steps=6)
    window = 120.0
    pieces = fc.pieces(window)
    assert _duration(pieces) == pytest.approx(window)
    # stairs at segment midpoints integrate the linear ramps exactly:
    # mean multiplier (peak+1)/2 over ramp and decay, peak over hold,
    # 1 elsewhere
    extra = (4.0 - 1.0) / 2.0 * (12.0 + 18.0) + (4.0 - 1.0) * 6.0
    assert _integral(pieces) == pytest.approx(window + extra, rel=1e-9)


def test_flash_crowd_clips_to_window():
    fc = FlashCrowd(at_s=50.0, peak=2.0, ramp_s=20.0, hold_s=20.0,
                    decay_s=20.0)
    pieces = fc.pieces(60.0)  # cuts off mid-ramp
    assert _duration(pieces) == pytest.approx(60.0)
    assert max(v for v, _ in pieces) < 2.0


def test_merge_conserves_product_integral():
    a = [(2.0, 3.0), (0.5, 7.0)]
    b = [(1.0, 5.0), (3.0, 5.0)]
    merged = _merge([a, b], 10.0)
    assert _duration(merged) == pytest.approx(10.0)
    # piecewise product integral, hand-computed over the joint grid
    expect = 2.0 * 3.0 + 0.5 * 2.0 + 0.5 * 3.0 * 5.0
    assert _integral(merged) == pytest.approx(expect)


# ---------------------------------------------------------------------------
# determinism + rate conservation (deterministic versions run everywhere)
# ---------------------------------------------------------------------------


def test_segments_and_arrivals_deterministic_in_seed():
    s1 = DAY.segments(400.0, seed=11)
    s2 = DAY.segments(400.0, seed=11)
    assert s1 == s2
    assert list(poisson_arrivals(s1, seed=5)) == \
        list(poisson_arrivals(s2, seed=5))
    # a different seed re-draws the burst layout
    assert DAY.segments(400.0, seed=12) != s1


def test_segments_cover_duration_and_stay_positive():
    segs = DAY.segments(400.0, seed=3)
    assert _duration(segs) == pytest.approx(400.0)
    assert all(r > 0 and d > 0 for r, d in segs)
    assert DAY.peak_rate(400.0, seed=3) == max(r for r, _ in segs)
    assert DAY.mean_rate(400.0, seed=3) == \
        pytest.approx(_integral(segs) / 400.0)


def _max_poisson_z(model: TrafficModel, duration: float, seed: int,
                   min_expect: float = 25.0) -> float:
    """Largest per-segment |count - rate*dur| / sqrt(rate*dur) over
    segments big enough for the normal approximation."""
    segs = model.segments(duration, seed=seed)
    times = [t for t, _ in poisson_arrivals(segs, seed=seed)]
    zmax, t0, i = 0.0, 0.0, 0
    for rate, dur in segs:
        t1 = t0 + dur
        n = 0
        while i < len(times) and times[i] < t1:
            n += 1
            i += 1
        expect = rate * dur
        if expect >= min_expect:
            zmax = max(zmax, abs(n - expect) / math.sqrt(expect))
        t0 = t1
    return zmax


def test_rate_conservation_per_segment_deterministic():
    # steady high-rate model => every segment is checkable
    model = TrafficModel(8.0, diurnal=DiurnalCycle(period_s=400.0,
                                                   amplitude=0.4, bins=8))
    assert _max_poisson_z(model, 400.0, seed=0) <= 5.0


def test_arrival_times_strictly_inside_window_and_ordered():
    segs = DAY.segments(300.0, seed=1)
    arr = list(poisson_arrivals(segs, seed=1))
    times = [t for t, _ in arr]
    rids = [r for _, r in arr]
    assert times == sorted(times)
    assert all(0.0 < t < 300.0 for t in times)
    assert rids == list(range(len(rids)))  # dense request ids


# ---------------------------------------------------------------------------
# trace record / replay
# ---------------------------------------------------------------------------


def _two_model_fleet():
    return {
        "react_agent": TrafficModel(
            0.8, bursts=BurstModulator(factor=2.0, mean_on_s=5.0,
                                       mean_off_s=25.0)),
        "session_chat": TrafficModel(
            0.6, diurnal=DiurnalCycle(period_s=80.0, amplitude=0.5)),
    }


def test_record_trace_roundtrip(tmp_path):
    trace = record_trace(_two_model_fleet(), 80.0, seed=4)
    assert len(trace) > 0
    path = tmp_path / "trace.jsonl"
    trace.save(path)
    loaded = ArrivalTrace.load(path)
    assert loaded.events == trace.events
    assert loaded.counts() == trace.counts()
    assert loaded.duration == pytest.approx(trace.duration)


def test_trace_events_sorted_total_order():
    tr = ArrivalTrace([TraceEvent(2.0, "b", 0), TraceEvent(1.0, "a", 1),
                       TraceEvent(1.0, "a", 0), TraceEvent(2.0, "a", 9)])
    keys = [(e.t, e.workflow, e.session) for e in tr.events]
    assert keys == sorted(keys)


def _fleet_drivers(loop):
    drivers = {}
    for name in ("react_agent", "session_chat"):
        wf = get_workflow(name)
        routers = routers_from_allocations(
            wf, {llm: Allocation(replicas=1, tp=1) for llm in wf.llms}, loop)
        drivers[name] = ClusterDriver(wf, routers, loop)
    return drivers


def _replay_records(trace, *, eager):
    loop = EventLoop()
    drivers = _fleet_drivers(loop)
    replay_trace(drivers, trace, seed=2, eager=eager)
    loop.run(1e9)
    return {name: [(r.request_id, r.arrival, r.done)
                   for r in drv.records]
            for name, drv in drivers.items()}


def test_replay_lazy_eager_parity():
    trace = record_trace(_two_model_fleet(), 60.0, seed=9)
    lazy = _replay_records(trace, eager=False)
    eager = _replay_records(trace, eager=True)
    assert lazy == eager
    assert sum(len(v) for v in lazy.values()) == len(trace)


def test_lazy_replay_keeps_one_pending_arrival():
    trace = record_trace(_two_model_fleet(), 60.0, seed=9)
    loop = EventLoop()
    drivers = _fleet_drivers(loop)
    src = replay_trace(drivers, trace, seed=2)
    # before running: exactly the first trace row is pending
    assert loop.pending == 1
    loop.run(1e9)
    assert src.exhausted and src.scheduled == len(trace)


def test_replay_rejects_unknown_workflow_and_split_loops():
    trace = record_trace(_two_model_fleet(), 30.0, seed=9)
    loop = EventLoop()
    drivers = _fleet_drivers(loop)
    with pytest.raises(KeyError):
        replay_trace({"react_agent": drivers["react_agent"]}, trace)
    with pytest.raises(KeyError):
        replay_trace({"react_agent": drivers["react_agent"]}, trace,
                     eager=True)
    other = _fleet_drivers(EventLoop())
    mixed = {"react_agent": drivers["react_agent"],
             "session_chat": other["session_chat"]}
    with pytest.raises(ValueError):
        replay_trace(mixed, trace)


def test_trace_load_skips_blank_lines(tmp_path):
    path = tmp_path / "trace.jsonl"
    rows = [{"t": 1.5, "workflow": "react_agent", "session": 0}]
    path.write_text("\n" + json.dumps(rows[0]) + "\n\n")
    tr = ArrivalTrace.load(path)
    assert len(tr) == 1 and tr.events[0].workflow == "react_agent"


# ---------------------------------------------------------------------------
# golden fixture (tier-1 guard: serving semantics cannot silently shift)
# ---------------------------------------------------------------------------


def test_golden_trace_fingerprint_pinned():
    from benchmarks.bench_traffic import (GOLDEN_EXPECTED, GOLDEN_TRACE,
                                          golden_fingerprint, golden_replay)
    with open(GOLDEN_EXPECTED) as f:
        expected = json.load(f)
    trace = ArrivalTrace.load(GOLDEN_TRACE)
    assert len(trace) == expected["events"]
    rows = golden_replay(trace, seed=int(expected["seed"]))
    assert len(rows) == expected["completed"]
    assert golden_fingerprint(rows) == expected["fingerprint"], (
        "golden trace replay diverged: if the serving-semantics change is "
        "intentional, regenerate via "
        "`python -m benchmarks.bench_traffic --regen-golden` and commit "
        "both fixture files")


# ---------------------------------------------------------------------------
# hypothesis properties (skipped without hypothesis)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @given(seed=st.integers(0, 10_000),
           base=st.floats(0.2, 8.0),
           amplitude=st.floats(0.0, 1.0),
           phase=st.floats(0.0, 1.0))
    @settings(max_examples=40, deadline=None)
    def test_prop_model_conserves_integrated_intensity(seed, base,
                                                       amplitude, phase):
        model = TrafficModel(
            base,
            diurnal=DiurnalCycle(period_s=120.0, amplitude=amplitude,
                                 phase=phase),
            bursts=BurstModulator(factor=2.0, mean_on_s=10.0,
                                  mean_off_s=30.0),
            flash=FlashCrowd(at_s=40.0, peak=2.5, ramp_s=10.0,
                             hold_s=10.0, decay_s=10.0))
        segs = model.segments(120.0, seed=seed)
        assert _duration(segs) == pytest.approx(120.0)
        assert all(r >= 0 for r, _ in segs)
        # the product integral equals the re-merged integral of the
        # same components (merge is associative over the breakpoint
        # grid and never loses mass)
        again = model.segments(120.0, seed=seed)
        assert _integral(segs) == pytest.approx(_integral(again))

    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_prop_arrivals_bit_identical_in_seed(seed):
        segs = DAY.segments(200.0, seed=seed)
        a = list(poisson_arrivals(segs, seed=seed))
        b = list(poisson_arrivals(DAY.segments(200.0, seed=seed),
                                  seed=seed))
        assert a == b

    @given(seed=st.integers(0, 2_000), rate=st.floats(4.0, 16.0))
    @settings(max_examples=20, deadline=None)
    def test_prop_rate_conservation_steady_segments(seed, rate):
        model = TrafficModel(rate, diurnal=DiurnalCycle(
            period_s=160.0, amplitude=0.3, bins=4))
        # 5-sigma bound per segment: false-failure odds are negligible
        # over the sampled seed space
        assert _max_poisson_z(model, 160.0, seed=seed) <= 5.0
