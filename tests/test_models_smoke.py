"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs.  One test per assigned arch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import shapes_for
from repro.configs.registry import ASSIGNED, get_config, reduced_config
from repro.models import build_model

SEQ = 16
BATCH = 2


def _batch_for(bundle, cfg, rng):
    k1, k2 = jax.random.split(rng)
    batch = {
        "tokens": jax.random.randint(k1, (BATCH, SEQ), 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, (BATCH, SEQ), 0, cfg.vocab_size),
    }
    for name, spec_fn in (bundle.extra_inputs or {}).items():
        s = spec_fn(BATCH)
        batch[name] = jnp.ones(s.shape, s.dtype) * 0.01
    return batch


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_train_step_smoke(arch):
    cfg = reduced_config(get_config(arch))
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0))
    batch = _batch_for(bundle, cfg, jax.random.key(1))

    loss, grads = jax.jit(jax.value_and_grad(bundle.loss_fn))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss {loss}"
    gnorm = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads))
    assert np.isfinite(float(gnorm)), f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_prefill_decode_smoke(arch):
    cfg = reduced_config(get_config(arch))
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0))
    batch = _batch_for(bundle, cfg, jax.random.key(1))

    logits, cache = jax.jit(bundle.prefill)(params, batch)
    assert logits.shape == (BATCH, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), f"{arch}: prefill NaN"

    if bundle.decode_step is None:
        return
    # grow the cache to hold more tokens than the prompt
    if bundle.cache_shape_fn is not None and cfg.family not in ("ssm",):
        # dense-style cache: rebuild at max_len and copy prefix
        max_len = SEQ + 4
        big = bundle.init_cache(BATCH, max_len)

        def copy_prefix(dst, src):
            if dst.shape == src.shape:
                return src
            sl = tuple(slice(0, s) for s in src.shape)
            return dst.at[sl].set(src.astype(dst.dtype))

        cache = jax.tree.map(copy_prefix, big, cache)

    tok = jnp.argmax(logits, axis=-1)
    step = jax.jit(bundle.decode_step)
    for i in range(3):
        pos = jnp.asarray(SEQ + i, jnp.int32)
        logits, cache = step(params, cache, tok, pos)
        assert logits.shape == (BATCH, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), (
            f"{arch}: decode NaN at step {i}")
        tok = jnp.argmax(logits, axis=-1)


def test_param_counts_sane():
    # full configs should land near their nameplate sizes
    expected = {
        "gemma-7b": (7e9, 10e9),
        "glm4-9b": (8e9, 11e9),
        "qwen2-7b": (6.5e9, 8.5e9),
        "qwen2.5-3b": (2.5e9, 4e9),
        "olmoe-1b-7b": (5.5e9, 8e9),
        "llama-3.2-vision-11b": (8.5e9, 12e9),
        "rwkv6-7b": (6e9, 9e9),
        "hymba-1.5b": (1.0e9, 2.2e9),
    }
    for name, (lo, hi) in expected.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, f"{name}: param count {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]B"


def test_shapes_for_rules():
    assert [s.name for s in shapes_for(get_config("rwkv6-7b"))] == [
        "train_4k", "prefill_32k", "decode_32k", "long_500k"]
    assert [s.name for s in shapes_for(get_config("gemma-7b"))] == [
        "train_4k", "prefill_32k", "decode_32k"]
    assert [s.name for s in shapes_for(get_config("hymba-1.5b"))] == [
        "train_4k", "prefill_32k", "decode_32k", "long_500k"]
