"""Training-loop integration: loss decreases, compression path works,
ZeRO specs are valid."""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_config, reduced_config
from repro.distributed.sharding import zero_opt_specs
from repro.launch.train import main as train_main
from repro.models import build_model
from repro.models.layers import param_shapes, param_specs


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "granite-moe-3b-a800m"])
def test_loss_decreases(arch):
    losses = train_main(["--arch", arch, "--steps", "20", "--batch", "8",
                         "--seq", "32", "--lr", "2e-3"])
    assert np.mean(losses[-5:]) < 0.75 * np.mean(losses[:5]), (
        f"{arch}: loss did not decrease: {losses[:3]} ... {losses[-3:]}")


def test_loss_decreases_with_compression():
    losses = train_main(["--arch", "qwen2.5-3b", "--steps", "20",
                         "--batch", "8", "--seq", "32", "--lr", "2e-3",
                         "--compress"])
    assert np.mean(losses[-5:]) < 0.8 * np.mean(losses[:5])


def test_zero_opt_specs_structure():
    cfg = reduced_config(get_config("qwen2.5-3b"))
    bundle = build_model(cfg)
    specs = param_specs(bundle.defs)
    shapes = param_shapes(bundle.defs)
    z = zero_opt_specs(specs, shapes, data_ways=4)
    # same tree structure, and at least one moment leaf gained 'data'
    assert any("data" in tuple(s) for s in jax.tree.leaves(
        z.m, is_leaf=lambda x: hasattr(x, "index")) if s is not None)
