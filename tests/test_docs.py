"""Docs stay honest: the markdown link/reference checker runs in tier-1.

Mirrors the CI ``docs`` job (``tools/check_docs.py``): every relative
link in README.md, docs/ and benchmarks/README.md must resolve, and
every backtick reference to a ``repro.*`` module or a ``*.py`` file
must name something that exists in the repo.
"""
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _checker():
    sys.path.insert(0, str(REPO / "tools"))
    try:
        import check_docs
    finally:
        sys.path.pop(0)
    return check_docs


def test_docs_links_and_references_resolve(capsys):
    check_docs = _checker()
    rc = check_docs.main([])
    out = capsys.readouterr().out
    assert rc == 0, f"docs check failed:\n{out}"


def test_checker_catches_broken_link(tmp_path, monkeypatch):
    check_docs = _checker()
    bad = tmp_path / "bad.md"
    bad.write_text("see [missing](no/such/file.md) and `repro.no.such`\n")
    monkeypatch.setattr(check_docs, "REPO", tmp_path)
    errors = check_docs.check_file(bad)
    assert any("broken link" in e for e in errors)
    assert any("nonexistent module" in e for e in errors)


def test_checker_catches_submodule_typo_of_real_package():
    check_docs = _checker()
    # an existing package prefix must not excuse a misspelled submodule
    assert not check_docs.module_exists("repro.core.plcement")
    assert not check_docs.module_exists("repro.qos.nonexistent")
    # but packages themselves and attribute tails of module files resolve
    assert check_docs.module_exists("repro.core")
    assert check_docs.module_exists("repro.core.placement.place_fleet")
    assert check_docs.module_exists("repro.qos.slo.RequestQoS.slack")
