"""Heterogeneous chip classes + just-in-time model substitution.

Covers the hetero tentpole's load-bearing invariants: a uniform
cluster written as an explicit default-class host group schedules and
places bit-for-bit like the legacy spec (the ChipClass refactor is a
pure extension); TP groups never span chip classes or hb domains
(hypothesis property over random mixed clusters); the admission
layer's SUBSTITUTE decision conserves served calls and never upgrades
a request's SLO class or deadline; and the per-(chip_class, tp)
profiler sweep memo makes re-profiling across classes free.
"""
import dataclasses
import math

import pytest

from repro import hw
from repro.core import profiler
from repro.core.pipeline import Allocation
from repro.core.placement import PlacementError, place_fleet
from repro.core.scepsy import build_pipeline, deploy_multi
from repro.core.scheduler import SchedulerConfig, schedule
from repro.qos.admission import (ADMIT, SUBSTITUTE, AdmissionController,
                                 fleet_admission)
from repro.qos.slo import SLOClass, WorkModel, WorkflowQoS
from repro.serving.deploy import pooled_fleet_routers, tenant_routers
from repro.serving.simulator import EventLoop
from repro.workflows.registry import get_workflow
from repro.workflows.runtime import ClusterDriver

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


LEGACY = hw.ClusterSpec(num_hosts=2, chips_per_host=4, hb_domain_size=2)
EXPLICIT = hw.hetero_cluster(
    [hw.HostGroup(num_hosts=2, chips_per_host=4,
                  chip_class=hw.DEFAULT_CHIP_CLASS.name)],
    hb_domain_size=2)


@pytest.fixture(scope="module")
def react_pipeline():
    pipe, stats, _ = build_pipeline(get_workflow("react_agent"),
                                    n_trace_requests=6,
                                    max_profile_groups=4, seed=0)
    return pipe, stats


# ---------------------------------------------------------------------------
# uniform-cluster parity: explicit default class == legacy spec
# ---------------------------------------------------------------------------


def test_uniform_default_class_schedule_parity(react_pipeline):
    pipe, _ = react_pipeline
    cfg = SchedulerConfig(max_tp=2)
    r_legacy = schedule(pipe, LEGACY, 1.0, cfg)
    r_explicit = schedule(pipe, EXPLICIT, 1.0, cfg)
    assert r_legacy.allocations == r_explicit.allocations
    assert all(a.chip_class is None
               for a in r_explicit.allocations.values())
    assert r_legacy.units == r_explicit.units
    assert r_legacy.prediction.latency == r_explicit.prediction.latency
    assert r_legacy.prediction.max_throughput == \
        r_explicit.prediction.max_throughput


def test_uniform_default_class_placement_parity(react_pipeline):
    pipe, _ = react_pipeline
    cfg = SchedulerConfig(max_tp=2)
    allocs = schedule(pipe, LEGACY, 1.0, cfg).allocations
    p_legacy = place_fleet({"react_agent": dict(allocs)}, LEGACY)
    p_explicit = place_fleet({"react_agent": dict(allocs)}, EXPLICIT)
    m_legacy = p_legacy.to_deployment()
    m_explicit = p_explicit.to_deployment()
    # instance-for-instance identical chips; the explicit spec's
    # manifest additionally records its host groups
    assert m_legacy["instances"] == m_explicit["instances"]
    assert "host_groups" not in m_legacy["cluster"]
    assert m_explicit["cluster"]["host_groups"] == [
        {"chip_class": hw.DEFAULT_CHIP_CLASS.name,
         "num_hosts": 2, "chips_per_host": 4}]


def test_uniform_chip_table_matches_legacy_domains():
    table = EXPLICIT.chip_table()
    assert len(table) == LEGACY.num_chips
    for i, (host, domain, cls) in enumerate(table):
        assert host == i // LEGACY.chips_per_host
        assert domain == i // LEGACY.hb_domain_size
        assert cls == hw.DEFAULT_CHIP_CLASS.name


# ---------------------------------------------------------------------------
# property: TP groups never span chip classes (or hb domains)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    _group = st.tuples(st.sampled_from(["v5e", "v5p", "v4i"]),
                       st.integers(1, 2), st.sampled_from([2, 4]))
    _alloc = st.tuples(st.integers(1, 2),          # replicas
                       st.sampled_from([1, 2]),    # tp
                       st.booleans())               # bind to a class?

    @given(groups=st.lists(_group, min_size=1, max_size=3, unique=True),
           allocs=st.lists(_alloc, min_size=1, max_size=3),
           bind_idx=st.integers(0, 5))
    @settings(max_examples=30, deadline=None)
    def test_tp_groups_never_span_classes(groups, allocs, bind_idx):
        spec = hw.hetero_cluster(
            [hw.HostGroup(num_hosts=n, chips_per_host=c, chip_class=cls)
             for cls, n, c in groups],
            hb_domain_size=2)
        classes = spec.classes()
        table = spec.chip_table()
        fleet = {"wf": {
            f"m{i}": Allocation(
                replicas=r, tp=tp,
                chip_class=(classes[bind_idx % len(classes)]
                            if bind else None))
            for i, (r, tp, bind) in enumerate(allocs)}}
        try:
            placement = place_fleet(fleet, spec)
        except PlacementError:
            return  # infeasible shapes are fine; only placed ones matter
        placement.validate()  # raises on any span/binding violation
        for inst in placement.instances:
            rows = [table[c] for c in inst.chips]
            assert len({cls for _, _, cls in rows}) == 1
            assert len({dom for _, dom, _ in rows}) == 1
            bound = fleet["wf"][inst.llm.split("/", 1)[1]].chip_class
            if bound is not None:
                assert all(cls == bound for _, _, cls in rows)


# ---------------------------------------------------------------------------
# substitution: decision logic, conservation, never-upgrade
# ---------------------------------------------------------------------------


class _FakeReplica:
    def __init__(self, load):
        self.load = load
        self.failed = False


class _FakeRouter:
    def __init__(self, *loads):
        self.replicas = [_FakeReplica(x) for x in loads]


def _silver_entry(ctrl, busy, sub=None):
    slo = SLOClass("silver", latency_target_s=1.0, shed_policy="degrade")
    work = WorkModel(per_call_s={"m": 0.1}, total_s=0.1, serial_s=0.1,
                     sec_per_token={"m": 0.001})
    ctrl.register("wf", slo, work, routers={"m": busy},
                  substitutes={"m": sub} if sub else None)


def test_admission_substitutes_before_shedding():
    ctrl = AdmissionController(min_rate_samples=10 ** 9)
    # primary backlog prices the request over its 1s deadline; the idle
    # substitute tier brings it back under
    _silver_entry(ctrl, _FakeRouter(5000.0), sub=_FakeRouter(0.0))
    assert ctrl.admit("wf", now=0.0) == SUBSTITUTE
    stats = ctrl.stats()["wf"]
    assert stats == {"arrived": 1, "admitted": 0, "rejected": 0,
                     "degraded": 0, "substituted": 1}
    assert ctrl.substitution_rates()["wf"] == 1.0


def test_admission_without_substitute_sheds():
    ctrl = AdmissionController(min_rate_samples=10 ** 9)
    _silver_entry(ctrl, _FakeRouter(5000.0))
    assert ctrl.admit("wf", now=0.0) == "degrade"
    assert ctrl.stats()["wf"]["substituted"] == 0


def test_admission_backlogged_substitute_is_no_escape():
    ctrl = AdmissionController(min_rate_samples=10 ** 9)
    _silver_entry(ctrl, _FakeRouter(5000.0), sub=_FakeRouter(9000.0))
    assert ctrl.admit("wf", now=0.0) == "degrade"


def test_admission_idle_primary_admits_normally():
    ctrl = AdmissionController(min_rate_samples=10 ** 9)
    _silver_entry(ctrl, _FakeRouter(0.0), sub=_FakeRouter(0.0))
    assert ctrl.admit("wf", now=0.0) == ADMIT


@pytest.fixture(scope="module")
def substitution_run():
    """A pooled two-workflow burst where debate's judge (8B, bronze)
    substitutes to react's qwen tier — the bench_hetero Part B flow at
    test scale."""
    lams = {"react_agent": 1.0, "debate": 1.6}
    wfs = {n: get_workflow(n) for n in lams}
    spec = hw.ClusterSpec(num_hosts=2, chips_per_host=4)
    dep = deploy_multi(list(wfs.values()), spec, lams,
                       scheduler_config=SchedulerConfig(max_tp=2),
                       mode="pooled", n_trace_requests=6,
                       max_profile_groups=4, seed=0)
    pooled = dep.schedule.pooled
    loop = EventLoop()
    tenants = tenant_routers(pooled.allocations, pooled.cfgs, loop,
                             discipline="priority",
                             members=pooled.members, routing=pooled.routing)
    per_wf = pooled_fleet_routers(tenants, pooled.members, pooled.routing)
    sub_maps, sub_routers = {}, {}
    for name, wf in wfs.items():
        for local, cfg in wf.llms.items():
            target = cfg.substitute
            if target and target in tenants:
                key = f"~sub:{target}"
                per_wf[name][key] = tenants[target]
                sub_maps.setdefault(name, {})[local] = key
                sub_routers.setdefault(name, {})[local] = tenants[target]
    qos = {n: WorkflowQoS(slo=q.slo, work=q.work)
           for n, q in dep.qos.items()}
    ctrl = fleet_admission(qos, per_wf, substitutes=sub_routers)
    drivers = {}
    for k, name in enumerate(sorted(wfs)):
        drv = ClusterDriver(wfs[name], per_wf[name], loop,
                            qos=qos.get(name),
                            substitute_map=sub_maps.get(name))
        lam = lams[name]
        drv.schedule_arrivals([(lam, 20.0), (lam * 12.0, 60.0),
                               (lam, 20.0)], seed=k)
        drivers[name] = drv
    loop.run(100.0 + 400.0)
    return wfs, qos, ctrl, drivers


def test_substitution_conserves_served_calls(substitution_run):
    _, _, ctrl, drivers = substitution_run
    total_sub = 0
    for name, drv in drivers.items():
        recs = drv.records
        # after the drain every admitted request completed: nothing is
        # lost in the reroute, substituted or not
        assert all(r.done >= 0 or r.rejected for r in recs)
        assert len(recs) == sum(1 for r in recs if r.done >= 0) \
            + sum(1 for r in recs if r.rejected)
        total_sub += sum(1 for r in recs if r.substituted)
        # controller and driver agree on what was substituted
        assert ctrl.stats()[name]["substituted"] == \
            sum(1 for r in recs if r.substituted)
    assert total_sub > 0  # the burst actually exercised the path


def test_substitution_never_upgrades_slo(substitution_run):
    wfs, qos, _, drivers = substitution_run
    for name, drv in drivers.items():
        slo = qos[name].slo
        for r in drv.records:
            if not r.substituted:
                continue
            # a substituted request keeps its own class's deadline and
            # is never silently demoted to best-effort
            assert not r.rejected and not r.degraded
            assert r.deadline == pytest.approx(
                r.arrival + slo.deadline_s)


def test_substitution_rates_feed_share_attribution(substitution_run):
    from repro.core.pipeline import merge_pipelines
    wfs, _, ctrl, _ = substitution_run
    rates = ctrl.substitution_rates()
    assert 0.0 < rates["debate"] <= 1.0
    pipes = {n: build_pipeline(wf, n_trace_requests=6,
                               max_profile_groups=4, seed=0)[0]
             for n, wf in wfs.items()}
    merged = merge_pipelines(pipes, {"react_agent": 1.0, "debate": 1.6})
    judge = wfs["debate"].llms["judge"]
    cid, sub = judge.name, judge.substitute
    resub = merged.with_substitution({cid: rates["debate"]})
    # call volume moves off the substituted tenant onto its substitute
    assert resub.stages[cid].n < merged.stages[cid].n
    assert resub.stages[sub].n > merged.stages[sub].n
    moved = merged.stages[cid].n - resub.stages[cid].n
    gained = resub.stages[sub].n - merged.stages[sub].n
    assert moved == pytest.approx(gained)


# ---------------------------------------------------------------------------
# per-(chip_class, tp) profile memoization
# ---------------------------------------------------------------------------


def test_profile_sweep_memoized_per_class():
    profiler.clear_profile_cache()
    classes = (hw.chip_class("v5e"), hw.chip_class("v5p"))
    build_pipeline(get_workflow("map_reduce"), n_trace_requests=6,
                   max_profile_groups=4, seed=0, chip_classes=classes)
    hits0, misses0 = profiler.profile_cache_stats()
    assert misses0 > 0
    # identical re-profile: every (class, tp) sweep is a cache hit
    build_pipeline(get_workflow("map_reduce"), n_trace_requests=6,
                   max_profile_groups=4, seed=0, chip_classes=classes)
    hits1, misses1 = profiler.profile_cache_stats()
    assert misses1 == misses0
    assert hits1 > hits0
    # a subset of the classes adds no new sweeps either
    build_pipeline(get_workflow("map_reduce"), n_trace_requests=6,
                   max_profile_groups=4, seed=0,
                   chip_classes=(hw.chip_class("v5p"),))
    assert profiler.profile_cache_stats()[1] == misses0


def test_blend_class_is_chip_weighted():
    v5e, v5p = hw.chip_class("v5e"), hw.chip_class("v5p")
    blend = hw.blend_classes([(v5e, 3), (v5p, 1)], name="blend-test")
    expect = (3 * v5e.hbm_bytes + 1 * v5p.hbm_bytes) / 4
    assert blend.hbm_bytes == pytest.approx(expect, rel=0.01)
    assert math.isfinite(blend.peak_flops_bf16)


def test_class_bound_allocation_survives_replace():
    a = Allocation(replicas=2, tp=2, chip_class="v5p")
    stripped = dataclasses.replace(a, chip_class=None)
    assert stripped.chip_class is None
    assert (stripped.replicas, stripped.tp) == (a.replicas, a.tp)
