"""Topology-aware placement: co-placement, feasibility probe, errors.

Covers the scheduler<->placement loop added with the placement-aware
split search (ROADMAP "Placement-aware partitioned splits"): the
hierarchical packing's invariants (exclusive chip ownership, TP groups
inside one hb domain, tail chips usable), the fragmentation metric
(property: 0 for any exactly-tiling placement), probe/deploy agreement
(``fleet_feasibility`` says ok iff ``place_fleet`` succeeds), the
structured :class:`PlacementError` diagnostics, and the placement-aware
``schedule_multi`` rejecting unplaceable splits a blind search picks.
"""
import math

import pytest

from repro import hw
from repro.core import placement as pl
from repro.core.pipeline import Allocation
from repro.core.placement import (FeasibilityResult, Placement,
                                  PlacedInstance, PlacementError,
                                  feasibility, fleet_feasibility, place,
                                  place_fleet, split_fleet)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SPEC8 = hw.ClusterSpec(num_hosts=2, chips_per_host=4, hb_domain_size=2)


# ---------------------------------------------------------------------------
# fragmentation: 0 for exactly-tiling placements
# ---------------------------------------------------------------------------


def _tiling_placement(spec: hw.ClusterSpec, per_chip_units) -> Placement:
    """A synthetic placement where every chip is either untouched or
    exactly tiled by sub-chip instances summing to F."""
    placement = Placement(spec)
    for chip, parts in per_chip_units.items():
        assert sum(parts) == spec.fractions_per_chip
        for k, u in enumerate(parts):
            placement.instances.append(PlacedInstance(
                llm=f"m{chip}", replica=k, tp=1, chips=[chip],
                units_per_chip=u, host=chip // spec.chips_per_host,
                domain=chip // spec.hb_domain_size))
    return placement


def test_fragmentation_zero_when_exactly_tiled():
    spec = SPEC8
    placement = _tiling_placement(spec, {0: [10], 3: [4, 6], 5: [2, 2, 6]})
    placement.validate()
    assert placement.fragmentation() == 0.0


def test_fragmentation_positive_on_partial_chip():
    placement = _tiling_placement(SPEC8, {0: [10]})
    placement.instances.append(PlacedInstance(
        llm="p", replica=0, tp=1, chips=[1], units_per_chip=3,
        host=0, domain=0))
    assert placement.fragmentation() > 0.0


if HAVE_HYPOTHESIS:

    @st.composite
    def tilings(draw):
        spec = hw.ClusterSpec(
            num_hosts=draw(st.integers(1, 3)),
            chips_per_host=draw(st.sampled_from([2, 4])),
            hb_domain_size=2,
            tail_chips=draw(st.integers(0, 2)))
        F = spec.fractions_per_chip
        per_chip = {}
        for chip in draw(st.sets(st.integers(0, spec.num_chips - 1))):
            parts, left = [], F
            while left > 0:
                u = draw(st.integers(1, left))
                parts.append(u)
                left -= u
            per_chip[chip] = parts
        return spec, per_chip

    @settings(max_examples=40, deadline=None)
    @given(tilings())
    def test_property_tiling_has_zero_fragmentation(tiling):
        spec, per_chip = tiling
        placement = _tiling_placement(spec, per_chip)
        placement.validate()
        assert placement.fragmentation() == 0.0

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 4), st.sampled_from([1, 2, 5, 10]))
    def test_property_packed_fractions_tile_exactly(hosts, units):
        # F/units replicas per chip, every chip filled: the greedy pack
        # must reach an exactly-tiling (fragmentation 0) placement
        spec = hw.ClusterSpec(num_hosts=hosts, chips_per_host=2,
                              hb_domain_size=2)
        F = spec.fractions_per_chip
        n = spec.num_chips * (F // units)
        placement = place(
            {"m": Allocation(replicas=n, tp=1, fraction=units / F)}, spec)
        assert placement.fragmentation() == 0.0


# ---------------------------------------------------------------------------
# co-placement: ownership, keys, tail chips
# ---------------------------------------------------------------------------


def test_place_fleet_keys_and_disjoint_ownership():
    fleet = {
        "alpha": {"agent": Allocation(replicas=1, tp=2, fraction=1.0),
                  "summ": Allocation(replicas=2, tp=1, fraction=0.4)},
        "beta": {"judge": Allocation(replicas=1, tp=2, fraction=1.0),
                 "debater": Allocation(replicas=3, tp=1, fraction=0.3)},
    }
    placement = place_fleet(fleet, SPEC8)
    placement.validate()
    assert all("/" in inst.llm for inst in placement.instances)
    chips = {"alpha": set(), "beta": set()}
    for inst in placement.instances:
        chips[inst.llm.split("/")[0]].update(inst.chips)
    assert not chips["alpha"] & chips["beta"]
    # per-workflow views keep global chip ids and local llm names
    views = split_fleet(placement)
    assert set(views) == {"alpha", "beta"}
    assert {i.llm for i in views["alpha"].instances} == {"agent", "summ"}
    assert {c for i in views["alpha"].instances
            for c in i.chips} == chips["alpha"]


def test_view_fragmentation_uses_touched_scope():
    # a split_fleet view keeps the full-cluster spec: cluster-scope
    # fragmentation would count other workflows' chips as free capacity,
    # scope="touched" restricts to the workflow's own footprint
    fleet = {
        "a": {"m": Allocation(replicas=1, tp=1, fraction=0.3)},
        "b": {"m": Allocation(replicas=6, tp=1, fraction=1.0)},
    }
    placement = place_fleet(fleet, SPEC8)
    view_a = split_fleet(placement)["a"]
    # a's single 3-unit replica strands 7 units on its one chip
    assert view_a.fragmentation(scope="touched") == 1.0
    # cluster scope dilutes it with the untouched free chip
    assert view_a.fragmentation() < 1.0
    with pytest.raises(ValueError):
        view_a.fragmentation(scope="bogus")


def test_tail_chips_survive_co_placement():
    # 2 full hosts of 4 + one tail chip = 9 chips; the fleet needs all 9
    spec = hw.ClusterSpec(num_hosts=2, chips_per_host=4,
                          hb_domain_size=2, tail_chips=1)
    fleet = {
        "a": {"m": Allocation(replicas=5, tp=1, fraction=1.0)},
        "b": {"m": Allocation(replicas=4, tp=1, fraction=1.0)},
    }
    placement = place_fleet(fleet, spec)
    placement.validate()
    used = {c for i in placement.instances for c in i.chips}
    assert used == set(range(9)), "tail chip must be placeable"


def test_tail_chip_never_hosts_tp_group():
    spec = hw.ClusterSpec(num_hosts=1, chips_per_host=4,
                          hb_domain_size=2, tail_chips=1)
    fleet = {
        "a": {"m": Allocation(replicas=2, tp=2, fraction=1.0)},
        "b": {"m": Allocation(replicas=1, tp=1, fraction=0.5)},
    }
    placement = place_fleet(fleet, spec)
    placement.validate()  # would raise if a TP group spanned into chip 4
    for inst in placement.instances:
        if inst.tp > 1:
            assert 4 not in inst.chips


# ---------------------------------------------------------------------------
# probe <-> deploy agreement
# ---------------------------------------------------------------------------


def test_probe_matches_place_fleet_on_success():
    fleet = {
        "a": {"m": Allocation(replicas=2, tp=2, fraction=1.0)},
        "b": {"m": Allocation(replicas=4, tp=1, fraction=0.5)},
    }
    probe = fleet_feasibility(fleet, SPEC8)
    assert isinstance(probe, FeasibilityResult)
    ok, frag = probe  # iterable as (ok, fragmentation_cost)
    assert ok and probe.ok
    placement = place_fleet(fleet, SPEC8)
    assert math.isclose(frag, placement.fragmentation())


def test_probe_matches_place_fleet_on_failure():
    # 18 units on a 2-chip cluster passes unit accounting but each chip
    # holds only one 6-unit replica: unplaceable, and the probe says so
    spec = hw.ClusterSpec(num_hosts=1, chips_per_host=2, hb_domain_size=2)
    fleet = {"a": {"m": Allocation(replicas=3, tp=1, fraction=0.6)}}
    probe = fleet_feasibility(fleet, spec)
    assert not probe.ok
    assert probe.failed_shape["units_per_chip"] == 6
    with pytest.raises(PlacementError):
        place_fleet(fleet, spec)
    # single-group probe agrees
    assert not feasibility(fleet["a"], spec).ok


def test_placement_error_is_structured():
    spec = hw.ClusterSpec(num_hosts=1, chips_per_host=4, hb_domain_size=2)
    with pytest.raises(PlacementError) as ei:
        place({"m": Allocation(replicas=5, tp=2, fraction=1.0)}, spec)
    err = ei.value
    assert err.shape["tp"] == 2 and err.shape["units_per_chip"] == 10
    assert set(err.domain_capacity) == {0, 1}
    for cap in err.domain_capacity.values():
        assert {"host", "free_chips", "free_units",
                "largest_chip_free_units"} <= set(cap)
    assert "hint" in str(err) and err.hint


# ---------------------------------------------------------------------------
# placement-aware split search
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tight_fleet_pipelines():
    from repro.core.scepsy import build_pipeline
    from repro.workflows.registry import get_workflow

    pipes = {}
    for name in ("react_agent", "debate"):
        pipes[name], _, _ = build_pipeline(
            get_workflow(name), n_trace_requests=6, tp_degrees=(1, 2),
            max_profile_groups=4, seed=0)
    return pipes


def test_placement_aware_search_rejects_unplaceable_splits(
        tight_fleet_pipelines):
    import dataclasses as dc

    from repro.core.scheduler import SchedulerConfig, schedule_multi

    spec = hw.ClusterSpec(num_hosts=1, chips_per_host=4,
                          hb_domain_size=2, tail_chips=1)
    lams = {"react_agent": 1.0, "debate": 1.2}
    cfg = SchedulerConfig(max_tp=2, welfare="weighted")

    blind = schedule_multi(tight_fleet_pipelines, spec, lams, cfg,
                           mode="partitioned")
    aware = schedule_multi(tight_fleet_pipelines, spec, lams,
                           dc.replace(cfg, placement_aware=True),
                           mode="partitioned")

    aware_probe = fleet_feasibility(
        {n: aware.per_workflow[n].allocations for n in lams}, spec)
    assert aware.placement_ok is True
    assert aware_probe.ok
    assert aware.fragmentation == pytest.approx(aware_probe.fragmentation)
    # blind search has no placement fields
    assert blind.placement_ok is None and blind.fragmentation is None
    blind_probe = fleet_feasibility(
        {n: blind.per_workflow[n].allocations for n in lams}, spec)
    if blind_probe.ok:
        # placement did not bind here: the aware search must then agree
        assert aware.welfare == pytest.approx(blind.welfare, rel=1e-6)
    else:
        assert aware.placement_rejected_splits >= 1


def test_deploy_multi_partitioned_coplacement(tight_fleet_pipelines):
    from repro.core.scepsy import deploy_multi
    from repro.core.scheduler import SchedulerConfig
    from repro.workflows.registry import get_workflow

    spec = hw.ClusterSpec(num_hosts=1, chips_per_host=4,
                          hb_domain_size=2, tail_chips=1)
    lams = {"react_agent": 1.0, "debate": 1.2}
    wfs = [get_workflow(n) for n in lams]
    dep = deploy_multi(
        wfs, spec, lams,
        scheduler_config=SchedulerConfig(max_tp=2, welfare="weighted",
                                         placement_aware=True),
        pipelines=dict(tight_fleet_pipelines), mode="partitioned")
    assert dep.fleet_placement is not None
    dep.fleet_placement.validate()
    assert dep.chip_offsets == {n: 0 for n in lams}
    # per-workflow views are global-coordinate and disjoint
    seen = {}
    for name, d in dep.deployments.items():
        d.placement.validate()
        for inst in d.placement.instances:
            for c in inst.chips:
                assert 0 <= c < spec.num_chips
                assert seen.setdefault(c, name) == name
    # the global placement is keyed workflow/llm for migration diffs
    assert all("/" in i.llm for i in dep.fleet_placement.instances)


def test_fleet_routers_from_placement():
    from repro.serving.deploy import fleet_routers_from_placement
    from repro.serving.simulator import EventLoop
    from repro.workflows.registry import get_workflow

    wfs = {n: get_workflow(n) for n in ("react_agent", "debate")}
    fleet = {
        "react_agent": {"agent": Allocation(replicas=1, tp=2, fraction=1.0),
                        "summ": Allocation(replicas=2, tp=1, fraction=0.4)},
        "debate": {"debater": Allocation(replicas=2, tp=1, fraction=1.0),
                   "judge": Allocation(replicas=1, tp=1, fraction=0.5)},
    }
    placement = place_fleet(fleet, SPEC8)
    routers = fleet_routers_from_placement(wfs, placement, EventLoop())
    assert set(routers) == set(fleet)
    for wf_name, by_llm in routers.items():
        for llm, router in by_llm.items():
            alloc = fleet[wf_name][llm]
            assert len(router.replicas) == alloc.replicas
            for eng in router.replicas:
                assert eng.tp == alloc.tp


def test_legacy_contiguous_model_kept():
    # fleet_offsets/merge_fleet stay importable as the blind baseline
    sub = hw.ClusterSpec(num_hosts=1, chips_per_host=2)
    placements = {
        "a": place({"m": Allocation(replicas=2, tp=1, fraction=1.0)}, sub),
        "b": place({"m": Allocation(replicas=2, tp=1, fraction=1.0)}, sub),
    }
    offsets = pl.fleet_offsets(placements, ["a", "b"], SPEC8)
    merged = pl.merge_fleet(placements, offsets, SPEC8)
    assert {i.llm for i in merged.instances} == {"a/m", "b/m"}
    merged.validate()
