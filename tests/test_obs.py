"""Observability layer: spans, metrics, accuracy reconciliation.

Deterministic coverage of the Tracer's invariants — span phases tile
[arrival, done] exactly, the reservoir never exceeds its bound, the
disabled path leaves the stack untouched and bit-identical — plus the
metrics exposition round-trip, the observed-vs-expected share
reconciliation on a seeded fleet, DriftMonitor corroboration, and the
StatsSink empty-sketch regression.  A hypothesis property generalizes
the phase-sum invariant over seeds when hypothesis is installed.
"""
import math

import pytest

from repro.core.drift import DriftMonitor, expectation_from
from repro.core.scheduler import Allocation
from repro.core.telemetry import StatsSink
from repro.obs import (MetricsRegistry, Tracer, chrome_trace,
                       install_tracer, parse_exposition)
from repro.obs.accuracy import (critical_path_report, expected_shares,
                                share_report)
from repro.serving.deploy import routers_from_allocations
from repro.serving.simulator import EventLoop
from repro.workflows.registry import get_workflow
from repro.workflows.runtime import ClusterDriver


def _fleet(name="react_agent", n=40, rate=2.0, seed=1, tracer=None,
           replicas=2, telemetry=None):
    """One-workflow static fleet on a fresh loop, driven to completion."""
    loop = EventLoop()
    wf = get_workflow(name)
    allocs = {m: Allocation(replicas=replicas, tp=1, fraction=1.0)
              for m in wf.llms}
    routers = routers_from_allocations(wf, allocs, loop)
    drv = ClusterDriver(wf, routers, loop, telemetry=telemetry)
    install_tracer(tracer, drivers=[drv])
    drv.schedule_open_loop(rate, n, seed=seed, arrival_seed=seed + 100)
    loop.run(math.inf)
    return drv


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_metrics_exposition_round_trip():
    reg = MetricsRegistry()
    reg.counter("requests_total", "req", ("wf",)).labels("a").inc(3)
    reg.counter("requests_total", "req", ("wf",)).labels("b").inc()
    reg.gauge("depth", "queue", ("engine",)).labels("e0").set(7.5)
    h = reg.histogram("lat", "latency", (), buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 2.0):
        h.labels().observe(v)
    parsed = parse_exposition(reg.expose())
    assert parsed["requests_total"][(("wf", "a"),)] == 3.0
    assert parsed["requests_total"][(("wf", "b"),)] == 1.0
    assert parsed["depth"][(("engine", "e0"),)] == 7.5
    # histogram buckets are cumulative; +Inf equals the count
    assert parsed["lat_bucket"][(("le", "0.1"),)] == 1.0
    assert parsed["lat_bucket"][(("le", "1.0"),)] == 2.0
    assert parsed["lat_bucket"][(("le", "+Inf"),)] == 3.0
    assert parsed["lat_count"][()] == 3.0
    assert parsed["lat_sum"][()] == pytest.approx(2.55)


def test_metrics_snapshot_and_schema_conflict():
    reg = MetricsRegistry()
    reg.counter("c", "help", ("x",)).labels("1").inc(2)
    snap = reg.snapshot()
    assert snap["c"]["series"][0] == {"labels": {"x": "1"}, "value": 2.0}
    with pytest.raises(ValueError):
        reg.gauge("c", "other", ("x",))
    with pytest.raises(ValueError):
        reg.counter("c", "help", ("x", "y"))


# ---------------------------------------------------------------------------
# span invariants on a deterministic fleet
# ---------------------------------------------------------------------------


def test_phases_tile_request_exactly():
    tracer = Tracer(sample_per_workflow=64, seed=3)
    drv = _fleet(n=40, tracer=tracer)
    assert drv.n_completed == 40
    traces = tracer.traces(finished_only=True)
    assert traces
    for tr in traces:
        phases = tr["phases"]
        assert phases, "every request runs at least one group"
        # ordered, gap-free tiling of [arrival, done]
        assert phases[0]["t0"] == pytest.approx(tr["arrival"])
        for a, b in zip(phases, phases[1:]):
            assert a["t1"] == pytest.approx(b["t0"])
            assert a["t1"] >= a["t0"]
        assert phases[-1]["t1"] == pytest.approx(tr["done"])
        total = sum(p["t1"] - p["t0"] for p in phases)
        assert total == pytest.approx(tr["done"] - tr["arrival"])


def test_call_spans_nest_inside_group_phases():
    tracer = Tracer(sample_per_workflow=64, seed=3)
    _fleet(n=30, tracer=tracer)
    for tr in tracer.traces(finished_only=True):
        groups = [p for p in tr["phases"] if p["kind"] == "group"]
        for call in tr["calls"]:
            assert call["done"] >= call["start"] >= call["submit"] >= 0
            owner = [g for g in groups
                     if g["t0"] <= call["submit"] and call["done"] <= g["t1"]]
            assert owner, "call span outside any group phase"
        for g in groups:
            assert g["critical_llm"], "closed group phases are attributed"


def test_reservoir_bound_and_counts():
    k = 8
    tracer = Tracer(sample_per_workflow=k, seed=5)
    _fleet(n=50, tracer=tracer)
    counts = tracer.sampled_counts()
    assert counts["react_agent"]["seen"] == 50
    assert counts["react_agent"]["sampled"] == k
    assert len(tracer.traces(finished_only=False)) == k
    # aggregates still cover every request, not just the reservoir
    assert tracer.request_latency("react_agent")["count"] == 50


def test_disabled_tracer_installs_nothing():
    tracer = Tracer(enabled=False, seed=0)
    drv = _fleet(n=10, tracer=tracer)
    assert drv.tracer is None
    assert all(e.tracer is None
               for r in drv.routers.values() for e in r.replicas)
    assert not tracer.traces(finished_only=False)
    assert install_tracer(None) is None


def test_enabled_tracing_is_bit_identical():
    """The tracer draws from its own RNG: same-seed runs with no
    tracer, a disabled tracer and an enabled tracer complete every
    request at exactly the same times."""
    runs = []
    for tr in (None, Tracer(enabled=False, seed=9), Tracer(seed=9)):
        drv = _fleet(n=30, tracer=tr)
        runs.append([(r.request_id, r.arrival, r.done) for r in drv.records])
    assert runs[0] == runs[1] == runs[2]


# ---------------------------------------------------------------------------
# accuracy reconciliation
# ---------------------------------------------------------------------------


def test_share_reconciliation_against_monitor():
    wf = get_workflow("react_agent")
    from repro.core.scepsy import build_pipeline
    pipe, _, _ = build_pipeline(wf, n_trace_requests=6, tp_degrees=(1,),
                                max_profile_groups=4, seed=0)
    monitor = DriftMonitor({"react_agent": expectation_from(pipe, 1.0)})
    tracer = Tracer(sample_per_workflow=32, seed=2)
    _fleet(n=60, rate=1.0, tracer=tracer, telemetry=monitor)

    observed = tracer.observed_shares()["react_agent"]
    assert set(observed) == set(wf.llms)
    assert sum(observed.values()) == pytest.approx(1.0)

    expected = expected_shares(pipe)
    rep = share_report({"react_agent": observed},
                       {"react_agent": expected})
    assert rep["max_rel_err"] < 0.5  # same fleet, same traffic

    corr = monitor.corroborate(tracer.observed_shares())
    assert all(cell["agree"] for cell in corr["react_agent"].values())


def test_critical_path_sums_to_latency():
    tracer = Tracer(sample_per_workflow=32, seed=2)
    _fleet(n=30, tracer=tracer)
    rep = critical_path_report(tracer)
    row = rep["react_agent"]
    assert row["residual_rel"] < 1e-9
    assert row["dominant"] in set(get_workflow("react_agent").llms) | {"tool"}
    total_frac = sum(c["fraction"] for c in row["breakdown"].values())
    assert total_frac == pytest.approx(1.0)


def test_expected_shares_duck_dispatch():
    wf = get_workflow("react_agent")
    from repro.core.scepsy import build_pipeline
    pipe, stats, _ = build_pipeline(wf, n_trace_requests=4, tp_degrees=(1,),
                                    max_profile_groups=3, seed=0)
    from_pipe = expected_shares(pipe)
    from_stats = expected_shares(stats)
    assert set(from_pipe) == set(from_stats) == set(wf.llms)
    assert sum(from_pipe.values()) == pytest.approx(1.0)
    assert sum(from_stats.values()) == pytest.approx(1.0)
    with pytest.raises(TypeError):
        expected_shares(object())


def test_corroborate_flags_divergence():
    wf = get_workflow("react_agent")
    from repro.core.scepsy import build_pipeline
    pipe, _, _ = build_pipeline(wf, n_trace_requests=4, tp_degrees=(1,),
                                max_profile_groups=3, seed=0)
    monitor = DriftMonitor({"react_agent": expectation_from(pipe, 1.0)})
    _fleet(n=40, rate=1.0, telemetry=monitor)
    own = monitor.observed_shares("react_agent")
    agree = monitor.corroborate({"react_agent": own})
    assert all(cell["agree"] for cell in agree["react_agent"].values())
    flipped = {m: 1.0 - s for m, s in own.items()}
    disagree = monitor.corroborate({"react_agent": flipped})
    assert not all(cell["agree"] for cell in disagree["react_agent"].values())


# ---------------------------------------------------------------------------
# exports
# ---------------------------------------------------------------------------


def test_chrome_trace_export_shape():
    tracer = Tracer(sample_per_workflow=16, seed=4)
    _fleet(n=20, tracer=tracer)
    doc = tracer.to_chrome_trace()
    events = doc["traceEvents"]
    assert events
    cats = {e.get("cat") for e in events if e.get("ph") == "X"}
    assert {"request", "phase", "call"} <= cats
    names = [e for e in events if e.get("ph") == "M"]
    assert names and names[0]["args"]["name"] == "react_agent"
    # offline converter produces the same document from exported dicts
    assert chrome_trace(tracer.traces(finished_only=False)) == doc


def test_export_is_json_safe_and_collected():
    import json
    tracer = Tracer(sample_per_workflow=8, seed=4)
    _fleet(n=20, tracer=tracer)
    doc = tracer.export()
    json.dumps(doc)  # must not raise
    parsed = parse_exposition(doc["exposition"])
    total = sum(parsed["scepsy_requests_total"].values())
    assert total == 20
    assert doc["shares"]["react_agent"]
    assert doc["sampling"]["counts"]["react_agent"]["seen"] == 20


# ---------------------------------------------------------------------------
# StatsSink regression (satellite): empty sketch must not crash
# ---------------------------------------------------------------------------


def test_stats_sink_summary_no_completions():
    sink = StatsSink()
    sink.observe_arrival("wf", 0.0)  # arrivals but zero completions
    summ = sink.summary()["wf"]
    assert summ["completed"] == 0
    assert math.isnan(summ["latency_p50"])
    assert math.isnan(summ["latency_p99"])


def test_stats_sink_summary_with_completions():
    from repro.workflows.runtime import RequestRecord
    sink = StatsSink()
    for i in range(5):
        sink.observe_arrival("wf", float(i))
        rec = RequestRecord(i, float(i))
        rec.done = float(i) + 2.0
        sink.observe("wf", rec)
    summ = sink.summary()["wf"]
    assert summ["completed"] == 5
    assert summ["latency_p50"] == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# hypothesis property: phase tiling holds for arbitrary seeds/workloads
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_phase_sum_property():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=10, deadline=None)
    @hyp.given(seed=st.integers(min_value=0, max_value=2**16),
               name=st.sampled_from(["react_agent", "map_reduce", "debate"]))
    def check(seed, name):
        tracer = Tracer(sample_per_workflow=16, seed=seed)
        _fleet(name=name, n=12, rate=1.5, seed=seed, tracer=tracer)
        for tr in tracer.traces(finished_only=True):
            total = sum(p["t1"] - p["t0"] for p in tr["phases"])
            assert total == pytest.approx(tr["done"] - tr["arrival"])

    check()
