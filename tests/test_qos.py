"""Request-level QoS: queue disciplines, admission control, SLO telemetry.

EngineSim scheduling coverage the ISSUE asks for: fifo/priority parity
when every request is in the same class, conservation of served tokens
across disciplines, and a hypothesis property test that wfq is
starvation-free under overload.  Plus the SLOViolation drift trigger,
the replan cool-down hysteresis, and partitioned migration diffs.
"""
import math
from types import SimpleNamespace

import pytest

from repro.configs.base import ArchConfig
from repro.core.drift import (DriftConfig, DriftMonitor, Expectation,
                              RateDrift, SLOViolation, expectation_from)
from repro.core.replan import (RUNG_REBALANCE, RUNG_WARM_REPLAN,
                               ReplanController, partitioned_fleet_placement,
                               recommend_rung)
from repro.core.scheduler import schedule_multi
from repro.qos.admission import AdmissionController
from repro.qos.policy import make_policy, request_cost
from repro.qos.slo import BRONZE, GOLD, RequestQoS, SLOClass, WorkModel
from repro.serving.simulator import EngineRequest, EngineSim, EventLoop
from repro.workflows.registry import get_workflow
from repro.workflows.runtime import Workflow, with_slo

from tests.test_drift import LAMS, SCFG, SPEC  # noqa: F401
from tests.test_drift import SHARED, sharing_fleet  # noqa: F401

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


ENGINE_CFG = ArchConfig(name="qos-small", family="dense", num_layers=8,
                        d_model=1024, num_heads=8, num_kv_heads=8,
                        d_ff=4096, vocab_size=32_000)


def _run_engine(discipline, reqs, *, weights=None, max_batch=2,
                until=math.inf):
    loop = EventLoop()
    eng = EngineSim(ENGINE_CFG, loop, name="e",
                    max_batch_override=max_batch,
                    policy=make_policy(discipline, weights=weights))
    for r in reqs:
        eng.submit(r)
    loop.run(until)
    return eng


def _req(i, *, prompt=300, out=48, qos=None):
    return EngineRequest(req_id=i, prompt_tokens=prompt, output_tokens=out,
                         arrival=0.001 * i, qos=qos)


# ---------------------------------------------------------------------------
# queue disciplines
# ---------------------------------------------------------------------------


def test_make_policy_fifo_is_none_and_unknown_raises():
    assert make_policy("fifo") is None
    assert make_policy("priority") is not None
    assert make_policy("wfq", weights={"a": 1.0}) is not None
    with pytest.raises(ValueError):
        make_policy("nope")


@pytest.mark.parametrize("qos_of", [
    lambda i: None,  # unclassified traffic
    lambda i: RequestQoS(tenant="wf", slo="gold", weight=2.0,
                         deadline=500.0, remaining_s=1.0),  # one equal class
])
def test_priority_parity_with_fifo_when_classes_equal(qos_of):
    """With every request in the same class (identical deadline and
    remaining work) — or with no QoS metadata at all — the priority
    discipline degenerates to arrival order: completion order and times
    match FIFO exactly."""
    fifo = _run_engine("fifo", [_req(i, qos=qos_of(i)) for i in range(16)])
    prio = _run_engine("priority", [_req(i, qos=qos_of(i)) for i in range(16)])
    assert [r.req_id for r in fifo.done] == [r.req_id for r in prio.done]
    assert [r.t_done for r in fifo.done] == [r.t_done for r in prio.done]


def test_priority_urgent_request_jumps_burst():
    """A nearly-finished workflow request (tight deadline, tiny remaining
    work) submitted behind a fresh best-effort burst is served first."""
    burst = [_req(i, qos=RequestQoS(tenant="batch", slo="best_effort",
                                    weight=1.0, deadline=math.inf))
             for i in range(12)]
    urgent = _req(99, qos=RequestQoS(tenant="chat", slo="gold", weight=4.0,
                                     deadline=5.0, remaining_s=0.1))
    eng = _run_engine("priority", burst + [urgent])
    order = [r.req_id for r in eng.done]
    assert order.index(99) == 0
    fifo = _run_engine("fifo", [_req(i) for i in range(12)] + [_req(99)])
    assert [r.req_id for r in fifo.done].index(99) == 12


def test_served_token_conservation_across_disciplines():
    """Scheduling reorders service; it must not create or destroy work."""
    def mk():
        reqs = []
        for i in range(24):
            tenant = ("a", "b", "c")[i % 3]
            q = RequestQoS(tenant=tenant, slo="gold", weight=1.0 + (i % 3),
                           deadline=10.0 + i, remaining_s=0.5 * (i % 5))
            reqs.append(_req(i, prompt=200 + 40 * (i % 4), out=32 + (i % 7),
                             qos=q))
        return reqs

    totals = {}
    for disc in ("fifo", "priority", "wfq"):
        eng = _run_engine(disc, mk(), weights={"a": 1.0, "b": 2.0, "c": 3.0})
        assert len(eng.done) == 24
        totals[disc] = sum(request_cost(r) for r in eng.done)
    assert totals["fifo"] == totals["priority"] == totals["wfq"]


def test_wfq_served_tokens_track_weights_under_overload():
    """With both tenants continuously backlogged, DRR serves tokens in
    proportion to the configured weights (within 10%)."""
    reqs = []
    for i in range(120):
        t = "a" if i % 2 == 0 else "b"
        reqs.append(_req(i, prompt=256, out=32, qos=RequestQoS(tenant=t)))
    loop = EventLoop()
    eng = EngineSim(ENGINE_CFG, loop, name="e", max_batch_override=2,
                    policy=make_policy("wfq", weights={"a": 3.0, "b": 1.0}))
    for r in reqs:
        eng.submit(r)
    # stop while both tenants still have backlog
    loop.run(until=0.0)
    while eng.waiting and min(
            sum(1 for r in eng.waiting if r.qos.tenant == t)
            for t in ("a", "b")) > 4:
        nxt = loop.peek_time()
        loop.run(until=nxt if nxt is not None else math.inf)
    served = {"a": 0.0, "b": 0.0}
    for r in eng.done:
        served[r.qos.tenant] += request_cost(r)
    assert served["a"] > 0 and served["b"] > 0
    share_a = served["a"] / (served["a"] + served["b"])
    assert abs(share_a - 0.75) <= 0.10


if HAVE_HYPOTHESIS:

    @settings(deadline=None, max_examples=25)
    @given(
        w_a=st.floats(0.1, 5.0), w_b=st.floats(0.1, 5.0),
        w_c=st.floats(0.1, 5.0),
        sizes=st.lists(st.integers(64, 512), min_size=9, max_size=30),
    )
    def test_wfq_starvation_free_under_overload_property(w_a, w_b, w_c,
                                                         sizes):
        """Every positive-weight tenant with backlog is eventually
        served: its deficit grows by quantum x weight per round, so no
        weight assignment or request-size mix can starve it."""
        tenants = ("a", "b", "c")
        reqs = [_req(i, prompt=sz, out=16,
                     qos=RequestQoS(tenant=tenants[i % 3]))
                for i, sz in enumerate(sizes)]
        eng = _run_engine("wfq", reqs,
                          weights={"a": w_a, "b": w_b, "c": w_c},
                          max_batch=1)
        assert len(eng.done) == len(reqs)  # nothing stranded in the queue
        done_of = {t: [r for r in eng.done if r.qos.tenant == t]
                   for t in tenants}
        for t in tenants:
            expect = [r for r in reqs if r.qos.tenant == t]
            assert len(done_of[t]) == len(expect)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def _work(total=2.0, serial=1.0, spt=0.001):
    return WorkModel(per_call_s={"m": 0.5}, total_s=total, serial_s=serial,
                     sec_per_token={"m": spt})


def _router(load):
    return SimpleNamespace(replicas=[SimpleNamespace(load=load,
                                                     failed=False)])


def test_admission_reject_and_degrade_on_backlog():
    ctrl = AdmissionController()
    rej = SLOClass("bronze", latency_target_s=2.0, shed_policy="reject")
    deg = SLOClass("silver", latency_target_s=2.0, shed_policy="degrade")
    nev = SLOClass("gold", latency_target_s=2.0, shed_policy="never")
    ctrl.register("wf_r", rej, _work(), routers={"m": _router(50_000)})
    ctrl.register("wf_d", deg, _work(), routers={"m": _router(50_000)})
    ctrl.register("wf_n", nev, _work(), routers={"m": _router(50_000)})
    # 50k queued tokens at 1ms/token = 50s wait >> 2s target
    assert ctrl.admit("wf_r", 0.0) == "reject"
    assert ctrl.admit("wf_d", 0.0) == "degrade"
    assert ctrl.admit("wf_n", 0.0) == "admit"
    assert ctrl.admit("unknown", 0.0) == "admit"
    s = ctrl.stats()
    assert s["wf_r"]["rejected"] == 1 and s["wf_d"]["degraded"] == 1
    assert s["wf_n"]["admitted"] == 1


def test_admission_sees_only_routable_replicas():
    """Partition routing: an idle replica in another tenant's block
    (weight 0 for this workflow) must not mask the backlog on the
    replica this workflow actually routes to."""
    ctrl = AdmissionController()
    slo = SLOClass("bronze", latency_target_s=2.0, shed_policy="reject")
    router = SimpleNamespace(
        replicas=[SimpleNamespace(load=50_000, failed=False),
                  SimpleNamespace(load=0, failed=False)],
        weights={0: 1.0, 1: 0.0})
    ctrl.register("wf", slo, _work(), routers={"m": router})
    assert ctrl.admit("wf", 0.0) == "reject"
    # unweighted router: the idle replica IS routable -> admit
    router2 = SimpleNamespace(
        replicas=[SimpleNamespace(load=50_000, failed=False),
                  SimpleNamespace(load=0, failed=False)],
        weights=None)
    ctrl.register("wf2", slo, _work(), routers={"m": router2})
    assert ctrl.admit("wf2", 0.0) == "admit"


def test_admission_admits_when_idle_and_uses_predictor():
    ctrl = AdmissionController()
    slo = SLOClass("bronze", latency_target_s=2.0, shed_policy="reject")
    ctrl.register("wf", slo, _work(), routers={"m": _router(0)},
                  predictor=lambda lam: 100.0)  # model says: hopeless
    # predictor only kicks in once the rate EWMA has samples
    assert ctrl.admit("wf", 0.0) == "admit"
    for k in range(12):
        ctrl.admit("wf", 0.1 * (k + 1))
    assert ctrl.admit("wf", 2.0) == "reject"


def test_cluster_driver_rejects_and_tags_records():
    from repro.qos.slo import WorkflowQoS

    wf = Workflow("wf", lambda rng: iter(()), {})
    slo = SLOClass("bronze", latency_target_s=0.5, shed_policy="reject")
    ctrl = AdmissionController()
    ctrl.register("wf", slo, _work(spt=0.01),
                  routers={"m": _router(10_000)})
    qos = WorkflowQoS(slo=slo, work=_work(spt=0.01), admission=ctrl)
    loop = EventLoop()
    from repro.workflows.runtime import ClusterDriver

    drv = ClusterDriver(wf, {}, loop, qos=qos)
    drv.start_request(0)
    assert drv.records[0].rejected and drv.records[0].done < 0
    assert not drv.records[0].slo_met
    assert drv.records[0].slo_class == "bronze"


# ---------------------------------------------------------------------------
# SLO classes + work model
# ---------------------------------------------------------------------------


def test_slo_resolve_and_validation():
    g = GOLD.resolve(10.0)
    assert g.latency_target_s == pytest.approx(20.0)
    assert g.target_factor is None and g.deadline_s == pytest.approx(20.0)
    assert GOLD.resolve(10.0).resolve(99.0).latency_target_s == \
        pytest.approx(20.0)  # absolute targets never re-resolve
    be = SLOClass("free")
    assert be.best_effort and be.deadline_s == math.inf
    with pytest.raises(ValueError):
        SLOClass("bad", shed_policy="drop-everything")
    with pytest.raises(ValueError):
        SLOClass("bad", weight=0.0)


def test_work_model_from_pipeline(sharing_fleet):  # noqa: F811
    pipe = sharing_fleet["wf_a"]
    wm = WorkModel.from_pipeline(pipe)
    assert set(wm.per_call_s) == set(pipe.stages)
    st_ = pipe.stages["gen"]
    assert wm.total_s == pytest.approx(st_.n * wm.per_call_s["gen"])
    assert wm.serial_s == pytest.approx(
        st_.n / st_.p * wm.per_call_s["gen"])
    assert wm.remaining_after(wm.total_s + 1.0) == 0.0
    assert wm.remaining_after(0.0) == pytest.approx(wm.total_s)


def test_registry_workflows_carry_slos():
    assert get_workflow("react_agent").slo.name == "gold"
    assert get_workflow("debate").slo.shed_policy == "reject"
    swapped = with_slo(get_workflow("debate"), BRONZE)
    assert swapped.slo is BRONZE and swapped.name == "debate"


# ---------------------------------------------------------------------------
# SLOViolation drift trigger
# ---------------------------------------------------------------------------


def _slo_monitor(target=1.0, threshold=0.3):
    exp = Expectation(lam=1.0, shares={}, slo_target=target,
                      slo_class="gold")
    cfg = DriftConfig(min_samples=10, slo_violation_threshold=threshold)
    return DriftMonitor({"wf": exp}, cfg)


def _done(mon, i, latency, violate_target=1.0):
    mon.record_request_done(
        "wf", SimpleNamespace(request_id=i, done=float(i) + latency,
                              latency=latency, degraded=False))


def test_slo_violation_fires_on_sustained_misses():
    mon = _slo_monitor()
    for i in range(40):
        _done(mon, i, 0.5)  # within target: silent
    assert mon.poll() == []
    for i in range(40, 120):
        _done(mon, i, 3.0)  # sustained misses
    events = [e for e in mon.poll() if isinstance(e, SLOViolation)]
    assert events and events[0].slo_class == "gold"
    assert events[0].violation_rate > 0.3
    assert mon.slo_counters["wf"]["violations"] > 0
    assert mon.observed_violation_rate("wf") > 0.3
    # rung mapping: the rising-edge event (rate just past the 0.3
    # threshold) is a mild overload -> rebalance; a heavy violation
    # rate needs capacity -> warm re-plan
    assert recommend_rung(events) == RUNG_REBALANCE
    heavy = SLOViolation(workflow="wf", at=1.0, magnitude=0.8,
                         slo_class="gold", violation_rate=0.8, target_s=1.0)
    assert recommend_rung([heavy]) == RUNG_WARM_REPLAN


def test_slo_sheds_count_as_violations():
    mon = _slo_monitor()
    for i in range(60):
        mon.record_shed("wf", "gold", "reject", float(i))
    events = [e for e in mon.poll() if isinstance(e, SLOViolation)]
    assert events
    assert mon.slo_counters["wf"]["rejected"] == 60


def test_slo_detector_disarmed_without_target():
    exp = Expectation(lam=1.0, shares={})
    mon = DriftMonitor({"wf": exp}, DriftConfig(min_samples=5))
    for i in range(50):
        _done(mon, i, 100.0)
    assert [e for e in mon.poll() if isinstance(e, SLOViolation)] == []


def test_expectation_from_arms_slo(sharing_fleet):  # noqa: F811
    slo = SLOClass("gold", latency_target_s=4.2)
    exp = expectation_from(sharing_fleet["wf_a"], 1.0, slo=slo)
    assert exp.slo_target == pytest.approx(4.2)
    assert exp.slo_class == "gold"
    assert expectation_from(sharing_fleet["wf_a"], 1.0).slo_target == 0.0


# ---------------------------------------------------------------------------
# replan: cool-down hysteresis + partitioned migration diffs
# ---------------------------------------------------------------------------


def _rate_event(wf, magnitude, observed, expected, at=1.0):
    return RateDrift(workflow=wf, at=at, magnitude=magnitude,
                     observed=observed, expected=expected)


def test_replan_cooldown_suppresses_flapping(sharing_fleet):  # noqa: F811
    res = schedule_multi(sharing_fleet, SPEC, LAMS, SCFG, mode="pooled")
    ctrl = ReplanController(sharing_fleet, SPEC, LAMS, SCFG, result=res,
                            cooldown_s=100.0)
    act = ctrl.react([_rate_event("wf_a", 0.3, 0.52, 0.4, at=10.0)])
    assert act is not None and act.rung == RUNG_REBALANCE
    # flapping: same-rung drift inside the window is ignored
    assert ctrl.react([_rate_event("wf_a", 0.3, 0.41, 0.52, at=20.0)]) is None
    # genuine escalation is never delayed
    act2 = ctrl.react([_rate_event("wf_a", 1.5, 1.0, 0.4, at=30.0)])
    assert act2 is not None and act2.rung == RUNG_WARM_REPLAN
    # once the window expires, the same rung reacts again
    act3 = ctrl.react([_rate_event("wf_a", 0.3, 0.5, 1.0, at=200.0)])
    assert act3 is not None


def test_replan_cooldown_defers_persistent_drift(sharing_fleet):  # noqa: F811
    """The monitor is edge-triggered, so a suppressed event must be
    deferred and acted on once the window expires — not dropped forever
    while the condition persists."""
    from repro.core.drift import DriftMonitor

    res = schedule_multi(sharing_fleet, SPEC, LAMS, SCFG, mode="pooled")
    mon = DriftMonitor({w: Expectation(lam=LAMS[w], shares={})
                        for w in LAMS}, DriftConfig())
    ctrl = ReplanController(sharing_fleet, SPEC, LAMS, SCFG, result=res,
                            monitor=mon, cooldown_s=100.0)
    mon.now = 10.0
    assert ctrl.react([_rate_event("wf_a", 0.3, 0.52, 0.4, at=10.0)])
    # suppressed inside the window: deferred, not dropped
    mon.now = 20.0
    assert ctrl.react([_rate_event("wf_a", 0.3, 0.55, 0.4, at=20.0)]) is None
    assert ctrl._deferred
    # still inside the window: step() keeps deferring (no new events)
    mon.now = 50.0
    assert ctrl.step() is None
    # window expired: step() reacts to the deferred drift with no fresh
    # event needed (the latched detector will never re-fire on its own)
    mon.now = 200.0
    act = ctrl.step()
    assert act is not None and act.rung == RUNG_REBALANCE
    assert not ctrl._deferred


def test_replan_no_cooldown_by_default(sharing_fleet):  # noqa: F811
    res = schedule_multi(sharing_fleet, SPEC, LAMS, SCFG, mode="pooled")
    ctrl = ReplanController(sharing_fleet, SPEC, LAMS, SCFG, result=res)
    assert ctrl.react([_rate_event("wf_a", 0.3, 0.52, 0.4, at=1.0)])
    assert ctrl.react([_rate_event("wf_a", 0.3, 0.41, 0.52, at=1.5)])


def test_partitioned_replan_emits_migration_diff(sharing_fleet):  # noqa: F811
    res = schedule_multi(sharing_fleet, SPEC, LAMS, SCFG, mode="partitioned")
    incumbent = partitioned_fleet_placement(res, SPEC)
    assert incumbent is not None
    names = {i.llm.split("/")[0] for i in incumbent.instances}
    assert names == set(sharing_fleet)  # instances keyed workflow/llm
    ctrl = ReplanController(sharing_fleet, SPEC, LAMS, SCFG, result=res,
                            placement=incumbent)
    act = ctrl.replan({"wf_a": 0.9, "wf_b": 0.6}, cold=False)
    assert act.feasible and act.result.alloc_mode == "partitioned"
    assert act.placement is not None
    assert act.migration is not None
    s = act.migration.summary()
    assert (s["replicas_added"] + s["replicas_moved"]
            + s["replicas_unchanged"]) == len(act.placement.instances)
    # identical targets -> identical placement -> all-unchanged diff
    same = ctrl.replan({"wf_a": 0.9, "wf_b": 0.6}, cold=False)
    assert same.migration.summary()["replicas_moved"] == 0
    assert same.migration.summary()["replicas_added"] == 0


def test_deploy_multi_partitioned_controller_has_incumbent(sharing_fleet):  # noqa: F811
    wfa = Workflow("wf_a", lambda rng: iter(()), {"gen": SHARED})
    wfb = Workflow("wf_b", lambda rng: iter(()), {"draft": SHARED})
    from repro.core.scepsy import deploy_multi

    dep = deploy_multi([wfa, wfb], SPEC, LAMS, pipelines=sharing_fleet,
                       scheduler_config=SCFG, mode="partitioned",
                       online=True)
    assert dep.controller.placement is not None
    act = dep.controller.replan({"wf_a": 0.9, "wf_b": 0.6}, cold=False)
    assert act.migration is not None


def test_deploy_multi_threads_slos(sharing_fleet):  # noqa: F811
    wfa = with_slo(Workflow("wf_a", lambda rng: iter(()), {"gen": SHARED}),
                   GOLD)
    wfb = Workflow("wf_b", lambda rng: iter(()), {"draft": SHARED})
    from repro.core.scepsy import deploy_multi

    dep = deploy_multi([wfa, wfb], SPEC, LAMS, pipelines=sharing_fleet,
                       scheduler_config=SCFG, mode="pooled", online=True)
    assert "wf_a" in dep.qos and "wf_b" not in dep.qos
    q = dep.qos["wf_a"]
    assert q.slo.latency_target_s is not None  # resolved
    assert q.work.total_s > 0
    exp = dep.controller.monitor.expectations["wf_a"]
    assert exp.slo_target == pytest.approx(q.slo.latency_target_s)
    assert dep.controller.monitor.expectations["wf_b"].slo_target == 0.0
    # slos= override wins over Workflow.slo
    dep2 = deploy_multi([wfa, wfb], SPEC, LAMS, pipelines=sharing_fleet,
                        scheduler_config=SCFG, mode="pooled",
                        slos={"wf_b": SLOClass("gold", latency_target_s=9.0)})
    assert dep2.qos["wf_b"].slo.latency_target_s == pytest.approx(9.0)
