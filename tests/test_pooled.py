"""Pooled multi-tenant allocation: pipeline merging (rate-weighted share
fusion keyed by canonical model identity), pooled scheduling vs the
partitioned split, per-workflow attribution, routing weights, welfare
objectives, and the warm-started split search.

Synthetic analytic profiles cover the algebra; the 3-workflow registry
fleet (react_agent / map_reduce / debate — all serving the same 1B/8B
configs) covers the end-to-end pooled path.
"""
import math

import pytest

from repro import hw
from repro.configs.base import ArchConfig
from repro.core.pipeline import (AggregateLLMPipeline, MergedLLMProfile,
                                 PipelineStage, canonical_llm_id,
                                 merge_pipelines)
from repro.core.profiler import LLMProfile, TPProfile
from repro.core.scepsy import build_pipeline, deploy_multi
from repro.core.scheduler import (SchedulerConfig, schedule_multi)
from repro.workflows.registry import get_workflow


def _cfg(name: str) -> ArchConfig:
    return ArchConfig(name=name, family="dense", num_layers=16,
                      d_model=2048, num_heads=16, num_kv_heads=8,
                      d_ff=8192, vocab_size=32_000)


def _stage(llm: str, cfg: ArchConfig, size_gb: float, n: float,
           p: float = 2.0) -> PipelineStage:
    base_lat = 0.05 * size_gb
    t_max = 40.0 / size_gb
    by_tp = {}
    for tp in (1, 2):
        tmax = t_max * (tp ** 0.85)
        rates = [f * tmax for f in (0.1, 0.3, 0.5, 0.7, 0.9)]
        lat = [base_lat / tp / max(1 - r / tmax, 0.05) for r in rates]
        by_tp[tp] = TPProfile(tp=tp, rates=rates,
                              latency={"mean": lat, "p50": lat,
                                       "p90": [2 * x for x in lat],
                                       "p99": [4 * x for x in lat]},
                              max_throughput=tmax)
    prof = LLMProfile(llm=llm, arch=cfg.name, calls_per_group=n, by_tp=by_tp)
    return PipelineStage(llm=llm, cfg=cfg, n=n, p=p, profile=prof,
                         mean_share=1.0)


SHARED_SMALL = _cfg("shared-small")
SHARED_BIG = _cfg("shared-big")


@pytest.fixture()
def sharing_fleet():
    """Two workflows sharing both configs under different local names."""
    wf_a = AggregateLLMPipeline("wf_a", [
        _stage("gen", SHARED_SMALL, 1.0, n=4.0, p=2.0),
        _stage("ver", SHARED_BIG, 4.0, n=2.0, p=1.0),
    ])
    wf_b = AggregateLLMPipeline("wf_b", [
        _stage("draft", SHARED_SMALL, 1.0, n=1.5, p=1.0),
        _stage("judge", SHARED_BIG, 4.0, n=1.0, p=1.0),
    ])
    return {"wf_a": wf_a, "wf_b": wf_b}


@pytest.fixture()
def disjoint_fleet():
    return {
        "wf_a": AggregateLLMPipeline("wf_a", [
            _stage("gen", _cfg("only-a-small"), 1.0, n=3.0),
            _stage("ver", _cfg("only-a-big"), 4.0, n=1.0),
        ]),
        "wf_b": AggregateLLMPipeline("wf_b", [
            _stage("gen", _cfg("only-b-small"), 2.0, n=2.0),
            _stage("ver", _cfg("only-b-big"), 3.0, n=1.0),
        ]),
    }


LAMS = {"wf_a": 0.5, "wf_b": 0.3}


# ---------------------------------------------------------------------------
# pipeline merging
# ---------------------------------------------------------------------------


def test_merge_keys_by_canonical_identity(sharing_fleet):
    merged = merge_pipelines(sharing_fleet, LAMS)
    assert set(merged.stages) == {"shared-small", "shared-big"}
    assert set(merged.shared_llms()) == {"shared-small", "shared-big"}
    assert canonical_llm_id(SHARED_SMALL) == "shared-small"
    # both members present, tagged with their workflow-local names
    mem = merged.tenants["shared-small"]
    assert [(t.workflow, t.llm) for t in mem] == [("wf_a", "gen"),
                                                 ("wf_b", "draft")]


def test_merge_rate_weights_shares(sharing_fleet):
    merged = merge_pipelines(sharing_fleet, LAMS)
    lam_total = sum(LAMS.values())
    st = merged.stages["shared-small"]
    # driven at the fleet rate, the stage sees the sum of member call
    # rates: lam_total * n == 0.5*4.0 + 0.3*1.5
    assert lam_total * st.n == pytest.approx(0.5 * 4.0 + 0.3 * 1.5)
    prof: MergedLLMProfile = st.profile
    total = 0.5 * 4.0 + 0.3 * 1.5
    assert prof.phi == pytest.approx([0.5 * 4.0 / total, 0.3 * 1.5 / total])


def test_merge_order_invariant(sharing_fleet):
    fwd = merge_pipelines(sharing_fleet, LAMS)
    rev = merge_pipelines(dict(reversed(list(sharing_fleet.items()))), LAMS)
    assert list(fwd.stages) == list(rev.stages)
    for cid in fwd.stages:
        a, b = fwd.stages[cid], rev.stages[cid]
        assert a.n == pytest.approx(b.n)
        assert a.p == pytest.approx(b.p)
        assert a.profile.phi == pytest.approx(b.profile.phi)
        assert ([(t.workflow, t.llm) for t in fwd.tenants[cid]]
                == [(t.workflow, t.llm) for t in rev.tenants[cid]])


def test_merged_profile_single_member_is_exact(sharing_fleet):
    """With one member the mixture reduces to the member profile."""
    st = sharing_fleet["wf_a"].stages["gen"]
    merged = merge_pipelines({"wf_a": AggregateLLMPipeline("wf_a", [st])},
                             {"wf_a": 0.5})
    prof: MergedLLMProfile = merged.stages["shared-small"].profile
    for tp in (1, 2):
        assert (prof.max_throughput(tp)
                == pytest.approx(st.profile.max_throughput(tp)))
        for rate in (0.5, 2.0, 8.0):
            want = st.profile.latency(rate, tp)
            assert prof.latency(rate, tp) == pytest.approx(want)
        # fraction scaling maps through unchanged
        assert (prof.latency(1.0, 1, fraction=0.5)
                == pytest.approx(st.profile.latency(1.0, 1, fraction=0.5)))


def test_same_workflow_duplicate_model_stages_all_attributed():
    """Regression: a workflow pointing two of its own stages at the same
    model must keep BOTH stages in per-workflow attribution (members_of
    used to key by canonical id and silently drop one)."""
    wf = AggregateLLMPipeline("wf_a", [
        _stage("draft", SHARED_SMALL, 1.0, n=3.0, p=1.0),
        _stage("refine", SHARED_SMALL, 1.0, n=1.0, p=1.0),
    ])
    merged = merge_pipelines({"wf_a": wf, "wf_b": AggregateLLMPipeline(
        "wf_b", [_stage("gen", SHARED_SMALL, 1.0, n=2.0, p=1.0)])},
        {"wf_a": 0.5, "wf_b": 0.3})
    members = merged.members_of("wf_a")
    assert [t.llm for t in members["shared-small"]] == ["draft", "refine"]
    from repro.core.pipeline import Allocation
    preds = merged.attribute({"shared-small": Allocation(replicas=2)})
    assert set(preds["wf_a"].per_llm_latency) == {"draft", "refine"}
    assert (preds["wf_a"].latency
            == pytest.approx(sum(preds["wf_a"].per_llm_latency.values())))


def test_merged_capacity_is_harmonic_mixture(sharing_fleet):
    merged = merge_pipelines(sharing_fleet, LAMS)
    prof: MergedLLMProfile = merged.stages["shared-small"].profile
    t = [m.profile.max_throughput(1) for m in prof.members]
    want = 1.0 / sum(phi / ti for phi, ti in zip(prof.phi, t))
    assert prof.max_throughput(1) == pytest.approx(want)
    # identical member profiles -> mixture capacity equals theirs
    assert prof.max_throughput(1) == pytest.approx(t[0])


# ---------------------------------------------------------------------------
# pooled scheduling
# ---------------------------------------------------------------------------


def test_pooled_equals_partitioned_when_disjoint(disjoint_fleet):
    """No shared configs: pooling cannot differ from a split, and the
    pooled mode must return the byte-identical partitioned schedule."""
    cfg = SchedulerConfig(max_tp=2)
    spec = hw.PAPER_CLUSTER_16
    part = schedule_multi(disjoint_fleet, spec, LAMS, cfg,
                          mode="partitioned")
    pooled = schedule_multi(disjoint_fleet, spec, LAMS, cfg, mode="pooled")
    assert pooled.alloc_mode == "partitioned"
    assert pooled.pooled is None
    assert pooled.chip_split == part.chip_split
    assert pooled.welfare == part.welfare
    for n in disjoint_fleet:
        assert (pooled.per_workflow[n].allocations
                == part.per_workflow[n].allocations)
        assert (pooled.per_workflow[n].units == part.per_workflow[n].units)


def test_pooled_shares_tenants(sharing_fleet):
    cfg = SchedulerConfig(max_tp=2)
    res = schedule_multi(sharing_fleet, hw.PAPER_CLUSTER_16, LAMS, cfg,
                         mode="pooled")
    assert res.alloc_mode == "pooled"
    assert res.chip_split == {}
    assert set(res.pooled.allocations) == {"shared-small", "shared-big"}
    # the shared allocation stays within the cluster
    chips = sum(a.chip_units for a in res.pooled.allocations.values())
    assert chips <= hw.PAPER_CLUSTER_16.num_chips + 1e-9
    # both workflows see the SAME shared allocation object per tenant
    assert (res.per_workflow["wf_a"].allocations["gen"]
            == res.per_workflow["wf_b"].allocations["draft"])
    for n, pred in res.pooled.predictions.items():
        assert pred.feasible and math.isfinite(pred.latency)
    assert 0.0 <= res.welfare <= 1.0


def test_pooled_routing_weights_sum_to_one(sharing_fleet):
    res = schedule_multi(sharing_fleet, hw.PAPER_CLUSTER_16, LAMS,
                         SchedulerConfig(max_tp=2), mode="pooled")
    routing = res.pooled.routing
    assert set(routing) == set(sharing_fleet)
    for wf, tables in routing.items():
        for llm, table in tables.items():
            assert sum(table.values()) == pytest.approx(1.0)
            assert all(w >= 0 for w in table.values())


def test_auto_picks_better_welfare(sharing_fleet):
    cfg = SchedulerConfig(max_tp=2)
    spec = hw.PAPER_CLUSTER_16
    part = schedule_multi(sharing_fleet, spec, LAMS, cfg, mode="partitioned")
    pooled = schedule_multi(sharing_fleet, spec, LAMS, cfg, mode="pooled")
    auto = schedule_multi(sharing_fleet, spec, LAMS, cfg, mode="auto")
    best = max(part.welfare, pooled.welfare)
    assert auto.welfare == pytest.approx(best)
    assert auto.welfare >= part.welfare - 1e-12  # never worse than PR 1
    assert set(auto.welfare_by_mode) == {"partitioned", "pooled"}
    assert auto.welfare_by_mode["partitioned"] == pytest.approx(part.welfare)
    assert auto.welfare_by_mode["pooled"] == pytest.approx(pooled.welfare)


# ---------------------------------------------------------------------------
# registry fleet (react_agent / map_reduce / debate share 1B + 8B)
# ---------------------------------------------------------------------------

REGISTRY_FLEET = ("react_agent", "map_reduce", "debate")
REGISTRY_LAMS = {"react_agent": 0.5, "map_reduce": 0.4, "debate": 0.8}


@pytest.fixture(scope="module")
def registry_pipes():
    out = {}
    for name in REGISTRY_FLEET:
        pipe, _, _ = build_pipeline(get_workflow(name), n_trace_requests=10,
                                    tp_degrees=(1, 2), max_profile_groups=8)
        out[name] = pipe
    return out


@pytest.mark.parametrize("spec", (hw.PAPER_CLUSTER_8, hw.PAPER_CLUSTER_16),
                         ids=("8chips", "16chips"))
def test_pooled_welfare_ge_partitioned_on_registry_fleet(registry_pipes,
                                                         spec):
    cfg = SchedulerConfig(max_tp=2)
    part = schedule_multi(registry_pipes, spec, REGISTRY_LAMS, cfg,
                          mode="partitioned")
    pooled = schedule_multi(registry_pipes, spec, REGISTRY_LAMS, cfg,
                            mode="pooled")
    assert pooled.alloc_mode == "pooled"
    assert pooled.welfare >= part.welfare - 1e-9


def test_pooled_deploy_places_once_and_routes(registry_pipes):
    from benchmarks.common import joint_run_pooled

    spec = hw.PAPER_CLUSTER_16
    wfs = {n: get_workflow(n) for n in REGISTRY_FLEET}
    fleet = deploy_multi(list(wfs.values()), spec, REGISTRY_LAMS,
                         scheduler_config=SchedulerConfig(max_tp=2),
                         pipelines=registry_pipes, mode="pooled")
    assert fleet.mode == "pooled"
    fleet.tenant_placement.validate()
    # ONE physical placement: tenant instances, global chip ids, no
    # per-workflow offsets
    assert fleet.chip_offsets is None
    names = {i.llm for i in fleet.tenant_placement.instances}
    assert names == set(fleet.schedule.pooled.allocations)
    for inst in fleet.global_instances():
        assert all(0 <= c < spec.num_chips for c in inst.chips)
    # every workflow got a routing table over placed instances, each
    # summing to 1
    inst_names = {f"{i.llm}-r{i.replica}"
                  for i in fleet.tenant_placement.instances}
    for n in REGISTRY_FLEET:
        for llm, table in fleet.routing[n].items():
            assert set(table) <= inst_names
            assert sum(table.values()) == pytest.approx(1.0)
    manifest = fleet.to_deployment()
    assert set(manifest["routing"]) == set(REGISTRY_FLEET)
    # the pooled fleet actually serves traffic end-to-end
    meas = joint_run_pooled(wfs, fleet.schedule.pooled, REGISTRY_LAMS, 10)
    for n in REGISTRY_FLEET:
        assert meas[n]["completed"] == 10
        assert math.isfinite(meas[n]["mean_latency_s"])


# ---------------------------------------------------------------------------
# welfare objectives
# ---------------------------------------------------------------------------


def test_weighted_welfare_is_weight_normalized_mean(disjoint_fleet):
    cfg = SchedulerConfig(max_tp=2, welfare="weighted",
                          welfare_weights={"wf_a": 3.0, "wf_b": 1.0})
    res = schedule_multi(disjoint_fleet, hw.PAPER_CLUSTER_16, LAMS, cfg)
    u = res.utilities
    want = (3.0 * u["wf_a"] + 1.0 * u["wf_b"]) / 4.0
    assert res.welfare == pytest.approx(want)


def test_proportional_welfare_is_log_sum(disjoint_fleet):
    cfg = SchedulerConfig(max_tp=2, welfare="proportional")
    res = schedule_multi(disjoint_fleet, hw.PAPER_CLUSTER_16, LAMS, cfg)
    u = res.utilities
    want = sum(math.log(max(x, 1e-9)) for x in u.values())
    assert res.welfare == pytest.approx(want)
    assert res.welfare <= 0.0  # utilities are capped at 1


def test_unknown_welfare_rejected(disjoint_fleet):
    with pytest.raises(ValueError, match="welfare objective"):
        schedule_multi(disjoint_fleet, hw.PAPER_CLUSTER_16, LAMS,
                       SchedulerConfig(max_tp=2, welfare="utilitarian"))


# ---------------------------------------------------------------------------
# warm-started split search
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("search", ("enumerate", "greedy"))
def test_warm_start_parity(disjoint_fleet, search):
    """Warm-starting each sub-schedule (shared option tables + seeded
    branch-and-bound incumbents) must not change the chosen split, the
    welfare, or any per-workflow predicted latency — the seed and the
    floor bound only prune provably-worse branches."""
    spec = hw.PAPER_CLUSTER_16
    results = {}
    for warm in (False, True):
        cfg = SchedulerConfig(max_tp=2, warm_start=warm)
        results[warm] = schedule_multi(disjoint_fleet, spec, LAMS, cfg,
                                       search=search)
    a, b = results[False], results[True]
    assert a.chip_split == b.chip_split
    assert a.welfare == pytest.approx(b.welfare)
    for n in disjoint_fleet:
        assert (a.per_workflow[n].prediction.latency
                == pytest.approx(b.per_workflow[n].prediction.latency))
