"""Million-request event core: calendar-queue loop, lazy arrivals,
indexed routing, aggregate telemetry.

Parity is the theme — every fast path must be *behaviorally identical*
to the legacy path it replaces: calendar queue vs binary heap, lazy vs
eager arrival generation, indexed vs full-scan routing, and the GK
sketch's hard rank-error bound vs exact records.
"""
import math
import random
from bisect import bisect_left, bisect_right

import pytest

from repro.configs.base import ArchConfig
from repro.core.scheduler import Allocation
from repro.core.telemetry import GKQuantile, StatsSink
from repro.serving.deploy import routers_from_allocations
from repro.serving.simulator import EngineSim, EventLoop, Router
from repro.workflows.registry import get_workflow
from repro.workflows.runtime import ClusterDriver

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# EventLoop: ordering, parity, counters
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["calendar", "heap"])
def test_same_timestamp_fifo_order(kind):
    """Events sharing a timestamp run in schedule order — determinism
    that every seeded benchmark relies on."""
    loop = EventLoop(kind=kind)
    out = []
    for i in range(200):
        loop.schedule(1.0, out.append, i)      # all at the same instant
        loop.schedule(0.5, out.append, 1000 + i)
    loop.run()
    assert out[:200] == [1000 + i for i in range(200)]
    assert out[200:] == list(range(200))
    assert loop.events_processed == 400 and loop.pending == 0


@pytest.mark.parametrize("kind", ["calendar", "heap"])
def test_reentrant_and_past_schedules(kind):
    """Events scheduled in the past clamp to now; events scheduled from
    inside an event at the current time still run this pass."""
    loop = EventLoop(kind=kind)
    out = []

    def ev(tag):
        out.append((loop.now, tag))
        if tag == "a":
            loop.schedule(loop.now - 5.0, ev, "clamped")  # past -> now
            loop.schedule(loop.now, ev, "again")

    loop.schedule(2.0, ev, "a")
    loop.run()
    assert [t for t, _ in out] == [2.0, 2.0, 2.0]
    assert [tag for _, tag in out] == ["a", "clamped", "again"]


def test_calendar_heap_random_trace_parity():
    """Random re-entrant schedules incl. far-future overflow events pop
    in the identical order on both engines."""
    def run(kind, seed):
        loop = EventLoop(kind=kind)
        trace = []
        rng = random.Random(seed)

        def ev(tag):
            trace.append((loop.now, tag))
            if len(trace) < 3000:
                for _ in range(rng.randrange(0, 3)):
                    dt = rng.choice([0.0, rng.expovariate(5.0),
                                     rng.expovariate(0.01)])
                    loop.schedule(loop.now + dt, ev, len(trace))

        for i in range(100):
            loop.schedule(rng.choice([0.0, rng.uniform(0, 2),
                                      rng.uniform(0, 500)]), ev, -i)
        loop.run()
        assert loop.pending == 0
        return trace

    for seed in range(3):
        assert run("calendar", seed) == run("heap", seed)


def test_partial_runs_and_peek():
    loop = EventLoop()
    out = []
    for i in range(50):
        loop.schedule(i * 0.1, out.append, i)
    assert loop.peek_time() == pytest.approx(0.0)
    loop.run(until=2.0)
    assert out == list(range(21))
    assert loop.peek_time() == pytest.approx(2.1)
    loop.run()
    assert out == list(range(50)) and loop.peek_time() is None
    assert loop.empty()


def test_far_future_overflow_events_fire():
    """Events far past the wheel horizon live in the overflow heap and
    still fire, in order, without the wheel spinning through the gap."""
    loop = EventLoop()
    out = []
    loop.schedule(1e6, out.append, "far")
    loop.schedule(0.001, out.append, "near")
    loop.schedule(2e6, out.append, "farther")
    loop.run()
    assert out == ["near", "far", "farther"]
    assert loop.now == 2e6


# ---------------------------------------------------------------------------
# Fleet-level parity: loop kinds, lazy arrivals, indexed routing
# ---------------------------------------------------------------------------

WF_NAMES = ("react_agent", "rag_reranker")


def _run_fleet(*, kind="calendar", indexed=True, eager=False, sink=None,
               n=25, rate=4.0, replicas=2):
    loop = EventLoop(kind=kind)
    drivers = []
    for k, name in enumerate(WF_NAMES):
        wf = get_workflow(name)
        allocs = {m: Allocation(replicas=replicas, tp=1, fraction=1.0)
                  for m in wf.llms}
        routers = routers_from_allocations(wf, allocs, loop)
        if not indexed:
            routers = {m: Router(r.replicas, affinity=r.affinity,
                                 indexed=False)
                       for m, r in routers.items()}
        drv = ClusterDriver(wf, routers, loop, sink=sink)
        drv.schedule_open_loop(rate, n, seed=7 + k, eager=eager)
        drivers.append(drv)
    loop.run(math.inf)
    return loop, drivers


def _trace(drivers):
    return [[(r.request_id, r.arrival, r.done) for r in d.records]
            for d in drivers]


def test_calendar_vs_heap_fleet_parity():
    """The tentpole gate: identical completion traces on a seeded
    registry-fleet run under both event-loop engines."""
    _, a = _run_fleet(kind="calendar")
    _, b = _run_fleet(kind="heap")
    ta, tb = _trace(a), _trace(b)
    assert ta == tb
    assert all(d.n_completed == len(d.records) for d in a)
    assert all(rec.done >= 0 for d in a for rec in d.records)


def test_lazy_vs_eager_arrival_equivalence():
    """Lazy sources draw the same RNG stream as the eager scheduler:
    same arrival count, same times, same completions."""
    loop_l, lazy = _run_fleet(eager=False)
    loop_e, eager = _run_fleet(eager=True)
    assert _trace(lazy) == _trace(eager)
    # ... but the lazy run never held more than a handful of arrival
    # events; the eager one pre-materialized all of them
    assert loop_l.peak_pending < loop_e.peak_pending


def test_indexed_vs_scan_router_parity():
    """The indexed router (owner map + load heap) picks the same replica
    as the legacy full scan on every call."""
    _, a = _run_fleet(indexed=True)
    _, b = _run_fleet(indexed=False)
    assert _trace(a) == _trace(b)


def test_engine_load_invariant_and_counters():
    _, drivers = _run_fleet()
    engines = {id(e): e
               for d in drivers
               for r in d._router_objs
               for e in r.replicas}
    assert engines
    for e in engines.values():
        assert e.load == e.recompute_load() == 0
        assert e.n_done == len(e.done)
    assert sum(e.n_done for e in engines.values()) > 0


def test_keep_done_false_bounds_memory():
    cfg = ArchConfig(name="tiny", family="dense", num_layers=2,
                     d_model=256, num_heads=4, num_kv_heads=4,
                     d_ff=1024, vocab_size=1000)
    from repro.serving.simulator import EngineRequest
    loop = EventLoop()
    eng = EngineSim(cfg, loop, name="e", keep_done=False)
    for i in range(20):
        eng.submit(EngineRequest(req_id=i, prompt_tokens=64,
                                 output_tokens=8, arrival=0.0))
    loop.run()
    assert eng.n_done == 20 and eng.done == []
    assert eng.load == eng.recompute_load() == 0


def test_owner_map_matches_radix_heads():
    """The router index's prefix-owner map stays consistent with each
    replica's actual resident head segments."""
    _, drivers = _run_fleet()
    for d in drivers:
        for router in d._router_objs:
            idx = router._index
            assert idx is not None
            want = {}
            for i, e in enumerate(router.replicas):
                for seg, _start in e.radix.root.children:
                    want.setdefault(seg, set()).add(i)
            assert idx.owners == want


def test_sticky_pruned_on_completion():
    """Satellite fix: sticky entries die with their workflow instance
    instead of accumulating one per request forever."""
    wf = get_workflow("react_agent")
    loop = EventLoop()
    allocs = {m: Allocation(replicas=2, tp=1, fraction=1.0)
              for m in wf.llms}
    base = routers_from_allocations(wf, allocs, loop)
    views = {m: r.view({0: 1.0, 1: 1.0}) for m, r in base.items()}
    drv = ClusterDriver(wf, views, loop)
    recorded = []

    class SpyDict(dict):
        def __setitem__(self, k, val):
            recorded.append(k)
            dict.__setitem__(self, k, val)

    for v in views.values():
        v._sticky = SpyDict()
    drv.run_open_loop(4.0, 12, seed=3)
    assert drv.n_completed == 12
    assert recorded  # sticky WAS used during the run...
    for v in views.values():
        assert v._sticky == {}  # ...and fully pruned at completion


# ---------------------------------------------------------------------------
# Telemetry: sketch bound + sink vs exact records
# ---------------------------------------------------------------------------


def _rank_error(data_sorted, v, q):
    n = len(data_sorted)
    lo, hi = bisect_left(data_sorted, v), bisect_right(data_sorted, v)
    target = q * n
    if lo <= target <= hi:
        return 0.0
    return min(abs(lo - target), abs(hi - target))


if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.floats(min_value=0.0, max_value=1e9,
                              allow_nan=False, allow_infinity=False),
                    min_size=1, max_size=1500),
           st.floats(min_value=0.0, max_value=1.0))
    def test_gk_sketch_rank_error_bound(data, q):
        """GK guarantee: the returned value's stream rank is within
        eps*n of the target rank (the property the ISSUE gates on)."""
        eps = 0.02
        gk = GKQuantile(eps=eps)
        for v in data:
            gk.add(v)
        v = gk.query(q)
        assert _rank_error(sorted(data), v, q) <= eps * len(data) + 1


def test_gk_exact_min_max_and_empty():
    gk = GKQuantile(eps=0.01)
    assert math.isnan(gk.query(0.5))
    for v in [5.0, 1.0, 9.0, 3.0]:
        gk.add(v)
    assert gk.query(0.0) == 1.0
    assert gk.query(1.0) == 9.0


def test_sink_mode_matches_exact_records():
    """Aggregate-sink runs keep no per-request records yet report the
    same counts and near-identical quantiles."""
    _, exact = _run_fleet(sink=None)
    sink = StatsSink(eps=0.001)
    _, sunk = _run_fleet(sink=sink)
    for d_exact, d_sink, name in zip(exact, sunk, WF_NAMES):
        assert d_sink.records == []          # nothing retained
        s = sink.stats[name]
        assert s.arrived == len(d_exact.records)
        assert s.completed == sum(1 for r in d_exact.records if r.done >= 0)
        lats = sorted(r.latency for r in d_exact.records if r.done >= 0)
        for q in (0.5, 0.99):
            approx = sink.latency_quantile(name, q)
            # at this eps the sketch is rank-exact up to rounding (the
            # 2% *value* gate runs on bench_scale's smoke-sized sample)
            assert _rank_error(lats, approx, q) <= \
                sink.eps * len(lats) + 1
    summary = sink.summary()
    assert set(summary) == set(WF_NAMES)
    for row in summary.values():
        assert row["completed"] > 0 and math.isfinite(row["latency_p99"])
