"""Scheduler search tests: option-table memoization parity, N-workflow
egalitarian splits (incl. 2-workflow parity with the enumerated loop),
welfare monotonicity, and infeasible-cluster error paths.

Synthetic analytic profiles (no discrete-event replay) keep these fast
and deterministic — only the search itself is under test.
"""
import math

import pytest

from repro import hw
from repro.configs.base import ArchConfig
from repro.core.pipeline import AggregateLLMPipeline, PipelineStage
from repro.core.profiler import LLMProfile, TPProfile
from repro.core.scheduler import (SchedulerConfig, _min_chips_for_units,
                                  _subcluster, schedule, schedule_multi)
from repro.serving import costmodel as cm


def _synthetic_stage(name: str, size_gb: float, n: float = 4.0,
                     p: float = 2.0, cfg: ArchConfig = None) -> PipelineStage:
    """Analytic M/M/1-flavored profile for a model of the given size."""
    base_lat = 0.05 * size_gb
    t_max = 40.0 / size_gb
    by_tp = {}
    for tp in (1, 2):
        tmax = t_max * (tp ** 0.85)
        rates = [f * tmax for f in (0.1, 0.3, 0.5, 0.7, 0.9)]
        lat = [base_lat / tp / max(1 - r / tmax, 0.05) for r in rates]
        by_tp[tp] = TPProfile(tp=tp, rates=rates,
                              latency={"mean": lat, "p50": lat,
                                       "p90": [2 * x for x in lat],
                                       "p99": [4 * x for x in lat]},
                              max_throughput=tmax)
    if cfg is None:
        cfg = ArchConfig(name=name, family="dense", num_layers=16,
                         d_model=2048, num_heads=16, num_kv_heads=8,
                         d_ff=8192, vocab_size=32_000)
    prof = LLMProfile(llm=name, arch=name, calls_per_group=n, by_tp=by_tp)
    return PipelineStage(llm=name, cfg=cfg, n=n, p=p, profile=prof,
                         mean_share=1.0)


def _pipeline(tag: str, sizes, n: float = 2.0) -> AggregateLLMPipeline:
    stages = [_synthetic_stage(f"{tag}-m{i}", s, n=n + i)
              for i, s in enumerate(sizes)]
    return AggregateLLMPipeline(tag, stages)


@pytest.fixture(scope="module")
def fleet():
    return {
        "wf0": _pipeline("wf0", (1.0, 4.0)),
        "wf1": _pipeline("wf1", (2.0, 3.0)),
        "wf2": _pipeline("wf2", (1.5, 5.0)),
    }


LAMS = {"wf0": 0.5, "wf1": 0.3, "wf2": 0.4}


# ---------------------------------------------------------------------------
# memoization parity
# ---------------------------------------------------------------------------


def test_memoized_search_matches_brute_recomputation(fleet):
    pipe = _pipeline("memo", (1.0, 3.0, 6.0))
    spec = hw.PAPER_CLUSTER_8
    results = {}
    for memo in (False, True):
        cfg = SchedulerConfig(max_tp=2, memoize=memo)
        results[memo] = schedule(pipe, spec, 0.4, cfg)
    a, b = results[False], results[True]
    assert a.evaluated == b.evaluated
    assert a.units == b.units
    assert a.allocations == b.allocations
    assert a.feasible == b.feasible
    assert a.prediction.latency == pytest.approx(b.prediction.latency)


# ---------------------------------------------------------------------------
# N-workflow splits
# ---------------------------------------------------------------------------


def _seed_two_way_loop(pipelines, spec, lam_targets, config, split_step=1):
    """The pre-generalization enumerated 2-workflow split, verbatim
    semantics: first strictly-better split wins."""
    names = list(pipelines)
    a, b = names
    refs = {}
    for n in names:
        r = schedule(pipelines[n], spec, lam_targets[n], config)
        refs[n] = r.prediction.latency if r.feasible else math.inf
    lo_chips = {
        n: math.ceil(sum(cm.min_fraction_units(pipelines[n].stages[m].cfg,
                                               spec)
                         for m in pipelines[n].stages)
                     / spec.fractions_per_chip)
        for n in names
    }
    G = spec.num_chips
    best = None
    for ca in range(lo_chips[a], G - lo_chips[b] + 1, split_step):
        cb = G - ca
        try:
            ra = schedule(pipelines[a], _subcluster(spec, ca),
                          lam_targets[a], config)
            rb = schedule(pipelines[b], _subcluster(spec, cb),
                          lam_targets[b], config)
        except (ValueError, RuntimeError):
            continue
        utils = {}
        for n, r in ((a, ra), (b, rb)):
            if not r.feasible or not math.isfinite(r.prediction.latency):
                utils[n] = 0.0
            else:
                utils[n] = (min(refs[n] / r.prediction.latency, 1.0)
                            if refs[n] > 0 else 0.0)
        welfare = min(utils.values())
        if best is None or welfare > best[0]:
            best = (welfare, {a: ca, b: cb})
    assert best is not None
    return best


@pytest.mark.parametrize("split_step", (1, 2))
def test_two_workflow_parity_with_enumerated_loop(fleet, split_step):
    pipes = {n: fleet[n] for n in ("wf0", "wf1")}
    cfg = SchedulerConfig(max_tp=2)
    spec = hw.PAPER_CLUSTER_16
    want_welfare, want_split = _seed_two_way_loop(pipes, spec, LAMS, cfg,
                                                  split_step)
    res = schedule_multi(pipes, spec, LAMS, cfg, split_step=split_step)
    assert res.search_mode == "enumerate"
    assert res.chip_split == want_split
    assert res.welfare == pytest.approx(want_welfare)


def test_three_workflow_split_partitions_cluster(fleet):
    spec = hw.PAPER_CLUSTER_16
    res = schedule_multi(fleet, spec, LAMS, SchedulerConfig(max_tp=2))
    assert sum(res.chip_split.values()) == spec.num_chips
    assert set(res.chip_split) == set(fleet)
    assert 0.0 <= res.welfare <= 1.0
    assert res.welfare == pytest.approx(min(res.utilities.values()))
    for r in res.per_workflow.values():
        assert r.feasible


def test_greedy_search_close_to_enumeration(fleet):
    spec = hw.PAPER_CLUSTER_16
    cfg = SchedulerConfig(max_tp=2)
    enum = schedule_multi(fleet, spec, LAMS, cfg, search="enumerate")
    greedy = schedule_multi(fleet, spec, LAMS, cfg, search="greedy")
    assert greedy.search_mode == "greedy"
    assert greedy.welfare >= enum.welfare * 0.9
    # greedy explores far fewer splits than full enumeration
    assert greedy.schedule_calls <= enum.schedule_calls


def test_min_chips_exact_no_host_rounding():
    spec = hw.PAPER_CLUSTER_16  # 4 chips/host, F=10
    assert _min_chips_for_units(10, spec) == 1
    assert _min_chips_for_units(40, spec) == 4
    # partial hosts are modeled as tail_chips now, so the floor is the
    # exact unit quotient — no jump to the next full-host multiple
    assert _min_chips_for_units(41, spec) == 5
    assert _min_chips_for_units(61, spec) == 7
    assert _min_chips_for_units(81, spec) == 9


def test_subcluster_keeps_partial_host_remainder():
    """Regression: _subcluster used to truncate partial hosts beyond the
    first (9, 10, 11 chips all modeled as 8 on a 4-chip/host spec),
    silently stranding the remainder chips of any misaligned split."""
    spec = hw.PAPER_CLUSTER_16
    for chips in (5, 9, 10, 11, 15):
        sub = _subcluster(spec, chips)
        assert sub.num_chips == chips, f"{chips} chips truncated"
        assert sub.total_units == chips * spec.fractions_per_chip
    # tail chips land on one extra partially-filled host
    sub = _subcluster(spec, 9)
    assert (sub.num_hosts, sub.chips_per_host, sub.tail_chips) == (2, 4, 1)


def test_misaligned_split_remainder_not_stranded(fleet):
    """A 2-workflow split on a cluster whose optimum lands between host
    multiples: the winning split's slices must schedule with their full
    chip count (previously chips past the last full host were dropped,
    so a 9-chip slice scheduled as 8)."""
    spec = hw.PAPER_CLUSTER_16
    pipes = {n: fleet[n] for n in ("wf0", "wf1")}
    res = schedule_multi(pipes, spec, LAMS, SchedulerConfig(max_tp=2))
    assert sum(res.chip_split.values()) == spec.num_chips
    F = spec.fractions_per_chip
    for n, r in res.per_workflow.items():
        used = sum(a.chip_units for a in r.allocations.values())
        assert used <= res.chip_split[n] * F + 1e-9
    # a misaligned slice really provides its full capacity to schedule()
    r9 = schedule(pipes["wf0"], _subcluster(spec, 9), LAMS["wf0"],
                  SchedulerConfig(max_tp=2))
    assert sum(r9.units.values()) <= 9 * F
    assert max(r9.units.values()) > 0


def test_greedy_survives_host_misaligned_memory_floor():
    """A workflow whose memory floor lands between host multiples (four
    1.5-chip stages -> 6 chips on a 4-chip/host cluster) schedules on
    exactly its floor now that _subcluster models the remainder."""
    spec = hw.PAPER_CLUSTER_16
    mid_cfg = ArchConfig(name="mid", family="dense", num_layers=48,
                         d_model=4096, num_heads=32, num_kv_heads=8,
                         d_ff=14336, vocab_size=32_000)
    units = cm.min_fraction_units(mid_cfg, spec)
    F = spec.fractions_per_chip
    assert units <= 2 * F  # each stage still fits one tp<=2 replica
    total = 4 * units
    assert spec.chips_per_host * F < total  # floor crosses a host and
    assert total % (spec.chips_per_host * F)  # is not host-aligned
    pipes = {
        "big": AggregateLLMPipeline(
            "big", [_synthetic_stage(f"big-m{i}", 4.0, n=1.0, cfg=mid_cfg)
                    for i in range(4)]),
        "small": _pipeline("small", (1.0,)),
    }
    lams = {"big": 0.2, "small": 0.3}
    res = schedule_multi(pipes, spec, lams, SchedulerConfig(max_tp=2),
                         search="greedy")
    assert res.chip_split["big"] >= _min_chips_for_units(total, spec)
    assert res.welfare > 0.0


def test_welfare_monotone_in_cluster_size(fleet):
    cfg = SchedulerConfig(max_tp=2)
    small = schedule_multi(fleet, hw.PAPER_CLUSTER_8, LAMS, cfg)
    large = schedule_multi(fleet, hw.PAPER_CLUSTER_16, LAMS, cfg)
    assert large.welfare >= small.welfare - 1e-9


# ---------------------------------------------------------------------------
# error paths
# ---------------------------------------------------------------------------


def test_single_workflow_rejected(fleet):
    with pytest.raises(ValueError, match=">= 2 workflows"):
        schedule_multi({"wf0": fleet["wf0"]}, hw.PAPER_CLUSTER_8,
                       LAMS, SchedulerConfig(max_tp=2))


def test_missing_rate_target_rejected(fleet):
    pipes = {n: fleet[n] for n in ("wf0", "wf1")}
    with pytest.raises(ValueError, match="arrival-rate target"):
        schedule_multi(pipes, hw.PAPER_CLUSTER_8, {"wf0": 0.5},
                       SchedulerConfig(max_tp=2))


def test_unknown_search_mode_rejected(fleet):
    pipes = {n: fleet[n] for n in ("wf0", "wf1")}
    with pytest.raises(ValueError, match="search mode"):
        schedule_multi(pipes, hw.PAPER_CLUSTER_8, LAMS,
                       SchedulerConfig(max_tp=2), search="annealing")


def test_cluster_too_small_for_fleet_raises(fleet):
    tiny = hw.ClusterSpec(num_hosts=1, chips_per_host=1)
    with pytest.raises(ValueError, match="too small"):
        schedule_multi(fleet, tiny, LAMS, SchedulerConfig(max_tp=1))


def test_enumeration_bound_enforced(fleet):
    with pytest.raises(ValueError, match="enumeration bound"):
        schedule_multi(fleet, hw.PAPER_CLUSTER_16, LAMS,
                       SchedulerConfig(max_tp=2), search="enumerate",
                       max_enumerated_splits=3)
