"""The prefix-aware serving hot path (real engine + fleet simulator).

Real engine: exact-prefix KV reuse is numerically transparent — a prompt
served from the cache generates token-for-token what a cold prefill
generates — and honestly accounted (``cached_tokens``).  Simulator: the
radix-cache model's measured cached-prefix length equals the driver's
ground-truth shared prefix when nothing is evicted, eviction removes
hits deterministically, the router prefers the longest-prefix replica
and fails over cleanly, and QoS preemption never inverts priority
(hypothesis property when available).
"""
import math

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config, reduced_config
from repro.models import build_model
from repro.qos.policy import make_policy
from repro.qos.slo import RequestQoS
from repro.serving.engine import ServeRequest, ServingEngine
from repro.serving.prefix_cache import PrefixCache
from repro.serving.radix import RadixCache
from repro.serving.simulator import (EngineRequest, EngineSim, EventLoop,
                                     Router, output_segment)
from repro.workflows.registry import get_workflow
from repro.workflows.runtime import ClusterDriver

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# real engine: prefix reuse is exact and honestly accounted
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def qwen_engine_parts():
    cfg = reduced_config(get_config("qwen2.5-3b"))
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0))
    return cfg, bundle, params


def test_engine_identical_prompt_served_from_cache(qwen_engine_parts):
    """Satellite regression: the second of two identical prompts must
    prefill its shared prefix from the cache (the seed engine built a
    PrefixCache and never consulted it)."""
    cfg, bundle, params = qwen_engine_parts
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, size=24).astype(np.int32)
    eng = ServingEngine(bundle, params, slots=2, max_len=64)
    eng.submit(ServeRequest(0, prompt, max_new_tokens=4))
    first = eng.run_to_completion()[0]
    eng.submit(ServeRequest(1, prompt, max_new_tokens=4))
    second = eng.run_to_completion()[0]
    assert second.cached_tokens == len(prompt) - 1
    assert eng.stats["cached_tokens"] == len(prompt) - 1
    # reuse is numerically transparent
    assert second.generated == first.generated


def test_engine_prefix_reuse_token_identical_vs_cold(qwen_engine_parts):
    cfg, bundle, params = qwen_engine_parts
    rng = np.random.default_rng(5)
    base = rng.integers(0, cfg.vocab_size, size=20).astype(np.int32)
    ext = np.concatenate(
        [base, rng.integers(0, cfg.vocab_size, size=9).astype(np.int32)])

    warm_eng = ServingEngine(bundle, params, slots=2, max_len=64)
    warm_eng.submit(ServeRequest(0, base, max_new_tokens=3))
    warm_eng.run_to_completion()
    warm_eng.submit(ServeRequest(1, ext, max_new_tokens=5))
    warm = warm_eng.run_to_completion()[0]
    assert warm.cached_tokens >= len(base)  # prompt + generated prefix

    cold_eng = ServingEngine(bundle, params, slots=1, max_len=64,
                             prefix_caching=False)
    cold_eng.submit(ServeRequest(9, ext, max_new_tokens=5))
    cold = cold_eng.run_to_completion()[0]
    assert cold.cached_tokens == 0
    assert warm.generated == cold.generated


def test_engine_slot_reuse_invalidates_stale_entries(qwen_engine_parts):
    """Once a slot's KV is overwritten, cache entries pointing at it
    must not produce hits (correctness, not just accounting)."""
    cfg, bundle, params = qwen_engine_parts
    rng = np.random.default_rng(11)
    p1 = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    eng = ServingEngine(bundle, params, slots=1, max_len=64)
    eng.submit(ServeRequest(0, p1, max_new_tokens=2))
    eng.run_to_completion()
    # p2 overwrites the only slot; p1's entries must be gone
    eng.submit(ServeRequest(1, p2, max_new_tokens=2))
    eng.run_to_completion()
    matched, slot = eng.prefix_cache.longest_prefix([int(t) for t in p1])
    assert slot is None and matched == 0


# ---------------------------------------------------------------------------
# prefix-cache / paged-cache regressions (satellites)
# ---------------------------------------------------------------------------


def test_prefix_cache_eviction_bounded():
    """insert adds one node per token; eviction must loop until the trie
    is back under budget (the seed evicted exactly one node)."""
    pc = PrefixCache(max_entries=32)
    for i in range(10):
        pc.insert(list(range(i * 100, i * 100 + 20)), slot=i)
        assert pc.entries <= 32
    # the freshly inserted chain itself is never evicted
    assert pc.longest_prefix(list(range(900, 920)))[1] == 9


def test_prefix_cache_invalidate_prunes_dead_chains():
    pc = PrefixCache()
    pc.insert([1, 2, 3, 4], slot=0)
    pc.insert([1, 2, 9], slot=1)
    assert pc.entries == 5
    pc.invalidate_slot(0)
    # the [3, 4] tail is slotless and childless -> pruned, entries drop
    assert pc.entries == 3
    assert pc.longest_prefix([1, 2, 3, 4]) == (2, 1) or \
        pc.longest_prefix([1, 2, 3, 4])[0] <= 2
    pc.invalidate_slot(1)
    assert pc.entries == 0


def test_paged_append_batches_pages_against_oracle():
    """Multi-page append in one call must match the gather_seq oracle
    (the write path batches one dynamic_update_slice per touched page)."""
    import jax.numpy as jnp
    from repro.serving.kv_cache import PagedKVCache

    L, KV, D, ps = 2, 2, 8, 4
    cache = PagedKVCache.create(L, num_pages=8, kv_heads=KV, page_size=ps,
                                head_dim=D, dtype=jnp.float32)
    rng = jax.random.key(1)
    T = 11  # spans 3 pages, starts/ends mid-page after the second append
    k_all = jax.random.normal(rng, (L, KV, T, D))
    v_all = k_all * 3
    cache.alloc_seq(0)
    cache.append(0, k_all[:, :, :3], v_all[:, :, :3])   # mid-page start
    cache.append(0, k_all[:, :, 3:], v_all[:, :, 3:])   # crosses 2 pages
    k, v, length = cache.gather_seq(0)
    assert length == T
    np.testing.assert_allclose(np.asarray(k[:, :, :T]), np.asarray(k_all),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v[:, :, :T]), np.asarray(v_all),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# simulator: token-accurate radix model
# ---------------------------------------------------------------------------


def _react_driver(loop, engines, affinity=True):
    wf = get_workflow("react_agent")
    routers = {m: Router(engines, affinity=affinity) for m in wf.llms}
    return ClusterDriver(wf, routers, loop)


def test_sim_cached_prefix_exact_without_eviction():
    wf = get_workflow("react_agent")
    cfg = next(iter(wf.llms.values()))
    loop = EventLoop()
    eng = EngineSim(cfg, loop)
    drv = _react_driver(loop, [eng])
    recs = drv.run_open_loop(0.5, 8, seed=4, until=1e6)
    assert len(recs) == 8
    assert eng.done
    for r in eng.done:
        assert r.cached_prefix == r.true_prefix
    assert sum(r.cached_prefix for r in eng.done) > 0


def test_sim_evicted_parent_yields_no_cached_prefix():
    """Deterministic: a parent whose KV fell out of the token budget
    stops producing prefix hits."""
    cfg = next(iter(get_workflow("react_agent").llms.values()))
    loop = EventLoop()
    eng = EngineSim(cfg, loop, kv_capacity_override=100)
    done = []
    parent = EngineRequest(req_id=1, prompt_tokens=80, output_tokens=10,
                           arrival=0.0, on_complete=done.append,
                           prefix=((("s", 1), 80),))
    eng.submit(parent)
    loop.run()
    # a fat stranger evicts the parent's 90 resident tokens
    stranger = EngineRequest(req_id=2, prompt_tokens=95, output_tokens=4,
                             arrival=loop.now, on_complete=done.append,
                             prefix=((("s", 2), 95),))
    eng.submit(stranger)
    loop.run()
    child_prefix = ((("s", 1), 80), output_segment(1, 10), (("d", 3), 5))
    child = EngineRequest(req_id=3, prompt_tokens=95, output_tokens=4,
                          arrival=loop.now, on_complete=done.append,
                          prefix=child_prefix)
    eng.submit(child)
    loop.run()
    assert child.cached_prefix == 0


def test_sim_legacy_served_registry_is_lru_bounded():
    """The parent-id heuristic path must forget completed requests once
    their modeled KV exceeds the budget (the seed grew without bound)."""
    cfg = next(iter(get_workflow("react_agent").llms.values()))
    loop = EventLoop()
    eng = EngineSim(cfg, loop, kv_capacity_override=250)
    for i in range(3):
        eng.submit(EngineRequest(req_id=i, prompt_tokens=90,
                                 output_tokens=10, arrival=loop.now))
        loop.run()
    # 3 x 100 tokens > 250: the oldest entry must have been evicted
    assert not eng.has_parent(0)
    assert eng.has_parent(2)
    assert len(eng._served) <= 2


def test_router_prefers_longest_prefix_replica_and_fails_over():
    cfg = next(iter(get_workflow("react_agent").llms.values()))
    loop = EventLoop()
    engines = [EngineSim(cfg, loop, name=f"r{i}") for i in range(3)]
    router = Router(engines)
    done = []
    parent = EngineRequest(req_id=1, prompt_tokens=50, output_tokens=8,
                           arrival=0.0, on_complete=done.append,
                           prefix=((("s", 1), 50),))
    # load replica 0 so least-loaded would NOT pick it later
    engines[0].submit(parent)
    loop.run()
    host = engines[0]
    assert host.done  # parent's KV lives on replica 0
    child_prefix = parent.prefix + (output_segment(1, 8),) + ((("d", 2), 6),)
    child = EngineRequest(req_id=2, prompt_tokens=64, output_tokens=4,
                          arrival=loop.now, on_complete=done.append,
                          prefix=child_prefix)
    assert host.prefix_lookup(child) == 58
    router.submit(child)
    loop.run()
    assert child in host.done  # affinity routed to the prefix holder
    assert child.cached_prefix == 58

    # replica failure clears prefix state and fails over cleanly
    grandchild = EngineRequest(
        req_id=3, prompt_tokens=70, output_tokens=4, arrival=loop.now,
        on_complete=done.append,
        prefix=child_prefix + (output_segment(2, 4),) + ((("d", 3), 2),))
    router.fail_replica(0)
    assert host.radix.tokens == 0 and not host._served
    router.submit(grandchild)
    loop.run()
    assert grandchild not in host.done
    assert grandchild.t_done >= 0 and grandchild.cached_prefix == 0


# ---------------------------------------------------------------------------
# preemption never inverts priority
# ---------------------------------------------------------------------------


_TIERS = (
    ("gold", 4.0, 5.0),       # (slo, weight, relative deadline)
    ("silver", 2.0, 20.0),
    ("bronze", 1.0, 60.0),
    ("best_effort", 0.5, math.inf),
)


def _qos_for(tier_idx: int, arrival: float):
    name, weight, dl = _TIERS[tier_idx]
    if not math.isfinite(dl):
        return RequestQoS(tenant="t", slo=name, weight=weight,
                          deadline=math.inf, remaining_s=0.0)
    return RequestQoS(tenant="t", slo=name, weight=weight,
                      deadline=arrival + dl, remaining_s=0.0)


def _run_preemption_stream(spec):
    """spec: list of (tier_idx, inter_arrival_scaled) request templates."""
    cfg = next(iter(get_workflow("react_agent").llms.values()))
    loop = EventLoop()
    eng = EngineSim(cfg, loop, policy=make_policy("priority"),
                    preemption=True, max_batch_override=2,
                    prefill_chunk=4096)
    t = 0.0
    for i, (tier, gap) in enumerate(spec):
        t += gap / 10.0
        arrival = t

        def submit(i=i, tier=tier, arrival=arrival):
            eng.submit(EngineRequest(
                req_id=i, prompt_tokens=256, output_tokens=64,
                arrival=arrival, qos=_qos_for(tier, arrival)))

        loop.schedule(arrival, submit)
    loop.run()
    return eng


def test_preemption_never_inverts_priority_smoke():
    eng = _run_preemption_stream(
        [(3, 0.0), (3, 0.1), (2, 1.0), (0, 1.0), (0, 0.5), (1, 2.0)])
    assert eng.preempt_log, "stream should trigger at least one preemption"
    for pw, vw, _ in eng.preempt_log:
        assert pw > vw
    assert len(eng.done) == 6  # every victim still completes


# ---------------------------------------------------------------------------
# stateful sessions monetize the radix/sticky path (PR: realistic traffic)
# ---------------------------------------------------------------------------


class _SessionCallLog:
    """Telemetry sink recording each LLM call's prefix-cache hit, keyed
    by the workflow request (= session) that issued it."""

    def __init__(self):
        self.calls = []

    def record_arrival(self, workflow, t):
        pass

    def record_call(self, workflow, llm, req):
        self.calls.append((req.workflow_request, req.arrival,
                           req.prompt_tokens, req.cached_prefix))

    def record_request_done(self, workflow, rec):
        pass


def _run_sessions(n=24, seed=6):
    wf = get_workflow("session_chat")
    loop = EventLoop()
    cfg = wf.llms["chat"]
    engines = [EngineSim(cfg, loop, name=f"r{i}") for i in range(2)]
    base = Router(engines)
    view = base.view({0: 1.0, 1: 1.0})  # weighted => sticky tier active
    log = _SessionCallLog()
    drv = ClusterDriver(wf, {"chat": view}, loop, telemetry=log)
    drv.schedule_open_loop(0.8, n, seed=seed)
    loop.run(1e7)
    return view, engines, log, drv


def test_session_cached_tokens_grow_turn_over_turn():
    """A chat session's turn-k call extends turn k-1's transcript via a
    parent handle, so under sticky routing the cached fraction of each
    prompt must RISE turn over turn — the radix path monetizing
    conversation state."""
    view, engines, log, drv = _run_sessions()
    per_session = {}
    for sid, at, prompt, cached in sorted(log.calls,
                                          key=lambda c: (c[0], c[1])):
        per_session.setdefault(sid, []).append(cached / max(prompt, 1))
    multi = [fracs for fracs in per_session.values() if len(fracs) > 1]
    assert len(multi) >= 5  # session lengths are random but multi-turn
    first = sum(f[0] for f in multi) / len(multi)
    later = [x for f in multi for x in f[1:]]
    assert first < 0.1  # turn 1 is a cold transcript
    assert sum(later) / len(later) > first + 0.5
    # and the engines' own accounting agrees: most prefill was cached
    cached = sum(e.cached_tokens for e in engines)
    prefill = sum(e.prefill_tokens for e in engines)
    assert cached / (cached + prefill) > 0.5


def test_session_end_prunes_sticky_routing_state():
    """The driver's done path calls Router.forget, so sticky entries are
    bounded by in-flight sessions and empty after drain."""
    view, engines, log, drv = _run_sessions()
    assert all(r.done >= 0 for r in drv.records)
    assert len(view._sticky) == 0
    # forget is idempotent and safe for unknown instances
    view._sticky[999] = 0
    view.forget(999)
    view.forget(999)
    assert len(view._sticky) == 0


def test_recursive_agent_branches_share_plan_prefix():
    """The decomposition agent's subtask calls chain off the plan call's
    handle: with a single replica every recursion level after the root
    should see a nonzero cached prefix."""
    wf = get_workflow("recursive_agent")
    loop = EventLoop()
    routers = {m: Router([EngineSim(c, loop, name=m)])
               for m, c in wf.llms.items()}
    log = _SessionCallLog()
    drv = ClusterDriver(wf, routers, loop, telemetry=log)
    drv.schedule_open_loop(0.5, 12, seed=13)
    loop.run(1e7)
    assert all(r.done >= 0 for r in drv.records)
    by_session = {}
    for sid, at, prompt, cached in sorted(log.calls,
                                          key=lambda c: (c[0], c[1])):
        by_session.setdefault(sid, []).append(cached)
    # every session's follow-up agent calls reuse the transcript
    multi = [c for c in by_session.values() if len(c) > 1]
    assert multi and all(any(x > 0 for x in c[1:]) for c in multi)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_preemption_never_inverts_priority_property():
    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 3),
                              st.floats(0.0, 3.0)),
                    min_size=2, max_size=12))
    def check(spec):
        eng = _run_preemption_stream(spec)
        for pw, vw, _ in eng.preempt_log:
            assert pw > vw
        assert len(eng.done) == len(spec)

    check()
