"""Integration tests: full Scepsy flow against the cluster simulator,
baselines, multi-workflow scheduling, and pod-scale placement."""
import math

import pytest

from repro import hw
from repro.core.scepsy import build_pipeline, deploy
from repro.core.scheduler import SchedulerConfig, schedule, schedule_multi
from repro.core.placement import place
from repro.serving.deploy import routers_from_allocations
from repro.serving.simulator import EventLoop
from repro.workflows.baselines import AegaeonLike, AyoLike, KubernetesHPA
from repro.workflows.beam_search import BEAM_SEARCH
from repro.workflows.rag_reranker import RAG_RERANKER
from repro.workflows.runtime import ClusterDriver


@pytest.fixture(scope="module")
def beam_pipe():
    p, _, _ = build_pipeline(BEAM_SEARCH, n_trace_requests=12,
                             tp_degrees=(1, 2), max_profile_groups=10)
    return p


def _run(wf, routers, loop, rate, n):
    driver = ClusterDriver(wf, routers, loop)
    recs = driver.run_open_loop(rate, n, seed=11, until=1e5)
    recs = [r for r in recs if r.done >= 0]
    assert recs, "no requests completed"
    lats = [r.latency for r in recs]
    span = max(r.done for r in recs) - min(r.arrival for r in recs)
    return len(recs) / span, sum(lats) / len(lats), len(recs)


def test_scepsy_end_to_end_serving(beam_pipe):
    spec = hw.PAPER_CLUSTER_8
    res = schedule(beam_pipe, spec, 0.3, SchedulerConfig(max_tp=2))
    assert res.feasible
    place(res.allocations, spec).validate()
    loop = EventLoop()
    routers = routers_from_allocations(BEAM_SEARCH, res.allocations, loop)
    tput, lat, n = _run(BEAM_SEARCH, routers, loop, 0.3, 25)
    assert n == 25
    assert math.isfinite(lat)
    # served near the offered rate (not saturated at the target)
    assert tput > 0.2


def test_scepsy_beats_multiplexing_baseline(beam_pipe):
    spec = hw.PAPER_CLUSTER_8
    rate, n = 0.4, 25
    res = schedule(beam_pipe, spec, rate, SchedulerConfig(max_tp=2))
    loop = EventLoop()
    routers = routers_from_allocations(BEAM_SEARCH, res.allocations, loop)
    s_tput, s_lat, _ = _run(BEAM_SEARCH, routers, loop, rate, n)

    loop2 = EventLoop()
    aeg = AegaeonLike(BEAM_SEARCH, spec, loop2)
    a_tput, a_lat, _ = _run(BEAM_SEARCH, aeg.routers, loop2, rate, n)
    assert s_lat < a_lat, f"scepsy {s_lat} vs aegaeon {a_lat}"
    assert s_tput >= a_tput * 0.9


def test_k8s_and_ayo_baselines_run():
    spec = hw.PAPER_CLUSTER_4
    for cls in (KubernetesHPA, AyoLike):
        loop = EventLoop()
        sysm = cls(RAG_RERANKER, spec, loop)
        tput, lat, n = _run(RAG_RERANKER, sysm.routers, loop, 2.0, 20)
        assert n == 20 and math.isfinite(lat)


def test_multi_workflow_schedule():
    pipes = {}
    for wf in (BEAM_SEARCH, RAG_RERANKER):
        pipes[wf.name], _, _ = build_pipeline(
            wf, n_trace_requests=10, tp_degrees=(1, 2), max_profile_groups=8)
    res = schedule_multi(pipes, hw.PAPER_CLUSTER_16,
                         {"beam_search": 0.2, "rag_reranker": 2.0},
                         SchedulerConfig(max_tp=2), split_step=2)
    assert res.chip_split["beam_search"] + res.chip_split["rag_reranker"] == 16
    assert 0.0 <= res.welfare <= 1.0
    for r in res.per_workflow.values():
        assert r.feasible


def test_pod_scale_placement(beam_pipe):
    """Scheduler + placement on a 256-chip pod-scale serving cluster."""
    spec = hw.POD_CLUSTER_256
    res = schedule(beam_pipe, spec, 8.0,
                   SchedulerConfig(max_tp=spec.hb_domain_size, units_grid=6))
    pl = place(res.allocations, spec)
    pl.validate()
    assert res.prediction.max_throughput >= 8.0
    # every TP instance stays inside one hb domain
    for inst in pl.instances:
        if inst.tp > 1:
            assert len({c // spec.hb_domain_size for c in inst.chips}) == 1


def test_deployment_manifest_roundtrip(tmp_path, beam_pipe):
    from repro.core.placement import save_deployment
    import json

    spec = hw.PAPER_CLUSTER_8
    dep = deploy(BEAM_SEARCH, spec, 0.3, pipeline=beam_pipe)
    path = tmp_path / "deploy.json"
    save_deployment(dep.placement, str(path))
    manifest = json.loads(path.read_text())
    assert manifest["kind"] == "WorkflowServingDeployment"
    total_frac = sum(i["chip_fraction"] * len(i["chips"]) if i["tensor_parallel"] > 1
                     else i["chip_fraction"] for i in manifest["instances"])
    assert total_frac <= spec.num_chips + 1e-9

def test_replica_failover_completes_all_requests(beam_pipe):
    """Kill a replica mid-run; router failover re-dispatches in-flight
    work (KV lost -> full prefill) and every workflow still completes."""
    spec = hw.PAPER_CLUSTER_8
    res = schedule(beam_pipe, spec, 0.3, SchedulerConfig(max_tp=2))
    loop = EventLoop()
    routers = routers_from_allocations(BEAM_SEARCH, res.allocations, loop)
    victim_router = max(routers.values(), key=lambda r: len(r.replicas))
    assert len(victim_router.replicas) >= 2, "need >=2 replicas to fail one"
    driver = ClusterDriver(BEAM_SEARCH, routers, loop)
    loop.schedule(20.0, lambda: victim_router.fail_replica(0))
    recs = driver.run_open_loop(0.3, 20, seed=3, until=1e5)
    done = [r for r in recs if r.done >= 0]
    assert len(done) == 20, f"only {len(done)}/20 completed after failover"
