"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention_op, decode_attention_ref
from repro.kernels.flash_attention import flash_attention_op, flash_attention_ref
from repro.kernels.rwkv6_scan import wkv6_op, wkv6_scan_ref
from repro.models.layers import causal_flash_attention
from repro.models.rwkv import wkv6_chunked

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOL[jnp.bfloat16] if dtype == jnp.bfloat16 else TOL[jnp.float32]


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,KV,S,D", [
    (1, 2, 2, 128, 64),   # MHA
    (2, 4, 2, 256, 64),   # GQA group 2
    (1, 8, 1, 128, 128),  # MQA, wide head
    (1, 2, 2, 192, 64),   # non-power-of-two seq (block padding path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, KV, S, D, dtype):
    if S % 64 != 0:
        pytest.skip("kernel requires block-divisible seq")
    ks = jax.random.split(jax.random.key(0), 3)
    q = (jax.random.normal(ks[0], (B, S, H, D)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (B, S, KV, D)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (B, S, KV, D)) * 0.5).astype(dtype)
    out = flash_attention_op(q, k, v, block_q=64, block_kv=64)
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=_tol(dtype),
                               rtol=_tol(dtype))


@pytest.mark.parametrize("window", [64, 128])
def test_flash_attention_window(window):
    B, H, KV, S, D = 1, 4, 2, 256, 64
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, D)) * 0.5
    k = jax.random.normal(ks[1], (B, S, KV, D)) * 0.5
    v = jax.random.normal(ks[2], (B, S, KV, D)) * 0.5
    out = flash_attention_op(q, k, v, window=window, block_q=64, block_kv=64)
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3),
                              window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_flash_matches_model_attention():
    """Kernel agrees with the model-layer chunked flash used by the dry-run."""
    B, H, KV, S, D = 2, 4, 4, 128, 32
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, D)) * 0.5
    k = jax.random.normal(ks[1], (B, S, KV, D)) * 0.5
    v = jax.random.normal(ks[2], (B, S, KV, D)) * 0.5
    out_kernel = flash_attention_op(q, k, v, block_q=64, block_kv=64)
    out_model = causal_flash_attention(q, k, v, q_chunk=64)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_model),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,KV,D,Smax,clen", [
    (2, 8, 2, 64, 256, 200),
    (1, 16, 16, 128, 512, 512),  # MHA full cache
    (4, 4, 4, 64, 128, 1),       # single valid entry
    (2, 32, 2, 64, 256, 130),    # glm4-style extreme GQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, H, KV, D, Smax, clen, dtype):
    ks = jax.random.split(jax.random.key(3), 3)
    q = (jax.random.normal(ks[0], (B, H, D)) * 0.5).astype(dtype)
    kc = (jax.random.normal(ks[1], (B, KV, Smax, D)) * 0.5).astype(dtype)
    vc = (jax.random.normal(ks[2], (B, KV, Smax, D)) * 0.5).astype(dtype)
    out = decode_attention_op(q, kc, vc, jnp.asarray(clen), block_s=64)
    ref = decode_attention_ref(q.reshape(B, KV, H // KV, D), kc, vc,
                               clen).reshape(B, H, D)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=_tol(dtype),
                               rtol=_tol(dtype))


# ---------------------------------------------------------------------------
# rwkv6 scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,S,D,chunk", [
    (1, 2, 64, 32, 32),
    (2, 3, 128, 64, 64),
    (1, 1, 256, 64, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_sweep(B, H, S, D, chunk, dtype):
    ks = jax.random.split(jax.random.key(4), 5)
    r, k, v = ((jax.random.normal(ks[i], (B, H, S, D)) * 0.5).astype(dtype)
               for i in range(3))
    logw = (-jnp.exp(jax.random.normal(ks[3], (B, H, S, D)) * 0.5 - 1.0)
            ).astype(jnp.float32)
    u = (jax.random.normal(ks[4], (H, D)) * 0.2).astype(jnp.float32)
    s0 = jnp.zeros((B, H, D, D), jnp.float32)
    out, s1 = wkv6_op(r, k, v, logw.astype(dtype), u, s0, chunk=chunk)
    fl = lambda a: a.reshape(B * H, S, D)
    ref, sref = wkv6_scan_ref(fl(r), fl(k), fl(v), fl(logw.astype(dtype)), u,
                              s0.reshape(B * H, D, D), num_heads=H)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref.reshape(B, H, S, D), np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(sref.reshape(B, H, D, D)),
                               atol=tol, rtol=tol)


def test_wkv6_state_carry():
    """Two half-sequence kernel calls == one full call (state threading)."""
    B, H, S, D = 1, 2, 128, 32
    ks = jax.random.split(jax.random.key(5), 5)
    r, k, v = (jax.random.normal(ks[i], (B, H, S, D)) * 0.5 for i in range(3))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, H, S, D)) * 0.5 - 1.0)
    u = jax.random.normal(ks[4], (H, D)) * 0.2
    s0 = jnp.zeros((B, H, D, D), jnp.float32)
    full, s_full = wkv6_op(r, k, v, logw, u, s0, chunk=32)
    h = S // 2
    a, s_mid = wkv6_op(r[:, :, :h], k[:, :, :h], v[:, :, :h], logw[:, :, :h],
                       u, s0, chunk=32)
    b, s_end = wkv6_op(r[:, :, h:], k[:, :, h:], v[:, :, h:], logw[:, :, h:],
                       u, s_mid, chunk=32)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([a, b], axis=2)),
                               np.asarray(full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_end), np.asarray(s_full),
                               atol=1e-4, rtol=1e-4)


def test_model_wkv_chunked_matches_kernel():
    """The model's jnp chunked WKV (dry-run path) == kernel == naive scan."""
    B, H, S, D = 1, 2, 128, 32
    ks = jax.random.split(jax.random.key(6), 5)
    r, k, v = (jax.random.normal(ks[i], (B, H, S, D)) * 0.5 for i in range(3))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, H, S, D)) * 0.5 - 1.0)
    u = jax.random.normal(ks[4], (H, D)) * 0.2
    s0 = jnp.zeros((B, H, D, D), jnp.float32)
    out_model, s_model = wkv6_chunked(r, k, v, logw, u, s0)
    out_kernel, s_kernel = wkv6_op(r, k, v, logw, u, s0, chunk=64)
    np.testing.assert_allclose(np.asarray(out_model), np.asarray(out_kernel),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_model), np.asarray(s_kernel),
                               atol=1e-4, rtol=1e-4)
