"""Hypothesis property tests on the system's invariants."""

import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; skip where absent")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro import hw
from repro.core.aggregate import merged_busy_time, request_parallelism
from repro.core.pipeline import Allocation
from repro.core.placement import PlacementError, place
from repro.core.trace import LLMCall
from repro.distributed.compression import dequantize_int8, quantize_int8
from repro.models.layers import causal_flash_attention
from repro.serving import costmodel as cm
from repro.configs.registry import ASSIGNED, get_config

# ---------------------------------------------------------------------------
# sweep-line aggregation
# ---------------------------------------------------------------------------

intervals = st.lists(
    st.tuples(st.floats(0, 100, allow_nan=False),
              st.floats(0.01, 50, allow_nan=False)).map(
        lambda t: (t[0], t[0] + t[1])),
    min_size=1, max_size=30)


@given(intervals)
@settings(max_examples=100, deadline=None)
def test_merged_busy_time_bounds(ivs):
    union = merged_busy_time(ivs)
    total = sum(e - s for s, e in ivs)
    longest = max(e - s for s, e in ivs)
    span = max(e for _, e in ivs) - min(s for s, _ in ivs)
    assert longest - 1e-9 <= union <= min(total, span) + 1e-9


@given(intervals)
@settings(max_examples=100, deadline=None)
def test_parallelism_bounds(ivs):
    calls = [LLMCall(0, "m", s, e, 1, 1) for s, e in ivs]
    p = request_parallelism(calls)
    assert 1.0 - 1e-9 <= p <= len(calls) + 1e-9


# ---------------------------------------------------------------------------
# placement invariants
# ---------------------------------------------------------------------------

alloc_strategy = st.dictionaries(
    st.sampled_from(["a", "b", "c", "d"]),
    st.builds(Allocation,
              replicas=st.integers(1, 3),
              tp=st.sampled_from([1, 2]),
              fraction=st.sampled_from([0.2, 0.5, 1.0])),
    min_size=1, max_size=4)


@given(alloc_strategy)
@settings(max_examples=60, deadline=None)
def test_placement_never_oversubscribes(allocs):
    # normalize: tp>1 implies whole chips
    allocs = {m: (Allocation(a.replicas, a.tp, 1.0) if a.tp > 1 else a)
              for m, a in allocs.items()}
    spec = hw.ClusterSpec(num_hosts=2, chips_per_host=4, hb_domain_size=2)
    try:
        pl = place(allocs, spec)
    except PlacementError:
        return  # refusing is always allowed; placing invalidly is not
    pl.validate()
    F = spec.fractions_per_chip
    used = {}
    for inst in pl.instances:
        for c in inst.chips:
            used[c] = used.get(c, 0) + inst.units_per_chip
    assert all(v <= F for v in used.values())
    # every requested replica was placed
    want = sum(a.replicas for a in allocs.values())
    assert len(pl.instances) == want


# ---------------------------------------------------------------------------
# cost model invariants
# ---------------------------------------------------------------------------


@given(st.sampled_from(sorted(ASSIGNED)),
       st.integers(1, 64), st.integers(128, 8192))
@settings(max_examples=30, deadline=None)
def test_decode_cost_monotone(arch, batch, ctx):
    cfg = get_config(arch)
    c1 = cm.decode_step_cost(cfg, batch, ctx)
    c2 = cm.decode_step_cost(cfg, batch + 1, ctx)
    c3 = cm.decode_step_cost(cfg, batch, ctx, tp=2)
    assert c2.total >= c1.total - 1e-12  # more work never cheaper
    assert c3.compute_s <= c1.compute_s + 1e-12  # TP divides compute
    assert c1.total > 0


@given(st.sampled_from(sorted(ASSIGNED)), st.integers(64, 4096))
@settings(max_examples=40, deadline=None)
def test_prefill_cache_discount(arch, prompt):
    cfg = get_config(arch)
    full = cm.prefill_cost(cfg, prompt)
    cached = cm.prefill_cost(cfg, prompt, cached_tokens=prompt // 2)
    assert cached.compute_s <= full.compute_s + 1e-12


# ---------------------------------------------------------------------------
# model-layer invariants
# ---------------------------------------------------------------------------


@given(st.integers(1, 2), st.sampled_from([2, 4]), st.sampled_from([1, 2]),
       st.sampled_from([32, 64]))
@settings(max_examples=6, deadline=None)
def test_flash_attention_chunk_invariance(b, h, kv_div, s_mult):
    """Output must not depend on the query-chunk size."""
    kv = max(h // kv_div, 1)
    S, D = 16 * s_mult, 8
    ks = jax.random.split(jax.random.key(b * 7 + h), 3)
    q = jax.random.normal(ks[0], (b, S, h, D))
    k = jax.random.normal(ks[1], (b, S, kv, D))
    v = jax.random.normal(ks[2], (b, S, kv, D))
    o1 = causal_flash_attention(q, k, v, q_chunk=8)
    o2 = causal_flash_attention(q, k, v, q_chunk=S)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5,
                               rtol=2e-5)


@given(st.integers(10, 500))
@settings(max_examples=30, deadline=None)
def test_quantize_roundtrip_error_bound(n):
    x = jax.random.normal(jax.random.key(n), (n,)) * (n % 7 + 1)
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s, x.shape)
    assert float(jnp.max(jnp.abs(deq - x))) <= float(
        jnp.max(jnp.abs(x))) / 127.0 + 1e-6


# ---------------------------------------------------------------------------
# scheduler invariant: allocations never exceed the cluster
# ---------------------------------------------------------------------------


@given(st.floats(0.05, 1.5), st.sampled_from([4, 8, 16]))
@settings(max_examples=10, deadline=None)
def test_scheduler_budget_property(rate, chips):
    from repro.core.scepsy import build_pipeline
    from repro.core.scheduler import SchedulerConfig, schedule
    from repro.workflows.rag_reranker import RAG_RERANKER

    global _PIPE_CACHE
    try:
        _PIPE_CACHE
    except NameError:
        _PIPE_CACHE, _, _ = build_pipeline(
            RAG_RERANKER, n_trace_requests=8, tp_degrees=(1, 2),
            max_profile_groups=6)
    spec = hw.ClusterSpec(num_hosts=max(chips // 4, 1), chips_per_host=4)
    try:
        res = schedule(_PIPE_CACHE, spec, rate,
                       SchedulerConfig(max_tp=spec.hb_domain_size))
    except (ValueError, RuntimeError):
        return
    used = 0.0
    for a in res.allocations.values():
        per = a.tp * spec.fractions_per_chip if a.tp > 1 or a.fraction >= 1.0 \
            else round(a.fraction * spec.fractions_per_chip)
        used += a.replicas * per
    assert used <= spec.total_units + 1e-9
    for a in res.allocations.values():
        assert a.tp <= spec.hb_domain_size
