"""Tests for the training/serving substrates: checkpointing, data
pipeline, fault tolerance, gradient compression, KV caches, prefix cache,
and the real serving engine."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, reduced_config
from repro.distributed.compression import (compress_tree, dequantize_int8,
                                           init_error, quantize_int8)
from repro.distributed.fault_tolerance import FaultToleranceController
from repro.models import build_model
from repro.serving.engine import ServeRequest, ServingEngine
from repro.serving.kv_cache import PagedKVCache
from repro.serving.prefix_cache import PrefixCache
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, SyntheticCorpus
from repro.training.optimizer import AdamWConfig, apply_updates, init_state


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_and_latest():
    cfg = reduced_config(get_config("qwen2.5-3b"))
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0))
    opt = init_state(params)
    with tempfile.TemporaryDirectory() as d:
        assert ckpt.latest_step(d) is None
        ckpt.save_checkpoint(d, 10, (params, opt), extra={"arch": cfg.name})
        ckpt.save_checkpoint(d, 20, (params, opt))
        assert ckpt.latest_step(d) == 20
        (p2, o2), step, extra = ckpt.restore_checkpoint(d, (params, opt),
                                                        step=10)
        assert step == 10 and extra["arch"] == cfg.name
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        ckpt.prune_old(d, keep=1)
        assert ckpt.latest_step(d) == 20
        with pytest.raises(Exception):
            ckpt.restore_checkpoint(d, (params, opt), step=10)


def test_training_resumes_identically():
    """Train 4 steps == train 2, checkpoint, restore, train 2 more."""
    cfg = reduced_config(get_config("qwen2.5-3b"))
    bundle = build_model(cfg)
    data = SyntheticCorpus(DataConfig(cfg.vocab_size, 16, 4))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2)
    grad = jax.jit(jax.value_and_grad(bundle.loss_fn))

    def steps(params, opt, start, n):
        for s in range(start, start + n):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
            _, g = grad(params, batch)
            params, opt = apply_updates(params, g, opt, opt_cfg)
        return params, opt

    p0 = bundle.init(jax.random.key(0))
    pa, oa = steps(p0, init_state(p0), 0, 4)

    pb, ob = steps(p0, init_state(p0), 0, 2)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save_checkpoint(d, 2, (pb, ob))
        (pb, ob), step, _ = ckpt.restore_checkpoint(d, (pb, ob))
        pb = jax.tree.map(jnp.asarray, pb)
        ob = jax.tree.map(jnp.asarray, ob)
    pb, ob = steps(pb, ob, 2, 2)
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_data_pipeline_deterministic_and_sharded():
    c = DataConfig(vocab_size=100, seq_len=8, global_batch=8)
    full = SyntheticCorpus(c)
    s0 = SyntheticCorpus(c, shard=0, num_shards=2)
    s1 = SyntheticCorpus(c, shard=1, num_shards=2)
    b = full.batch_at(3)
    assert b["tokens"].shape == (8, 8)
    np.testing.assert_array_equal(b["tokens"], full.batch_at(3)["tokens"])
    assert s0.batch_at(3)["tokens"].shape == (4, 8)
    assert not np.array_equal(s0.batch_at(3)["tokens"],
                              s1.batch_at(3)["tokens"])
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def test_failure_detection_and_remesh():
    ftc = FaultToleranceController(8, grace=10.0, model_ways=16)
    for w in range(8):
        ftc.heartbeat(w, 0.0)
    assert ftc.check(5.0) is None
    for w in range(7):  # worker 7 goes silent
        ftc.heartbeat(w, 20.0)
    plan = ftc.check(28.0)  # 7 last seen at t=0 (>grace); others at t=20
    assert plan is not None
    assert plan.dropped_workers == (7,)
    assert plan.data_ways == 4  # largest pow2 <= 7
    assert plan.restart_from_checkpoint
    assert 7 not in ftc.alive_workers()


def test_straggler_detection():
    ftc = FaultToleranceController(4, straggler_factor=2.0, patience=2)
    for t in range(5):
        for w in range(4):
            ftc.heartbeat(w, float(t))
            ftc.report_step(w, 1.0 if w != 2 else 5.0)
        plan = ftc.check(float(t))
        if plan:
            assert 2 in plan.dropped_workers
            return
    pytest.fail("straggler never detected")


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_int8_quantization_bounded_error():
    x = jax.random.normal(jax.random.key(0), (1000,)) * 3.0
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s, x.shape)
    err = jnp.max(jnp.abs(deq - x))
    assert float(err) <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


def test_error_feedback_preserves_signal():
    grads = {"w": jax.random.normal(jax.random.key(1), (64, 64))}
    err = init_error(grads)
    total_sent = jnp.zeros((64, 64))
    total_true = jnp.zeros((64, 64))
    for i in range(20):
        g = {"w": jax.random.normal(jax.random.key(i + 2), (64, 64)) * 0.1}
        total_true = total_true + g["w"]
        sent, err = compress_tree(g, err)
        total_sent = total_sent + sent["w"]
    # accumulated compressed sum tracks the true sum (error feedback)
    resid = float(jnp.max(jnp.abs(total_sent + err["w"] - total_true)))
    assert resid < 1e-3


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------


def test_paged_cache_matches_contiguous():
    L, KV, D, ps = 2, 2, 8, 4
    cache = PagedKVCache.create(L, num_pages=16, kv_heads=KV, page_size=ps,
                                head_dim=D, dtype=jnp.float32)
    rng = jax.random.key(0)
    k_all = jax.random.normal(rng, (L, KV, 10, D))
    v_all = k_all * 2
    cache.alloc_seq(7)
    cache.append(7, k_all[:, :, :6], v_all[:, :, :6])
    cache.append(7, k_all[:, :, 6:], v_all[:, :, 6:])
    k, v, length = cache.gather_seq(7)
    assert length == 10
    np.testing.assert_allclose(np.asarray(k[:, :, :10]), np.asarray(k_all),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v[:, :, :10]), np.asarray(v_all),
                               rtol=1e-6)
    cache.free_seq(7)
    assert len(cache.free_pages) == 16


def test_paged_cache_exhaustion():
    cache = PagedKVCache.create(1, num_pages=2, kv_heads=1, page_size=2,
                                head_dim=4)
    cache.alloc_seq(0)
    k = jnp.zeros((1, 1, 4, 4))
    cache.append(0, k, k)  # uses both pages
    cache.alloc_seq(1)
    with pytest.raises(MemoryError):
        cache.append(1, k[:, :, :1], k[:, :, :1])


def test_prefix_cache_longest_match():
    pc = PrefixCache()
    pc.insert([1, 2, 3, 4], slot=0)
    pc.insert([1, 2, 9], slot=1)
    assert pc.longest_prefix([1, 2, 3, 4, 5]) == (4, 0)
    assert pc.longest_prefix([1, 2, 9, 9]) == (3, 1)
    assert pc.longest_prefix([7]) == (0, None)
    pc.invalidate_slot(0)
    assert pc.longest_prefix([1, 2, 3, 4, 5])[1] is None


# ---------------------------------------------------------------------------
# serving engine (real model)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "olmoe-1b-7b", "rwkv6-7b"])
def test_engine_batched_equals_solo(arch):
    cfg = reduced_config(get_config(arch))
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=10).astype(np.int32)
               for _ in range(3)]

    eng = ServingEngine(bundle, params, slots=3, max_len=32)
    for i, p in enumerate(prompts):
        eng.submit(ServeRequest(i, p, max_new_tokens=5))
    batched = {r.req_id: r.generated for r in eng.run_to_completion()}

    for i, p in enumerate(prompts):
        solo_eng = ServingEngine(bundle, params, slots=1, max_len=32)
        solo_eng.submit(ServeRequest(i, p, max_new_tokens=5))
        solo = solo_eng.run_to_completion()[0]
        assert solo.generated == batched[i], f"{arch}: req {i} diverged"
