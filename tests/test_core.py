"""Unit tests for the Scepsy core (trace → aggregate → pipeline →
scheduler → placement)."""

import pytest

from repro import hw
from repro.core.aggregate import aggregate, merged_busy_time, request_parallelism
from repro.core.pipeline import Allocation
from repro.core.placement import PlacementError, place
from repro.core.profiler import extract_groups
from repro.core.scheduler import SchedulerConfig, schedule
from repro.core.trace import LLMCall, TraceStore, WorkflowTrace
from repro.workflows.beam_search import BEAM_SEARCH
from repro.workflows.rag_reranker import RAG_RERANKER
from repro.workflows.runtime import trace_workflow


def _mk_trace(calls, rid=0):
    t_end = max(c.t_end for c in calls)
    return WorkflowTrace(request_id=rid, workflow="t", t_start=0.0,
                         t_end=t_end, calls=calls)


def test_merged_busy_time():
    assert merged_busy_time([(0, 1), (2, 3)]) == 2.0
    assert merged_busy_time([(0, 2), (1, 3)]) == 3.0
    assert merged_busy_time([(0, 5), (1, 2)]) == 5.0


def test_request_parallelism_sequential_vs_parallel():
    seq = [LLMCall(0, "m", 0, 1, 10, 10), LLMCall(0, "m", 1, 2, 10, 10)]
    par = [LLMCall(0, "m", 0, 1, 10, 10), LLMCall(0, "m", 0, 1, 10, 10),
           LLMCall(0, "m", 0, 1, 10, 10)]
    assert request_parallelism(seq) == pytest.approx(1.0)
    assert request_parallelism(par) == pytest.approx(3.0)


def test_aggregate_n_and_p():
    tr = _mk_trace([
        LLMCall(0, "gen", 0, 1, 100, 50),
        LLMCall(0, "gen", 0, 1, 100, 50),
        LLMCall(0, "ver", 1, 2, 100, 2),
    ])
    store = TraceStore(workflow="t", traces=[tr])
    stats = aggregate(store)
    assert stats.per_llm["gen"].n == 2
    assert stats.per_llm["gen"].p == pytest.approx(2.0)
    assert stats.per_llm["ver"].n == 1
    assert stats.per_llm["gen"].mean_share == pytest.approx(2 / 3)


def test_relative_share_more_stable_than_absolute():
    """The paper's §2.4 observation on our beam-search traces."""
    store = trace_workflow(BEAM_SEARCH, 25, seed=3)
    stats = aggregate(store)
    for m, st in stats.per_llm.items():
        assert st.share_cov < 0.5 * st.abs_cov, (
            f"{m}: share cov {st.share_cov} not ≪ abs cov {st.abs_cov}")


def test_extract_groups_dependencies():
    tr = _mk_trace([
        LLMCall(0, "m", 0.0, 1.0, 10, 5),
        LLMCall(0, "m", 0.0, 1.2, 10, 5),  # parallel with first
        LLMCall(0, "m", 1.5, 2.0, 10, 5),  # depends on both
    ])
    store = TraceStore(workflow="t", traces=[tr])
    groups = extract_groups(store, "m")
    assert len(groups) == 1
    g = groups[0]
    assert g.calls[0].preds == [] and g.calls[1].preds == []
    assert set(g.calls[2].preds) == {0, 1}


@pytest.fixture(scope="module")
def beam_pipeline():
    from repro.core.scepsy import build_pipeline

    pipeline, stats, store = build_pipeline(
        BEAM_SEARCH, n_trace_requests=12, tp_degrees=(1, 2),
        max_profile_groups=10)
    return pipeline


def test_profile_monotonic_latency(beam_pipeline):
    for st in beam_pipeline.stages.values():
        for prof in st.profile.by_tp.values():
            lat = prof.latency["mean"]
            # latency should not decrease as load rises (within noise)
            assert lat[-1] >= lat[0] * 0.8
            assert prof.max_throughput > 0


def test_pipeline_predict_monotone_in_replicas(beam_pipeline):
    base = {m: Allocation(replicas=1, tp=1, fraction=1.0)
            for m in beam_pipeline.llms()}
    more = {m: Allocation(replicas=2, tp=1, fraction=1.0)
            for m in beam_pipeline.llms()}
    lam = 0.05
    p1 = beam_pipeline.predict(base, lam)
    p2 = beam_pipeline.predict(more, lam)
    assert p2.max_throughput >= p1.max_throughput
    if p1.feasible and p2.feasible:
        assert p2.latency <= p1.latency * 1.05


def test_pipeline_eq2_bottleneck(beam_pipeline):
    alloc = {m: Allocation(replicas=1, tp=1, fraction=1.0)
             for m in beam_pipeline.llms()}
    pred = beam_pipeline.predict(alloc, 0.01)
    expected = min(
        st.profile.max_throughput(1) / st.n
        for st in beam_pipeline.stages.values())
    assert pred.max_throughput == pytest.approx(expected, rel=1e-6)


def test_scheduler_feasible_and_within_budget(beam_pipeline):
    spec = hw.PAPER_CLUSTER_16
    res = schedule(beam_pipeline, spec, lam_target=0.3, config=SchedulerConfig())
    total_units = sum(
        a.replicas * a.tp * (a.fraction if a.tp == 1 else 1.0)
        * spec.fractions_per_chip
        for a in res.allocations.values())
    assert total_units <= spec.total_units + 1e-6
    assert res.feasible
    assert res.prediction.max_throughput >= 0.3
    for a in res.allocations.values():
        assert a.tp <= spec.hb_domain_size
        assert a.fraction <= 1.0


def test_scheduler_higher_rate_needs_more_throughput(beam_pipeline):
    spec = hw.PAPER_CLUSTER_16
    lo = schedule(beam_pipeline, spec, lam_target=0.1)
    hi = schedule(beam_pipeline, spec, lam_target=0.8)
    if lo.feasible and hi.feasible:
        assert hi.prediction.max_throughput >= lo.prediction.max_throughput * 0.9


def test_placement_valid_and_topology_constrained(beam_pipeline):
    spec = hw.PAPER_CLUSTER_16
    res = schedule(beam_pipeline, spec, lam_target=0.3)
    pl = place(res.allocations, spec)
    pl.validate()  # raises on oversubscription / domain violations
    dep = pl.to_deployment()
    assert dep["kind"] == "WorkflowServingDeployment"
    assert len(dep["instances"]) == sum(
        a.replicas for a in res.allocations.values())


def test_placement_rejects_oversubscription():
    spec = hw.ClusterSpec(num_hosts=1, chips_per_host=2, hb_domain_size=2,
                          fractions_per_chip=10)
    allocs = {f"m{i}": Allocation(replicas=1, tp=1, fraction=0.9)
              for i in range(4)}
    with pytest.raises(PlacementError):
        place(allocs, spec)


def test_placement_tp_in_one_domain():
    spec = hw.ClusterSpec(num_hosts=2, chips_per_host=4, hb_domain_size=2)
    allocs = {"big": Allocation(replicas=2, tp=2, fraction=1.0),
              "small": Allocation(replicas=3, tp=1, fraction=0.3)}
    pl = place(allocs, spec)
    for inst in pl.instances:
        if inst.tp > 1:
            domains = {c // spec.hb_domain_size for c in inst.chips}
            assert len(domains) == 1


def test_rag_fractional_colocation():
    """Tiny embedder/reranker should get sub-chip shares (paper §5)."""
    from repro.core.scepsy import build_pipeline

    pipeline, _, _ = build_pipeline(RAG_RERANKER, n_trace_requests=10,
                                    tp_degrees=(1, 2), max_profile_groups=8)
    res = schedule(pipeline, hw.PAPER_CLUSTER_16, lam_target=4.0)
    gen_units = res.units["gen"]
    emb_units = res.units["emb"]
    assert gen_units > emb_units
    assert res.allocations["emb"].fraction < 1.0 or res.allocations["emb"].tp == 1
