"""Fig. 10 — ablation: full Scepsy vs no-parallelism vs no-colocation vs
neither, on both workflows at 4 and 8 chips."""
from __future__ import annotations

from repro.core.scepsy import build_pipeline
from repro.core.scheduler import SchedulerConfig
from benchmarks.common import HEADER, cluster_for, run_scepsy
from repro.workflows.beam_search import BEAM_SEARCH
from repro.workflows.rag_reranker import RAG_RERANKER

VARIANTS = {
    "scepsy-full": SchedulerConfig(),
    "scepsy-no-parallelism": SchedulerConfig(allow_parallelism=False),
    "scepsy-no-colocation": SchedulerConfig(allow_fractional=False),
    "scepsy-neither": SchedulerConfig(allow_parallelism=False,
                                      allow_fractional=False),
}

RATES = {"beam_search": 0.3, "rag_reranker": 4.0}


def run(quick: bool = False):
    n_req = 30 if quick else 80
    print(HEADER)
    results = []
    for wf in (BEAM_SEARCH, RAG_RERANKER):
        pipeline, _, _ = build_pipeline(
            wf, n_trace_requests=15 if quick else 40, tp_degrees=(1, 2),
            max_profile_groups=12)
        for chips in (4, 8):
            spec = cluster_for(chips)
            rate = RATES[wf.name] * chips / 4
            for name, sc in VARIANTS.items():
                import dataclasses

                sc = dataclasses.replace(sc, max_tp=spec.hb_domain_size
                                         if sc.allow_parallelism else 1)
                try:
                    r = run_scepsy(wf, pipeline, spec, rate, n_req,
                                   scheduler_config=sc)
                except (ValueError, RuntimeError) as e:
                    print(f"{name},{wf.name},{chips},{rate},"
                          f"infeasible({type(e).__name__})")
                    continue
                r.system = name
                print(r.row())
                results.append(r)
    return results


if __name__ == "__main__":
    run(quick=True)
